"""Shared benchmark helpers: timing, CSV emission, result persistence."""
from __future__ import annotations

import json
import os
import time
from typing import Callable

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


def emit(table: str, name: str, value, derived: str = "") -> None:
    """One CSV line per measurement: table,name,value,derived."""
    print(f"{table},{name},{value},{derived}", flush=True)


def save(table: str, payload: dict) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{table}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=str)
    return path


def timeit(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds of fn(*args) after warmup calls."""
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]
