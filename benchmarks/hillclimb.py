"""Hillclimb driver (§Perf): lower one (arch x shape) pair under a named
variant, print the three roofline terms + dominant collective breakdown.

    PYTHONPATH=src python -m benchmarks.hillclimb --arch llama3.2-3b \
        --shape train_4k --profile dp2 [--microbatches 8] [--tag iter1]

Results append to benchmarks/results/hillclimb.jsonl so EXPERIMENTS.md §Perf
can cite exact numbers.
"""
from __future__ import annotations

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import json
import time

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--profile", default="baseline")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--attn-chunk", type=int, default=None,
                    help="override cfg.attn_chunk_size for this lowering")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    from repro.launch.dryrun import run_one
    if args.attn_chunk:
        import dataclasses
        import repro.configs as _cfgs
        base = _cfgs.REGISTRY[args.arch]
        _cfgs.REGISTRY[args.arch] = dataclasses.replace(
            base, attn_chunk_size=args.attn_chunk)
    t0 = time.time()
    rec = run_one(args.arch, args.shape, multi_pod=args.multi_pod,
                  profile=args.profile, num_microbatches=args.microbatches)
    if rec["status"] != "ok":
        print(json.dumps(rec, indent=1)[:2000])
        raise SystemExit(1)
    h = rec["hlo"]
    terms = {
        "compute": h["dot_flops_executed"] / PEAK_FLOPS,
        "memory": h["hbm_bytes_executed"] / HBM_BW,
        "collective": h["collective_bytes_executed"] / LINK_BW,
    }
    dom = max(terms, key=terms.get)
    print(f"{args.arch} x {args.shape} [{args.profile}"
          f"{' mb=' + str(args.microbatches) if args.microbatches else ''}]"
          f" mesh={rec['mesh']}")
    print(f"  compute={terms['compute']:.3f}s memory={terms['memory']:.3f}s "
          f"collective={terms['collective']:.3f}s -> bound "
          f"{max(terms.values()):.3f}s dominant={dom}")
    print(f"  peak={rec['memory']['peak_estimate_bytes'] / 2**30:.2f} GiB "
          f"compile={rec['compile_s']:.0f}s")
    for k, v in h["collectives"].items():
        if v["count"]:
            print(f"    {k:20s} n={v['count']:4d} "
                  f"exec={v['executed_bytes'] / 2**30:9.1f} GiB")
    out = {"tag": args.tag, "arch": args.arch, "shape": args.shape,
           "profile": args.profile, "microbatches": args.microbatches,
           "attn_chunk": args.attn_chunk,
           "mesh": rec["mesh"], "terms": terms, "dominant": dom,
           "peak_gib": rec["memory"]["peak_estimate_bytes"] / 2**30,
           "collectives": {k: v["executed_bytes"]
                           for k, v in h["collectives"].items()},
           "wall_s": round(time.time() - t0, 1)}
    path = os.path.join(os.path.dirname(__file__), "results",
                        "hillclimb.jsonl")
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "a") as f:
        f.write(json.dumps(out) + "\n")


if __name__ == "__main__":
    main()
