"""Roofline analysis: renders EXPERIMENTS.md §Roofline from the dry-run
records (benchmarks/results/dryrun/*.json).

Per (arch x shape x mesh):
  compute    = dot_flops_executed / 197e12          [s]
  memory     = hbm_bytes_executed / 819e9           [s]
  collective = collective_bytes_executed / 50e9     [s]
(all per-device; executed = loop-corrected over scan trip counts)

plus MODEL_FLOPS (6ND train / 2ND prefill / 2NB decode, N = active params),
the useful-compute ratio MODEL_FLOPS / HLO_FLOPs, the dominant term, and a
one-line lever on the dominant term.

Usage:
    PYTHONPATH=src python -m benchmarks.roofline [--mesh 16x16] [--md out.md]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

PEAK_FLOPS = 197e12     # TPU v5e bf16
HBM_BW = 819e9
LINK_BW = 50e9

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def model_flops(rec: dict, seq: int, batch: int) -> float:
    """Useful model FLOPs for the whole step, per device."""
    n_active = rec["model"]["active_params"]
    kind = rec["kind"]
    if kind == "train":
        tokens = batch * seq
        per_tok = 6 * n_active          # fwd 2N + bwd 4N (policy)
        per_tok += 4 * n_active         # tri-model: old+ref forwards
    elif kind == "prefill":
        tokens = batch * seq
        per_tok = 2 * n_active
    else:  # decode: ONE token per row
        tokens = batch
        per_tok = 2 * n_active
    return per_tok * tokens / rec["chips"]


def lever(dom: str, rec: dict) -> str:
    c = rec["hlo"]["collectives"]
    biggest = max(c, key=lambda k: c[k]["executed_bytes"])
    if dom == "collective":
        return (f"dominant collective is {biggest} "
                f"({c[biggest]['executed_bytes'] / 2**30:.1f} GiB) — reshard "
                f"to keep it out of the scan body / overlap with compute")
    if dom == "memory":
        return ("HBM-bound: fuse/choose layouts to cut materialised "
                "intermediates; larger per-step tile reuse (Pallas kernel)")
    return ("compute-bound (good): only algorithmic FLOP cuts (SPA, "
            "sparsity) or higher MXU utilisation move this")


def load(mesh: str, dryrun_dir: str = None):
    rows = []
    from repro.configs import SHAPES
    base = dryrun_dir or DRYRUN_DIR
    for path in sorted(glob.glob(os.path.join(base, f"*__{mesh}.json"))):
        rec = json.load(open(path))
        if rec.get("status") == "skipped":
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "skip": rec.get("skip_reason", "skipped")})
            continue
        if rec.get("status") != "ok":
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "skip": f"ERROR {rec.get('error', '?')[:60]}"})
            continue
        shp = SHAPES[rec["shape"]]
        h = rec["hlo"]
        compute = h["dot_flops_executed"] / PEAK_FLOPS
        memory = h.get("hbm_bytes_executed", 0) / HBM_BW
        coll = h["collective_bytes_executed"] / LINK_BW
        terms = {"compute": compute, "memory": memory, "collective": coll}
        dom = max(terms, key=terms.get)
        mf = model_flops(rec, shp.seq_len, shp.global_batch)
        rows.append({
            "arch": rec["arch"], "shape": rec["shape"], "kind": rec["kind"],
            "compute_s": compute, "memory_s": memory, "collective_s": coll,
            "dominant": dom,
            "model_flops": mf,
            "useful_ratio": mf / max(h["dot_flops_executed"], 1),
            "bound_s": max(terms.values()),
            "peak_gib": rec["memory"]["peak_estimate_bytes"] / 2**30,
            "lever": lever(dom, rec),
        })
    return rows


def fmt(v: float) -> str:
    if v >= 1:
        return f"{v:.2f}"
    if v >= 1e-3:
        return f"{v * 1e3:.2f}m"
    return f"{v * 1e6:.0f}u"


def render(rows, mesh: str) -> str:
    out = [f"### Roofline — mesh {mesh} (seconds/step/device; "
           "executed = scan-trip-corrected)", "",
           "| arch | shape | compute | memory | collective | dominant | "
           "useful ratio | peak GiB | lever |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if "skip" in r:
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | skipped |"
                       f" — | — | {r['skip'][:70]} |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt(r['compute_s'])} "
            f"| {fmt(r['memory_s'])} | {fmt(r['collective_s'])} "
            f"| **{r['dominant']}** | {r['useful_ratio']:.2f} "
            f"| {r['peak_gib']:.2f} | {r['lever'][:80]} |")
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--md", default=None)
    ap.add_argument("--dir", default=None)
    args = ap.parse_args()
    rows = load(args.mesh, args.dir)
    if not rows:
        print(f"no dry-run records for mesh {args.mesh} — run "
              "`python -m repro.launch.dryrun --all` first")
        return
    text = render(rows, args.mesh)
    print(text)
    if args.md:
        with open(args.md, "w") as f:
            f.write(text + "\n")


if __name__ == "__main__":
    main()
