"""Benchmark runner — one function per paper table.

Emits ``table,name,value,derived`` CSV lines and persists JSON to
benchmarks/results/. The roofline table (from dry-run records, if present)
prints at the end.

    PYTHONPATH=src python -m benchmarks.run [--only table3]
"""
from __future__ import annotations

import argparse
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="run a single table (table1..table5, roofline)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: the continuous-batching table (slot "
                         "engine + pool-level paged-vs-group), the "
                         "weight-plane sync-gap table, the spec-decode "
                         "table, the serving-latency table, and the "
                         "device-resident decode-loop table, skipping "
                         "the slow training-side tables")
    args = ap.parse_args()
    if args.smoke and args.only:
        ap.error("--smoke picks its own table set; drop --only")

    from benchmarks import (table1_async, table2_trimodel, table3_spa,
                            table4_dp_baselines, table5_scaling,
                            table6_cbatch, table7_transfer, table8_specdec,
                            table9_serving, table10_device_loop)
    tables = {
        "table1": table1_async.main,
        "table2": table2_trimodel.main,
        "table3": table3_spa.main,
        "table4": table4_dp_baselines.main,
        "table5": table5_scaling.main,
        "table6": table6_cbatch.main,   # beyond-paper: continuous batching
        "table7": table7_transfer.main,  # beyond-paper: weight-plane sync-gap
        "table8": table8_specdec.main,   # beyond-paper: speculative decode
        "table9": table9_serving.main,   # beyond-paper: radix-cache serving
        "table10": table10_device_loop.main,  # beyond-paper: fused decode
    }
    if args.smoke:
        import functools
        import os
        os.makedirs("benchmarks/results", exist_ok=True)
        tables = {"table6": table6_cbatch.main,
                  "table6_pool": table6_cbatch.pool_mode,
                  "table7": table7_transfer.main,
                  "table8": table8_specdec.main,
                  # serve_port=0 adds the live-ops rep: an OpsServer on an
                  # ephemeral port is scraped mid-run and serves one SSE
                  # request bitwise-identical to the in-process driver
                  "table9": functools.partial(table9_serving.main,
                                              serve_port=0),
                  "table10": table10_device_loop.main,
                  # traced sync-vs-async pipeline run: exports Perfetto
                  # traces to benchmarks/results/ and asserts the async
                  # bubble fraction beats sync (DESIGN.md §Observability)
                  "table1_traced": functools.partial(
                      table1_async.main, trace_dir="benchmarks/results")}
    print("table,name,value,derived")
    failures = 0
    for name, fn in tables.items():
        if args.only and args.only != name:
            continue
        t0 = time.time()
        try:
            fn()
        except Exception:
            failures += 1
            print(f"{name},ERROR,,")
            traceback.print_exc()
        print(f"# {name} done in {time.time() - t0:.0f}s", flush=True)

    if not args.smoke and args.only in (None, "roofline"):
        from benchmarks import roofline
        rows = roofline.load("16x16")
        if rows:
            print()
            print(roofline.render(rows, "16x16"))
    if failures:
        raise SystemExit(f"{failures} benchmark failures")


if __name__ == "__main__":
    main()
