"""Beyond-paper table: the device-resident decode loop (DESIGN.md
§Device-resident-decode) — how much host time per engine step the fused
D-step decode block removes, across the cache families the paged pool
serves (GQA pages, MLA latent pages, sliding-window with reclamation).

``drain_interval=1`` is the legacy cadence: every step dispatches one
jitted token step and immediately drains it, so the host blocks on a
device fence once per token. ``drain_interval=D`` fuses D steps into one
``lax.scan`` block and pipelines one block deep — block n+1 is dispatched
before block n's (async-started) transfer is read — so the host touches
Python bookkeeping once per D tokens and the fence it does sit on has
usually already landed.

The measured quantity is exactly that touch: wall seconds inside the
engine's drain (the loop's ONLY device->host sync) divided by decode
steps, fused vs legacy, next to end-to-end tokens/s. The exactness
contract is asserted every variant: fused serving is TOKEN-IDENTICAL to
legacy serving per request (paged sampling draws per-token keys, so the
chain cannot re-align under a different block shape), and the continuous-
batching engine is checked the same way under greedy decode (its sampled
chain legitimately realigns at D>1 — DESIGN.md §Device-resident-decode).

Measurement caveat: on CPU the device "compute" shares the cores with the
host loop, so the legacy drain time is dominated by the step's compute
itself — the fused ratio understates what an accelerator sees, where the
same drain is a cross-PCIe round trip per token.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from benchmarks.common import emit, save
from repro.configs import get_config, reduced_config
from repro.core.cbatch import ContinuousBatchingSampler
from repro.core.paged import PagedGroupEngine
from repro.models import init

N_REQ, SLOTS, T, LP, PAGE = 8, 4, 32, 16, 8
EOS = 2
FUSED_D = 8


def _variants():
    # MoE disabled on the MLA variant for the same reason as table6:
    # router tie luck under different batch shapes would pollute the
    # token-identity assertion the table rests on.
    mla_dense = dataclasses.replace(
        reduced_config(get_config("deepseek-v2-lite-16b")),
        num_experts=0, num_experts_per_tok=0, num_shared_experts=0,
        first_k_dense=0, dense_d_ff=0, moe_d_ff=0)
    return {
        "gqa": reduced_config(get_config("llama3.2-3b")),
        "mla": mla_dense,
        "swa": dataclasses.replace(reduced_config(get_config("llama3.2-3b")),
                                   sliding_window=8),
    }


def _prompts(n, seed=0):
    rng = np.random.RandomState(seed)
    return [rng.randint(3, 250, size=(rng.randint(4, LP),)).astype(np.int32)
            for _ in range(n)]


def _instrument_drain(obj, method: str) -> dict:
    """Time every call to the engine's drain — the decode loop's single
    device->host touch — without editing the engine."""
    acc = {"host_s": 0.0, "drains": 0}
    orig = getattr(obj, method)

    def timed(*a, **kw):
        t0 = time.perf_counter()
        out = orig(*a, **kw)
        acc["host_s"] += time.perf_counter() - t0
        acc["drains"] += 1
        return out

    setattr(obj, method, timed)
    return acc


def _serve_paged(cfg, params, prompts, *, drain: int):
    """One warmup + one measured serve; returns (streams, metrics)."""
    eng = PagedGroupEngine(cfg, num_slots=SLOTS, page_size=PAGE,
                           num_pages=0, max_prompt_len=LP,
                           max_new_tokens=T, group_size=1,
                           temperature=1.0, eos_id=EOS,
                           capture_logprobs=False, drain_interval=drain)
    eng.serve(params, prompts, jax.random.PRNGKey(7))      # jit warmup
    eng.reset_stats()
    acc = _instrument_drain(eng, "_drain_block")
    t0 = time.perf_counter()
    done = eng.serve(params, prompts, jax.random.PRNGKey(7))
    wall = time.perf_counter() - t0
    streams = {c.request_id: list(c.response_ids) for c in done}
    toks = sum(len(s) for s in streams.values())
    return streams, {"wall_s": wall, "tokens": toks,
                     "tok_per_s": toks / wall,
                     "decode_steps": eng.decode_steps,
                     "drains": acc["drains"],
                     "host_s": acc["host_s"],
                     "host_us_per_step": 1e6 * acc["host_s"]
                     / max(eng.decode_steps, 1)}


def _serve_cbatch(cfg, params, prompts, *, drain: int):
    eng = ContinuousBatchingSampler(cfg, num_slots=SLOTS, max_prompt_len=LP,
                                    max_new_tokens=T, temperature=0.0,
                                    eos_id=EOS, drain_interval=drain)
    eng.run(params, prompts, jax.random.PRNGKey(7))        # jit warmup
    acc = _instrument_drain(eng, "_drain_run")
    t0 = time.perf_counter()
    done = eng.run(params, prompts, jax.random.PRNGKey(7))
    wall = time.perf_counter() - t0
    streams = {c.request_id: list(c.response_ids) for c in done}
    toks = sum(len(s) for s in streams.values())
    steps = max(c.finish_step for c in done)
    return streams, {"wall_s": wall, "tokens": toks,
                     "tok_per_s": toks / wall,
                     "decode_steps": steps,
                     "drains": acc["drains"],
                     "host_s": acc["host_s"],
                     "host_us_per_step": 1e6 * acc["host_s"]
                     / max(steps, 1)}


def main() -> dict:
    out = {"config": {"n_req": N_REQ, "slots": SLOTS, "max_prompt_len": LP,
                      "max_new": T, "page_size": PAGE, "fused_D": FUSED_D}}
    prompts = _prompts(N_REQ, seed=5)
    for vname, cfg in _variants().items():
        params = init(jax.random.PRNGKey(0), cfg)
        legacy_ids, legacy = _serve_paged(cfg, params, prompts, drain=1)
        fused_ids, fused = _serve_paged(cfg, params, prompts, drain=FUSED_D)
        # exactness: the fused block shape must not change a single token
        assert legacy_ids == fused_ids, \
            f"{vname}: fused paged serving diverged from legacy"
        out[f"{vname}_legacy"] = legacy
        out[f"{vname}_fused"] = fused
        for mode, m in (("legacy", legacy), ("fused", fused)):
            emit("table10", f"{vname}_{mode}_host_us_per_step",
                 f"{m['host_us_per_step']:.0f}",
                 f"{m['drains']} drains / {m['decode_steps']} steps")
            emit("table10", f"{vname}_{mode}_tok_s",
                 f"{m['tok_per_s']:.1f}", f"{m['wall_s']:.2f}s wall")
        emit("table10", f"{vname}_host_time_reduction",
             f"{legacy['host_us_per_step'] / max(fused['host_us_per_step'], 1e-9):.1f}x",
             f"drain syncs {legacy['drains']} -> {fused['drains']}, "
             "token-identical asserted")

    # the slot engine gained the same fused loop; greedy so D>1 cannot
    # legitimately realign the sampled chain
    cfg = _variants()["gqa"]
    params = init(jax.random.PRNGKey(0), cfg)
    legacy_ids, legacy = _serve_cbatch(cfg, params, prompts, drain=1)
    fused_ids, fused = _serve_cbatch(cfg, params, prompts, drain=FUSED_D)
    assert legacy_ids == fused_ids, \
        "fused cbatch greedy serving diverged from legacy"
    out["cbatch_legacy"], out["cbatch_fused"] = legacy, fused
    emit("table10", "cbatch_host_time_reduction",
         f"{legacy['host_us_per_step'] / max(fused['host_us_per_step'], 1e-9):.1f}x",
         f"greedy, drain syncs {legacy['drains']} -> {fused['drains']}")
    save("table10_device_loop", out)
    return out


if __name__ == "__main__":
    t0 = time.time()
    main()
    print(f"# table10 done in {time.time() - t0:.0f}s")
