"""Table 1 analogue: end-to-end throughput of sync vs periodic-async
scheduling under a decoupled deployment.

The paper's Table 1 measures TPSPD on 16 NPUs; here the inference service is
a simulated remote deployment (constant-latency instances — exactly the
trainer's-eye view of separate inference devices) while training runs the
REAL jitted tri-model GRPO step on CPU. This isolates the quantity Table 1
varies: the *pipeline structure*.

Reported: TPSPD (tokens/s/device) for sync and async, speedup, and the
theoretical bound (T_i + T_t) / max(T_i, T_t) from Eq. 4, plus (--timeline)
per-stage occupancy mirroring Figure 3.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, save
from repro.configs import get_config, reduced_config
from repro.configs.base import RLConfig
from repro.launch.train import build_pipeline
from repro.obs import trace as otrace
from repro.obs.analyze import analyze_file
from repro.rl.rollout import RolloutBatch

T_RESP = 12           # scripted response length
LATENCY = 0.125       # tuned so T_infer ~= T_train (Eq. 4 bound -> 2)


def scripted(prompts, key):
    G = len(prompts)
    rng = np.random.RandomState(int(np.asarray(prompts[0]).sum()) % 997)
    resp = rng.randint(3, 200, size=(G, T_RESP)).astype(np.int32)
    return RolloutBatch(response_ids=jnp.asarray(resp),
                        response_len=jnp.full((G,), T_RESP, jnp.int32))


def run_mode(mode: str, iterations: int = 3, batch: int = 16,
             instances: int = 2, trace_path: str = ""):
    cfg = reduced_config(get_config("llama3.2-3b"))
    rl = RLConfig(mode=mode, batch_prompts=batch, group_size=4,
                  micro_batch=4, num_inference_instances=instances,
                  max_prompt_len=32, max_response_len=T_RESP,
                  learning_rate=1e-4)
    sched, parts = build_pipeline(cfg, rl, scripted_fn=scripted,
                                  latency_fn=lambda out: LATENCY)
    sched.run(1)                      # jit warmup iteration
    parts["pool"].reset_stats()
    if trace_path:
        # install AFTER warmup so the trace holds only measured iterations
        otrace.install(process_name=f"table1-{mode}")
    t0 = time.perf_counter()
    hist = sched.run(iterations)
    wall = time.perf_counter() - t0
    if trace_path:
        otrace.export(trace_path)
        otrace.uninstall()
    tokens = sum(s.trained_tokens for s in hist)
    infer_busy = sum(i.busy_time for i in parts["pool"].instances)
    # consumer BUSY-time (scheduler accumulates around grad steps and the
    # boundary update only) — in async mode the consumer also spends wall
    # time blocked on queue.get(), which must NOT count as training cost
    # or the async/sync comparison conflates the two stages
    train_time = sum(s.train_time for s in hist)
    return {"tpspd": tokens / wall, "wall": wall, "tokens": tokens,
            "infer_busy": infer_busy, "train_time": train_time,
            "history": [s.__dict__ for s in hist]}


def main(timeline: bool = False, trace_dir: str = "") -> dict:
    t_sync = f"{trace_dir}/trace_table1_sync.json" if trace_dir else ""
    t_async = f"{trace_dir}/trace_table1_async.json" if trace_dir else ""
    sync = run_mode("sync", trace_path=t_sync)
    async_ = run_mode("async", trace_path=t_async)
    speedup = async_["tpspd"] / sync["tpspd"]
    # Eq. 4 bound from the measured stage times of the sync run: in sync
    # mode the stages are serial, so wall - consumer-busy IS inference
    t_i = sync["wall"] - sync["train_time"]
    t_t = sync["train_time"]
    bound = (t_i + t_t) / max(t_i, t_t)
    emit("table1", "sync_tpspd", f"{sync['tpspd']:.1f}")
    emit("table1", "async_tpspd", f"{async_['tpspd']:.1f}")
    emit("table1", "speedup", f"{speedup:.2f}",
         f"eq4_bound={bound:.2f}")
    if timeline:
        for name, r in (("sync", sync), ("async", async_)):
            occ_i = r["infer_busy"] / (r["wall"] * 2)
            occ_t = r["train_time"] / r["wall"]
            print(f"  [{name}] inference-instance occupancy {occ_i:.2f}, "
                  f"trainer occupancy {occ_t:.2f}")
    out = {"sync": sync, "async": async_, "speedup": speedup,
           "eq4_bound": bound}
    if trace_dir:
        # bubble fraction from the traces themselves (Figure-3 occupancy,
        # computed by the analyzer, not the benchmark): overlapping the
        # stages must shrink the idle fraction, strictly
        b_sync = analyze_file(t_sync)["summary"]["bubble_fraction"]
        b_async = analyze_file(t_async)["summary"]["bubble_fraction"]
        emit("table1", "bubble_sync", f"{b_sync:.3f}")
        emit("table1", "bubble_async", f"{b_async:.3f}")
        assert b_async < b_sync, \
            f"async bubble {b_async:.3f} !< sync bubble {b_sync:.3f}"
        out["bubble_sync"], out["bubble_async"] = b_sync, b_async
    save("table1_async", out)
    return out


if __name__ == "__main__":
    import sys
    trace_dir = ""
    if "--trace-dir" in sys.argv:
        trace_dir = sys.argv[sys.argv.index("--trace-dir") + 1]
    main(timeline="--timeline" in sys.argv, trace_dir=trace_dir)
