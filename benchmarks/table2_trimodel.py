"""Table 2 analogue: the unified tri-model architecture's contribution.

The paper's Table 2 attributes part of its 32B-model advantage to the
tri-model design: policy, old-policy and reference logits computed in one
micro-step under a shared parallel layout instead of three separately
scheduled models.

Measured here (CPU, reduced model, REAL jitted programs):
  * fused:    one jitted program, old+ref via stacked-vmap + policy forward
              (the shape the dry-run lowers)
  * separate: three sequential jitted forwards (the colocated baseline)
  * capture on/off: the rollout-time logprob capture
    (DESIGN.md §Tri-model-capture) deletes the old-policy half of the
    no-grad pass — measured as stacked old+ref vs single ref forward, and
    as the full grad micro-step with captured vs recomputed old-logprobs
and the decoupled-vs-colocated step-time model that generates Table 2's
resource-economy argument.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, save, timeit
from repro.configs import get_config, reduced_config
from repro.configs.base import RLConfig
from repro.models import forward_hidden, init, token_logprobs
from repro.rl.grpo import (MicroBatch, make_grad_step,
                           make_grad_step_captured,
                           trimodel_ref_old_logprobs)


def _mb(cfg, B=4, S=64):
    key = jax.random.PRNGKey(0)
    toks = jax.random.randint(key, (B, S), 3, cfg.vocab_size)
    return MicroBatch(
        tokens=toks, labels=toks,
        positions=jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S)),
        segments=jnp.zeros((B, S), jnp.int32),
        loss_mask=jnp.ones((B, S), jnp.float32) / S,
        advantages=jnp.ones((B, S), jnp.float32),
        n_samples=jnp.float32(B))


def main() -> dict:
    cfg = reduced_config(get_config("llama3.2-3b"))
    params = init(jax.random.PRNGKey(0), cfg)
    mb = _mb(cfg)

    @jax.jit
    def fused(p_old, p_ref, mb):
        return trimodel_ref_old_logprobs(p_old, p_ref, cfg, mb)

    @jax.jit
    def single(p, mb):
        h, _, _, _ = forward_hidden(p, cfg, mb.tokens,
                                    positions=mb.positions,
                                    segments=mb.segments)
        return token_logprobs(p, cfg, h, mb.labels)

    t_fused = timeit(fused, params, params, mb)
    t_single = timeit(single, params, mb)
    t_separate = 2 * t_single      # old + ref as two scheduled programs
    emit("table2", "fused_oldref_ms", f"{t_fused * 1e3:.1f}",
         "1 dispatch, 1 compiled program")
    emit("table2", "separate_oldref_ms", f"{t_separate * 1e3:.1f}",
         "2 dispatches, 2 compiled programs")
    emit("table2", "trimodel_wall_ratio", f"{t_separate / t_fused:.2f}",
         "NOTE: the tri-model win the paper credits is structural "
         "(one scheduled program, shared layout, no per-model resource "
         "allocation) — single-core CPU wall time may not show it")

    # --- rollout-time logprob capture (DESIGN.md §Tri-model-capture) ----
    # capture OFF: the no-grad pass is the stacked old+ref vmap (t_fused);
    # capture ON:  the behavior logprobs ride the micro-batch and the
    #              no-grad pass is ONE ref forward (t_single).
    emit("table2", "capture_off_nograd_ms", f"{t_fused * 1e3:.1f}",
         "stacked old+ref vmap per micro-step")
    emit("table2", "capture_on_nograd_ms", f"{t_single * 1e3:.1f}",
         "single ref forward — old-policy logprobs captured at rollout")
    emit("table2", "capture_nograd_saving", f"{t_fused / t_single:.2f}x",
         "no-grad forward shrink per micro-step")
    # full grad micro-step, both paths (policy fwd+bwd dominates; the
    # delta IS the deleted old-policy forward)
    rl = RLConfig(max_prompt_len=16, max_response_len=48)
    gs_off = make_grad_step(cfg, rl)
    gs_on = make_grad_step_captured(cfg, rl)
    mb_cap = mb._replace(logp_behavior=-jnp.ones_like(mb.loss_mask))
    t_step_off = timeit(gs_off, params, params, params, mb)
    t_step_on = timeit(gs_on, params, params, params, mb_cap)
    emit("table2", "capture_off_grad_step_ms", f"{t_step_off * 1e3:.1f}",
         "policy fwd+bwd + stacked old+ref no-grad")
    emit("table2", "capture_on_grad_step_ms", f"{t_step_on * 1e3:.1f}",
         "policy fwd+bwd + single ref no-grad")
    emit("table2", "capture_grad_step_speedup",
         f"{t_step_off / t_step_on:.2f}x",
         "upper bound 1.5x when fwd:bwd is 1:2 and forwards dominate")

    # --- deployment step-time model (Table 2's resource-economy axis) ---
    # decoupled SYNC  (paper Eq. 2): step = I/n_inf + T/n_train
    # decoupled ASYNC (paper Eq. 3): step = max(I/n_inf, T/n_train)
    # With the optimal instance ratio the async pipeline recovers the
    # perfect-packing ideal (I+T)/N; sync pays the serial sum — this is
    # exactly the <= 2x bound of Eq. 4 plus the ratio-tuning lever the paper
    # ships (training:rollout configurable, 1:4 used on NPUs).
    I, T, N = 4.0, 1.0, 48          # 32B regime: inference-heavy
    ideal = (I + T) / N
    best_sync = best_async = None
    for r in range(1, 12):
        n_inf = N * r / (r + 1.0)
        n_tr = N - n_inf
        s_sync = I / n_inf + T / n_tr
        s_async = max(I / n_inf, T / n_tr)
        if best_sync is None or s_sync < best_sync[1]:
            best_sync = (r, s_sync)
        if best_async is None or s_async < best_async[1]:
            best_async = (r, s_async)
    emit("table2", "ideal_step", f"{ideal:.4f}", "(I+T)/N perfect packing")
    emit("table2", "decoupled_sync_step", f"{best_sync[1]:.4f}",
         f"best ratio {best_sync[0]}:1")
    emit("table2", "decoupled_async_step", f"{best_async[1]:.4f}",
         f"best ratio {best_async[0]}:1, async/sync speedup "
         f"{best_sync[1] / best_async[1]:.2f}x (Eq. 4 bound 2.0)")
    out = {"fused_s": t_fused, "separate_s": t_separate,
           "capture_off_nograd_s": t_fused, "capture_on_nograd_s": t_single,
           "capture_off_grad_step_s": t_step_off,
           "capture_on_grad_step_s": t_step_on,
           "ideal_step": ideal, "sync_step": best_sync[1],
           "async_step": best_async[1],
           "async_ratio": best_async[0]}
    save("table2_trimodel", out)
    return out


if __name__ == "__main__":
    main()
