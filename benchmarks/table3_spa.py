"""Table 3 analogue: Shared-Prompt Attention ablation (paper §6.2.3).

The paper's Table 3 shows SPA alone giving ~8x TPSPD in the long-prompt /
short-response GSM8K regime (K=16 rollouts per prompt). Here we measure, on
the REAL jitted grad step:

  * trained tokens per group: plain vs SPA packing (the paper's
    'Training Tokens' column),
  * wall time per group grad step, plain vs SPA,
  * executed dot FLOPs of the lowered programs (loop-corrected HLO count) —
    compared against Eq. 5's predicted rho.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, save, timeit
from repro.configs import get_config, reduced_config
from repro.configs.base import RLConfig
from repro.core.queue import RolloutGroup
from repro.core.spa import PAD, pack_plain, pack_spa, spa_reduction_ratio
from repro.launch.hlo_analysis import analyze
from repro.models import init
from repro.rl.grpo import jaxify, make_grad_step, group_advantages

Lp, Lr, K = 192, 12, 16    # long prompt, short responses (GSM8K regime)


def make_group(seed=0):
    rng = np.random.RandomState(seed)
    return RolloutGroup(
        uid=0, prompt_ids=rng.randint(3, 250, size=(Lp,)).astype(np.int32),
        response_ids=rng.randint(3, 250, size=(K, Lr)).astype(np.int32),
        response_len=np.full((K,), Lr, np.int32),
        rewards=rng.randint(0, 2, size=(K,)).astype(np.float32),
        weight_version=0)


def as_jnp(mb):
    return jaxify(mb)


def main() -> dict:
    cfg = reduced_config(get_config("llama3.2-3b"))
    rl = RLConfig(max_prompt_len=Lp, max_response_len=Lr, group_size=K)
    params = init(jax.random.PRNGKey(0), cfg)
    grad_step = make_grad_step(cfg, rl)

    g = make_group()
    adv = np.asarray(group_advantages(jnp.asarray(g.rewards)))
    mb_plain = as_jnp(pack_plain([g], [adv], Lp, Lr))
    mb_spa = as_jnp(pack_spa(g, adv, Lp, Lr, responses_per_row=K))

    tok_plain = int((np.asarray(mb_plain.tokens) != PAD).sum())
    tok_spa = int((np.asarray(mb_spa.tokens) != PAD).sum())
    emit("table3", "tokens_plain", tok_plain)
    emit("table3", "tokens_spa", tok_spa,
         f"{tok_plain / tok_spa:.2f}x fewer")

    t_plain = timeit(lambda m: grad_step(params, params, params, m), mb_plain)
    t_spa = timeit(lambda m: grad_step(params, params, params, m), mb_spa)
    emit("table3", "grad_step_plain_ms", f"{t_plain * 1e3:.1f}")
    emit("table3", "grad_step_spa_ms", f"{t_spa * 1e3:.1f}",
         f"speedup {t_plain / t_spa:.2f}x")

    # FLOP-level check vs Eq. 5 on the lowered programs
    def flops(mb):
        lowered = jax.jit(lambda *a: grad_step(*a)).lower(
            params, params, params, mb)
        return analyze(lowered.compile().as_text())["dot_flops_executed"]

    f_plain, f_spa = flops(mb_plain), flops(mb_spa)
    rho_eq5 = spa_reduction_ratio(Lp, Lr, K)
    if f_plain > 0:
        rho_meas = f_spa / f_plain
        emit("table3", "flops_ratio_measured", f"{rho_meas:.3f}",
             f"eq5_rho={rho_eq5:.3f} (attention-only bound; measured program "
             f"includes FFN/logits so measured >= rho)")
    else:
        # some jax versions emit compiled HLO the dot-FLOP counter cannot
        # parse (returns 0) — report the wall/token columns and skip the
        # FLOP cross-check instead of dividing by zero
        rho_meas = float("nan")
        emit("table3", "flops_ratio_measured", "n/a",
             f"eq5_rho={rho_eq5:.3f} (HLO dot-FLOP count unavailable on "
             "this backend/jax version)")
    out = {"tokens_plain": tok_plain, "tokens_spa": tok_spa,
           "t_plain_s": t_plain, "t_spa_s": t_spa,
           "flops_plain": f_plain, "flops_spa": f_spa,
           "rho_measured": rho_meas, "rho_eq5": rho_eq5}
    save("table3_spa", out)
    return out


if __name__ == "__main__":
    main()
