"""Table 4 analogue: DP-only comparison of sync / periodic-async /
off-policy-async (AReaL-like, staleness eta=1) on the synthetic math task.

The paper's Table 4 runs 8 A100s with data parallelism only; ours is the
1-device analogue with REAL jitted inference + training, so the relative
ordering (async > sync in TPSPD; off-policy async fastest-or-similar but
stale) reflects pipeline structure, not hardware.

Reported per mode: TPSPD, mean reward of the final iteration, max staleness.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, save
from repro.configs import get_config, reduced_config
from repro.configs.base import RLConfig
from repro.launch.train import build_pipeline


def run_mode(mode: str, iterations: int = 3):
    cfg = reduced_config(get_config("llama3.2-3b"))
    rl = RLConfig(mode=mode, batch_prompts=4, group_size=4, micro_batch=4,
                  num_inference_instances=2, max_prompt_len=32,
                  max_response_len=12, learning_rate=1e-4,
                  staleness_eta=1)
    sched, _ = build_pipeline(cfg, rl)
    sched.run(1)      # warmup
    t0 = time.perf_counter()
    hist = sched.run(iterations)
    wall = time.perf_counter() - t0
    tokens = sum(s.trained_tokens for s in hist)
    return {"tpspd": tokens / wall,
            "reward": float(np.mean([s.reward_mean for s in hist])),
            "max_staleness": max(s.max_staleness for s in hist)}


def main() -> dict:
    out = {}
    for mode in ("sync", "async", "async_offpolicy"):
        r = run_mode(mode)
        out[mode] = r
        emit("table4", f"{mode}_tpspd", f"{r['tpspd']:.1f}",
             f"reward={r['reward']:.3f} staleness={r['max_staleness']} "
             "(single CPU core: real inference+training contend, so async"
             "~=sync here; the pipeline gain appears in table1/table5's "
             "remote-service view)")
    # ordering claims of Table 4
    emit("table4", "async_over_sync",
         f"{out['async']['tpspd'] / out['sync']['tpspd']:.2f}")
    emit("table4", "onpolicy_staleness", out["async"]["max_staleness"],
         "periodic async stays at 0; AReaL-like baseline >= 1")
    save("table4_dp_baselines", out)
    return out


if __name__ == "__main__":
    main()
