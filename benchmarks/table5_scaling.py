"""Table 5 / Figure 6 analogue: scaling.

Two axes, matching the paper's scalability section:
  1. producer-pool scaling (measured): total rollout throughput with 1/2/4
     simulated inference instances under the async scheduler — the paper's
     near-linear scaling comes from the producer side scaling independently.
  2. chip scaling (derived): roofline-model projected TPSPD of the
     llama3.2-3b train_4k step at 16/32/64-chip data-parallel slices of the
     dry-run mesh, from the measured per-device FLOPs/bytes and the
     bandwidth-proportional gradient all-reduce.
"""
from __future__ import annotations

import glob
import json
import os
import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, save
from repro.configs import get_config, reduced_config
from repro.configs.base import RLConfig
from repro.launch.train import build_pipeline
from repro.rl.rollout import RolloutBatch

T_RESP = 12
LATENCY = 0.30   # inference-dominated at 1 instance -> scaling visible

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9


def scripted(prompts, key):
    G = len(prompts)
    rng = np.random.RandomState(0)
    resp = rng.randint(3, 200, size=(G, T_RESP)).astype(np.int32)
    return RolloutBatch(response_ids=jnp.asarray(resp),
                        response_len=jnp.full((G,), T_RESP, jnp.int32))


def measure_instances(n: int, iterations: int = 2) -> float:
    cfg = reduced_config(get_config("llama3.2-3b"))
    rl = RLConfig(mode="async", batch_prompts=8, group_size=4, micro_batch=4,
                  num_inference_instances=n, max_prompt_len=32,
                  max_response_len=T_RESP, learning_rate=1e-4)
    sched, _ = build_pipeline(cfg, rl, scripted_fn=scripted,
                              latency_fn=lambda out: LATENCY)
    sched.run(1)
    t0 = time.perf_counter()
    hist = sched.run(iterations)
    wall = time.perf_counter() - t0
    return sum(s.trained_tokens for s in hist) / wall


def projected_tpspd(chips: int, rec: dict, tokens_per_step: int) -> float:
    """Roofline projection: per-device work from the 256-chip dry-run,
    rescaled to a data-parallel slice of `chips` devices (per-device batch
    share grows by 256/chips; gradient all-reduce bytes stay ~constant)."""
    scale = 256 / chips
    h = rec["hlo"]
    compute = h["dot_flops_executed"] * scale / PEAK_FLOPS
    memory = h["hbm_bytes_executed"] * scale / HBM_BW
    coll = h["collective_bytes_executed"] / LINK_BW   # grads: size-constant
    step = max(compute, memory, coll)
    return tokens_per_step / step / chips


def main() -> dict:
    out = {"instances": {}, "chips": {}}
    base = None
    for n in (1, 2, 4):
        tp = measure_instances(n)
        out["instances"][n] = tp
        base = base or tp
        emit("table5", f"tpspd_{n}_instances", f"{tp:.1f}",
             f"scaling x{tp / base:.2f}")

    rec_path = os.path.join(os.path.dirname(__file__), "results", "dryrun",
                            "llama3.2-3b__train_4k__16x16.json")
    if os.path.exists(rec_path):
        rec = json.load(open(rec_path))
        if rec.get("status") == "ok" and "hbm_bytes_executed" in rec["hlo"]:
            tokens = 256 * 4096
            prev = None
            for chips in (16, 32, 64, 128, 256):
                tp = projected_tpspd(chips, rec, tokens)
                out["chips"][chips] = tp
                note = f"x{tp * chips / (prev[1] * prev[0]):.2f} total" \
                    if prev else ""
                emit("table5", f"projected_tpspd_{chips}chips",
                     f"{tp:.0f}", note)
                prev = (chips, tp)
    save("table5_scaling", out)
    return out


if __name__ == "__main__":
    main()
