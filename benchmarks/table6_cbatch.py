"""Beyond-paper table: continuous batching vs fixed batching on the REAL
jitted engine.

Paper §4.2.2: "without continuous batching, synchronous training is gated
by the slowest rollout in each inference batch". The fixed-batch sampler
decodes max_new steps for EVERY row (finished rows ride along as PAD);
the slot engine frees a slot at EOS and admits the next request, so total
decode steps track the SUM of true lengths, not batches x max length.

Both engines serve the same requests with the same weights; response lengths
vary via per-request targets (in RL they vary via EOS); the fixed engine
always pays max_new decode steps per batch, which is the paper's point.

``--pool`` adds the end-to-end POOL-LEVEL comparison (DESIGN.md
§Continuous-batching): concurrent GRPO groups submitted from worker threads
— exactly what the temporary data generator does — through an
InferenceInstance running (a) the group-at-a-time Sampler and (b) the
token-level paged engine, reporting decode tokens/sec for both paths on
token-identical output.
"""
from __future__ import annotations

import threading
import time

import jax
import numpy as np

from benchmarks.common import emit, save
from repro.configs import get_config, reduced_config
from repro.core.cbatch import ContinuousBatchingSampler
from repro.models import init
from repro.rl.rollout import Sampler

N_REQ, SLOTS, T, LP = 12, 4, 32, 16
EOS = 2


def _prompts(n, seed=0):
    rng = np.random.RandomState(seed)
    return [rng.randint(3, 250, size=(rng.randint(4, LP),)).astype(np.int32)
            for _ in range(n)]


def main() -> dict:
    cfg = reduced_config(get_config("llama3.2-3b"))
    params = init(jax.random.PRNGKey(0), cfg)
    prompts = _prompts(N_REQ)

    # per-request response-length targets: rollout lengths vary in RL
    # (EOS-driven); the fixed engine still decodes max_new for every row.
    rng = np.random.RandomState(1)
    targets = rng.randint(4, T + 1, size=N_REQ).tolist()

    # serving-side decode comparison: no trainer consumes logprobs, and the
    # slot engine does not capture — keep the baseline unburdened too
    fixed = Sampler(cfg, LP, T, temperature=1.0, eos_id=EOS,
                    capture_logprobs=False)
    cb = ContinuousBatchingSampler(cfg, num_slots=SLOTS, max_prompt_len=LP,
                                   max_new_tokens=T, temperature=1.0,
                                   eos_id=EOS)
    # warm both jit caches
    fixed.generate(params, prompts[:SLOTS], jax.random.PRNGKey(9))
    cb.run(params, prompts[:SLOTS + 1], jax.random.PRNGKey(9))

    t0 = time.perf_counter()
    for lo in range(0, N_REQ, SLOTS):        # fixed batches of SLOTS rows
        out = fixed.generate(params, prompts[lo: lo + SLOTS],
                             jax.random.PRNGKey(1 + lo))
        jax.block_until_ready(out.response_ids)
    t_fixed = time.perf_counter() - t0
    steps_fixed = (N_REQ // SLOTS) * T       # every batch decodes T steps

    t0 = time.perf_counter()
    done = cb.run(params, prompts, jax.random.PRNGKey(2),
                  max_new_per_request=targets)
    t_cb = time.perf_counter() - t0
    steps_cb = max(c.finish_step for c in done)
    lens_cb = [len(c.response_ids) for c in done]

    emit("table6", "mean_response_len", f"{np.mean(lens_cb):.1f}",
         f"max_new={T}, per-request targets U[4,{T}]")
    emit("table6", "fixed_decode_steps", steps_fixed,
         f"{t_fixed:.2f}s wall — every batch pays max_new")
    emit("table6", "cbatch_decode_steps", steps_cb,
         f"{t_cb:.2f}s wall — slots freed at EOS")
    emit("table6", "cbatch_step_reduction",
         f"{steps_fixed / max(steps_cb, 1):.2f}x",
         f"wall speedup {t_fixed / t_cb:.2f}x")
    out = {"t_fixed": t_fixed, "t_cbatch": t_cb,
           "steps_fixed": steps_fixed, "steps_cbatch": steps_cb,
           "lens": lens_cb}
    save("table6_cbatch", out)
    return out


def pool_mode(n_groups: int = 6, group_size: int = 4, workers: int = 4
              ) -> dict:
    """Pool-level decode throughput: the same concurrent group workload
    through the group-at-a-time instance and the paged token-level
    instance, across the CacheBackend families the paged pool serves
    (DESIGN.md §Cache-backends) — GQA K/V pages, MLA latent pages, and a
    sliding-window config with out-of-window page reclamation. Outputs are
    asserted token-identical per variant, so the tokens/sec numbers compare
    engines, not sampling luck; page accounting (per-token cache bytes,
    peak resident pages, reclaimed pages) rides alongside."""
    import dataclasses

    from repro.core.engine import InferenceInstance
    from repro.core.paged import PagedGroupEngine
    from repro.models.attention import cache_streams
    from repro.transfer.service import WeightTransferService

    # The MLA variant benchmarks LATENT paging, so the MoE half of
    # deepseek-v2 is disabled: near-boundary expert-routing flips under
    # different prefill batch shapes amplify fp noise into O(0.1) logit
    # shifts (DESIGN.md §Continuous-batching caveat), which would make the
    # token-identity assertion below measure router tie luck, not engines.
    mla_dense = dataclasses.replace(
        reduced_config(get_config("deepseek-v2-lite-16b")),
        num_experts=0, num_experts_per_tok=0, num_shared_experts=0,
        first_k_dense=0, dense_d_ff=0, moe_d_ff=0)
    variants = {
        "gqa": reduced_config(get_config("llama3.2-3b")),
        "mla": mla_dense,
        "swa": dataclasses.replace(reduced_config(get_config("llama3.2-3b")),
                                   sliding_window=8),
    }

    def drive(inst, prompts, keys):
        """Submit every group from worker threads, generator-style."""
        results = [None] * n_groups
        lock = threading.Lock()
        todo = list(range(n_groups))

        def worker():
            while True:
                with lock:
                    if not todo:
                        return
                    i = todo.pop(0)
                results[i] = inst.generate_group(
                    [prompts[i]] * group_size, keys[i])[0]

        threads = [threading.Thread(target=worker) for _ in range(workers)]
        t0 = time.perf_counter()
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        wall = time.perf_counter() - t0
        toks = sum(int(np.asarray(r.response_len).sum()) for r in results)
        return results, wall, toks

    out = {}
    for vname, cfg in variants.items():
        params = init(jax.random.PRNGKey(0), cfg)
        prompts = _prompts(n_groups, seed=5)
        keys = jax.random.split(jax.random.PRNGKey(3), n_groups)
        # decode-throughput comparison: capture off on BOTH engines so the
        # numbers match the serving regime (the RL pipeline captures on both)
        sampler = Sampler(cfg, LP, T, temperature=1.0, eos_id=EOS,
                          capture_logprobs=False)
        # per-token cache footprint: what one page slot stores per layer
        tok_vals = sum(int(np.prod(shp)) for _, shp in cache_streams(cfg))
        out[f"{vname}_cache_bytes_per_token"] = 4 * tok_vals   # f32 reduced

        def make_paged():
            eng = PagedGroupEngine(
                cfg, num_slots=2 * group_size, page_size=8, num_pages=0,
                max_prompt_len=LP, max_new_tokens=T, group_size=group_size,
                temperature=1.0, eos_id=EOS, capture_logprobs=False)
            inst = InferenceInstance(0, cfg, sampler, paged_engine=eng)
            # weights arrive via the weight-plane's bucket stream — the
            # shipped trainer->pool path, not a raw whole-tree install
            WeightTransferService([inst], bucket_bytes=1 << 20
                                  ).publish(params, 0)
            return inst, eng

        def make_group():
            inst = InferenceInstance(0, cfg, sampler)
            WeightTransferService([inst], bucket_bytes=1 << 20
                                  ).publish(params, 0)
            return inst, None

        results = {}
        for name, make in (("group", make_group), ("paged", make_paged)):
            inst, eng = make()
            drive(inst, prompts, keys)                # jit warmup pass
            if eng is not None:
                eng.reset_stats()
            inst.busy_time = 0.0
            res, wall, toks = drive(inst, prompts, keys)
            results[name] = res
            out[f"{vname}_pool_{name}_wall"] = wall
            out[f"{vname}_pool_{name}_tokens"] = toks
            out[f"{vname}_pool_{name}_tok_s"] = toks / wall
            if eng is not None:
                out[f"{vname}_pool_peak_pages"] = eng.peak_pages_used
                out[f"{vname}_pool_reclaimed_pages"] = eng.reclaimed_pages
                extra = (f"{eng.decode_steps} decode steps "
                         f"(<= {2 * group_size} wide), peak "
                         f"{eng.peak_pages_used} pages, "
                         f"{eng.reclaimed_pages} reclaimed, "
                         f"busy {inst.busy_time:.2f}s")
            else:
                extra = (f"{n_groups * T} scan steps ({group_size} wide), "
                         f"busy {inst.busy_time:.2f}s")
            emit("table6", f"{vname}_pool_{name}_decode_tok_s",
                 f"{toks / wall:.1f}",
                 f"{n_groups} groups x{group_size}, {wall:.2f}s wall — "
                 f"{extra}")
        for a, b in zip(results["group"], results["paged"]):
            np.testing.assert_array_equal(np.asarray(a.response_ids),
                                          np.asarray(b.response_ids))
        emit("table6", f"{vname}_pool_paged_speedup",
             f"{out[f'{vname}_pool_paged_tok_s'] / out[f'{vname}_pool_group_tok_s']:.2f}x",
             "token-identical output (verified)")

    # the MLA latent-page win: latent rows vs the per-head K/V the expanded
    # path would cache (H * (nd + rd) keys + H * vd values per token)
    mla = variants["mla"]
    expanded = mla.num_heads * (mla.qk_nope_head_dim + mla.qk_rope_head_dim
                                + mla.v_head_dim)
    latent = mla.kv_lora_rank + mla.qk_rope_head_dim
    out["mla_latent_compression"] = expanded / latent
    emit("table6", "mla_latent_page_compression",
         f"{expanded / latent:.1f}x",
         f"{latent} latent values/token vs {expanded} expanded per-head")
    if variants["swa"].sliding_window:
        emit("table6", "swa_reclaimed_pages",
             out["swa_pool_reclaimed_pages"],
             f"window {variants['swa'].sliding_window}: out-of-window pages "
             f"returned to the freelist mid-decode "
             f"(peak {out['swa_pool_peak_pages']} resident)")
    save("table6_pool", out)
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--pool", action="store_true",
                    help="also run the end-to-end pool-level engine "
                         "comparison (group-at-a-time vs paged)")
    args = ap.parse_args()
    main()
    if args.pool:
        pool_mode()
