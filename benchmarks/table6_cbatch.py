"""Beyond-paper table: continuous batching vs fixed batching on the REAL
jitted engine.

Paper §4.2.2: "without continuous batching, synchronous training is gated
by the slowest rollout in each inference batch". The fixed-batch sampler
decodes max_new steps for EVERY row (finished rows ride along as PAD);
the slot engine frees a slot at EOS and admits the next request, so total
decode steps track the SUM of true lengths, not batches x max length.

Both engines serve the same requests with the same weights; response lengths
vary via per-request targets (in RL they vary via EOS); the fixed engine
always pays max_new decode steps per batch, which is the paper's point.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit, save
from repro.configs import get_config, reduced_config
from repro.core.cbatch import ContinuousBatchingSampler
from repro.models import init
from repro.rl.rollout import Sampler

N_REQ, SLOTS, T, LP = 12, 4, 32, 16
EOS = 2


def _prompts(n, seed=0):
    rng = np.random.RandomState(seed)
    return [rng.randint(3, 250, size=(rng.randint(4, LP),)).astype(np.int32)
            for _ in range(n)]


def main() -> dict:
    cfg = reduced_config(get_config("llama3.2-3b"))
    params = init(jax.random.PRNGKey(0), cfg)
    prompts = _prompts(N_REQ)

    # per-request response-length targets: rollout lengths vary in RL
    # (EOS-driven); the fixed engine still decodes max_new for every row.
    rng = np.random.RandomState(1)
    targets = rng.randint(4, T + 1, size=N_REQ).tolist()

    fixed = Sampler(cfg, LP, T, temperature=1.0, eos_id=EOS)
    cb = ContinuousBatchingSampler(cfg, num_slots=SLOTS, max_prompt_len=LP,
                                   max_new_tokens=T, temperature=1.0,
                                   eos_id=EOS)
    # warm both jit caches
    fixed.generate(params, prompts[:SLOTS], jax.random.PRNGKey(9))
    cb.run(params, prompts[:SLOTS + 1], jax.random.PRNGKey(9))

    t0 = time.perf_counter()
    for lo in range(0, N_REQ, SLOTS):        # fixed batches of SLOTS rows
        out = fixed.generate(params, prompts[lo: lo + SLOTS],
                             jax.random.PRNGKey(1 + lo))
        jax.block_until_ready(out.response_ids)
    t_fixed = time.perf_counter() - t0
    steps_fixed = (N_REQ // SLOTS) * T       # every batch decodes T steps

    t0 = time.perf_counter()
    done = cb.run(params, prompts, jax.random.PRNGKey(2),
                  max_new_per_request=targets)
    t_cb = time.perf_counter() - t0
    steps_cb = max(c.finish_step for c in done)
    lens_cb = [len(c.response_ids) for c in done]

    emit("table6", "mean_response_len", f"{np.mean(lens_cb):.1f}",
         f"max_new={T}, per-request targets U[4,{T}]")
    emit("table6", "fixed_decode_steps", steps_fixed,
         f"{t_fixed:.2f}s wall — every batch pays max_new")
    emit("table6", "cbatch_decode_steps", steps_cb,
         f"{t_cb:.2f}s wall — slots freed at EOS")
    emit("table6", "cbatch_step_reduction",
         f"{steps_fixed / max(steps_cb, 1):.2f}x",
         f"wall speedup {t_fixed / t_cb:.2f}x")
    out = {"t_fixed": t_fixed, "t_cbatch": t_cb,
           "steps_fixed": steps_fixed, "steps_cbatch": steps_cb,
           "lens": lens_cb}
    save("table6_cbatch", out)
    return out


if __name__ == "__main__":
    main()
