"""Beyond-paper table: weight-plane boundary sync-gap, overlap on vs off.

The iteration-boundary weight push is periodic asynchrony's critical
synchronisation point (paper §4.1-4.2): while the trainer's weights move to
the pool, every inference instance idles. The weight-plane
(DESIGN.md §Weight-plane) streams the tree as buckets and, with overlap
on, starts the stream the moment the optimizer update materialises — so by
the time the boundary barrier (``WeightTransferService.ensure``) runs, the
buckets have landed under the trainer's iteration tail and the residual
gap is just the version flip.

Two measurements:

  * **service-level** — a scripted trainer loop over instance stores with a
    simulated per-bucket interconnect latency (this host has no real
    trainer->pool wire) and a fixed iteration tail; reports mean boundary
    gap across pool sizes, overlap on vs off. Overlap must never be the
    larger number.
  * **pipeline-level** — the REAL scheduler (simulated-latency instances so
    decode cost doesn't drown the boundary) reporting
    ``IterationStats.metrics['sync_gap']`` both ways through the exact
    shipped code path.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit, save
from repro.configs import get_config, reduced_config
from repro.core.engine import InferenceInstance
from repro.models import init
from repro.transfer.service import WeightTransferService

POOL_SIZES = (1, 2, 4)
ITERS = 6                  # boundaries measured (first = eager warmup)
BUCKET_BYTES = 64 << 10
WIRE_LATENCY = 0.002       # s per bucket broadcast (simulated DCN hop)
ITER_BODY = 0.04           # s of rollout-consumption + grad steps per
                           # iteration (before the optimizer update)
ITER_TAIL = 0.06           # s of trainer work after the update (stats,
                           # logging, next-batch fetch, the off-policy
                           # mode's early grad steps) — the window the
                           # overlapped stream hides under


def _service_level(cfg, params) -> dict:
    out = {}
    for n_inst in POOL_SIZES:
        for overlap in (False, True):
            insts = [InferenceInstance(i, cfg, sampler=None,
                                       scripted_fn=lambda p, k: None)
                     for i in range(n_inst)]
            svc = WeightTransferService(
                insts, bucket_bytes=BUCKET_BYTES,
                wire_latency=WIRE_LATENCY, overlap=overlap)
            for it in range(ITERS):
                svc.ensure(params, it)              # boundary barrier
                time.sleep(ITER_BODY)               # grad steps -> update
                svc.publish_async(params, it + 1)   # no-op when overlap off
                time.sleep(ITER_TAIL)               # post-update tail
            svc.drain()
            stats = svc.gap_stats(skip=1)
            tag = "overlap" if overlap else "eager"
            out[f"pool{n_inst}_{tag}_mean_gap_s"] = stats["mean_gap"]
            out[f"pool{n_inst}_{tag}_max_gap_s"] = stats["max_gap"]
            plan = svc.plan.describe()
            out.setdefault("buckets", plan["buckets"])
            out.setdefault("wire_bytes", plan["total_wire_bytes"])
            emit("table7", f"pool{n_inst}_{tag}_sync_gap_ms",
                 f"{stats['mean_gap'] * 1e3:.1f}",
                 f"{plan['buckets']} buckets x {WIRE_LATENCY * 1e3:.0f}ms "
                 f"wire, {ITERS - 1} boundaries, pool={n_inst}")
        hidden = (out[f"pool{n_inst}_eager_mean_gap_s"]
                  - out[f"pool{n_inst}_overlap_mean_gap_s"])
        out[f"pool{n_inst}_gap_hidden_s"] = hidden
        emit("table7", f"pool{n_inst}_gap_hidden_ms", f"{hidden * 1e3:.1f}",
             "boundary pool-idle time hidden under the trainer's "
             "iteration tail (eager - overlap)")
    return out


def _pipeline_level(cfg) -> dict:
    """The shipped path: scheduler boundary -> ensure -> metrics."""
    import jax.numpy as jnp

    from repro.configs.base import RLConfig
    from repro.launch.train import build_pipeline
    from repro.rl.rollout import RolloutBatch

    def scripted(prompts, key):
        G, T = len(prompts), 8
        resp = np.random.RandomState(0).randint(
            3, 200, size=(G, T)).astype(np.int32)
        return RolloutBatch(response_ids=jnp.asarray(resp),
                            response_len=jnp.full((G,), T, jnp.int32))

    out = {}
    for overlap in (False, True):
        rl = RLConfig(mode="async", batch_prompts=2, group_size=2,
                      micro_batch=2, num_inference_instances=2,
                      max_prompt_len=32, max_response_len=12,
                      transfer_overlap=overlap,
                      transfer_bucket_bytes=BUCKET_BYTES, seed=0)
        sched, parts = build_pipeline(cfg, rl, scripted_fn=scripted,
                                      latency_fn=lambda o: 0.02)
        parts["transfer"].wire_latency = 5e-4
        hist = sched.run(4)
        gaps = [s.metrics["sync_gap"] for s in hist[1:]]   # skip warmup
        tag = "overlap" if overlap else "eager"
        out[f"pipeline_{tag}_mean_gap_s"] = float(np.mean(gaps))
        emit("table7", f"pipeline_{tag}_sync_gap_ms",
             f"{np.mean(gaps) * 1e3:.1f}",
             "scheduler-measured boundary gap, async mode, "
             f"{len(gaps)} boundaries")
    return out


def main() -> dict:
    cfg = reduced_config(get_config("llama3.2-3b"))
    params = init(jax.random.PRNGKey(0), cfg)
    out = _service_level(cfg, params)
    out.update(_pipeline_level(cfg))
    for n_inst in POOL_SIZES:
        assert (out[f"pool{n_inst}_overlap_mean_gap_s"]
                <= out[f"pool{n_inst}_eager_mean_gap_s"] + 5e-3), \
            f"overlap increased the boundary sync-gap at pool={n_inst}"
    save("table7_transfer", out)
    return out


if __name__ == "__main__":
    main()
