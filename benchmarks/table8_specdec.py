"""Beyond-paper table: speculative decode on the paged rollout/serving
engine (DESIGN.md §Spec-decode).

Rollout decode is the producer the periodic-async pipeline exists to hide;
spec decode is the memory-bandwidth lever that speeds the producer itself
WITHOUT off-policy staleness — rejection sampling is distribution-exact,
so the greedy runs below are asserted token-identical to the non-spec
baseline, per variant.

For each cache-backend variant (GQA pages / MLA latent pages (MoE half
disabled — router capacity ties couple batch shapes, see table6) /
sliding-window with reclamation) the same request batch is served greedy
through the paged engine with spec off and with spec on (prompt-lookup
drafts; the GQA variant also measures the resident draft-model provider),
reporting tokens/s, acceptance rate, committed tokens per verify forward,
and the engine-step reduction.

Measurement caveat (same as table6): on this container's single CPU core
a k+1-token forward pays ~k+1x the FLOPs of a 1-token forward, so the
wall-clock win here comes from fewer dispatches and long accepted runs
(greedy repetition); on accelerator decode the verify forward is
bandwidth-bound and costs ~1 step, which is the production case.
"""
from __future__ import annotations

import time

import dataclasses
import numpy as np

from benchmarks.common import emit, save
from repro.configs import get_config, reduced_config
from repro.data.tasks import ArithmeticTask
from repro.data.tokenizer import Tokenizer

N_REQ, SLOTS, LP, T = 6, 4, 48, 128
SPEC_K = 4
REPS = 3


def _variants():
    gqa = reduced_config(get_config("llama3.2-3b"))
    mla = dataclasses.replace(
        reduced_config(get_config("deepseek-v2-lite-16b")),
        num_experts=0, num_experts_per_tok=0, num_shared_experts=0,
        moe_d_ff=0, first_k_dense=0, dense_d_ff=0)
    swa = dataclasses.replace(gqa, sliding_window=32)
    return [("gqa", gqa), ("mla", mla), ("swa", swa)]


def _serve(cfg, prompts, spec_k: int, draft: str = "prompt_lookup"):
    """Median-of-REPS serve through the paged engine (greedy)."""
    from repro.launch.serve import serve_paged
    best = None
    for _ in range(REPS + 1):           # +1 warmup (jit compile)
        done, stats = serve_paged(
            cfg, prompts, max_prompt_len=LP, max_new=T, num_slots=SLOTS,
            temperature=0.0, seed=0, spec_k=spec_k, spec_draft=draft)
        if best is None or stats["wall_s"] < best[1]["wall_s"]:
            best = (done, stats)
    return best


def main() -> dict:
    tok_ = Tokenizer(512)
    prompts = [np.asarray(tok_.encode(p.prompt)[:LP], np.int32)
               for p in ArithmeticTask(seed=0).batch(N_REQ)]
    out = {"config": {"n_req": N_REQ, "slots": SLOTS, "max_prompt_len": LP,
                      "max_new": T, "spec_k": SPEC_K, "reps": REPS},
           "variants": {}}
    gqa_base_ids = None
    for vname, cfg in _variants():
        base_done, base = _serve(cfg, prompts, spec_k=0)
        spec_done, spec = _serve(cfg, prompts, spec_k=SPEC_K)
        # the exactness contract: greedy spec decode is token-identical
        base_ids = {c.request_id: c.response_ids.tolist() for c in base_done}
        spec_ids = {c.request_id: c.response_ids.tolist() for c in spec_done}
        assert base_ids == spec_ids, \
            f"{vname}: greedy spec decode diverged from the baseline"
        if vname == "gqa":
            gqa_base_ids = base_ids
        row = {
            "baseline_tok_s": base["tok_per_s"],
            "baseline_steps": base["decode_steps"],
            "spec_tok_s": spec["tok_per_s"],
            "spec_steps": spec["decode_steps"],
            "acceptance_rate": spec["acceptance_rate"],
            "tokens_per_forward": spec["tokens_per_forward"],
            "speedup": spec["tok_per_s"] / base["tok_per_s"],
            "step_reduction": base["decode_steps"] / spec["decode_steps"],
        }
        out["variants"][vname] = row
        emit("table8", f"{vname}_baseline_tok_s",
             f"{row['baseline_tok_s']:.1f}")
        emit("table8", f"{vname}_spec_tok_s", f"{row['spec_tok_s']:.1f}",
             f"k={SPEC_K} prompt-lookup, token-identical asserted")
        emit("table8", f"{vname}_acceptance_rate",
             f"{row['acceptance_rate']:.3f}")
        emit("table8", f"{vname}_tokens_per_forward",
             f"{row['tokens_per_forward']:.2f}", "1.0 = no speculation win")
        emit("table8", f"{vname}_step_reduction",
             f"{row['step_reduction']:.2f}x",
             "engine decode steps, baseline / spec")
        emit("table8", f"{vname}_speedup", f"{row['speedup']:.2f}x",
             "wall tokens/s, spec / baseline")
    # the resident draft-model provider on the GQA variant (random-init
    # draft: reports the machinery's cost floor, not a tuned acceptance)
    gqa = _variants()[0][1]
    md_done, md = _serve(gqa, prompts, spec_k=SPEC_K, draft="model")
    assert {c.request_id: c.response_ids.tolist() for c in md_done} \
        == gqa_base_ids, "model-draft greedy diverged from the baseline"
    out["variants"]["gqa_model_draft"] = {
        "spec_tok_s": md["tok_per_s"],
        "acceptance_rate": md["acceptance_rate"],
        "tokens_per_forward": md["tokens_per_forward"],
    }
    emit("table8", "gqa_model_draft_tok_s", f"{md['tok_per_s']:.1f}",
         "resident draft model (random-init)")
    emit("table8", "gqa_model_draft_acceptance",
         f"{md['acceptance_rate']:.3f}")
    save("table8_specdec", out)
    return out


if __name__ == "__main__":
    t0 = time.time()
    main()
    print(f"# table8 done in {time.time() - t0:.0f}s")
