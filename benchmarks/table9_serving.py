"""Beyond-paper table: request-driven serving latency with the radix
prefix cache (DESIGN.md §Radix-prefix-cache, §Continuous-batching).

The workload is the shared-system-prompt stream every RL-adjacent serving
deployment runs: N requests arrive as an open-loop Poisson process, each
one system prompt + a short private suffix, served greedily through the
paged engine by the ``RequestDriver`` (streaming per-token timestamps).
Cold (no prefix cache) vs warm (radix cache): the warm engine retains the
system pages in the tree and prefills only each request's suffix, so
time-to-first-token drops by roughly the shared-prefix fraction of the
prefill; time-per-output-token is unchanged (decode is identical).

The exactness contract is asserted every repetition: warm serving is
TOKEN-IDENTICAL to cold serving per request (a cached page is bitwise the
page a cold prefill would write), and the warm run actually hit the cache
— the latency win is never bought with a behavior change.

Measurement caveat: CPU prefill is compute-bound and ~linear in prompt
tokens, so the TTFT win tracks the prefix fraction; on accelerators the
same saving shows up as freed FLOPs and admission headroom.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit, save
from repro.configs import get_config, reduced_config
from repro.models import init
from repro.obs import trace as otrace
from repro.obs.analyze import analyze_file

N_REQ, SLOTS = 8, 4
LP, T, PAGE = 128, 32, 8
RATE = 4.0                  # req/s — arrivals spread over ~N/RATE seconds
SYS_TOKENS = 120            # 15 full shared pages of 8: prefill-dominated
REPS = 3


def _workload(seed: int = 0):
    """One system prompt + short per-request suffixes, Poisson arrivals."""
    rng = np.random.RandomState(seed)
    system = rng.randint(2, 500, size=SYS_TOKENS)
    prompts = [np.asarray(list(system) + list(rng.randint(2, 500, size=6)),
                          np.int32) for _ in range(N_REQ)]
    from repro.launch.serve import poisson_arrivals
    return prompts, poisson_arrivals(N_REQ, RATE, seed=seed)


def _run(cfg, params, prompts, arrivals, *, prefix_cache: bool):
    """Warmup pass (jit compile; fills the radix tree when caching), then
    REPS measured passes on the same engine; returns the per-request token
    streams and the best-latency metrics/stats."""
    from repro.launch.serve import build_paged_engine, serve_requests
    eng = build_paged_engine(cfg, max_prompt_len=LP, max_new=T,
                             num_slots=SLOTS, page_size=PAGE,
                             temperature=0.0, seed=0,
                             prefix_cache=prefix_cache)
    best = None
    for rep in range(REPS + 1):
        eng.reset_stats()
        reqs, metrics, stats = serve_requests(
            cfg, prompts, max_prompt_len=LP, max_new=T, arrivals=arrivals,
            params=params, engine=eng)
        streams = [r.tokens for r in sorted(reqs, key=lambda r: r.rid)]
        if rep == 0:
            continue                    # untimed: compile + tree warmup
        if best is None or metrics["ttft_p50_s"] < best[1]["ttft_p50_s"]:
            best = (streams, metrics, stats)
    return best + (eng,)


def _traced_rep(cfg, params, prompts, arrivals, eng, trace_path: str):
    """One extra warm rep with the tracer installed: exports the request
    lifecycle timeline and cross-checks analyzer TTFT against the
    driver's own compute_latency_metrics for the SAME rep."""
    from repro.launch.serve import serve_requests
    eng.reset_stats()
    otrace.install(process_name="table9-warm")
    _, metrics, _ = serve_requests(
        cfg, prompts, max_prompt_len=LP, max_new=T, arrivals=arrivals,
        params=params, engine=eng)
    otrace.export(trace_path)
    otrace.uninstall()
    serving = analyze_file(trace_path).get("serving") or {}
    ref = metrics["ttft_p50_s"]
    got = serving.get("ttft_p50_s", 0.0)
    # loose tolerance: the begin event fires a hair after submit_t and
    # token instants a hair after token_t, so skew is bounded by event
    # emission cost, not decode time
    assert ref == 0 or abs(got - ref) / ref < 0.25, \
        f"trace-derived ttft_p50 {got:.4f}s vs driver {ref:.4f}s"
    return serving


def _ops_rep(cfg, params, prompts, arrivals, eng, warm_ids, port: int):
    """One extra warm rep observed end-to-end through the live ops plane
    (DESIGN.md §Observability): a scrape thread hammers /metrics and
    /status while the driver drains, then one request is served over the
    socket.  Asserts the plane never lies — every mid-run scrape parses
    as well-formed Prometheus text, counters are monotone across the
    scrape series, the final registry deltas agree with the engine's own
    stats delta, and the SSE-streamed tokens are bitwise-identical to
    what the in-process driver produced for the same rid."""
    import json
    import threading
    import urllib.request

    from repro.launch.serve import serve_requests
    from repro.obs.server import OpsServer, _sse_request, parse_prometheus_text

    eng.reset_stats()
    # PRNGKey(1) == PRNGKey(seed + 1) for seed 0: the driver's base key,
    # so fold_in(key, rid) matches request-for-request
    srv = OpsServer(engine=eng, key=jax.random.PRNGKey(1), port=port)
    srv.start()

    def get(path: str) -> str:
        with urllib.request.urlopen(srv.url + path, timeout=30) as r:
            assert r.status == 200, (path, r.status)
            return r.read().decode()

    scrapes: list[str] = []
    statuses: list[dict] = []
    stop = threading.Event()

    def scrape_loop():
        while not stop.is_set():
            scrapes.append(get("/metrics"))
            statuses.append(json.loads(get("/status")))
            time.sleep(0.02)

    stats0 = eng.stats_snapshot()
    before = parse_prometheus_text(get("/metrics"))
    th = threading.Thread(target=scrape_loop, name="table9-scrape")
    th.start()
    try:
        _, metrics, _ = serve_requests(
            cfg, prompts, max_prompt_len=LP, max_new=T, arrivals=arrivals,
            params=params, engine=eng)
    finally:
        stop.set()
        th.join(timeout=30)
    after = parse_prometheus_text(get("/metrics"))
    stats1 = eng.stats_snapshot()

    # every mid-run scrape parsed (parse_prometheus_text raises on torn
    # or malformed text); counters never move backwards
    series = [before] + [parse_prometheus_text(s) for s in scrapes] + [after]
    for prev, cur in zip(series, series[1:]):
        for name, v in prev.items():
            if name.endswith("_total") and name in cur:
                assert cur[name] >= v, f"counter {name} went backwards"
    # the scrape deltas are the engine's own deltas, not an approximation
    for prom, key in (("repro_prefix_hit_pages_total", "prefix_hit_pages"),
                      ("repro_prefix_miss_pages_total", "prefix_miss_pages")):
        want = stats1[key] - stats0[key]
        got = after.get(prom, 0.0) - before.get(prom, 0.0)
        assert got == want, f"{prom} delta {got} != engine {key} delta {want}"
    assert after["repro_paged_drain_blocks_total"] > \
        before["repro_paged_drain_blocks_total"]
    for st in statuses:
        e = st["engine"]
        assert e["pages_live"] >= 0 and e["pages_free"] >= 0
        assert e["pages_live"] + e["pages_free"] == e["pages_total"]

    # socket-served request == in-process driver output, bitwise
    toks, done = _sse_request(
        srv.url, {"prompt": [int(t) for t in prompts[0]],
                  "rid": 0, "max_new": T})
    assert done.get("verified"), "server-side stream verification failed"
    assert toks == warm_ids[0], \
        "socket-streamed tokens diverged from the in-process driver"
    srv.stop()
    return {"mid_run_scrapes": len(scrapes), "sse_tokens": len(toks),
            "ttft_p50_s": metrics["ttft_p50_s"]}


def main(trace_path: str = "", serve_port: int | None = None) -> dict:
    import dataclasses
    # reduced family config, scaled up enough that prefill FLOPs are
    # visible over per-step dispatch overhead (the regime the cache
    # targets) while staying CPU-benchable
    cfg = dataclasses.replace(reduced_config(get_config("llama3.2-3b")),
                              num_layers=4, d_model=512, d_ff=1536)
    params = init(jax.random.PRNGKey(0), cfg)
    prompts, arrivals = _workload()
    cold_ids, cold, _, _ = _run(cfg, params, prompts, arrivals,
                                prefix_cache=False)
    warm_ids, warm, wstats, weng = _run(cfg, params, prompts, arrivals,
                                        prefix_cache=True)
    # exactness: greedy warm serving == greedy cold serving, per request
    assert cold_ids == warm_ids, \
        "radix-cached serving diverged from cold serving"
    assert wstats["prefix_hit_rate"] > 0 and wstats["prefix_hit_pages"] > 0
    out = {
        "config": {"n_req": N_REQ, "slots": SLOTS, "max_prompt_len": LP,
                   "max_new": T, "page_size": PAGE, "rate_req_s": RATE,
                   "system_tokens": SYS_TOKENS, "reps": REPS},
        "cold": cold, "warm": warm,
        "warm_stats": {k: wstats[k] for k in
                       ("prefix_hit_rate", "prefix_hit_pages",
                        "prefix_evicted_pages", "peak_pages")},
        "ttft_p50_speedup": cold["ttft_p50_s"] / warm["ttft_p50_s"]
        if warm["ttft_p50_s"] else 0.0,
    }
    for mode, m in (("cold", cold), ("warm", warm)):
        emit("table9", f"{mode}_ttft_p50_ms", f"{m['ttft_p50_s'] * 1e3:.0f}")
        emit("table9", f"{mode}_ttft_p99_ms", f"{m['ttft_p99_s'] * 1e3:.0f}")
        emit("table9", f"{mode}_tpot_p50_ms", f"{m['tpot_p50_s'] * 1e3:.1f}")
        emit("table9", f"{mode}_tpot_p99_ms", f"{m['tpot_p99_s'] * 1e3:.1f}")
        emit("table9", f"{mode}_tok_s", f"{m['tok_per_s']:.1f}")
    emit("table9", "prefix_hit_rate", f"{wstats['prefix_hit_rate']:.2f}",
         "prompt pages served from the radix tree")
    emit("table9", "ttft_p50_speedup", f"{out['ttft_p50_speedup']:.2f}x",
         "cold / warm, token-identical asserted")
    if trace_path:
        serving = _traced_rep(cfg, params, prompts, arrivals, weng,
                              trace_path)
        emit("table9", "trace_ttft_p50_ms",
             f"{serving.get('ttft_p50_s', 0.0) * 1e3:.0f}",
             "from request lifecycle spans, cross-checked vs driver")
        out["trace_serving"] = serving
    if serve_port is not None:
        ops = _ops_rep(cfg, params, prompts, arrivals, weng, warm_ids,
                       serve_port)
        emit("table9", "ops_mid_run_scrapes", f"{ops['mid_run_scrapes']}",
             "well-formed /metrics+/status reads while the engine drained")
        emit("table9", "ops_sse_tokens", f"{ops['sse_tokens']}",
             "socket-streamed, bitwise-identical to the driver")
        out["ops"] = ops
    save("table9_serving", out)
    return out


if __name__ == "__main__":
    import sys
    t0 = time.time()
    trace_path = ""
    serve_port: int | None = None
    if "--trace" in sys.argv:
        trace_path = sys.argv[sys.argv.index("--trace") + 1]
    if "--serve-port" in sys.argv:
        serve_port = int(sys.argv[sys.argv.index("--serve-port") + 1])
    main(trace_path=trace_path, serve_port=serve_port)
    print(f"# table9 done in {time.time() - t0:.0f}s")
