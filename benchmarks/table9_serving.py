"""Beyond-paper table: request-driven serving latency with the radix
prefix cache (DESIGN.md §Radix-prefix-cache, §Continuous-batching).

The workload is the shared-system-prompt stream every RL-adjacent serving
deployment runs: N requests arrive as an open-loop Poisson process, each
one system prompt + a short private suffix, served greedily through the
paged engine by the ``RequestDriver`` (streaming per-token timestamps).
Cold (no prefix cache) vs warm (radix cache): the warm engine retains the
system pages in the tree and prefills only each request's suffix, so
time-to-first-token drops by roughly the shared-prefix fraction of the
prefill; time-per-output-token is unchanged (decode is identical).

The exactness contract is asserted every repetition: warm serving is
TOKEN-IDENTICAL to cold serving per request (a cached page is bitwise the
page a cold prefill would write), and the warm run actually hit the cache
— the latency win is never bought with a behavior change.

Measurement caveat: CPU prefill is compute-bound and ~linear in prompt
tokens, so the TTFT win tracks the prefix fraction; on accelerators the
same saving shows up as freed FLOPs and admission headroom.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit, save
from repro.configs import get_config, reduced_config
from repro.models import init
from repro.obs import trace as otrace
from repro.obs.analyze import analyze_file

N_REQ, SLOTS = 8, 4
LP, T, PAGE = 128, 32, 8
RATE = 4.0                  # req/s — arrivals spread over ~N/RATE seconds
SYS_TOKENS = 120            # 15 full shared pages of 8: prefill-dominated
REPS = 3


def _workload(seed: int = 0):
    """One system prompt + short per-request suffixes, Poisson arrivals."""
    rng = np.random.RandomState(seed)
    system = rng.randint(2, 500, size=SYS_TOKENS)
    prompts = [np.asarray(list(system) + list(rng.randint(2, 500, size=6)),
                          np.int32) for _ in range(N_REQ)]
    from repro.launch.serve import poisson_arrivals
    return prompts, poisson_arrivals(N_REQ, RATE, seed=seed)


def _run(cfg, params, prompts, arrivals, *, prefix_cache: bool):
    """Warmup pass (jit compile; fills the radix tree when caching), then
    REPS measured passes on the same engine; returns the per-request token
    streams and the best-latency metrics/stats."""
    from repro.launch.serve import build_paged_engine, serve_requests
    eng = build_paged_engine(cfg, max_prompt_len=LP, max_new=T,
                             num_slots=SLOTS, page_size=PAGE,
                             temperature=0.0, seed=0,
                             prefix_cache=prefix_cache)
    best = None
    for rep in range(REPS + 1):
        eng.reset_stats()
        reqs, metrics, stats = serve_requests(
            cfg, prompts, max_prompt_len=LP, max_new=T, arrivals=arrivals,
            params=params, engine=eng)
        streams = [r.tokens for r in sorted(reqs, key=lambda r: r.rid)]
        if rep == 0:
            continue                    # untimed: compile + tree warmup
        if best is None or metrics["ttft_p50_s"] < best[1]["ttft_p50_s"]:
            best = (streams, metrics, stats)
    return best + (eng,)


def _traced_rep(cfg, params, prompts, arrivals, eng, trace_path: str):
    """One extra warm rep with the tracer installed: exports the request
    lifecycle timeline and cross-checks analyzer TTFT against the
    driver's own compute_latency_metrics for the SAME rep."""
    from repro.launch.serve import serve_requests
    eng.reset_stats()
    otrace.install(process_name="table9-warm")
    _, metrics, _ = serve_requests(
        cfg, prompts, max_prompt_len=LP, max_new=T, arrivals=arrivals,
        params=params, engine=eng)
    otrace.export(trace_path)
    otrace.uninstall()
    serving = analyze_file(trace_path).get("serving") or {}
    ref = metrics["ttft_p50_s"]
    got = serving.get("ttft_p50_s", 0.0)
    # loose tolerance: the begin event fires a hair after submit_t and
    # token instants a hair after token_t, so skew is bounded by event
    # emission cost, not decode time
    assert ref == 0 or abs(got - ref) / ref < 0.25, \
        f"trace-derived ttft_p50 {got:.4f}s vs driver {ref:.4f}s"
    return serving


def main(trace_path: str = "") -> dict:
    import dataclasses
    # reduced family config, scaled up enough that prefill FLOPs are
    # visible over per-step dispatch overhead (the regime the cache
    # targets) while staying CPU-benchable
    cfg = dataclasses.replace(reduced_config(get_config("llama3.2-3b")),
                              num_layers=4, d_model=512, d_ff=1536)
    params = init(jax.random.PRNGKey(0), cfg)
    prompts, arrivals = _workload()
    cold_ids, cold, _, _ = _run(cfg, params, prompts, arrivals,
                                prefix_cache=False)
    warm_ids, warm, wstats, weng = _run(cfg, params, prompts, arrivals,
                                        prefix_cache=True)
    # exactness: greedy warm serving == greedy cold serving, per request
    assert cold_ids == warm_ids, \
        "radix-cached serving diverged from cold serving"
    assert wstats["prefix_hit_rate"] > 0 and wstats["prefix_hit_pages"] > 0
    out = {
        "config": {"n_req": N_REQ, "slots": SLOTS, "max_prompt_len": LP,
                   "max_new": T, "page_size": PAGE, "rate_req_s": RATE,
                   "system_tokens": SYS_TOKENS, "reps": REPS},
        "cold": cold, "warm": warm,
        "warm_stats": {k: wstats[k] for k in
                       ("prefix_hit_rate", "prefix_hit_pages",
                        "prefix_evicted_pages", "peak_pages")},
        "ttft_p50_speedup": cold["ttft_p50_s"] / warm["ttft_p50_s"]
        if warm["ttft_p50_s"] else 0.0,
    }
    for mode, m in (("cold", cold), ("warm", warm)):
        emit("table9", f"{mode}_ttft_p50_ms", f"{m['ttft_p50_s'] * 1e3:.0f}")
        emit("table9", f"{mode}_ttft_p99_ms", f"{m['ttft_p99_s'] * 1e3:.0f}")
        emit("table9", f"{mode}_tpot_p50_ms", f"{m['tpot_p50_s'] * 1e3:.1f}")
        emit("table9", f"{mode}_tpot_p99_ms", f"{m['tpot_p99_s'] * 1e3:.1f}")
        emit("table9", f"{mode}_tok_s", f"{m['tok_per_s']:.1f}")
    emit("table9", "prefix_hit_rate", f"{wstats['prefix_hit_rate']:.2f}",
         "prompt pages served from the radix tree")
    emit("table9", "ttft_p50_speedup", f"{out['ttft_p50_speedup']:.2f}x",
         "cold / warm, token-identical asserted")
    if trace_path:
        serving = _traced_rep(cfg, params, prompts, arrivals, weng,
                              trace_path)
        emit("table9", "trace_ttft_p50_ms",
             f"{serving.get('ttft_p50_s', 0.0) * 1e3:.0f}",
             "from request lifecycle spans, cross-checked vs driver")
        out["trace_serving"] = serving
    save("table9_serving", out)
    return out


if __name__ == "__main__":
    import sys
    t0 = time.time()
    trace_path = ""
    if "--trace" in sys.argv:
        trace_path = sys.argv[sys.argv.index("--trace") + 1]
    main(trace_path=trace_path)
    print(f"# table9 done in {time.time() - t0:.0f}s")
