"""Batched serving example — the inference half of the decoupled deployment.

Serves a batch of generation requests through the jitted prefill + KV-cache
decode loop (the vLLM stand-in that rollout workers run), for any assigned
architecture family, and prints per-request decoded text + throughput.

Run:
    PYTHONPATH=src python examples/serve_batch.py --arch mamba2-2.7b
    PYTHONPATH=src python examples/serve_batch.py --arch deepseek-v2-lite-16b
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.configs import ARCH_IDS, get_config, reduced_config
from repro.data.tasks import ArithmeticTask
from repro.data.tokenizer import Tokenizer
from repro.launch.serve import serve_batch


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b", choices=ARCH_IDS)
    ap.add_argument("--num-requests", type=int, default=8)
    ap.add_argument("--max-prompt-len", type=int, default=48)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.7)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--cbatch", type=int, default=0, metavar="SLOTS",
                    help="serve through the dense-slot continuous-batching "
                         "engine with this many slots (0 = fixed-batch "
                         "sampler)")
    ap.add_argument("--paged", type=int, default=0, metavar="SLOTS",
                    help="serve through the token-level paged-KV engine "
                         "with this many slots (shared page pool, slots "
                         "freed at EOS — see DESIGN.md §Continuous-batching)")
    ap.add_argument("--spec", action="store_true",
                    help="speculative decode on the paged engine "
                         "(DESIGN.md §Spec-decode); stats report the "
                         "draft acceptance rate")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="drafted tokens per verify step")
    ap.add_argument("--spec-draft", default="prompt_lookup",
                    choices=["prompt_lookup", "model"])
    ap.add_argument("--shared-system", type=int, default=0, metavar="N",
                    help="serve N requests sharing one system prompt "
                         "through the radix prefix cache (cached system "
                         "pages, suffix-only prefill — DESIGN.md "
                         "§Radix-prefix-cache)")
    args = ap.parse_args()

    cfg = reduced_config(get_config(args.arch))
    if cfg.is_encoder_decoder or cfg.vision_prefix_len:
        raise SystemExit(f"{args.arch}: modality-frontend archs are served "
                         "through the RL pipeline, not this text demo — "
                         "pick a decoder-only arch")
    tok = Tokenizer(cfg.vocab_size)
    problems = ArithmeticTask(seed=args.seed).batch(args.num_requests)
    prompts = [np.asarray(tok.encode(p.prompt)[: args.max_prompt_len],
                          np.int32) for p in problems]

    if args.paged and args.cbatch:
        raise SystemExit("--paged and --cbatch are different engines; "
                         "pick one")
    spec_k = args.spec_k if args.spec else 0
    if spec_k and not (args.paged or args.shared_system):
        raise SystemExit("--spec rides the paged engine in this demo; add "
                         "--paged SLOTS (or --shared-system N)")

    if args.shared_system:
        from repro.launch.serve import serve_shared
        system = np.asarray(
            tok.encode("You are a terse arithmetic solver. ")[
                : args.max_prompt_len], np.int32)
        suffixes = [np.asarray(tok.encode(p.prompt)[: args.max_new // 2],
                               np.int32)
                    for p in ArithmeticTask(seed=args.seed + 1).batch(
                        args.shared_system)]
        done, stats = serve_shared(
            cfg, system, suffixes, max_prompt_len=args.max_prompt_len,
            max_new=args.max_new, temperature=args.temperature,
            seed=args.seed, spec_k=spec_k, spec_draft=args.spec_draft)
        extra = (f", accept={stats['acceptance_rate']:.2f}"
                 if spec_k else "")
        print(f"{args.arch} (shared-system x{args.shared_system}): "
              f"{stats['generated_tokens']} tokens in "
              f"{stats['wall_s']:.2f}s ({stats['tok_per_s']:.1f} tok/s, "
              f"{stats['prompt_pages_saved']} prompt pages saved by "
              f"the prefix cache{extra})")
        for c in done[:4]:
            print(f"  req {c.request_id}: "
                  f"{tok.decode(c.response_ids.tolist())!r}")
        return

    if args.paged:
        from repro.launch.serve import serve_paged
        done, stats = serve_paged(
            cfg, prompts, max_prompt_len=args.max_prompt_len,
            max_new=args.max_new, num_slots=args.paged,
            temperature=args.temperature, seed=args.seed,
            spec_k=spec_k, spec_draft=args.spec_draft)
        extra = (f", accept={stats['acceptance_rate']:.2f}"
                 if spec_k else "")
        print(f"{args.arch} (paged x{args.paged}"
              f"{f' spec k={spec_k}' if spec_k else ''}): {len(done)} "
              f"requests in completion order, "
              f"{stats['generated_tokens']} tokens in "
              f"{stats['wall_s']:.2f}s ({stats['tok_per_s']:.1f} tok/s, "
              f"{stats['decode_steps']} decode steps{extra})")
        for c in done[:4]:
            print(f"  req {c.request_id} finished at step {c.finish_step}: "
                  f"{tok.decode(c.response_ids.tolist())!r}")
        return

    if args.cbatch:
        import time
        import jax
        from repro.core.cbatch import ContinuousBatchingSampler
        from repro.models import init
        params = init(jax.random.PRNGKey(args.seed), cfg)
        eng = ContinuousBatchingSampler(
            cfg, num_slots=args.cbatch, max_prompt_len=args.max_prompt_len,
            max_new_tokens=args.max_new, temperature=args.temperature)
        t0 = time.time()
        done = eng.run(params, prompts, jax.random.PRNGKey(args.seed + 1))
        wall = time.time() - t0
        toks = sum(len(c.response_ids) for c in done)
        print(f"{args.arch} (cbatch x{args.cbatch}): {len(done)} requests "
              f"in completion order, {toks} tokens in {wall:.2f}s "
              f"({toks / wall:.1f} tok/s)")
        for c in done[:4]:
            print(f"  req {c.request_id} finished at step {c.finish_step}: "
                  f"{tok.decode(c.response_ids.tolist())!r}")
        return

    out, stats = serve_batch(cfg, prompts,
                             max_prompt_len=args.max_prompt_len,
                             max_new=args.max_new,
                             temperature=args.temperature, seed=args.seed)

    print(f"{args.arch} ({cfg.family}): {args.num_requests} requests, "
          f"{stats['generated_tokens']} tokens in {stats['wall_s']:.2f}s "
          f"({stats['tok_per_s']:.1f} tok/s)")
    resp = np.asarray(out.response_ids)
    lens = np.asarray(out.response_len)
    for i in range(min(4, args.num_requests)):
        print(f"  {problems[i].prompt!r} -> "
              f"{tok.decode(resp[i, : lens[i]])!r}")


if __name__ == "__main__":
    main()
