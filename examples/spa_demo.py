"""Shared-Prompt Attention demo (paper §4.3).

Shows, on one GRPO group:
  1. the packed layout (tokens / positions / segments / loss weights),
  2. exactness: packed gradients == per-sample gradients (fp32 allclose),
  3. the Eq. 5 complexity reduction rho measured against its closed form,
  4. the block-sparse Pallas kernel's live-tile fraction (the structural
     realisation of rho on the MXU).

Run:
    PYTHONPATH=src python examples/spa_demo.py [--Lp 256] [--Lr 32] [--K 8]
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.configs.base import RLConfig
from repro.core.queue import RolloutGroup
from repro.core.spa import pack_plain, pack_spa, spa_reduction_ratio
from repro.kernels.spa_attention import block_map
from repro.models import init
from repro.rl.grpo import jaxify, make_grad_step, group_advantages


def make_group(Lp: int, Lr: int, K: int, seed: int = 0) -> RolloutGroup:
    rng = np.random.RandomState(seed)
    return RolloutGroup(
        uid=0,
        prompt_ids=rng.randint(3, 250, size=(Lp,)).astype(np.int32),
        response_ids=rng.randint(3, 250, size=(K, Lr)).astype(np.int32),
        response_len=np.full((K,), Lr, np.int32),
        rewards=rng.randint(0, 2, size=(K,)).astype(np.float32),
        weight_version=0)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--Lp", type=int, default=256)
    ap.add_argument("--Lr", type=int, default=16)
    ap.add_argument("--K", type=int, default=8)
    args = ap.parse_args()
    Lp, Lr, K = args.Lp, args.Lr, args.K

    group = make_group(Lp, Lr, K)
    adv = np.asarray(group_advantages(jnp.asarray(group.rewards)))

    # --- 1. layout ---------------------------------------------------------
    mb = pack_spa(group, adv, Lp, Lr, responses_per_row=K)
    print(f"packed row: S = {mb.tokens.shape[1]} "
          f"(= (Lp-1) + K*(1+Lr) = {(Lp - 1) + K * (1 + Lr)})")
    print(f"  segments: prompt=0, responses=1..{K}; "
          f"positions restart at {Lp - 1} per response")

    # --- 2. exactness ------------------------------------------------------
    cfg = reduced_config(get_config("llama3.2-3b"))
    rl = RLConfig(max_prompt_len=Lp, max_response_len=Lr, group_size=K)
    params = init(jax.random.PRNGKey(0), cfg)
    grad_step = make_grad_step(cfg, rl)
    g_spa, _ = grad_step(params, params, params, jaxify(mb))
    g_plain, _ = grad_step(params, params, params,
                           jaxify(pack_plain([group], [adv], Lp, Lr)))
    err = max(float(jnp.abs(a - b).max()) for a, b in
              zip(jax.tree.leaves(g_spa), jax.tree.leaves(g_plain)))
    print(f"max |grad_SPA - grad_plain| = {err:.2e}  "
          f"(exact up to fp32 reduction order)")

    # --- 3. Eq. 5 ----------------------------------------------------------
    rho = spa_reduction_ratio(Lp, Lr, K)
    print(f"Eq.5 rho = {rho:.3f}  (1/K = {1 / K:.3f}; "
          f"rho -> 1/K as Lp >> Lr)")

    # --- 4. kernel block sparsity -----------------------------------------
    pos, seg = jnp.asarray(mb.positions), jnp.asarray(mb.segments)
    bq = bk = 16
    S = pos.shape[1]
    pad = (-S) % bq
    if pad:
        pos = jnp.pad(pos, ((0, 0), (0, pad)), constant_values=2**30 - 1)
        seg = jnp.pad(seg, ((0, 0), (0, pad)), constant_values=-1)
    bm = np.asarray(block_map(pos, pos, seg, seg, bq, bk))
    dense_causal = np.tril(np.ones(bm.shape[1:])).mean()
    print(f"Pallas block map: live tiles {bm.mean():.3f} "
          f"vs dense-causal {dense_causal:.3f} "
          f"-> {dense_causal / max(bm.mean(), 1e-9):.2f}x fewer MXU tiles")


if __name__ == "__main__":
    main()
