"""End-to-end GRPO training driver — trains a reduced model for a few
hundred steps on the synthetic math task and (with --compare) overlays the
sync/async reward trajectories, reproducing the paper's Figure 5 claim that
the two runs are statistically indistinguishable.

Run (fast demo):
    PYTHONPATH=src python examples/train_grpo.py --iterations 8

Paper Figure 5 comparison:
    PYTHONPATH=src python examples/train_grpo.py --compare --iterations 12

Longer training (a few hundred steps, as the deliverable dictates):
    PYTHONPATH=src python examples/train_grpo.py --iterations 300 \
        --batch-prompts 8 --group-size 8
"""
from __future__ import annotations

import argparse
import json
import time

from repro.configs import ARCH_IDS, get_config, reduced_config
from repro.configs.base import RLConfig
from repro.launch.train import build_pipeline


def run(arch: str, mode: str, iterations: int, args) -> list:
    cfg = reduced_config(get_config(arch))
    rl = RLConfig(mode=mode,
                  batch_prompts=args.batch_prompts,
                  group_size=args.group_size,
                  micro_batch=args.micro_batch,
                  num_inference_instances=args.instances,
                  max_prompt_len=args.max_prompt_len,
                  max_response_len=args.max_response_len,
                  shared_prompt_attention=args.spa,
                  learning_rate=args.lr, seed=args.seed)
    sched, _ = build_pipeline(cfg, rl, seed=args.seed,
                              prompt_pad=args.prompt_pad)
    t0 = time.time()
    hist = sched.run(iterations)
    wall = time.time() - t0
    toks = sum(s.trained_tokens for s in hist)
    print(f"[{mode}] {iterations} iters, {toks} tokens, {wall:.1f}s "
          f"-> TPSPD {toks / wall:.1f}")
    return hist


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b", choices=ARCH_IDS)
    ap.add_argument("--mode", default="async",
                    choices=["sync", "async", "async_offpolicy"])
    ap.add_argument("--iterations", type=int, default=8)
    ap.add_argument("--batch-prompts", type=int, default=4)
    ap.add_argument("--group-size", type=int, default=4)
    ap.add_argument("--micro-batch", type=int, default=2)
    ap.add_argument("--instances", type=int, default=2)
    ap.add_argument("--max-prompt-len", type=int, default=48)
    ap.add_argument("--max-response-len", type=int, default=16)
    ap.add_argument("--prompt-pad", type=int, default=0)
    ap.add_argument("--lr", type=float, default=1e-4)
    ap.add_argument("--spa", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--compare", action="store_true",
                    help="run sync AND async, print reward trajectories "
                         "side by side (paper Figure 5)")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()

    if args.compare:
        h_sync = run(args.arch, "sync", args.iterations, args)
        h_async = run(args.arch, "async", args.iterations, args)
        print("\niter |  sync reward | async reward")
        for a, b in zip(h_sync, h_async):
            print(f"{a.iteration:4d} | {a.reward_mean:12.3f} "
                  f"| {b.reward_mean:12.3f}")
        if args.json_out:
            with open(args.json_out, "w") as f:
                json.dump({"sync": [s.reward_mean for s in h_sync],
                           "async": [s.reward_mean for s in h_async]}, f)
    else:
        hist = run(args.arch, args.mode, args.iterations, args)
        for s in hist:
            print(f"  iter {s.iteration}: reward={s.reward_mean:.3f} "
                  f"tokens={s.trained_tokens} staleness={s.max_staleness}")
        if args.json_out:
            with open(args.json_out, "w") as f:
                json.dump([s.__dict__ for s in hist], f, default=str)


if __name__ == "__main__":
    main()
