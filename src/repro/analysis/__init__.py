"""repro-check: repo-specific static analysis (DESIGN.md §Static-analysis).

Five AST-based checkers guard the invariants the paper's exactness
contract depends on:

  * host-sync        device->host transfers reachable from decode hot paths
  * lock-discipline  cross-thread attribute access outside the owning lock
  * refcount-pairing PageAllocator/RadixCache retain/release symmetry
  * trace-purity     impurities inside jit/pallas-traced functions
  * support-matrix   configs/base.py engine_support vs the actual guards

Stdlib-only (``ast``); findings are suppressible exclusively via
``# repro: allow(<checker>): <justification>`` pragmas. CLI: ``repro-check``
(console script) or ``python -m repro.analysis.cli``.
"""
from repro.analysis.framework import Finding, Module, run_checkers
from repro.analysis.registry import ALL_CHECKERS, CHECKER_NAMES

__all__ = ["Finding", "Module", "run_checkers", "ALL_CHECKERS",
           "CHECKER_NAMES"]
