"""Shared AST utilities: function index, name-heuristic call graph.

Resolution is deliberately name-based (no import tracking, no types):
``self.m()`` resolves within the enclosing class, a bare ``f()`` to the
module-level ``f``, and ``obj.m()`` to every analyzed method named ``m``
anywhere (cross-module). That over-approximates reachability — the right
bias for checkers whose job is "could this be on the hot path / called
while locked", and cheap enough to run on every commit.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Set, Tuple

from repro.analysis.framework import Module


def dotted(node: ast.AST) -> Optional[str]:
    """'jax.random.split' for a Name/Attribute chain, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


@dataclass
class FuncInfo:
    module: Module
    node: ast.FunctionDef
    qualname: str                 # "Cls.meth", "func", "Cls.meth.inner"
    cls: Optional[str]            # innermost enclosing class name
    parent: Optional[str] = None  # qualname of enclosing function, if any

    @property
    def name(self) -> str:
        return self.node.name

    @property
    def ref(self) -> str:
        """Global id: '<module path>::<qualname>'."""
        return "%s::%s" % (self.module.path, self.qualname)


def iter_functions(mod: Module) -> Iterator[FuncInfo]:
    """Every def in the module, with class context and nesting."""
    def walk(node, cls, qual, parent):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.ClassDef):
                yield from walk(child, child.name,
                                qual + [child.name], parent)
            elif isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                q = ".".join(qual + [child.name])
                yield FuncInfo(module=mod, node=child, qualname=q,
                               cls=cls, parent=parent)
                yield from walk(child, cls, qual + [child.name], q)
            else:
                yield from walk(child, cls, qual, parent)
    yield from walk(mod.tree, None, [], None)


def own_statements(fn: ast.FunctionDef) -> Iterator[ast.AST]:
    """Walk fn's body, NOT descending into nested defs (which are their
    own FuncInfo nodes) — nested lambdas/comprehensions are included."""
    stack: List[ast.AST] = list(fn.body)
    while stack:
        node = stack.pop()
        yield node
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef)):
                continue
            stack.append(child)


@dataclass
class CallGraph:
    funcs: Dict[str, FuncInfo] = field(default_factory=dict)  # by ref
    edges: Dict[str, Set[str]] = field(default_factory=dict)  # ref -> refs

    def callees(self, ref: str) -> Set[str]:
        return self.edges.get(ref, set())

    def bfs_depth(self, roots: List[str]) -> Dict[str, int]:
        """Min call depth from any root, over the edge relation."""
        depth = {r: 0 for r in roots if r in self.funcs}
        frontier = list(depth)
        while frontier:
            nxt = []
            for ref in frontier:
                for cal in self.callees(ref):
                    if cal not in depth:
                        depth[cal] = depth[ref] + 1
                        nxt.append(cal)
            frontier = nxt
        return depth


def _callee_names(fn: ast.FunctionDef) -> Iterator[Tuple[str, bool]]:
    """(name, is_self_call) for every call AND bound-method reference in
    fn's own statements. ``self.m(...)`` and a bare ``m`` defined locally
    both count; ``Thread(target=self._run)`` yields ('_run', True)."""
    for node in own_statements(fn):
        if isinstance(node, ast.Call):
            f = node.func
            if isinstance(f, ast.Attribute):
                base = dotted(f.value)
                yield f.attr, base == "self"
            elif isinstance(f, ast.Name):
                yield f.id, False
        elif isinstance(node, ast.Attribute):
            # bound-method reference passed around (thread targets,
            # callbacks): only self.X references, to bound the fan-out
            if dotted(node) is not None and \
                    dotted(node).startswith("self."):
                yield node.attr, True


# Method names so common on stdlib/numpy/jax objects that a non-self
# ``obj.m()`` call is almost never the repo function of the same name —
# following them by name creates bogus edges (``x.at[i].add(v)`` is not
# GradAccumulator.add, ``state.get(k)`` is not RolloutQueue.get).
_COMMON_METHODS = {
    "get", "put", "add", "update", "pop", "append", "extend", "clear",
    "items", "keys", "values", "copy", "join", "start", "set", "sort",
    "remove", "discard", "index", "count", "split", "strip", "close",
    "read", "write", "mean", "sum", "max", "min", "all", "any", "wait",
    "notify", "notify_all", "acquire", "result", "done", "insert",
}


def build_callgraph(modules: List[Module]) -> CallGraph:
    g = CallGraph()
    by_name: Dict[str, List[str]] = {}        # bare name -> refs
    by_cls: Dict[Tuple[str, str, str], str] = {}  # (mod, cls, name) -> ref
    for mod in modules:
        for fi in iter_functions(mod):
            g.funcs[fi.ref] = fi
            by_name.setdefault(fi.name, []).append(fi.ref)
            if fi.cls is not None:
                by_cls[(mod.path, fi.cls, fi.name)] = fi.ref

    for ref, fi in g.funcs.items():
        out: Set[str] = set()
        for name, is_self in _callee_names(fi.node):
            if is_self and fi.cls is not None:
                hit = by_cls.get((fi.module.path, fi.cls, name))
                if hit:
                    out.add(hit)
                    continue
            # nested function defined in this function?
            nested = "%s::%s.%s" % (fi.module.path, fi.qualname, name)
            if nested in g.funcs:
                out.add(nested)
                continue
            # module-level / any-class name heuristic (skipped for
            # ubiquitous container/array method names — see above)
            if name in _COMMON_METHODS:
                continue
            for cand in by_name.get(name, ()):
                out.add(cand)
        out.discard(ref)
        g.edges[ref] = out
    return g
