"""``repro-check``: run the static-analysis pass over the repo.

Usage::

    repro-check src/                     # human-readable, exit 1 on
                                         # unsuppressed findings
    repro-check src/ --json report.json  # plus a JSON report (CI artifact)
    repro-check src/ --checker host-sync --show-suppressed

Exit code 0 iff every finding is suppressed by a justified
``# repro: allow(<checker>): <why>`` pragma (DESIGN.md §Static-analysis).
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import Counter
from pathlib import Path
from typing import List, Optional

from repro.analysis.framework import Finding, discover, run_checkers
from repro.analysis.registry import ALL_CHECKERS, CHECKER_NAMES


def build_arg_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="repro-check",
        description="repo-specific static analysis "
                    "(DESIGN.md §Static-analysis)")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories to analyze (default: src)")
    ap.add_argument("--checker", action="append", default=None,
                    choices=CHECKER_NAMES, metavar="NAME",
                    help="run only the named checker(s); repeatable "
                         "(default: all of %s)" % ", ".join(CHECKER_NAMES))
    ap.add_argument("--json", metavar="FILE", default=None,
                    help="also write the full findings report as JSON")
    ap.add_argument("--show-suppressed", action="store_true",
                    help="print suppressed findings too (the inventory "
                         "view)")
    ap.add_argument("--root", default=".",
                    help="path findings are reported relative to "
                         "(default: cwd)")
    ap.add_argument("--forbid-hot", action="store_true",
                    help="fail (exit 2) on any error-severity host-sync "
                         "finding, SUPPRESSED OR NOT — the device-resident "
                         "decode gate: a pragma can justify a warm/cold "
                         "sync, but nothing on the hot tier "
                         "(DESIGN.md §Device-resident-decode)")
    return ap


def summarize(findings: List[Finding]) -> str:
    open_f = [f for f in findings if not f.suppressed]
    supp = [f for f in findings if f.suppressed]
    per = Counter(f.checker for f in open_f)
    parts = ["%d finding(s): %d open, %d suppressed"
             % (len(findings), len(open_f), len(supp))]
    if per:
        parts.append("open by checker: " + ", ".join(
            "%s=%d" % kv for kv in sorted(per.items())))
    return "; ".join(parts)


def main(argv: Optional[List[str]] = None) -> int:
    args = build_arg_parser().parse_args(argv)
    checkers = ALL_CHECKERS if not args.checker else \
        [c for c in ALL_CHECKERS if c.name in args.checker]

    modules = discover([Path(p) for p in args.paths], Path(args.root))
    findings = run_checkers(modules, checkers, known_names=CHECKER_NAMES)

    shown = 0
    for f in findings:
        if f.suppressed and not args.show_suppressed:
            continue
        print(f.render())
        shown += 1
    if shown:
        print()
    print(summarize(findings))

    if args.json:
        report = {
            "tool": "repro-check",
            "checkers": [c.name for c in checkers],
            "findings": [f.to_json() for f in findings],
            "open": sum(1 for f in findings if not f.suppressed),
            "suppressed": sum(1 for f in findings if f.suppressed),
        }
        Path(args.json).parent.mkdir(parents=True, exist_ok=True)
        Path(args.json).write_text(json.dumps(report, indent=2) + "\n")

    if args.forbid_hot:
        hot = [f for f in findings
               if f.checker == "host-sync" and f.severity == "error"]
        if hot:
            print("\n--forbid-hot: %d hot-tier host-sync site(s) "
                  "(suppression does not exempt the hot tier):" % len(hot))
            for f in hot:
                print("  " + f.render())
            return 2

    return 1 if any(not f.suppressed for f in findings) else 0


if __name__ == "__main__":
    sys.exit(main())
