"""Checker harness: module loading, pragma application, finding model.

A checker is any object with ``name: str`` and
``run(modules) -> List[Finding]``. The harness parses every module once,
hands the same list to each checker, then applies suppression pragmas
and emits the ``pragma`` meta-findings (bare allow / unknown checker /
unused pragma) — those are not themselves suppressible, so the pragma
layer can't be used to silence its own rot.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from pathlib import Path
from typing import Iterable, List, Optional, Sequence

from repro.analysis.pragmas import Pragma, match_pragma, parse_pragmas

# Severity is informational tiering (host-sync call-depth etc.); the CLI
# exit code counts every unsuppressed finding regardless of severity.
SEVERITIES = ("error", "warning", "info")


@dataclass
class Finding:
    checker: str
    path: str            # repo-relative, '/'-separated
    line: int
    message: str
    severity: str = "error"
    suppressed: bool = False
    justification: Optional[str] = None

    def key(self):
        return (self.path, self.line, self.checker, self.message)

    def to_json(self) -> dict:
        return {"checker": self.checker, "path": self.path,
                "line": self.line, "severity": self.severity,
                "message": self.message, "suppressed": self.suppressed,
                "justification": self.justification}

    def render(self) -> str:
        tag = " [suppressed: %s]" % self.justification \
            if self.suppressed else ""
        return "%s:%d: %s(%s): %s%s" % (self.path, self.line,
                                        self.checker, self.severity,
                                        self.message, tag)


@dataclass
class Module:
    path: str            # repo-relative, '/'-separated (id for findings)
    source: str
    tree: ast.Module = field(repr=False)
    lines: List[str] = field(repr=False)
    pragmas: List[Pragma] = field(repr=False)

    @classmethod
    def from_source(cls, path: str, source: str) -> "Module":
        tree = ast.parse(source, filename=path)
        lines = source.splitlines()
        return cls(path=path, source=source, tree=tree, lines=lines,
                   pragmas=parse_pragmas(lines, tree))

    @classmethod
    def from_file(cls, file: Path, root: Path) -> "Module":
        rel = file.relative_to(root).as_posix() if root in file.parents \
            or file == root else file.as_posix()
        return cls.from_source(rel, file.read_text())


def discover(paths: Sequence[Path], root: Optional[Path] = None
             ) -> List[Module]:
    """Load every ``*.py`` under the given paths (files or directories)."""
    root = (root or Path.cwd()).resolve()
    files: List[Path] = []
    for p in paths:
        p = Path(p).resolve()
        if p.is_dir():
            files.extend(sorted(p.rglob("*.py")))
        else:
            files.append(p)
    mods = []
    for f in dict.fromkeys(files):  # dedupe, keep order
        try:
            mods.append(Module.from_file(f, root))
        except SyntaxError as e:  # surface as a finding, don't crash
            rel = f.relative_to(root).as_posix() if root in f.parents \
                else f.as_posix()
            mods.append(Module.from_source(rel, ""))
            mods[-1].pragmas = []
            mods[-1]._syntax_error = e  # type: ignore[attr-defined]
    return mods


def run_checkers(modules: List[Module], checkers: Iterable,
                 known_names: Optional[Sequence[str]] = None
                 ) -> List[Finding]:
    """Run checkers, apply pragmas, append pragma meta-findings."""
    findings: List[Finding] = []
    for mod in modules:
        err = getattr(mod, "_syntax_error", None)
        if err is not None:
            findings.append(Finding("parse", mod.path,
                                    err.lineno or 1,
                                    "syntax error: %s" % err.msg))
    for chk in checkers:
        findings.extend(chk.run(modules))

    # the pragma meta-layer always runs (it IS this function), so an
    # allow(pragma) is "unused" in every invocation
    ran = {c.name for c in checkers} | {"pragma"}
    known = set(known_names or ran)
    by_path = {m.path: m for m in modules}
    for f in findings:
        mod = by_path.get(f.path)
        if mod is None or f.checker == "pragma":
            continue
        p = match_pragma(mod.pragmas, f.checker, f.line)
        if p is not None:
            p.used = True
            if p.justification:
                f.suppressed = True
                f.justification = p.justification
            # A bare allow matches but does NOT suppress — it becomes a
            # pragma finding below, and the original stays open.

    for mod in modules:
        for p in mod.pragmas:
            if not p.justification:
                findings.append(Finding(
                    "pragma", mod.path, p.line,
                    "bare allow(%s) without a justification — write "
                    "'# repro: allow(%s): <why>'" % (p.checker, p.checker)))
            elif p.checker not in known:
                findings.append(Finding(
                    "pragma", mod.path, p.line,
                    "unknown checker %r in allow() — known: %s"
                    % (p.checker, ", ".join(sorted(known)))))
            elif not p.used and p.checker in ran:
                # only a checker that actually RAN this invocation can
                # vouch that its pragma matched nothing — a partial
                # `--checker` run must not flag other checkers' pragmas
                findings.append(Finding(
                    "pragma", mod.path, p.line,
                    "unused allow(%s) pragma — nothing it suppresses; "
                    "delete it" % p.checker, severity="warning"))

    findings.sort(key=lambda f: (f.path, f.line, f.checker))
    return findings
