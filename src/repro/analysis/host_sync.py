"""host-sync: device->host transfers reachable from decode hot paths.

Explicit syncs (``jax.device_get``, ``jax.block_until_ready``,
``.item()``) are flagged wherever they appear; implicit ones
(``np.asarray`` / ``int()`` / ``float()``) only when the operand is
*traced-tainted* — assigned from a jit-compiled handle, a
``jax.random`` producer, or an attribute known to carry device arrays
(``RolloutBatch`` fields etc., DEVICE_ATTRS).

Severity = min call depth from the per-token entry points
(HOT_ENTRY_POINTS): depth 0 is ``hot`` (error), 1-2 ``warm`` (warning),
deeper or unreachable ``cold`` (info). Every site is reported either
way — the full inventory is the scoping artifact for the
device-resident decode loop (ROADMAP).
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.callgraph import (FuncInfo, build_callgraph, dotted,
                                      iter_functions, own_statements)
from repro.analysis.framework import Finding, Module
from repro.analysis.repo_config import DEVICE_ATTRS, HOT_ENTRY_POINTS

_EXPLICIT = {
    "jax.device_get": "jax.device_get",
    "jax.block_until_ready": "jax.block_until_ready",
}
_RANDOM_PRODUCERS = {"jax.random.split", "jax.random.fold_in"}
_IMPLICIT_CASTS = {"int", "float"}
_ASARRAY = {"np.asarray", "numpy.asarray", "np.array", "numpy.array"}


def _jit_handle_attrs(mod: Module) -> Dict[str, Set[str]]:
    """class name -> attr names assigned ``self._x = jax.jit(...)``."""
    out: Dict[str, Set[str]] = {}
    for fi in iter_functions(mod):
        if fi.cls is None:
            continue
        for node in own_statements(fi.node):
            if not (isinstance(node, ast.Assign)
                    and isinstance(node.value, ast.Call)):
                continue
            callee = dotted(node.value.func)
            if callee not in ("jax.jit", "pl.pallas_call", "pallas_call"):
                continue
            for tgt in node.targets:
                if isinstance(tgt, ast.Attribute) and \
                        dotted(tgt.value) == "self":
                    out.setdefault(fi.cls, set()).add(tgt.attr)
    return out


def _jitted_module_funcs(modules: List[Module]) -> Set[str]:
    """Bare names of functions carrying a jax.jit decorator."""
    names: Set[str] = set()
    for mod in modules:
        for fi in iter_functions(mod):
            for dec in fi.node.decorator_list:
                d = dotted(dec.func if isinstance(dec, ast.Call) else dec)
                if d == "jax.jit" or (isinstance(dec, ast.Call)
                                      and _mentions_jit(dec)):
                    names.add(fi.name)
    return names


def _mentions_jit(node: ast.AST) -> bool:
    return any(dotted(n) == "jax.jit" for n in ast.walk(node)
               if isinstance(n, (ast.Attribute, ast.Name)))


class _FnScan:
    """One pass over a function: taint propagation + sync sites."""

    def __init__(self, fi: FuncInfo, jit_attrs: Set[str],
                 jit_funcs: Set[str]):
        self.fi = fi
        self.jit_attrs = jit_attrs
        self.jit_funcs = jit_funcs
        self.tainted: Set[str] = set()
        self.sites: List[Tuple[int, str]] = []

    def is_tainted_expr(self, node: ast.AST) -> bool:
        for n in ast.walk(node):
            if isinstance(n, ast.Name) and n.id in self.tainted:
                return True
            if isinstance(n, ast.Attribute) and n.attr in DEVICE_ATTRS:
                return True
            if isinstance(n, ast.Call):
                d = dotted(n.func)
                if d in _RANDOM_PRODUCERS:
                    return True
                if d is not None and d.startswith("self.") and \
                        n.func.attr in self.jit_attrs:  # type: ignore
                    return True
                if d in self.jit_funcs:
                    return True
                if d in _EXPLICIT or d in _ASARRAY:
                    return False  # result is host-side
        return False

    def _names_in(self, target: ast.AST) -> List[str]:
        """Binding names of an assignment target: plain names and
        tuple/list elements — NOT the base or index of a subscript /
        attribute store (``keys[s] = v`` binds neither ``keys`` nor
        ``s``; ``self.caches = v`` binds nothing local)."""
        if isinstance(target, ast.Name):
            return [target.id]
        if isinstance(target, (ast.Tuple, ast.List)):
            out = []
            for el in target.elts:
                out.extend(self._names_in(el))
            return out
        if isinstance(target, ast.Starred):
            return self._names_in(target.value)
        return []

    def run(self) -> List[Tuple[int, str]]:
        stmts = sorted(own_statements(self.fi.node),
                       key=lambda n: getattr(n, "lineno", 0))
        seen: Set[int] = set()

        def site(line, msg):
            if line not in seen:
                seen.add(line)
                self.sites.append((line, msg))

        # Two lexical passes (loop-carried taint); implicit-transfer sites
        # are recorded on the final pass, BEFORE the assignment untaints
        # its target — so ``tok = np.asarray(tok)`` flags the cast and
        # then treats tok as host-side downstream.
        for final in (False, True):
            self.tainted = set(self.tainted) if not final else self.tainted
            if final:
                for node in stmts:
                    if not isinstance(node, ast.Call):
                        continue
                    d = dotted(node.func)
                    if d in _EXPLICIT:
                        site(node.lineno, "explicit sync: %s" % d)
                    elif d is not None and d.endswith(".item") \
                            and not node.args:
                        site(node.lineno, "explicit sync: .item()")
            for node in stmts:
                if final and isinstance(node, ast.Call):
                    d = dotted(node.func)
                    if (d in _ASARRAY or d in _IMPLICIT_CASTS) \
                            and node.args \
                            and self.is_tainted_expr(node.args[0]):
                        site(node.lineno,
                             "implicit transfer: %s on a traced value" % d)
                if isinstance(node, (ast.Assign, ast.AugAssign,
                                     ast.AnnAssign)) and \
                        getattr(node, "value", None) is not None:
                    targets = node.targets if isinstance(node, ast.Assign) \
                        else [node.target]
                    val = node.value
                    if final:
                        # check embedded casts BEFORE the untaint below
                        for c in ast.walk(val):
                            if isinstance(c, ast.Call) and \
                                    dotted(c.func) in \
                                    _ASARRAY | _IMPLICIT_CASTS \
                                    and c.args and \
                                    self.is_tainted_expr(c.args[0]):
                                site(c.lineno,
                                     "implicit transfer: %s on a traced "
                                     "value" % dotted(c.func))
                    host = isinstance(val, ast.Call) and \
                        dotted(val.func) in set(_EXPLICIT) | _ASARRAY
                    if host:
                        for t in targets:
                            for nm in self._names_in(t):
                                self.tainted.discard(nm)
                    elif self.is_tainted_expr(val):
                        for t in targets:
                            self.tainted.update(self._names_in(t))
        return self.sites


class HostSyncChecker:
    name = "host-sync"

    def run(self, modules: List[Module]) -> List[Finding]:
        graph = build_callgraph(modules)
        roots = []
        for suffix, qual in HOT_ENTRY_POINTS:
            for ref, fi in graph.funcs.items():
                if fi.module.path.endswith(suffix) and fi.qualname == qual:
                    roots.append(ref)
        depth = graph.bfs_depth(roots)
        jit_funcs = _jitted_module_funcs(modules)

        findings: List[Finding] = []
        for mod in modules:
            jit_attrs = _jit_handle_attrs(mod)
            for fi in iter_functions(mod):
                attrs = jit_attrs.get(fi.cls or "", set())
                for line, msg in _FnScan(fi, attrs, jit_funcs).run():
                    d = depth.get(fi.ref)
                    tier, sev = ("hot", "error") if d == 0 else \
                        ("warm", "warning") if d is not None and d <= 2 \
                        else ("cold", "info")
                    where = "depth %s from step loop" % d \
                        if d is not None else "not on a decode path"
                    findings.append(Finding(
                        self.name, mod.path, line,
                        "%s in %s [%s: %s]" % (msg, fi.qualname, tier,
                                               where),
                        severity=sev))
        return findings
