"""lock-discipline: cross-thread attribute access outside the owning lock.

For each class in a THREADED_MODULES module:

  * thread roots = every ``Thread(target=...)`` function the class spawns,
    plus its public API. A class that owns a lock is a *concurrent class*
    — each public method is its own root (two public methods racing on
    the same attribute is exactly the PR-4 torn-read shape). A lockless
    class keeps its public API as one collective root (callers are
    assumed externally serialized) but still races it against any thread
    it spawns.
  * a *shared* attribute is written at least once outside ``__init__``
    and accessed (read or write) from >= 2 distinct roots.
  * every access to a shared attribute must be inside ``with self.<lock>``
    or in a function inferred lock-held: name ends in ``_locked``, or
    every intra-class call site is itself lock-held (fixed point) — the
    documented atomic-snapshot pattern (`VersionedParamStore`) passes
    because all its accesses sit under the condition variable.

One finding per (function, attribute), at the first unlocked access.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.callgraph import dotted, iter_functions, own_statements
from repro.analysis.framework import Finding, Module
from repro.analysis.repo_config import THREADED_MODULES, module_matches

_LOCK_CTORS = {"threading.Lock", "threading.RLock", "threading.Condition",
               "threading.Semaphore", "threading.BoundedSemaphore"}
# Methods that mutate their receiver: self.X.append(...) is a write to X.
# queue.Queue.put/get are internally synchronized, so NOT here.
_MUTATORS = {"append", "extend", "pop", "popleft", "appendleft", "add",
             "update", "clear", "remove", "discard", "insert",
             "setdefault", "sort"}


@dataclass
class _Access:
    attr: str
    line: int
    write: bool
    func: str          # qualname within the class
    held: bool


@dataclass
class _ClassInfo:
    name: str
    lock_attrs: Set[str] = field(default_factory=set)
    funcs: Dict[str, ast.FunctionDef] = field(default_factory=dict)
    thread_targets: Set[str] = field(default_factory=set)  # func qualnames
    accesses: List[_Access] = field(default_factory=list)
    call_sites: Dict[str, List[Tuple[str, bool]]] = \
        field(default_factory=dict)   # callee -> [(caller, site_held)]


def _held_ranges(fn: ast.FunctionDef, lock_attrs: Set[str]
                 ) -> List[Tuple[int, int]]:
    spans = []
    for node in own_statements(fn):
        if isinstance(node, ast.With):
            for item in node.items:
                d = dotted(item.context_expr)
                if d and d.startswith("self.") and \
                        d.split(".", 1)[1] in lock_attrs:
                    end = max((getattr(n, "end_lineno", 0) or 0
                               for n in ast.walk(node)), default=node.lineno)
                    spans.append((node.lineno, end))
    return spans


def _in_spans(line: int, spans: List[Tuple[int, int]]) -> bool:
    return any(a <= line <= b for a, b in spans)


def _collect_class(mod: Module, cls_name: str,
                   funcs: List) -> _ClassInfo:
    info = _ClassInfo(name=cls_name)
    for fi in funcs:
        info.funcs[fi.qualname] = fi.node

    # lock attributes (assigned anywhere, conventionally __init__)
    for fi in funcs:
        for node in own_statements(fi.node):
            if isinstance(node, ast.Assign) and \
                    isinstance(node.value, ast.Call) and \
                    dotted(node.value.func) in _LOCK_CTORS:
                for tgt in node.targets:
                    if isinstance(tgt, ast.Attribute) and \
                            dotted(tgt.value) == "self":
                        info.lock_attrs.add(tgt.attr)

    local_names = {fi.node.name: fi.qualname for fi in funcs}

    for fi in funcs:
        spans = _held_ranges(fi.node, info.lock_attrs)
        for node in own_statements(fi.node):
            # Thread(target=...) roots
            if isinstance(node, ast.Call):
                d = dotted(node.func) or ""
                if d.split(".")[-1] == "Thread":
                    for kw in node.keywords:
                        if kw.arg != "target":
                            continue
                        t = dotted(kw.value)
                        if t and t.startswith("self."):
                            q = "%s.%s" % (cls_name, t.split(".", 1)[1])
                            if q in info.funcs:
                                info.thread_targets.add(q)
                        elif t in local_names:
                            info.thread_targets.add(local_names[t])
            # intra-class call sites (calls AND bound references)
            held_here = None
            name: Optional[str] = None
            if isinstance(node, ast.Call) and \
                    isinstance(node.func, ast.Attribute) and \
                    dotted(node.func.value) == "self":
                name = node.func.attr
            elif isinstance(node, ast.Attribute) and \
                    dotted(node) and dotted(node).startswith("self.") and \
                    dotted(node).count(".") == 1:
                name = node.attr
            elif isinstance(node, ast.Call) and \
                    isinstance(node.func, ast.Name) and \
                    node.func.id in local_names:
                name = node.func.id
            if name is not None:
                q = local_names.get(name) or "%s.%s" % (cls_name, name)
                if q in info.funcs and q != fi.qualname:
                    held_here = _in_spans(node.lineno, spans)
                    info.call_sites.setdefault(q, []).append(
                        (fi.qualname, held_here))
            # attribute accesses on self
            if isinstance(node, ast.Attribute) and \
                    dotted(node.value) == "self":
                attr, line = node.attr, node.lineno
                if attr in info.lock_attrs or \
                        "%s.%s" % (cls_name, attr) in info.funcs:
                    continue  # the lock itself / method references
                write = isinstance(node.ctx, (ast.Store, ast.Del))
                info.accesses.append(_Access(
                    attr=attr, line=line, write=write, func=fi.qualname,
                    held=_in_spans(line, spans)))
            # subscript store: self.X[...] = ...  /  mutator calls
            if isinstance(node, ast.Subscript) and \
                    isinstance(node.ctx, (ast.Store, ast.Del)) and \
                    isinstance(node.value, ast.Attribute) and \
                    dotted(node.value.value) == "self":
                info.accesses.append(_Access(
                    attr=node.value.attr, line=node.lineno, write=True,
                    func=fi.qualname,
                    held=_in_spans(node.lineno, spans)))
            if isinstance(node, ast.Call) and \
                    isinstance(node.func, ast.Attribute) and \
                    node.func.attr in _MUTATORS and \
                    isinstance(node.func.value, ast.Attribute) and \
                    dotted(node.func.value.value) == "self":
                info.accesses.append(_Access(
                    attr=node.func.value.attr, line=node.lineno,
                    write=True, func=fi.qualname,
                    held=_in_spans(node.lineno, spans)))
    return info


def _whole_held(info: _ClassInfo) -> Set[str]:
    """Functions executed with the lock held at every call site."""
    held = {q for q in info.funcs if q.split(".")[-1].endswith("_locked")}
    changed = True
    while changed:
        changed = False
        for q in info.funcs:
            if q in held:
                continue
            sites = info.call_sites.get(q, [])
            if sites and all(h or caller in held for caller, h in sites):
                held.add(q)
                changed = True
    return held


def _reachable(info: _ClassInfo, root: str) -> Set[str]:
    seen = {root}
    frontier = [root]
    while frontier:
        q = frontier.pop()
        for callee, sites in info.call_sites.items():
            if callee not in seen and any(c == q for c, _ in sites):
                seen.add(callee)
                frontier.append(callee)
    return seen


class LockDisciplineChecker:
    name = "lock-discipline"

    def run(self, modules: List[Module]) -> List[Finding]:
        findings: List[Finding] = []
        for mod in modules:
            if not module_matches(mod.path, THREADED_MODULES):
                continue
            by_cls: Dict[str, List] = {}
            for fi in iter_functions(mod):
                if fi.cls is not None:
                    by_cls.setdefault(fi.cls, []).append(fi)
            for cls_name, funcs in by_cls.items():
                findings.extend(self._check_class(mod, cls_name, funcs))
        return findings

    def _check_class(self, mod, cls_name, funcs) -> List[Finding]:
        info = _collect_class(mod, cls_name, funcs)
        init = "%s.__init__" % cls_name

        roots: Dict[str, Set[str]] = {}   # root id -> reachable funcs
        public = [q for q in info.funcs
                  if not q.split(".")[-1].startswith("_")
                  and "." not in q[len(cls_name) + 1:]]
        if info.lock_attrs:
            for q in public:
                roots[q] = _reachable(info, q)
        elif public:
            api: Set[str] = set()
            for q in public:
                api |= _reachable(info, q)
            roots["public-api"] = api
        for q in sorted(info.thread_targets):
            roots["thread:" + q] = _reachable(info, q)
        if len(roots) < 2:
            return []

        whole = _whole_held(info)
        post_init = [a for a in info.accesses if a.func != init
                     and not a.func.startswith(init + ".")]

        # shared = written post-init somewhere, touched from >= 2 roots
        findings: List[Finding] = []
        attrs = {a.attr for a in post_init if a.write}
        for attr in sorted(attrs):
            acc = [a for a in post_init if a.attr == attr]
            owners = {rid for rid, reach in roots.items()
                      if any(a.func in reach for a in acc)}
            if len(owners) < 2:
                continue
            flagged: Set[str] = set()
            for a in sorted(acc, key=lambda a: a.line):
                if a.held or a.func in whole or a.func in flagged:
                    continue
                flagged.add(a.func)
                how = "written" if a.write else "read"
                lock = "with self.%s" % sorted(info.lock_attrs)[0] \
                    if info.lock_attrs else "a lock (class owns none)"
                findings.append(Finding(
                    self.name, mod.path, a.line,
                    "%s.%s %s in %s without holding %s; it is shared "
                    "across thread roots [%s]"
                    % (cls_name, attr, how, a.func, lock,
                       ", ".join(sorted(owners)))))
        return findings
