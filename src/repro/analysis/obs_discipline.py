"""obs-discipline: tracing-plane hygiene (DESIGN.md §Observability).

Two rules over the ``otrace`` emission surface (calls whose dotted base
is in OBS_TRACE_BASES — the ``from repro.obs import trace as otrace``
convention, so unrelated ``.begin()`` methods never match):

1. **begin/end balance.** ``otrace.begin("name", ...)`` opens an async
   span that some ``otrace.end("name", ...)`` must close — possibly in a
   different function or thread, so the pairing is checked repo-wide by
   span NAME, not lexically. A name that only ever begins (or only ever
   ends) renders as an unterminated track in Perfetto and usually means
   a lifecycle event was dropped in a refactor. Dynamic (non-literal)
   names defeat the check and are flagged as warnings.

2. **no span around a hot-tier host sync.** ``with otrace.span(...)``
   costs one context-manager entry/exit per use — fine anywhere — but a
   span WRAPPING a device->host sync inside a depth-0 function (the
   per-token entry points of HOT_ENTRY_POINTS) marks exactly the
   anti-pattern the tracer was designed to avoid: timing the hot path by
   fencing it. Sync sites come from the host-sync checker's own taint
   scan (_FnScan), so the two checkers can never disagree about what a
   sync is; the rule fires whether or not the sync itself carries an
   allow(host-sync) pragma — a deliberate sync still must not acquire a
   span barrier around it on the hot tier. Depth >= 1 (drain/boundary
   functions) stays legal: that is where retro-recorded spans belong.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Tuple

from repro.analysis.callgraph import build_callgraph, dotted, iter_functions
from repro.analysis.framework import Finding, Module
from repro.analysis.host_sync import (_FnScan, _jit_handle_attrs,
                                      _jitted_module_funcs)
from repro.analysis.repo_config import HOT_ENTRY_POINTS, OBS_TRACE_BASES

_OPENERS = {"begin"}
_CLOSERS = {"end"}


def _otrace_call(node: ast.Call) -> Optional[str]:
    """The method name ('begin'/'span'/...) when this is an emission call
    on a recognised tracer base, else None."""
    f = node.func
    if not isinstance(f, ast.Attribute):
        return None
    base = dotted(f.value)
    if base in OBS_TRACE_BASES:
        return f.attr
    return None


def _literal_name(node: ast.Call) -> Optional[str]:
    if node.args and isinstance(node.args[0], ast.Constant) \
            and isinstance(node.args[0].value, str):
        return node.args[0].value
    return None


class ObsDisciplineChecker:
    name = "obs-discipline"

    def run(self, modules: List[Module]) -> List[Finding]:
        findings: List[Finding] = []

        # --- rule 1: repo-wide begin/end balance by span name ----------
        begins: Dict[str, Tuple[str, int]] = {}   # name -> first site
        ends: Dict[str, Tuple[str, int]] = {}
        for mod in modules:
            for node in ast.walk(mod.tree):
                if not isinstance(node, ast.Call):
                    continue
                meth = _otrace_call(node)
                if meth not in _OPENERS | _CLOSERS:
                    continue
                nm = _literal_name(node)
                if nm is None:
                    findings.append(Finding(
                        self.name, mod.path, node.lineno,
                        "dynamic span name in otrace.%s(...) — the "
                        "begin/end balance check needs a string literal"
                        % meth, severity="warning"))
                    continue
                table = begins if meth in _OPENERS else ends
                table.setdefault(nm, (mod.path, node.lineno))
        for nm, (path, line) in sorted(begins.items()):
            if nm not in ends:
                findings.append(Finding(
                    self.name, path, line,
                    "otrace.begin(%r) has no matching otrace.end(%r) "
                    "anywhere — the async span never closes" % (nm, nm)))
        for nm, (path, line) in sorted(ends.items()):
            if nm not in begins:
                findings.append(Finding(
                    self.name, path, line,
                    "otrace.end(%r) has no matching otrace.begin(%r) "
                    "anywhere — the close is dead or the open was "
                    "dropped" % (nm, nm)))

        # --- rule 2: span wrapping a host sync on the hot tier ---------
        graph = build_callgraph(modules)
        roots = []
        for suffix, qual in HOT_ENTRY_POINTS:
            for ref, fi in graph.funcs.items():
                if fi.module.path.endswith(suffix) and fi.qualname == qual:
                    roots.append(ref)
        depth = graph.bfs_depth(roots)
        jit_funcs = _jitted_module_funcs(modules)

        for mod in modules:
            jit_attrs = _jit_handle_attrs(mod)
            for fi in iter_functions(mod):
                if depth.get(fi.ref) != 0:
                    continue
                spans = []   # (With node, span-call line)
                for node in ast.walk(fi.node):
                    if not isinstance(node, ast.With):
                        continue
                    for item in node.items:
                        c = item.context_expr
                        if isinstance(c, ast.Call) \
                                and _otrace_call(c) == "span":
                            spans.append((node, c.lineno))
                if not spans:
                    continue
                attrs = jit_attrs.get(fi.cls or "", set())
                sites = _FnScan(fi, attrs, jit_funcs).run()
                for wnode, sline in spans:
                    lo = wnode.lineno
                    hi = getattr(wnode, "end_lineno", wnode.lineno) or lo
                    for line, msg in sites:
                        if lo <= line <= hi:
                            findings.append(Finding(
                                self.name, mod.path, sline,
                                "otrace.span in hot-tier %s wraps a host "
                                "sync at line %d (%s) — use "
                                "otrace.complete() with existing "
                                "stopwatch reads instead of fencing the "
                                "dispatch stream" % (fi.qualname, line,
                                                     msg)))
        return findings
