"""Suppression pragmas (DESIGN.md §Static-analysis).

Grammar, one pragma per comment::

    # repro: allow(<checker>): <justification>

Placement decides scope:

  * on the flagged line, or on the line directly above it -> suppresses
    findings of that checker on that line only;
  * on a ``def`` line -> suppresses that checker for the whole function
    body (decorators excluded);
  * on a ``class`` line -> the whole class body.

A bare ``allow`` with no justification, an unknown checker name, and a
pragma that suppresses nothing are themselves findings (checker
``pragma``) — suppressions must stay justified and live.
"""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

# One comment may carry several pragmas (rare; keeps multi-checker
# suppressions on one line — each "repro: allow(<name>): <why>" clause is
# matched separately).
_PRAGMA_RE = re.compile(
    r"#\s*repro:\s*allow\(\s*([A-Za-z0-9_-]*)\s*\)\s*(?::\s*(.*?))?\s*"
    r"(?=(?:repro:\s*allow\()|$)")


@dataclass
class Pragma:
    checker: str                 # checker name inside allow(...)
    justification: Optional[str]  # None or "" for a bare allow
    line: int                    # 1-based line the comment sits on
    span: Tuple[int, int]        # inclusive line range it suppresses
    used: bool = field(default=False, compare=False)


def _scope_spans(tree: ast.AST) -> dict:
    """Map header line -> body end line for every def/class, so a pragma
    on a ``def``/``class`` line can cover the whole body."""
    spans = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            # node.lineno is the def/class keyword line (decorators have
            # their own linenos), which is where the pragma comment lives.
            spans[node.lineno] = node.end_lineno or node.lineno
    return spans


def parse_pragmas(lines: List[str], tree: ast.AST) -> List[Pragma]:
    spans = _scope_spans(tree)
    out: List[Pragma] = []
    for i, text in enumerate(lines, start=1):
        if "repro:" not in text:
            continue
        for m in _PRAGMA_RE.finditer(text):
            checker = m.group(1)
            just = m.group(2)
            if just is not None:
                just = just.strip() or None
            end = spans.get(i)
            if end is not None:
                # scope pragma: the whole def/class body
                span = (i, end)
            else:
                # line pragma: its own line, the rest of a comment block
                # it opens, and the first code line after it
                j = i + 1
                while j <= len(lines) and \
                        lines[j - 1].lstrip().startswith("#"):
                    j += 1
                span = (i, j)
            out.append(Pragma(checker=checker, justification=just,
                              line=i, span=span))
    return out


def match_pragma(pragmas: List[Pragma], checker: str,
                 line: int) -> Optional[Pragma]:
    """Innermost (narrowest-span) matching pragma, or None."""
    best = None
    for p in pragmas:
        if p.checker == checker and p.span[0] <= line <= p.span[1]:
            if best is None or (p.span[1] - p.span[0]) < \
                    (best.span[1] - best.span[0]):
                best = p
    return best
