"""refcount-pairing: PageAllocator / RadixCache reference symmetry.

Two rules, over REFCOUNT_MODULES (the paged pool and the radix cache):

R1 *acquire must be handed off*. A call to ``*.alloc(...)`` /
``*.retain(...)`` acquires references. An ``alloc`` whose result is
discarded, or whose result never reaches persistent state (attribute /
subscript store, a mutator push into an attribute-rooted container,
being passed to a callee, released, or returned) leaks its pages. A
``retain`` on a *local* list is held to the same handoff bar; retaining
an already-persistent container (``g.prompt_pages``) is inherently
paired. A bare ``return``/``raise`` between the acquire and its first
handoff is a leak-on-early-exit (the rollback paths must release first).

R2 *drop must release*. Removing entries from a page-tracking container
(``.pop()/.popleft()/.clear()/del`` on PAGE_CONTAINERS attributes, or
``<node>.page = None``) in a function that never calls
``release``/``free``/``evict`` silently drops references — the page can
never be freed (or was freed elsewhere with no local evidence; either
way the site needs a pragma explaining the protocol).
"""
from __future__ import annotations

import ast
from typing import List, Optional, Set

from repro.analysis.callgraph import dotted, iter_functions, own_statements
from repro.analysis.framework import Finding, Module
from repro.analysis.repo_config import (ACQUIRE_METHODS, PAGE_CONTAINERS,
                                        REFCOUNT_MODULES, RELEASE_METHODS,
                                        module_matches)


def _acquire_kind(node: ast.Call) -> Optional[str]:
    if isinstance(node.func, ast.Attribute) and \
            node.func.attr in ACQUIRE_METHODS:
        return node.func.attr
    return None


def _names_in(node: ast.AST) -> Set[str]:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


def _root_name(node: ast.AST) -> Optional[ast.AST]:
    """Innermost base of an attribute/subscript chain."""
    while isinstance(node, (ast.Attribute, ast.Subscript)):
        node = node.value
    return node


class RefcountChecker:
    name = "refcount-pairing"

    def run(self, modules: List[Module]) -> List[Finding]:
        findings: List[Finding] = []
        for mod in modules:
            if not module_matches(mod.path, REFCOUNT_MODULES):
                continue
            for fi in iter_functions(mod):
                # the allocator's own implementation manages the freelist
                # directly; pairing applies to its *clients*
                if fi.cls == "PageAllocator":
                    continue
                findings.extend(self._check_fn(mod, fi))
        return findings

    def _check_fn(self, mod: Module, fi) -> List[Finding]:
        stmts = sorted(own_statements(fi.node),
                       key=lambda n: getattr(n, "lineno", 0))
        findings: List[Finding] = []

        has_release = any(
            isinstance(n, ast.Call) and isinstance(n.func, ast.Attribute)
            and n.func.attr in RELEASE_METHODS for n in stmts)

        # ---- R1: acquires ------------------------------------------------
        # aliases: name -> the acquire name it derives from
        tracked: dict = {}   # local name -> acquire line
        for node in stmts:
            if isinstance(node, ast.Assign) and \
                    isinstance(node.value, ast.Call) and \
                    _acquire_kind(node.value) == "alloc":
                names = [t.id for t in node.targets
                         if isinstance(t, ast.Name)]
                if not names:
                    # allocated straight into persistent state: handoff
                    continue
                for nm in names:
                    tracked[nm] = node.lineno
            elif isinstance(node, ast.Expr) and \
                    isinstance(node.value, ast.Call):
                kind = _acquire_kind(node.value)
                if kind == "alloc":
                    findings.append(Finding(
                        self.name, mod.path, node.lineno,
                        "alloc() result discarded in %s — pages leak"
                        % fi.qualname))
                elif kind == "retain" and node.value.args:
                    root = _root_name(node.value.args[0])
                    if isinstance(root, ast.Name) and \
                            root.id in self._unhandled_locals(fi, stmts):
                        findings.append(Finding(
                            self.name, mod.path, node.lineno,
                            "retain() on local %r in %s with no handoff "
                            "to persistent state — reference can never "
                            "be released" % (root.id, fi.qualname)))

        for nm, line in tracked.items():
            handoff = self._first_handoff(stmts, nm)
            if handoff is None:
                findings.append(Finding(
                    self.name, mod.path, line,
                    "pages from alloc() into %r never handed off or "
                    "released in %s" % (nm, fi.qualname)))
                continue
            # early exit between acquire and handoff leaks the pages
            for node in stmts:
                if isinstance(node, (ast.Return, ast.Raise)) and \
                        line < node.lineno < handoff and \
                        nm not in _names_in(node):
                    findings.append(Finding(
                        self.name, mod.path, node.lineno,
                        "early %s between alloc() of %r (line %d) and "
                        "its handoff (line %d) in %s — release on this "
                        "path first"
                        % (type(node).__name__.lower(), nm, line,
                           handoff, fi.qualname)))
                    break

        # ---- R2: drops ---------------------------------------------------
        for node in stmts:
            drop = None
            if isinstance(node, ast.Call) and \
                    isinstance(node.func, ast.Attribute) and \
                    node.func.attr in ("pop", "popleft", "clear") and \
                    isinstance(node.func.value, ast.Attribute) and \
                    node.func.value.attr in PAGE_CONTAINERS:
                drop = "%s.%s()" % (node.func.value.attr, node.func.attr)
            elif isinstance(node, (ast.Assign,)) and \
                    isinstance(node.value, ast.Constant) and \
                    node.value.value is None:
                for t in node.targets:
                    if isinstance(t, ast.Attribute) and t.attr == "page":
                        drop = "%s.page = None" % (dotted(t.value) or "?")
            elif isinstance(node, ast.Delete):
                for t in node.targets:
                    base = t.value if isinstance(t, ast.Subscript) else t
                    if isinstance(base, ast.Attribute) and \
                            base.attr in PAGE_CONTAINERS:
                        drop = "del on %s" % base.attr
            if drop and not has_release:
                findings.append(Finding(
                    self.name, mod.path, node.lineno,
                    "%s in %s which never calls release()/free() — "
                    "dropped page references" % (drop, fi.qualname)))
        return findings

    # -- helpers -----------------------------------------------------------

    def _first_handoff(self, stmts, nm: str) -> Optional[int]:
        """Line of the first statement that persists or releases nm."""
        best = None

        def note(line):
            nonlocal best
            if best is None or line < best:
                best = line

        for node in stmts:
            if isinstance(node, ast.Assign):
                stores_persistent = any(
                    isinstance(t, (ast.Attribute, ast.Subscript))
                    for t in node.targets)
                if stores_persistent and nm in _names_in(node.value):
                    note(node.lineno)
                # alias: track via plain rename too (pid = new[0])
                if not stores_persistent and nm in _names_in(node.value) \
                        and any(isinstance(t, ast.Name)
                                for t in node.targets):
                    # treat the alias as the same obligation by scanning
                    # for ITS handoff transitively (one level is enough
                    # for this codebase)
                    for t in node.targets:
                        if isinstance(t, ast.Name):
                            sub = self._first_handoff(
                                [s for s in stmts
                                 if getattr(s, "lineno", 0)
                                 > node.lineno], t.id)
                            if sub is not None:
                                note(sub)
            if isinstance(node, ast.Call):
                attr = node.func.attr \
                    if isinstance(node.func, ast.Attribute) else None
                if attr in ACQUIRE_METHODS:
                    pass  # acquiring is not a handoff of its own argument
                elif attr in RELEASE_METHODS | {"extend", "append"} and \
                        any(nm in _names_in(a) for a in node.args):
                    note(node.lineno)
                elif isinstance(node.func, (ast.Name, ast.Attribute)) and \
                        any(nm in _names_in(a) for a in
                            list(node.args) +
                            [k.value for k in node.keywords]):
                    # passed to a callee: ownership transferred
                    note(node.lineno)
            if isinstance(node, ast.Return) and node.value is not None \
                    and nm in _names_in(node.value):
                note(node.lineno)
        return best

    def _unhandled_locals(self, fi, stmts) -> Set[str]:
        """Local names with no persistent handoff anywhere in fi."""
        out = set()
        params = {a.arg for a in fi.node.args.args}
        assigned = set()
        for node in stmts:
            if isinstance(node, ast.Assign):
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        assigned.add(t.id)
        for nm in assigned | params:
            if nm == "self":
                continue
            if self._first_handoff(stmts, nm) is None:
                out.add(nm)
        return out
