"""Checker registry: the six repo-specific checkers plus the implicit
``pragma``/``parse`` meta-checkers emitted by the harness."""
from repro.analysis.host_sync import HostSyncChecker
from repro.analysis.lock_discipline import LockDisciplineChecker
from repro.analysis.obs_discipline import ObsDisciplineChecker
from repro.analysis.refcount import RefcountChecker
from repro.analysis.support_matrix import SupportMatrixChecker
from repro.analysis.trace_purity import TracePurityChecker

ALL_CHECKERS = [
    HostSyncChecker(),
    LockDisciplineChecker(),
    RefcountChecker(),
    TracePurityChecker(),
    SupportMatrixChecker(),
    ObsDisciplineChecker(),
]

# names valid inside allow(...) — meta-checkers aren't suppressible but
# "pragma" is listed so an allow(pragma) is reported as unused, not
# unknown
CHECKER_NAMES = [c.name for c in ALL_CHECKERS] + ["pragma"]
