"""Repo-specific knowledge the checkers are parameterized on.

Kept in one place so the checkers themselves stay generic AST passes;
paths are matched by suffix against Module.path so the CLI works from
the repo root (``src/repro/core/paged.py``) and in tests (fixtures use
the bare suffix).
"""

# --- host-sync ------------------------------------------------------------

# Per-token decode/serve loops: call depth from these tiers the severity
# (0 = hot -> error, 1-2 = warm -> warning, deeper/unreachable = cold ->
# info). Format: (module path suffix, qualname).
HOT_ENTRY_POINTS = [
    ("core/paged.py", "PagedGroupEngine.step"),
    ("core/paged.py", "PagedGroupEngine._spec_step"),
    ("core/paged.py", "PagedGroupEngine.serve"),
    ("core/cbatch.py", "ContinuousBatchingSampler.run"),
    ("core/engine.py", "InferenceInstance.generate_group"),
    ("launch/serve.py", "RequestDriver.run"),
    ("launch/serve.py", "serve_batch"),
    ("launch/serve.py", "serve_paged"),
    ("launch/serve.py", "serve_shared"),
    ("spec/sampler.py", "SpecSampler.generate"),
]

# Attribute names that carry device arrays in this codebase (RolloutBatch
# fields, forward outputs): reading them taints the value for the
# implicit-transfer rules (np.asarray/int/float on traced values).
DEVICE_ATTRS = {
    "response_ids", "response_len", "response_logprobs",
    "logits", "prompt_logits", "caches",
}

# --- lock-discipline ------------------------------------------------------

# Modules with real cross-thread traffic (ISSUE 7). Classes here get
# per-public-method thread roots when they own a lock ("concurrent
# class"), plus one root per Thread(target=...) they spawn.
THREADED_MODULES = [
    "transfer/service.py",
    "core/engine.py",
    "core/queue.py",
    "core/generator.py",
    "core/paged.py",
    "obs/server.py",
]

# --- refcount-pairing -----------------------------------------------------

REFCOUNT_MODULES = ["core/paged.py", "core/radix.py"]
# Containers that track live page ids: removal without a release in the
# same function is a drop-without-release finding.
PAGE_CONTAINERS = {"pages", "live", "prompt_pages"}
ACQUIRE_METHODS = {"alloc", "retain"}
RELEASE_METHODS = {"release", "free", "evict"}

# --- support-matrix -------------------------------------------------------

SUPPORT_CONFIG_MODULE = "configs/base.py"
# ModelConfig fields that gate engine capability; a hand-rolled
# assert/raise on these outside configs/ must agree with the matrix.
CAPABILITY_FIELDS = {
    "family", "is_encoder_decoder", "vision_prefix_len", "hybrid",
    "attention_free",
}

# --- obs-discipline -------------------------------------------------------

# Dotted bases a tracer-emission call is recognised under. The repo
# convention (src/repro/obs/__init__.py) is
# ``from repro.obs import trace as otrace`` — keying on the alias keeps
# unrelated ``.begin()`` methods (VersionedParamStore.begin etc.) out of
# the balance check.
OBS_TRACE_BASES = {"otrace", "repro.obs.trace"}

# --- shared ---------------------------------------------------------------

# Paths never analyzed (generated reports, the analysis package's own
# fixture strings live in tests/).
EXCLUDE_SUFFIXES = []


def module_matches(path: str, suffixes) -> bool:
    return any(path.endswith(s) for s in suffixes)
