"""support-matrix: configs/base.py engine_support vs the actual guards.

The engine x family exclusion list is supposed to live in exactly one
place (``engine_support``), consulted via ``require_engine_support`` at
every engine construction site. Drift shows up three ways:

S1  a *restricted* engine/plane (one with a ``return False`` path in its
    support function) that no call site outside configs/ ever enforces —
    the matrix says "unsupported" but nothing would stop you;
S2  an enforcement call with an engine literal the matrix doesn't
    declare (typo'd plane name), or a non-literal engine argument the
    checker can't tie to the matrix;
S3  a hand-rolled capability guard — ``assert``/conditional ``raise`` on
    a capability field (``family``, ``is_encoder_decoder``, ...) outside
    configs/ — re-growing the per-site asserts the matrix replaced.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.callgraph import dotted, iter_functions, own_statements
from repro.analysis.framework import Finding, Module
from repro.analysis.repo_config import (CAPABILITY_FIELDS,
                                        SUPPORT_CONFIG_MODULE)

_ENFORCERS = {"require_engine_support", "engine_support"}


def _declared_engines(mod: Module) -> Dict[str, int]:
    """engine/plane name -> declaration line, from ROLLOUT_ENGINES and
    the *_PLANE constants."""
    out: Dict[str, int] = {}
    for node in mod.tree.body:
        if not isinstance(node, ast.Assign):
            continue
        for tgt in node.targets:
            if not isinstance(tgt, ast.Name):
                continue
            if tgt.id == "ROLLOUT_ENGINES" and \
                    isinstance(node.value, ast.Tuple):
                for el in node.value.elts:
                    if isinstance(el, ast.Constant) and \
                            isinstance(el.value, str):
                        out[el.value] = node.lineno
            elif tgt.id.endswith("_PLANE") and \
                    isinstance(node.value, ast.Constant) and \
                    isinstance(node.value.value, str):
                out[node.value.value] = node.lineno
    return out


def _restricted_engines(mod: Module, declared: Set[str]) -> Set[str]:
    """Engines whose support path can return False. An engine whose
    ``return True`` in engine_support precedes every ``return False``
    (the 'group' shape) is unrestricted; planes with their own
    ``_<x>_support`` function are restricted iff that function has a
    ``return False``."""
    funcs = {fi.name: fi.node for fi in iter_functions(mod)}

    def false_lines(fn):
        return [n.lineno for n in own_statements(fn)
                if isinstance(n, ast.Return)
                and isinstance(n.value, ast.Tuple) and n.value.elts
                and isinstance(n.value.elts[0], ast.Constant)
                and n.value.elts[0].value is False]

    restricted: Set[str] = set()
    main = funcs.get("engine_support")
    if main is not None:
        falses = false_lines(main)
        # anything declared without an early ``return True`` preceding
        # every ``return False`` inherits the fall-through: restricted
        # whenever the function has a False path. Planes with their own
        # ``_<x>_support`` helper are restricted iff the helper has one.
        for nm in declared:
            helper = funcs.get("_%s_support" % nm)
            if helper is not None:
                if false_lines(helper):
                    restricted.add(nm)
            elif nm not in restricted and falses:
                early_true = _early_true_line(main, nm)
                if early_true is None or \
                        any(f < early_true for f in falses):
                    restricted.add(nm)
    return restricted


def _early_true_line(fn, engine: str) -> Optional[int]:
    for node in own_statements(fn):
        if isinstance(node, ast.If) and \
                any(isinstance(n, ast.Constant) and n.value == engine
                    for n in ast.walk(node.test)):
            for s in ast.walk(node):
                if isinstance(s, ast.Return):
                    return s.lineno
    return None


def _raises(body: List[ast.stmt]) -> bool:
    return any(isinstance(n, ast.Raise) for st in body
               for n in ast.walk(st))


class SupportMatrixChecker:
    name = "support-matrix"

    def run(self, modules: List[Module]) -> List[Finding]:
        cfg_mod = next((m for m in modules
                        if m.path.endswith(SUPPORT_CONFIG_MODULE)), None)
        findings: List[Finding] = []
        declared: Dict[str, int] = {}
        restricted: Set[str] = set()
        if cfg_mod is not None:
            declared = _declared_engines(cfg_mod)
            restricted = _restricted_engines(cfg_mod, set(declared))

        enforced: Dict[str, List[Tuple[str, int]]] = {}
        for mod in modules:
            if cfg_mod is not None and mod.path == cfg_mod.path:
                continue
            in_configs = "/configs/" in ("/" + mod.path)
            for fi in iter_functions(mod):
                for node in own_statements(fi.node):
                    # S2: enforcement calls
                    if isinstance(node, ast.Call):
                        d = (dotted(node.func) or "").split(".")[-1]
                        if d in _ENFORCERS and len(node.args) >= 2:
                            arg = node.args[1]
                            if isinstance(arg, ast.Constant) and \
                                    isinstance(arg.value, str):
                                if declared and arg.value not in declared:
                                    findings.append(Finding(
                                        self.name, mod.path, node.lineno,
                                        "%s(..., %r): engine not declared "
                                        "in configs/base.py matrix (%s)"
                                        % (d, arg.value, ", ".join(
                                            sorted(declared)))))
                                else:
                                    enforced.setdefault(
                                        arg.value, []).append(
                                        (mod.path, node.lineno))
                            else:
                                findings.append(Finding(
                                    self.name, mod.path, node.lineno,
                                    "%s() with a non-literal engine "
                                    "argument — the matrix cross-check "
                                    "cannot see this site" % d,
                                    severity="warning"))
                    # S3: hand-rolled capability guards
                    if in_configs:
                        continue
                    guard = None
                    if isinstance(node, ast.Assert):
                        guard = ("assert", node.test, node.lineno)
                    elif isinstance(node, ast.If) and _raises(node.body):
                        guard = ("raise-under-if", node.test, node.lineno)
                    if guard is not None:
                        kind, test, line = guard
                        caps = sorted({n.attr for n in ast.walk(test)
                                       if isinstance(n, ast.Attribute)
                                       and n.attr in CAPABILITY_FIELDS})
                        if caps:
                            findings.append(Finding(
                                self.name, mod.path, line,
                                "hand-rolled capability guard (%s on "
                                ".%s) outside configs/ — route through "
                                "require_engine_support or justify why "
                                "this exclusion is not an engine-matrix "
                                "row" % (kind, ", .".join(caps))))

        # S1: restricted engines nobody enforces
        if cfg_mod is not None:
            for nm in sorted(restricted):
                if not enforced.get(nm):
                    findings.append(Finding(
                        self.name, cfg_mod.path, declared.get(nm, 1),
                        "engine %r has unsupported configs in the matrix "
                        "but no call site outside configs/ enforces it "
                        "(require_engine_support)" % nm))
        return findings
