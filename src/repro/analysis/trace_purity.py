"""trace-purity: impurities inside jit/pallas-traced functions.

Trace roots:
  * ``self._x = jax.jit(self._meth, ...)`` handles (the engines' pattern),
  * ``@jax.jit`` / ``@partial(jax.jit, static_arg...)`` decorated defs,
  * positional callables handed to ``pallas_call`` / ``pl.pallas_call``,
  * ``jax.jit(fn)`` / ``jax.jit(partial(fn, ...))`` value expressions.

Roots and their repo-resolved transitive callees are scanned for:
  * wall-clock / RNG calls (``time.time``, stdlib ``random``,
    ``np.random`` — NOT ``jax.random``) and ``print``: these run once at
    trace time and freeze, silently breaking what they claim to measure;
  * ``global`` declarations with writes;
  * attribute stores on ``self`` or on a parameter (outside
    ``__init__``), and subscript stores whose base IS a parameter —
    trace-time mutation of caller state. Fresh locals (the backends'
    ``new = {}; new[k] = ...`` rebuild idiom) are pure and allowed.

Only *direct* roots are additionally checked for Python ``if``/``while``
branching on a comparison over bare parameters (traced values raise
ConcretizationTypeError at best, silently specialize at worst);
``is``/``is not`` tests and parameters named in ``static_argnames`` /
``static_argnums`` are exempt, as are bare-name truthiness tests
(``if capture:`` — the static-flag pattern).
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.callgraph import (build_callgraph, dotted,
                                      iter_functions, own_statements)
from repro.analysis.framework import Finding, Module

_IMPURE_CALLS = {
    "time.time", "time.perf_counter", "time.monotonic",
    "time.process_time", "datetime.datetime.now", "print",
}
_IMPURE_PREFIXES = ("random.", "np.random.", "numpy.random.")


def _static_params(fn: ast.FunctionDef, jit_call: Optional[ast.Call]
                   ) -> Set[str]:
    """Parameter names declared static in a jax.jit(...) call/decorator."""
    out: Set[str] = set()
    if jit_call is None:
        return out
    params = [a.arg for a in fn.args.args]
    for kw in jit_call.keywords:
        v = kw.value
        if kw.arg == "static_argnames":
            for n in ast.walk(v):
                if isinstance(n, ast.Constant) and isinstance(n.value, str):
                    out.add(n.value)
        elif kw.arg in ("static_argnums", "donate_argnums"):
            if kw.arg != "static_argnums":
                continue
            for n in ast.walk(v):
                if isinstance(n, ast.Constant) and \
                        isinstance(n.value, int) and n.value < len(params):
                    out.add(params[n.value])
    return out


def _find_roots(modules: List[Module]) -> Dict[str, Tuple[str, Optional[ast.Call]]]:
    """func ref -> (how it is traced, the jit Call node if any)."""
    roots: Dict[str, Tuple[str, Optional[ast.Call]]] = {}
    by_name: Dict[str, List[str]] = {}
    by_cls: Dict[Tuple[str, str, str], str] = {}
    infos = {}
    for mod in modules:
        for fi in iter_functions(mod):
            infos[fi.ref] = fi
            by_name.setdefault(fi.name, []).append(fi.ref)
            if fi.cls:
                by_cls[(mod.path, fi.cls, fi.name)] = fi.ref

    def mark(ref, how, call):
        if ref in infos:
            roots.setdefault(ref, (how, call))

    for mod in modules:
        for fi in iter_functions(mod):
            # decorators
            for dec in fi.node.decorator_list:
                call = dec if isinstance(dec, ast.Call) else None
                names = {dotted(n) for n in ast.walk(dec)
                         if isinstance(n, (ast.Attribute, ast.Name))}
                if "jax.jit" in names:
                    jit_call = None
                    if call is not None and dotted(call.func) in \
                            ("partial", "functools.partial", "jax.jit"):
                        jit_call = call
                    mark(fi.ref, "@jax.jit", jit_call)
            # value expressions: jax.jit(<target>) and pallas_call(kernel)
            for node in own_statements(fi.node):
                if not isinstance(node, ast.Call):
                    continue
                d = dotted(node.func)
                if d == "jax.jit" and node.args:
                    for tref in _resolve_targets(node.args[0], fi, mod,
                                                 by_cls, by_name):
                        mark(tref, "jax.jit(...)", node)
                elif d in ("pl.pallas_call", "pallas_call") and node.args:
                    for tref in _resolve_targets(node.args[0], fi, mod,
                                                 by_cls, by_name):
                        mark(tref, "pallas_call", None)
    return roots


def _resolve_targets(arg: ast.AST, fi, mod, by_cls, by_name) -> List[str]:
    """The function(s) an expression like self._m / fn / partial(fn, ..)
    refers to."""
    if isinstance(arg, ast.Call) and \
            dotted(arg.func) in ("partial", "functools.partial") and \
            arg.args:
        arg = arg.args[0]
    d = dotted(arg)
    if d is None:
        return []
    if d.startswith("self.") and fi.cls:
        hit = by_cls.get((mod.path, fi.cls, d.split(".", 1)[1]))
        return [hit] if hit else []
    if "." not in d:
        # prefer same module, else unique global name
        local = [r for r in by_name.get(d, ()) if r.startswith(mod.path)]
        if local:
            return local
        cands = by_name.get(d, [])
        return cands if len(cands) == 1 else cands
    return []


class TracePurityChecker:
    name = "trace-purity"

    def run(self, modules: List[Module]) -> List[Finding]:
        graph = build_callgraph(modules)
        roots = _find_roots(modules)
        # transitive closure over repo-resolved callees
        traced: Dict[str, bool] = {}  # ref -> is_direct_root
        frontier = list(roots)
        for r in roots:
            traced[r] = True
        while frontier:
            ref = frontier.pop()
            for cal in graph.callees(ref):
                if cal not in traced:
                    fi = graph.funcs[cal]
                    # don't cross into obvious host-side helpers: traced
                    # closure stays within functions that look jax-pure
                    traced[cal] = False
                    frontier.append(cal)

        findings: List[Finding] = []
        for ref, direct in traced.items():
            fi = graph.funcs[ref]
            how, jit_call = roots.get(ref, ("transitively traced", None))
            findings.extend(self._check_fn(fi, direct, how, jit_call))
        return findings

    def _check_fn(self, fi, direct: bool, how: str,
                  jit_call) -> List[Finding]:
        mod = fi.module
        fn = fi.node
        out: List[Finding] = []
        in_init = fn.name == "__init__"
        params = {a.arg for a in fn.args.args} - {"self"}
        static = _static_params(fn, jit_call)

        def flag(line, msg, sev="error"):
            out.append(Finding(self.name, mod.path, line,
                               "%s in %s (%s)" % (msg, fi.qualname, how),
                               severity=sev))

        for node in own_statements(fn):
            if isinstance(node, ast.Call):
                d = dotted(node.func)
                if d in _IMPURE_CALLS or (
                        d and d.startswith(_IMPURE_PREFIXES)):
                    flag(node.lineno,
                         "impure call %s() freezes at trace time" % d)
            elif isinstance(node, ast.Global):
                flag(node.lineno, "global declaration (trace-time "
                     "mutation of module state)")
            elif isinstance(node, ast.Assign) and not in_init:
                for t in node.targets:
                    base = t.value if isinstance(
                        t, (ast.Attribute, ast.Subscript)) else None
                    d = dotted(base) if base is not None else None
                    if isinstance(t, ast.Attribute) and \
                            (d == "self" or d in params):
                        flag(t.lineno, "attribute store on %r mutates "
                             "caller state at trace time" % d)
                    elif isinstance(t, ast.Subscript) and d in params \
                            and how != "pallas_call":
                        # pallas kernels WRITE their output Refs by
                        # subscript store — that is the kernel contract,
                        # not an impurity
                        flag(t.lineno, "subscript store into parameter "
                             "%r mutates caller state at trace time" % d)
            elif isinstance(node, ast.AugAssign) and not in_init:
                t = node.target
                base = t.value if isinstance(
                    t, (ast.Attribute, ast.Subscript)) else None
                d = dotted(base) if base is not None else None
                if d == "self" or d in params:
                    flag(t.lineno,
                         "augmented store on %r at trace time" % d)
            elif direct and isinstance(node, (ast.If, ast.While)):
                bad = self._traced_branch(node.test, params - static)
                if bad:
                    flag(node.lineno,
                         "Python branch on traced parameter %r "
                         "(use lax.cond/jnp.where or mark it static)"
                         % bad, sev="warning")
        return out

    @staticmethod
    def _traced_branch(test: ast.AST, dyn_params: Set[str]
                       ) -> Optional[str]:
        """A Compare/BoolOp whose leaf is a bare dynamic parameter."""
        for n in ast.walk(test):
            if isinstance(n, ast.Compare):
                if any(isinstance(op, (ast.Is, ast.IsNot))
                       for op in n.ops):
                    continue
                for leaf in [n.left] + list(n.comparators):
                    if isinstance(leaf, ast.Name) and \
                            leaf.id in dyn_params:
                        return leaf.id
        return None
