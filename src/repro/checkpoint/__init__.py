from repro.checkpoint.ckpt import (load_checkpoint, load_tri,
                                   save_checkpoint, save_tri)

__all__ = ["save_checkpoint", "load_checkpoint", "save_tri", "load_tri"]
