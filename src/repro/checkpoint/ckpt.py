"""Pytree checkpointing: flat .npz shards + a JSON manifest.

Arrays are saved by flattened tree path. bf16 (no native numpy dtype) is
round-tripped via a uint16 view with a dtype tag in the manifest. Sharded
arrays are pulled to host with jax.device_get (fully-addressable meshes);
restore re-places them with the caller's shardings if provided.
"""
from __future__ import annotations

import json
import os
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "::"


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(e, "key", getattr(e, "idx", getattr(e, "name", e))))
                        for e in path)
        flat[key] = leaf
    return flat


def save_checkpoint(path: str, tree, step: Optional[int] = None) -> None:
    os.makedirs(path, exist_ok=True)
    flat = _flatten(tree)
    arrays, dtypes = {}, {}
    for k, v in flat.items():
        a = np.asarray(jax.device_get(v))
        dtypes[k] = str(a.dtype) if a.dtype != jnp.bfloat16 else "bfloat16"
        if a.dtype == jnp.bfloat16:
            a = a.view(np.uint16)
        arrays[k] = a
    np.savez(os.path.join(path, "arrays.npz"), **arrays)
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump({"dtypes": dtypes, "step": step}, f)


def load_checkpoint(path: str, like_tree, shardings=None):
    """Restore into the structure of ``like_tree``. Returns (tree, step)."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    flat_like = _flatten(like_tree)
    flat_shard = _flatten(shardings) if shardings is not None else {}
    out = {}
    for k in flat_like:
        a = data[k]
        if manifest["dtypes"][k] == "bfloat16":
            a = a.view(jnp.bfloat16)
        if k in flat_shard:
            out[k] = jax.device_put(a, flat_shard[k])
        else:
            out[k] = jnp.asarray(a)
    # rebuild the tree in like_tree's structure
    leaves_like, treedef = jax.tree_util.tree_flatten(like_tree)
    keys = list(_flatten(like_tree).keys())
    restored = treedef.unflatten([out[k] for k in keys])
    return restored, manifest.get("step")
