"""Pytree checkpointing: flat .npz shards + a JSON manifest.

Arrays are saved by flattened tree path. bf16 (no native numpy dtype) is
round-tripped via a uint16 view with a dtype tag in the manifest. Sharded
arrays are pulled to host with jax.device_get (fully-addressable meshes);
restore re-places them with the caller's shardings if provided.
"""
from __future__ import annotations

import json
import os
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.transfer.plan import flatten_with_keys


def _flatten(tree) -> dict:
    """Path-keyed flat view — same key scheme as the weight-plane's
    reshard plans (one shared helper, so checkpoint manifest keys and
    transfer leaf keys can never drift apart)."""
    keys, leaves, _ = flatten_with_keys(tree)
    return dict(zip(keys, leaves))


def save_checkpoint(path: str, tree, step: Optional[int] = None) -> None:
    os.makedirs(path, exist_ok=True)
    flat = _flatten(tree)
    arrays, dtypes = {}, {}
    for k, v in flat.items():
        # repro: allow(host-sync): checkpointing serialises params to host
        # storage by definition; never on a decode path
        a = np.asarray(jax.device_get(v))
        dtypes[k] = str(a.dtype) if a.dtype != jnp.bfloat16 else "bfloat16"
        if a.dtype == jnp.bfloat16:
            a = a.view(np.uint16)
        arrays[k] = a
    np.savez(os.path.join(path, "arrays.npz"), **arrays)
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump({"dtypes": dtypes, "step": step}, f)


def save_tri(path: str, tri) -> None:
    """Checkpoint the full tri-model state (policy, old, ref, Adam state)
    with the weight-plane version in the manifest — the version is part of
    the state: a resumed run must republish the SAME version to the pool
    or the on-policy monitor's staleness accounting restarts from zero."""
    save_checkpoint(path, {"policy": tri.policy, "old": tri.old,
                           "ref": tri.ref, "opt": tri.opt},
                    step=tri.version)


def load_tri(path: str, like_tri, shardings=None):
    """Restore a tri-model checkpoint into ``like_tri``'s structure
    (mutates it in place) and return it, version included. ``shardings``
    optionally re-places every leaf (same layout for the four trees)."""
    like = {"policy": like_tri.policy, "old": like_tri.old,
            "ref": like_tri.ref, "opt": like_tri.opt}
    # the three param trees share one layout; fp32 Adam state stays on the
    # trainer's default placement (the weight-plane never ships it)
    shard_tree = None if shardings is None else \
        {"policy": shardings, "old": shardings, "ref": shardings}
    restored, step = load_checkpoint(path, like,
                                     shardings=shard_tree)
    like_tri.policy = restored["policy"]
    like_tri.old = restored["old"]
    like_tri.ref = restored["ref"]
    like_tri.opt = restored["opt"]
    like_tri.version = int(step)
    return like_tri


def load_checkpoint(path: str, like_tree, shardings=None):
    """Restore into the structure of ``like_tree``. Returns (tree, step)."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    flat_like = _flatten(like_tree)
    flat_shard = _flatten(shardings) if shardings is not None else {}
    out = {}
    for k in flat_like:
        a = data[k]
        if manifest["dtypes"][k] == "bfloat16":
            a = a.view(jnp.bfloat16)
        if k in flat_shard:
            out[k] = jax.device_put(a, flat_shard[k])
        else:
            out[k] = jnp.asarray(a)
    # rebuild the tree in like_tree's structure
    leaves_like, treedef = jax.tree_util.tree_flatten(like_tree)
    keys = list(_flatten(like_tree).keys())
    restored = treedef.unflatten([out[k] for k in keys])
    return restored, manifest.get("step")
