"""Architecture registry: ``--arch <id>`` -> ModelConfig.

Module file names are sanitised (dots/dashes -> underscores); the public ids
match the assignment exactly.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import InputShape, ModelConfig, RLConfig
from repro.configs.shapes import SHAPES

from repro.configs.mamba2_2p7b import CONFIG as _mamba2
from repro.configs.hymba_1p5b import CONFIG as _hymba
from repro.configs.internlm2_20b import CONFIG as _internlm2
from repro.configs.deepseek_v2_lite_16b import CONFIG as _dsv2lite
from repro.configs.yi_34b import CONFIG as _yi
from repro.configs.llama3p2_3b import CONFIG as _llama32
from repro.configs.deepseek_coder_33b import CONFIG as _dscoder
from repro.configs.qwen3_moe_235b_a22b import CONFIG as _qwen3moe
from repro.configs.whisper_tiny import CONFIG as _whisper
from repro.configs.internvl2_76b import CONFIG as _internvl

REGISTRY: dict[str, ModelConfig] = {
    c.name: c
    for c in (
        _mamba2, _hymba, _internlm2, _dsv2lite, _yi,
        _llama32, _dscoder, _qwen3moe, _whisper, _internvl,
    )
}

ARCH_IDS = tuple(REGISTRY)


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[name]


def long_context_variant(cfg: ModelConfig, window: int = 8192) -> ModelConfig:
    """Sub-quadratic decode variant for the long_500k shape.

    SSM/hybrid archs already decode in O(1) state; full-attention archs get a
    sliding-window KV cache (DESIGN.md §Arch-applicability).
    """
    if cfg.family in ("ssm", "hybrid") or cfg.sliding_window is not None:
        return cfg
    return dataclasses.replace(cfg, sliding_window=window)


def reduced_config(cfg: ModelConfig) -> ModelConfig:
    """CPU-smoke-testable variant of the same family: 2 layers, d_model<=512,
    <=4 experts — used by per-arch smoke tests only."""
    d = min(cfg.d_model, 256)
    heads = min(cfg.num_heads, 4) if cfg.num_heads else 0
    kv = min(cfg.num_kv_heads, max(1, heads // 2)) if heads else 0
    if heads and heads % max(kv, 1):
        kv = 1
    kw: dict = dict(
        name=cfg.name + "-smoke",
        num_layers=2,
        d_model=d,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=64 if heads else 0,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
        attn_chunk_size=64,
        loss_chunk_size=64,
        param_dtype="float32",
        compute_dtype="float32",
    )
    if cfg.is_moe:
        kw.update(
            num_experts=4,
            num_experts_per_tok=2,
            num_shared_experts=min(cfg.num_shared_experts, 1),
            moe_d_ff=128,
            first_k_dense=min(cfg.first_k_dense, 1),
            dense_d_ff=256 if cfg.first_k_dense else 0,
        )
    if cfg.use_mla:
        kw.update(kv_lora_rank=64, qk_nope_head_dim=32, qk_rope_head_dim=16,
                  v_head_dim=32, head_dim=48)
    if cfg.ssm_state_size:
        kw.update(
            ssm_state_size=min(cfg.ssm_state_size, 16),
            ssm_num_heads=4,
            ssm_head_dim=32,
            ssm_expand=2,
            ssm_chunk_size=16,
        )
        # keep d_inner = expand*d divisible by heads*head_dim: 2*256=512=4*128?
        # 4 heads * 32 head_dim = 128 != 512 -> fix d to make it consistent:
        kw["d_model"] = 64  # d_inner=128 = 4 heads * 32
        kw["head_dim"] = 64 if heads else 0
    if cfg.sliding_window is not None:
        kw["sliding_window"] = 32
    if cfg.is_encoder_decoder:
        kw.update(num_encoder_layers=2, encoder_seq_len=64, max_target_positions=448)
    if cfg.vision_prefix_len:
        kw["vision_prefix_len"] = 8
    return dataclasses.replace(cfg, **kw)


__all__ = [
    "REGISTRY", "ARCH_IDS", "get_config", "reduced_config",
    "long_context_variant", "ModelConfig", "InputShape", "RLConfig", "SHAPES",
]
