"""Model / run configuration for the periodic-asynchrony RL framework.

Every assigned architecture is expressed as a :class:`ModelConfig`. The config
is a plain frozen dataclass (hashable -> usable as a jit static argument).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    # identity ----------------------------------------------------------
    name: str = "unnamed"
    family: str = "dense"  # dense | moe | ssm | hybrid | audio | vlm
    source: str = ""       # citation (arXiv id / model card)

    # core transformer ---------------------------------------------------
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 0          # 0 -> d_model // num_heads
    d_ff: int = 1024
    vocab_size: int = 1024
    rope_theta: float = 1e6
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # attention variants --------------------------------------------------
    sliding_window: Optional[int] = None   # None -> full causal
    use_mla: bool = False                  # DeepSeek-V2 multi-head latent attention
    kv_lora_rank: int = 512
    q_lora_rank: int = 0                   # 0 -> full-rank q projection
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128

    # MoE ------------------------------------------------------------------
    num_experts: int = 0                   # 0 -> dense FFN
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0                      # per-expert hidden size
    first_k_dense: int = 0                 # leading dense layers (DeepSeek-V2)
    dense_d_ff: int = 0                    # d_ff of those dense layers
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.001

    # SSM (Mamba-2 / SSD) --------------------------------------------------
    ssm_state_size: int = 0                # N; 0 -> no ssm path
    ssm_num_heads: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk_size: int = 128
    ssm_num_groups: int = 1

    # hybrid (Hymba): run attention AND ssm in parallel inside each block
    hybrid: bool = False

    # encoder/decoder (Whisper) ---------------------------------------------
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    encoder_seq_len: int = 1500            # precomputed frame embeddings (stub frontend)
    max_target_positions: int = 448

    # VLM (InternVL) ----------------------------------------------------------
    vision_prefix_len: int = 0             # precomputed patch embeddings (stub frontend)

    # numerics -----------------------------------------------------------------
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"

    # use the Pallas block-sparse flash kernel for training/prefill
    # attention instead of the pure-JAX chunked path (production TPU path;
    # on CPU it runs in interpret mode — correct but slow, tests only)
    use_pallas_attention: bool = False
    # activation checkpointing (paper Table 7: gradient checkpointing enabled)
    remat: bool = True
    # attention chunking for the pure-JAX flash path
    attn_chunk_size: int = 512
    # sequence chunk for the fused logp/loss scan
    loss_chunk_size: int = 512

    # ---------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model if self.ssm_state_size else 0

    @property
    def supports_long_decode(self) -> bool:
        """True if decode cost/state is sub-linear in context (SSM state or
        sliding-window KV) -> eligible for the long_500k shape."""
        if self.is_encoder_decoder:
            return False  # whisper decoder context is 448; see DESIGN.md
        return self.family in ("ssm", "hybrid") or self.sliding_window is not None

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head), for
        MODEL_FLOPS = 6 N D book-keeping."""
        d = self.d_model
        n = 0
        n += self.vocab_size * d                      # embedding
        if not self.tie_embeddings:
            n += self.vocab_size * d                  # lm head
        L = self.num_layers

        def attn_params() -> int:
            if self.use_mla:
                p = d * self.kv_lora_rank + d * self.qk_rope_head_dim
                p += self.kv_lora_rank * self.num_heads * (self.qk_nope_head_dim + self.v_head_dim)
                if self.q_lora_rank:
                    p += d * self.q_lora_rank + self.q_lora_rank * self.num_heads * (
                        self.qk_nope_head_dim + self.qk_rope_head_dim)
                else:
                    p += d * self.num_heads * (self.qk_nope_head_dim + self.qk_rope_head_dim)
                p += self.num_heads * self.v_head_dim * d
                return p
            hd = self.head_dim
            return d * (self.num_heads + 2 * self.num_kv_heads) * hd + self.num_heads * hd * d

        def dense_ffn(ff: int) -> int:
            return 3 * d * ff  # swiglu

        def ssm_params() -> int:
            di = self.ssm_d_inner
            G, N, H = self.ssm_num_groups, self.ssm_state_size, self.ssm_num_heads
            p = d * (2 * di + 2 * G * N + H)          # in_proj [z,x,B,C,dt]
            p += self.ssm_conv_width * (di + 2 * G * N)  # conv
            p += H * 2 + di                           # A_log, D, dt_bias-ish + norm
            p += di * d                               # out_proj
            return p

        per_layer = 2 * d  # norms
        if self.family == "ssm":
            per_layer += ssm_params()
        else:
            per_layer += attn_params()
            if self.hybrid:
                per_layer += ssm_params() + 2 * d
        n_moe_layers = 0
        if self.is_moe:
            n_moe_layers = L - self.first_k_dense
            n += self.first_k_dense * dense_ffn(self.dense_d_ff or self.d_ff)
            n += n_moe_layers * (
                self.num_experts * 3 * d * self.moe_d_ff
                + self.num_shared_experts * 3 * d * self.moe_d_ff
                + d * self.num_experts  # router
            )
        elif self.family != "ssm":
            per_layer += dense_ffn(self.d_ff)
        n += L * per_layer
        if self.is_encoder_decoder:
            # encoder blocks: self-attn + ffn; decoder adds cross-attn
            enc = self.num_encoder_layers * (attn_params() + dense_ffn(self.d_ff) + 2 * d)
            n += enc + L * (attn_params() + d)  # cross attention + norm
        return int(n)

    def active_param_count(self) -> int:
        """Activated parameters per token (MoE: routed top-k + shared)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        full = self.param_count()
        n_moe_layers = self.num_layers - self.first_k_dense
        all_experts = n_moe_layers * self.num_experts * 3 * d * self.moe_d_ff
        active = n_moe_layers * self.num_experts_per_tok * 3 * d * self.moe_d_ff
        return int(full - all_experts + active)


# ---------------------------------------------------------------------
# Engine x family validation matrix (DESIGN.md §Known-issues, README
# support matrix). Every decode-engine construction site consults this
# instead of hand-rolling family asserts, so the exclusion list lives in
# exactly one place and each remaining exclusion is architectural.
# ---------------------------------------------------------------------

ROLLOUT_ENGINES = ("group", "cbatch", "paged")

# The speculative-decode plane (DESIGN.md §Spec-decode) is not a fourth
# engine — it rides the three above — but its applicability is validated
# through the same matrix so the exclusion list lives in one place.
SPEC_PLANE = "spec"

# The radix prefix cache (DESIGN.md §Radix-prefix-cache) likewise rides the
# paged engine rather than being an engine of its own: it shares cached
# prompt pages across requests, so it needs per-token paged KV to share.
PREFIX_PLANE = "prefix"


def engine_support(cfg: ModelConfig, engine: str) -> Tuple[bool, str]:
    """(supported, reason) for running ``cfg`` on a decode engine:

    * ``group``  — the group-at-a-time Sampler (reference semantics);
    * ``cbatch`` — the dense-slot continuous-batching engine;
    * ``paged``  — the token-level paged pool (GQA K/V pages or MLA
      latent pages; sliding-window configs reclaim out-of-window pages);
    * ``spec``   — the draft/verify speculative-decode plane layered on
      any of the engines (src/repro/spec/);
    * ``prefix`` — the radix prefix cache layered on the paged pool
      (core/radix.py: cached prompt pages shared across requests).
    """
    if engine == SPEC_PLANE:
        return _spec_support(cfg)
    if engine == PREFIX_PLANE:
        return _prefix_support(cfg)
    if engine not in ROLLOUT_ENGINES:
        raise KeyError(f"unknown engine {engine!r}; known: "
                       f"{ROLLOUT_ENGINES + (SPEC_PLANE, PREFIX_PLANE)}")
    if engine == "group":
        return True, "reference decode path for every family"
    if cfg.is_encoder_decoder:
        return False, ("decoder context is bounded (max_target_positions) "
                       "and decode is dominated by cross-attention over a "
                       "fixed encoder memory — served via the group path")
    if cfg.vision_prefix_len:
        return False, ("the vision prefix is a per-request dense prefix "
                       "embedding, not token KV — served via the group path")
    if engine == "cbatch":
        return True, "fixed slot pool over one contiguous cache"
    # paged
    if cfg.family == "ssm" or cfg.hybrid:
        return False, ("O(1) recurrent state: there is no per-token KV to "
                       "page; prefix-state sharing (core/prefix.py) is the "
                       "prompt-sharing analogue")
    kind = "MLA latent (ckv, kr) rows" if cfg.use_mla else "per-head K/V rows"
    win = ("; out-of-window pages are reclaimed to the freelist"
           if cfg.sliding_window is not None else "")
    return True, f"pages hold {kind}{win}"


def _spec_support(cfg: ModelConfig) -> Tuple[bool, str]:
    """Speculative decode needs a REVERSIBLE per-token cache: a rejected
    draft's KV entries are overwritten or rolled back (paged engines return
    speculative pages to the freelist). Recurrent state cannot be rolled
    back cheaply, and the group-path-only modality families never see the
    multi-token verify forward (DESIGN.md §Spec-decode, §Known-issues)."""
    if cfg.family == "ssm" or cfg.hybrid:
        return False, ("O(1) recurrent state advances irreversibly per "
                       "token — a rejected draft would need the pre-draft "
                       "SSM state restored, i.e. a state checkpoint per "
                       "speculated token")
    if cfg.is_encoder_decoder:
        return False, ("served via the group path only (bounded decoder "
                       "context, cross-attention-dominated) — no verify "
                       "engine to ride")
    if cfg.vision_prefix_len:
        return False, ("served via the group path only (dense vision "
                       "prefix) — no verify engine to ride")
    kind = "MLA latent" if cfg.use_mla else "GQA"
    win = (" incl. sliding-window (speculative pages respect reclamation)"
           if cfg.sliding_window is not None else "")
    return True, f"k+1-token verify through the {kind} cache{win}"


def _prefix_support(cfg: ModelConfig) -> Tuple[bool, str]:
    """The radix prefix cache shares PAGES, so it inherits exactly the
    paged engine's applicability: per-token cache rows that are a pure
    function of (token, position) — which is also why a cached page is
    bitwise identical to a cold prefill of the same span (core/radix.py,
    tests/test_radix.py)."""
    ok, reason = engine_support(cfg, "paged")
    if not ok:
        return False, reason
    kind = "MLA latent" if cfg.use_mla else "per-head K/V"
    win = (" (window-dead leading pages are never cached)"
           if cfg.sliding_window is not None else "")
    return True, (f"radix tree shares cached {kind} prompt pages across "
                  f"any common token-span prefix{win}")


def engine_support_matrix(cfg: ModelConfig) -> dict:
    """{engine: (supported, reason)} for one config (+ the spec and
    prefix planes)."""
    return {e: engine_support(cfg, e)
            for e in ROLLOUT_ENGINES + (SPEC_PLANE, PREFIX_PLANE)}


def require_engine_support(cfg: ModelConfig, engine: str) -> None:
    ok, reason = engine_support(cfg, engine)
    if not ok:
        raise ValueError(f"{cfg.name}: rollout engine {engine!r} is not "
                         f"applicable — {reason} (DESIGN.md §Known-issues)")


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


@dataclasses.dataclass(frozen=True)
class RLConfig:
    """GRPO / periodic-asynchrony run configuration (paper Tables 7-9)."""
    algo: str = "grpo"                 # grpo | ppo
    group_size: int = 32               # answers per prompt (G)
    batch_prompts: int = 32            # prompts per iteration (N)
    micro_batch: int = 1               # samples per micro-step (m)
    kl_coef: float = 0.02
    clip_eps_low: float = 0.2
    clip_eps_high: float = 0.2
    temperature: float = 1.0
    top_p: float = 1.0
    learning_rate: float = 1e-6
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    adam_b1: float = 0.9
    adam_b2: float = 0.95
    max_prompt_len: int = 128
    max_response_len: int = 128
    shared_prompt_attention: bool = False
    # beyond-paper: round SPA slot stride/prompt block up to the Pallas
    # tile size (128) so response x response tiles prune exactly (see
    # core/spa.py pack_spa and EXPERIMENTS.md SPerf). 0 = paper layout.
    spa_align: int = 0
    mode: str = "async"                # sync | async | async_offpolicy
    staleness_eta: int = 1             # for the AReaL-like off-policy baseline
    num_inference_instances: int = 4   # train:rollout ratio (paper: 1:4)
    # rollout decode engine (DESIGN.md §Continuous-batching):
    #   "group" — one jitted group-at-a-time Sampler call per request;
    #   "paged" — token-level continuous batching over a paged KV cache
    #             with one physical prompt copy per GRPO group. Token-
    #             identical to "group" under the same key; requires a
    #             decoder-only GQA family and mode != async_offpolicy
    #             (weight sync needs a quiescent engine).
    rollout_engine: str = "group"
    cbatch_slots: int = 8              # decode slots per paged instance
    kv_page_size: int = 16             # tokens per KV page
    kv_pages: int = 0                  # physical pages (0 = auto-size)
    # Capture per-token logprobs of the sampled ids at rollout time
    # (DESIGN.md §Tri-model-capture). Under Proposition 1 the rollout
    # weights ARE the old-policy weights, so the captured values replace
    # the trainer's old-policy recompute: the tri-model's no-grad pass
    # shrinks from stacked old+ref to a single ref forward. In
    # async_offpolicy mode the captured values are evaluated under the
    # BEHAVIOR weights instead of the current old weights, removing the
    # old~behavior weights approximation from the importance ratio (both
    # paths use raw-distribution logprobs; sampling-time temperature/top-p
    # filtering sits outside the ratio convention either way). Rollouts
    # without captured values (simulated/scripted instances) fall back to
    # the recompute path per micro-batch.
    capture_logprobs: bool = True
    # --- speculative decode (DESIGN.md §Spec-decode) ------------------
    # Draft/verify plane over the rollout engines: propose spec_k tokens
    # cheaply, verify them in ONE k+1-token target forward, accept via
    # rejection sampling. Distribution-exact, so Proposition 1 survives:
    # greedy decode is token-identical to the non-spec engines, sampled
    # decode draws exactly from the target policy (tests/test_spec.py),
    # and capture_logprobs returns TARGET-model logprobs straight from
    # the verify pass. SSM/enc-dec/VLM are excluded (engine_support).
    spec_decode: bool = False
    spec_k: int = 4                    # drafted tokens per verify step
    # draft provider: "prompt_lookup" (n-gram reuse of prompt/response
    # tokens — no extra model) or "model" (small resident draft model)
    spec_draft: str = "prompt_lookup"
    spec_ngram: int = 3                # longest n-gram the lookup tries
    # --- device-resident decode (DESIGN.md §Device-resident-decode) ---
    # Steps fused per jitted decode block in the paged/cbatch engines:
    # tokens, EOS flags and logprobs accumulate in device buffers for D
    # steps and drain to Python once per block (double-buffered — block
    # n+1 dispatches before block n's readback lands), so the hot loop
    # never blocks on a per-token device_get. 1 = drain every step
    # (legacy cadence, bitwise-identical admission/eviction timing).
    # The paged engine is sampled-identical for every D (per-row step
    # keys); cbatch D>1 realigns the sampled key chain (greedy identical
    # for every D) — see core/cbatch.py.
    decode_drain_interval: int = 1
    # --- radix prefix cache (DESIGN.md §Radix-prefix-cache) -----------
    # Share cached prompt pages across requests with any common
    # token-span prefix (paged engine only): admission walks a radix
    # tree, retains matched pages, and prefills only the suffix. Cached
    # page content is bitwise what a cold prefill writes (per-token KV),
    # so rollouts stay token-identical (tests/test_radix.py). Idle cached
    # pages are LRU-evicted by the admission gate on a page deficit.
    prefix_cache: bool = False
    # --- weight-plane (DESIGN.md §Weight-plane) -----------------------
    # The iteration-boundary trainer->pool weight push streams the param
    # tree as fixed-size buckets through repro.transfer instead of one
    # whole-tree device_put per instance.
    transfer_bucket_bytes: int = 1 << 22   # wire bytes coalesced per bucket
    # Overlap: start streaming the new version's buckets the moment the
    # optimizer update materialises (background thread), hiding wire time
    # under the trainer's iteration tail. Rollouts stay version-GATED, so
    # Proposition 1 is preserved exactly — the param trajectory is
    # bitwise-identical to eager sync (tests/test_transfer.py).
    transfer_overlap: bool = True
    # Wire dtype for the payload ("" = stream the storage dtype, bitwise).
    # E.g. "bfloat16" streams a bf16 payload while fp32 master weights
    # stay trainer-side.
    transfer_wire_dtype: str = ""
    # Cast with the Pallas fused cast+copy kernel
    # (kernels/transfer_cast.py) instead of the pure-JAX astype path; only
    # meaningful when transfer_wire_dtype differs from storage.
    transfer_pallas_cast: bool = False
    # --- observability (DESIGN.md §Observability) ---------------------
    # Write a Chrome/Perfetto trace of the pipeline to this path ("" =
    # tracing disabled, the null-span fast path). Spans reuse the
    # pipeline's existing stopwatch reads, so enabling tracing adds no
    # device barriers; inspect with `repro-trace report <path>`.
    trace: str = ""
    # Streaming trace export: write rotating JSONL segments
    # (trace-NNNN.jsonl) into this directory instead of buffering the
    # whole run in memory ("" = monolithic `trace` behaviour). Peak
    # tracer memory is bounded at threads x flush batch regardless of
    # run length; read back with `repro-trace report <dir>`.
    trace_dir: str = ""
    # Events per segment file before rotation (and the order of the
    # bounded in-memory flush batch).
    trace_segment_events: int = 8192
    # Per-thread buffered events before a flush to the current segment —
    # the crash-durability granularity: at most this many events per
    # thread are lost to a hard kill.
    trace_flush_events: int = 256
    seed: int = 0
