"""deepseek-v2-lite-16b — MLA + fine-grained MoE [arXiv:2405.04434].

[moe] 27L d_model=2048 16H (MLA kv_lora=512) d_ff=1408(per expert)
vocab=102400, 64 routed experts top-6 + 2 shared, first layer dense
(d_ff=10944). MLA: qk_nope=128, qk_rope=64, v_head=128 (no q-LoRA in lite).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    source="arXiv:2405.04434",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=102400,
    use_mla=True,
    kv_lora_rank=512,
    q_lora_rank=0,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    num_experts=64,
    num_experts_per_tok=6,
    num_shared_experts=2,
    moe_d_ff=1408,
    first_k_dense=1,
    dense_d_ff=10944,
    rope_theta=1e4,
)
