"""hymba-1.5b — parallel attention + mamba heads [arXiv:2411.13676].

[hybrid] 32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
Attention and SSM heads run in parallel inside each block and their
(normalised) outputs are averaged. Hymba uses sliding-window attention on
most layers; we adopt SWA(1024) uniformly (adaptation noted in DESIGN.md).
d_inner = 2*1600 = 3200, ssm head_dim 64 -> 50 ssm heads.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    source="arXiv:2411.13676",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    sliding_window=1024,
    hybrid=True,
    ssm_state_size=16,
    ssm_num_heads=50,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv_width=4,
    ssm_chunk_size=128,
    ssm_num_groups=1,
)
