"""internlm2-20b — dense GQA [arXiv:2403.17297].

[dense] 48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92544.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-20b",
    family="dense",
    source="arXiv:2403.17297",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92544,
    rope_theta=1e6,
)
