"""internvl2-76b — InternViT (STUB frontend) + llama3-70b-class LM backbone
[arXiv:2404.16821].

[vlm] 80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.
The vision encoder + projector are stubs: input_specs() provides precomputed
patch embeddings (B, 256, 8192) prepended to the token sequence; the language
backbone is real.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    source="arXiv:2404.16821",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    vision_prefix_len=256,
    rope_theta=5e5,
)
