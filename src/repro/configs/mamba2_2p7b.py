"""mamba2-2.7b — SSD (state-space duality) [arXiv:2405.21060].

[ssm] 64L d_model=2560 (attn-free) d_ff=0 vocab=50280, ssm_state=128.
d_inner = 2*2560 = 5120, head_dim 64 -> 80 ssm heads, 1 group, conv width 4.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    source="arXiv:2405.21060",
    num_layers=64,
    d_model=2560,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state_size=128,
    ssm_num_heads=80,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv_width=4,
    ssm_chunk_size=128,
    ssm_num_groups=1,
    tie_embeddings=True,
)
