"""qwen3-moe-235b-a22b — 128 experts top-8 [hf:Qwen/Qwen3-235B-A22B family].

[moe] 94L d_model=4096 64H (GQA kv=4) d_ff=1536(per expert) vocab=151936,
MoE 128e top-8, no shared experts.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    source="hf:Qwen/Qwen3-30B-A3B",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    d_ff=1536,
    vocab_size=151936,
    num_experts=128,
    num_experts_per_tok=8,
    num_shared_experts=0,
    moe_d_ff=1536,
    first_k_dense=0,
    rope_theta=1e6,
)
