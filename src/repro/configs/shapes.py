"""The four assigned input shapes (see assignment block)."""
from repro.configs.base import InputShape

TRAIN_4K = InputShape(name="train_4k", seq_len=4_096, global_batch=256, kind="train")
PREFILL_32K = InputShape(name="prefill_32k", seq_len=32_768, global_batch=32, kind="prefill")
DECODE_32K = InputShape(name="decode_32k", seq_len=32_768, global_batch=128, kind="decode")
LONG_500K = InputShape(name="long_500k", seq_len=524_288, global_batch=1, kind="decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}
