"""whisper-tiny — encoder-decoder with conv frontend STUB [arXiv:2212.04356].

[audio] 4L d_model=384 6H (kv=6) d_ff=1536 vocab=51865.
The mel-spectrogram + conv feature extractor is a stub: input_specs()
provides precomputed frame embeddings (B, 1500, 384); the transformer
encoder + causal decoder with cross-attention are real.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    source="arXiv:2212.04356",
    num_layers=4,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51865,
    is_encoder_decoder=True,
    num_encoder_layers=4,
    encoder_seq_len=1500,
    max_target_positions=448,
    tie_embeddings=True,
    rope_theta=1e4,
)
