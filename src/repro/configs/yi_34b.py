"""yi-34b — llama-arch dense GQA [arXiv:2403.04652].

[dense] 60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="yi-34b",
    family="dense",
    source="arXiv:2403.04652",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab_size=64000,
    rope_theta=5e6,
)
