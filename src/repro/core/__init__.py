"""Periodic asynchrony — the paper's contribution as a composable library.

Pipeline wiring (paper Figure 1):
    PromptLoader -> TemporaryDataGenerator -> InferencePool (producer side)
                         |  RolloutQueue  |
    PeriodicAsyncScheduler (consumer: tri-model GRPO + accumulation)
"""
from repro.core.cbatch import (Completed, ContinuousBatchingSampler,
                               SlotScheduler)
from repro.core.engine import InferenceInstance, InferencePool
from repro.core.generator import TemporaryDataGenerator
from repro.core.onpolicy import OnPolicyMonitor, OnPolicyViolation
from repro.core.paged import GroupHandle, PagedGroupEngine, PageAllocator
from repro.core.prefix import (broadcast_states, prompt_states,
                               shared_prompt_logprobs, zero_ssm_states)
from repro.core.queue import RolloutGroup, RolloutQueue
from repro.core.scheduler import IterationStats, PeriodicAsyncScheduler
from repro.core.spa import pack_plain, pack_spa, spa_reduction_ratio
from repro.core.trimodel import TriModelState

__all__ = [
    "Completed", "ContinuousBatchingSampler", "SlotScheduler",
    "GroupHandle", "PagedGroupEngine", "PageAllocator",
    "InferenceInstance", "InferencePool", "TemporaryDataGenerator",
    "OnPolicyMonitor", "OnPolicyViolation", "RolloutGroup", "RolloutQueue",
    "IterationStats", "PeriodicAsyncScheduler", "pack_plain", "pack_spa",
    "spa_reduction_ratio", "TriModelState",
    "shared_prompt_logprobs", "prompt_states", "broadcast_states",
    "zero_ssm_states",
]
