"""Continuous batching (paper §4.2.1: the inference service "processes them
efficiently via continuous batching") — the slot-scheduler the async
pipeline's >2x practical speedup leans on: without it, the batch is gated by
its slowest rollout.

JAX-native design with fixed shapes:

  * a fixed pool of B slots shares one KV/SSM cache of length ``max_ctx``;
  * ``_prefill_row`` (jit) runs ONE prompt and splices its row cache +
    last-token logits into the pool at ``slot``;
  * ``_decode_step`` (jit) advances ALL slots by one token with PER-ROW
    cache offsets (models/attention.py one-hot row writes) — finished or
    empty slots carry along masked;
  * the host loop admits pending requests into freed slots every step, so
    short requests drain and new ones start while long ones keep decoding —
    completion order, not submission order.

Requests are emitted in completion order with their generation step, which
is exactly what the temporary data generator's queue consumes.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from functools import partial
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.tokenizer import Tokenizer
from repro.models import forward_hidden, init_caches
from repro.models.layers import lm_head_weight
from repro.obs import trace as otrace
from repro.obs.metrics import metrics
from repro.rl.rollout import _sample_token


@dataclasses.dataclass
class Completed:
    request_id: int
    response_ids: np.ndarray     # (n,) int32, includes EOS if hit
    finish_step: int             # engine step at completion (completion order)


class SlotScheduler:
    """Admission/eviction bookkeeping for a fixed pool of decode slots —
    the host-side policy every token-level engine here shares (this module's
    ``ContinuousBatchingSampler`` and the paged-pool engine in
    ``core/paged.py``).

    Requests join a FIFO; each engine step fills free slots from the front
    (an optional ``gate`` refuses admission while a resource — e.g. the KV
    page freelist — is exhausted, without reordering the FIFO), and
    completed requests leave their slot the step they finish, so the engine
    emits in completion order, never submission order."""

    def __init__(self, num_slots: int):
        self.num_slots = num_slots
        self.slot_req: List[Optional[object]] = [None] * num_slots
        self._pending: deque = deque()
        self.step = 0

    # -- queue state --------------------------------------------------------
    def submit(self, req) -> None:
        self._pending.append(req)

    @property
    def num_pending(self) -> int:
        return len(self._pending)

    def active_slots(self) -> List[int]:
        return [s for s in range(self.num_slots)
                if self.slot_req[s] is not None]

    @property
    def idle(self) -> bool:
        return not self._pending and not any(
            r is not None for r in self.slot_req)

    # -- admission / eviction ----------------------------------------------
    def admit(self, gate: Optional[Callable] = None,
              limit: Optional[int] = None) -> List[tuple]:
        """Fill free slots from the FIFO; returns [(slot, request), ...].
        ``gate(req) -> bool`` may refuse the request at the FIFO's front,
        which stops admission this step (strict FIFO, no overtaking).
        ``limit`` caps admissions per call — engines whose gate depends on
        resources consumed by admission itself (the paged engine's page
        freelist) admit one at a time so the gate never reads stale state."""
        out = []
        for s in range(self.num_slots):
            if limit is not None and len(out) >= limit:
                break
            if self.slot_req[s] is not None or not self._pending:
                continue
            if gate is not None and not gate(self._pending[0]):
                break
            req = self._pending.popleft()
            self.slot_req[s] = req
            out.append((s, req))
        return out

    def evict(self, slot: int):
        """Free a slot (completion or preemption); returns its request."""
        req = self.slot_req[slot]
        self.slot_req[slot] = None
        return req

    def tick(self) -> int:
        self.step += 1
        return self.step


class ContinuousBatchingSampler:
    def __init__(self, cfg: ModelConfig, *, num_slots: int,
                 max_prompt_len: int, max_new_tokens: int,
                 temperature: float = 1.0, top_p: float = 1.0,
                 eos_id: int = Tokenizer.EOS, pad_id: int = Tokenizer.PAD,
                 spec_k: int = 0, spec_draft: str = "prompt_lookup",
                 spec_ngram: int = 3, drain_interval: int = 1, seed: int = 0):
        from repro.configs.base import require_engine_support
        require_engine_support(cfg, "cbatch")
        if drain_interval < 1:
            raise ValueError(f"drain_interval must be >= 1, "
                             f"got {drain_interval}")
        self.cfg = cfg
        self.B = num_slots
        self.Lp = max_prompt_len
        self.T = max_new_tokens
        # fused decode-block length D (DESIGN.md §Device-resident-decode):
        # D == 1 drains synchronously (legacy cadence and, for sampled
        # decode, the legacy key chain); D > 1 pipelines one block deep —
        # admission then happens at block boundaries, and the carried PRNG
        # key splits once per DEVICE step, so sampled (non-greedy) token
        # streams are aligned differently than D == 1 (still exact draws
        # from the policy; greedy decode is bitwise identical for every D)
        self.drain = drain_interval
        self.spec_k = spec_k
        # speculative writes run up to k tokens past the frontier — give
        # the contiguous cache (and a windowed ring, via ring_slack) that
        # slack (DESIGN.md §Spec-decode)
        self.max_ctx = max_prompt_len + max_new_tokens + \
            (spec_k + 1 if spec_k else 0)
        self.temperature = temperature
        self.top_p = top_p
        self.eos_id = eos_id
        self.pad_id = pad_id
        self._prefill = jax.jit(self._prefill_row, donate_argnums=(1,))
        self._decode = jax.jit(self._decode_block, donate_argnums=(1,))
        if spec_k:
            require_engine_support(cfg, "spec")
            from functools import partial
            from repro.spec.draft import make_draft_provider
            from repro.spec.sampler import dense_verify_step
            # serving engine: no trainer consumes behavior logprobs —
            # capture off skips the verify pass's full-vocab log-softmax
            self._vstep = jax.jit(
                partial(dense_verify_step, cfg, temperature, top_p, False),
                donate_argnums=(1,))
            self._draft = make_draft_provider(
                spec_draft, cfg, num_slots, spec_k=spec_k,
                ngram=spec_ngram, max_prompt_len=max_prompt_len,
                max_new_tokens=max_new_tokens, pad_id=pad_id, seed=seed)
        self.reset_spec_stats()
        # registry metric, cached once; one add per drained block
        self._m_drain_blocks = metrics().counter("cbatch.drain_blocks")

    # -- spec stats ---------------------------------------------------------

    def reset_spec_stats(self) -> None:
        self.spec_steps = 0
        self.drafted_tokens = 0
        self.accepted_tokens = 0

    @property
    def acceptance_rate(self) -> float:
        return (self.accepted_tokens / self.drafted_tokens
                if self.drafted_tokens else 0.0)

    # -- jitted cores -------------------------------------------------------

    def _prefill_row(self, params, caches, tokens, length, slot):
        """tokens: (1, Lp) right-padded; splice row cache into ``slot``."""
        cfg = self.cfg
        ar = jnp.arange(self.Lp, dtype=jnp.int32)[None, :]
        real = ar < length
        positions = jnp.where(real, ar, 0).astype(jnp.int32)
        segments = jnp.where(real, 0, -1).astype(jnp.int32)
        row = init_caches(params, cfg, 1, self.max_ctx,
                          ring_slack=self.spec_k + 1 if self.spec_k else 0)
        h, row, _, _ = forward_hidden(params, cfg, tokens,
                                      positions=positions, segments=segments,
                                      caches=row, cache_offset=0)
        W = lm_head_weight(params["embed"], cfg)
        h_last = jnp.take_along_axis(
            h, (length - 1)[None, :, None], axis=1)[:, 0]
        logits = jnp.einsum("bd,dv->bv", h_last.astype(jnp.float32),
                            W.astype(jnp.float32))
        # splice the single-row cache into the pool at `slot` — every cache
        # leaf has layout (layers, batch, ...), so update along axis 1.
        def splice(pool, r):
            return jax.lax.dynamic_update_slice_in_dim(pool, r, slot, axis=1)
        caches = jax.tree.map(splice, caches, row)
        return caches, logits[0]

    def _decode_block(self, params, caches, logits, offsets, done, key,
                      valid, active):
        """D fused decode steps for every slot (the device-resident decode
        loop, DESIGN.md §Device-resident-decode): one ``lax.scan`` samples,
        writes the cache, stop-checks, and accumulates a (D, B) token
        buffer on device. ``offsets`` and the per-slot ``done`` stop flags
        are device-carried across blocks (reset at admission); a slot is
        live at step j when the host scheduled it (``active``,
        ``valid[j]`` — the per-request cap) and it has not sampled EOS.
        The PRNG key splits once per device step, replicating the legacy
        one-step chain exactly when D == 1. Returns
        (toks (D, B), caches, logits', offsets', done', key')."""
        cfg = self.cfg

        def body(carry, v_j):
            caches, logits, offsets, done, key = carry
            key, k = jax.random.split(key)
            _, k_s = jax.random.split(k)
            tok = _sample_token(k_s, logits, self.temperature, self.top_p)
            live = active & ~done & v_j
            tok = jnp.where(live, tok, self.pad_id)
            done = done | (live & (tok == self.eos_id))
            positions = jnp.where(live, offsets, 0).astype(jnp.int32)[:, None]
            segments = jnp.where(live, 0, -1).astype(jnp.int32)[:, None]
            h, caches, _, _ = forward_hidden(
                params, cfg, tok[:, None], positions=positions,
                segments=segments, caches=caches,
                cache_offset=jnp.where(live, offsets, 0).astype(jnp.int32))
            W = lm_head_weight(params["embed"], cfg)
            logits = jnp.einsum("bd,dv->bv", h[:, 0].astype(jnp.float32),
                                W.astype(jnp.float32))
            offsets = offsets + live.astype(jnp.int32)
            return (caches, logits, offsets, done, key), tok

        (caches, logits, offsets, done, key), toks = jax.lax.scan(
            body, (caches, logits, offsets, done, key), valid)
        return toks, caches, logits, offsets, done, key

    # -- host-side scheduler --------------------------------------------------

    def run(self, params, prompts: List[np.ndarray], key,
            max_new_per_request: Optional[List[int]] = None
            ) -> List[Completed]:
        """Serve all prompts through the slot pool; returns completions in
        completion order. ``max_new_per_request`` caps each request's
        generation individually (rollout lengths vary in RL; a freed slot
        admits the next request immediately)."""
        if self.spec_k:
            return self._run_spec(params, prompts, key, max_new_per_request)
        cfg, B, D = self.cfg, self.B, self.drain
        limits = (max_new_per_request if max_new_per_request is not None
                  else [self.T] * len(prompts))
        sched = SlotScheduler(B)
        for rid, p in enumerate(prompts):
            sched.submit((rid, p))
        caches = init_caches(params, cfg, B, self.max_ctx)
        logits = jnp.zeros((B, cfg.vocab_size), jnp.float32)
        # device-resident decode state (§Device-resident-decode): write
        # offsets and per-slot stop flags live on device and are only
        # touched host-side at admission
        offsets = jnp.zeros((B,), jnp.int32)
        stop = jnp.zeros((B,), bool)
        counts = [0] * B          # host mirror: tokens SCHEDULED per slot
        caps = [0] * B
        slot_toks: List[list] = [[] for _ in range(B)]
        done: List[Completed] = []
        pending = None            # in-flight (plan, base_step, tok_buf)

        while not sched.idle or pending is not None:
            # admit pending requests into free slots
            for s, (rid, p) in sched.admit():
                p = np.asarray(p, np.int32)[: self.Lp]
                row = np.full((1, self.Lp), self.pad_id, np.int32)
                row[0, : len(p)] = p
                caches, lg = self._prefill(
                    params, caches, jnp.asarray(row),
                    jnp.asarray([len(p)], jnp.int32), s)
                # dispatched after any in-flight block: these updates land
                # on its output state (the block saw this slot stopped)
                logits = logits.at[s].set(lg)
                offsets = offsets.at[s].set(len(p))
                stop = stop.at[s].set(False)
                counts[s] = 0
                caps[s] = min(self.T, limits[rid])
                slot_toks[s] = []
            # one fused D-step block for every slot — the scheduler's slot
            # occupancy IS the decode mask; the per-request cap becomes the
            # host-precomputed valid mask
            nxt = None
            plan = []
            valid = np.zeros((D, B), bool)
            active = np.zeros((B,), bool)
            for s in sched.active_slots():
                n_row = min(D, caps[s] - counts[s])
                if n_row <= 0:    # fully scheduled; awaiting drain
                    continue
                valid[:n_row, s] = True
                active[s] = True
                plan.append((s, sched.slot_req[s], n_row))
                counts[s] += n_row
            if plan:
                base = sched.step
                sched.step += D
                t_disp = time.perf_counter()
                toks, caches, logits, offsets, stop, key = self._decode(
                    params, caches, logits, offsets, stop, key,
                    jnp.asarray(valid), jnp.asarray(active))
                if hasattr(toks, "copy_to_host_async"):
                    toks.copy_to_host_async()   # overlap with next block
                otrace.complete("cbatch.dispatch", t_disp,
                                time.perf_counter(), slots=len(plan),
                                steps=D)
                nxt = (plan, base, toks)
            if D == 1:
                prev = nxt
            else:
                prev, pending = pending, nxt
            if prev is not None:
                self._drain_run(prev, sched, slot_toks, limits, done)
        return done

    def _drain_run(self, blk, sched, slot_toks, limits, done) -> None:
        """Commit one drained block into host bookkeeping — the only
        device->host touch of the run loop, once per D-step block (the
        transfer was started asynchronously at dispatch)."""
        plan, base, tok_buf = blk
        t_drain = time.perf_counter()
        # repro: allow(host-sync): one buffered readback per drained
        # D-step block, not per token — DESIGN.md §Device-resident-decode
        toks = jax.device_get(tok_buf)
        for s, req, n_row in plan:
            if sched.slot_req[s] is not req:
                # request finished in an earlier block; these optimistic
                # steps ran device-masked (stop flag)
                continue
            rid = req[0]
            for j in range(n_row):
                tv = int(toks[j, s])
                slot_toks[s].append(tv)
                if (tv == self.eos_id
                        or len(slot_toks[s]) >= min(self.T, limits[rid])):
                    done.append(Completed(
                        request_id=rid,
                        response_ids=np.asarray(slot_toks[s], np.int32),
                        finish_step=base + j + 1))
                    sched.evict(s)
                    break
        otrace.complete("cbatch.drain", t_drain, time.perf_counter(),
                        slots=len(plan))
        self._m_drain_blocks.add(1)

    def _drain_verify(self, ctoks, clps, count):
        """Drain one fused verify block's commit buffers (the spec-plane
        drain: the accept/commit walk already ran on device —
        ``spec/verify.py commit_block``)."""
        for buf in (ctoks, clps, count):
            if hasattr(buf, "copy_to_host_async"):
                buf.copy_to_host_async()
        # repro: allow(host-sync): one buffered readback per verify block
        # (device-side commit walk) — DESIGN.md §Device-resident-decode
        return jax.device_get((ctoks, clps, count))

    def _run_spec(self, params, prompts: List[np.ndarray], key,
                  max_new_per_request: Optional[List[int]] = None
                  ) -> List[Completed]:
        """Speculative run loop (DESIGN.md §Spec-decode): per engine step,
        every live slot drafts k tokens and ONE k+1-token verify forward
        commits 1..k+1 of them — variable per-row token counts, which is
        exactly the admission/eviction model the SlotScheduler already
        serves. Freshly admitted slots ride their first block with the
        prefill logits as p_0 (``fresh``); rejected speculative cache
        entries carry positions past the frontier (masked) until the next
        block overwrites them."""
        from repro.models.attention import INVALID_POS
        from repro.spec.sampler import pack_row_block
        self.reset_spec_stats()
        cfg, B, k = self.cfg, self.B, self.spec_k
        limits = (max_new_per_request if max_new_per_request is not None
                  else [self.T] * len(prompts))
        sched = SlotScheduler(B)
        for rid, p in enumerate(prompts):
            sched.submit((rid, p))
        caches = init_caches(params, cfg, B, self.max_ctx,
                             ring_slack=k + 1)
        logits = jnp.zeros((B, cfg.vocab_size), jnp.float32)
        # repro: allow(host-sync): one-time setup transfer of per-request
        # keys before the decode loop starts
        req_keys = np.asarray(jax.random.split(key, len(prompts)))
        plen = np.zeros((B,), np.int32)
        slot_keys = np.zeros((B, 2), np.uint32)
        fresh = np.zeros((B,), bool)
        slot_toks: List[list] = [[] for _ in range(B)]
        done: List[Completed] = []

        while not sched.idle:
            for s, (rid, p) in sched.admit():
                p = np.asarray(p, np.int32)[: self.Lp]
                row = np.full((1, self.Lp), self.pad_id, np.int32)
                row[0, : len(p)] = p
                caches, lg = self._prefill(
                    params, caches, jnp.asarray(row),
                    jnp.asarray([len(p)], jnp.int32), s)
                logits = logits.at[s].set(lg)
                plen[s] = len(p)
                slot_keys[s] = req_keys[rid]
                fresh[s] = True
                slot_toks[s] = []
                self._draft.start(s, p)
            act = sched.active_slots()
            t_draft = time.perf_counter()
            draft = self._draft.propose(act, k)
            otrace.complete("spec.draft", t_draft, time.perf_counter(),
                            slots=len(act), k=k)
            tokens = np.full((B, k + 1), self.pad_id, np.int32)
            positions = np.full((B, k + 1), int(INVALID_POS), np.int32)
            segs = np.full((B, k + 1), -1, np.int32)
            offs = np.zeros((B,), np.int32)
            for s in act:
                t = len(slot_toks[s])
                delta = pack_row_block(
                    tokens[s], positions[s], segs[s], fresh[s], draft[s],
                    slot_toks[s][-1] if slot_toks[s] else 0,
                    int(plen[s]) + t, k)
                # right-padded slots: cache slot index == position
                offs[s] = plen[s] + t + delta
            folds = np.full((B,), sched.step, np.int32)
            t_verify = time.perf_counter()
            ctoks, clps, count, caches = self._vstep(
                params, caches, jnp.asarray(tokens), jnp.asarray(positions),
                jnp.asarray(segs), jnp.asarray(offs), logits,
                jnp.asarray(fresh), jnp.asarray(draft),
                jnp.asarray(slot_keys), jnp.asarray(folds))
            otrace.complete("spec.verify", t_verify, time.perf_counter(),
                            slots=len(act))
            self._commit_spec_rows(act, ctoks, clps, count, sched,
                                   slot_toks, limits, fresh, done)
        return done

    def _commit_spec_rows(self, act, ctoks, clps, count, sched, slot_toks,
                          limits, fresh, done) -> None:
        """Drain one verify block and commit its rows -- the host half
        of the spec step, one frame below the run loop so the hot tier
        itself stays sync-free (DESIGN.md §Device-resident-decode). After
        the buffered drain the walk touches only host numpy."""
        from repro.spec.sampler import truncate_commit
        k = self.spec_k
        t_commit = time.perf_counter()
        ctoks, clps, count = self._drain_verify(ctoks, clps, count)
        step = sched.tick()
        for s in list(act):
            rid = sched.slot_req[s][0]
            n = int(count[s])
            ct = [int(t) for t in ctoks[s, :n]]
            cl = [float(x) for x in clps[s, :n]]
            self.spec_steps += 1
            self.drafted_tokens += k
            self.accepted_tokens += n - 1
            cap = min(self.T, limits[rid])
            ct, _, row_done = truncate_commit(
                ct, cl, cap - len(slot_toks[s]), self.eos_id)
            slot_toks[s].extend(ct)
            self._draft.commit(s, ct)
            fresh[s] = False
            if row_done:
                done.append(Completed(
                    request_id=rid,
                    response_ids=np.asarray(slot_toks[s], np.int32),
                    finish_step=step))
                sched.evict(s)
                self._draft.stop(s)
        otrace.complete("spec.commit", t_commit, time.perf_counter(),
                        slots=len(act))
        self._m_drain_blocks.add(1)
