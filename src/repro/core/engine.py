"""Inference service: a pool of independent engine instances with
iteration-boundary weight synchronisation (the decoupled deployment of
paper §4.1 — 'vLLM for inference, Megatron for training').

Three execution modes per instance:
  * real / group  — the jitted Sampler generates a whole group at a time
             (JAX releases the GIL during compute, so producer threads
             overlap with the consumer's training compute);
  * real / paged  — token-level continuous batching over a paged KV cache
             (core/paged.py): concurrent group requests from the generator's
             workers decode together one token per step, short rollouts
             free their slots early, and the GRPO group's prompt is stored
             once. Worker threads drive the engine convoy-style: whoever
             waits on a group steps the engine under the instance lock, so
             no dedicated decode thread exists and the engine goes quiet
             exactly when no requests are in flight (weight sync stays an
             iteration-boundary event — Proposition 1 intact);
  * simulated — the instance sleeps according to a latency model and returns
             scripted responses. This is the trainer's-eye view of a REMOTE
             inference deployment (inference on separate devices), and is
             what the throughput benchmarks use so results reflect pipeline
             structure rather than this container's single CPU core.

Weights live in a :class:`~repro.transfer.service.VersionedParamStore` per
instance: readers take an atomic (params, version) snapshot, and the
weight-plane service streams versioned buckets into the store's back
buffer (DESIGN.md §Weight-plane). ``sync_weights`` remains as the eager
whole-tree path (tests / serving), built on the same store so the
(params, version) pair can never tear.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, List, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.paged import PagedGroupEngine
from repro.obs import trace as otrace
from repro.rl.rollout import RolloutBatch, Sampler
from repro.transfer.service import VersionedParamStore


class InferenceInstance:
    def __init__(self, inst_id: int, cfg: ModelConfig, sampler: Optional[Sampler],
                 latency_fn: Optional[Callable] = None,
                 scripted_fn: Optional[Callable] = None,
                 paged_engine: Optional[PagedGroupEngine] = None):
        self.inst_id = inst_id
        self.cfg = cfg
        self.sampler = sampler
        self.latency_fn = latency_fn
        self.scripted_fn = scripted_fn
        self.paged_engine = paged_engine
        assert paged_engine is None or scripted_fn is None, \
            "paged engine runs real decode; simulated instances script it"
        # the paged engine's set_params asserts decode quiescence, so its
        # flips are DEFERRED to the scheduler's boundary (after the queue
        # drain) instead of landing from the stream thread
        self.store = VersionedParamStore(
            name=f"inst{inst_id}",
            on_flip=(None if paged_engine is None else paged_engine.set_params),
            defer_flip=paged_engine is not None)
        self._lock = threading.Lock()  # one request in flight per instance
        self.busy_time = 0.0
        # deferred busy clock (DESIGN.md §Device-resident-decode): the
        # generation call dispatches asynchronously, and a settle thread
        # charges the exact dispatch->ready interval later, so the hot
        # path never fences the dispatch stream on a device barrier
        self._busy_lock = threading.Lock()
        self._settles: List[threading.Thread] = []
        # settle threads that a boundary read actually had to block on —
        # completed settles deregister themselves, so repeated busy_time
        # reads between boundaries join nothing (O(1); regression-tested)
        self.settle_joins = 0

    def sync_weights(self, params, version: int) -> None:
        """Eager whole-tree publish (legacy path; the RL scheduler streams
        buckets through the weight-plane service instead)."""
        self.store.install(params, version)

    @property
    def version(self) -> int:
        return self.store.version

    def generate_group(self, prompts: List[np.ndarray], key,
                       min_version: Optional[int] = None) -> tuple:
        """Returns (RolloutBatch, weight_version).

        ``min_version`` is the rollout-side half of the weight-plane's
        version gate: the request blocks until the store's ACTIVE buffer
        holds at least that version, so overlapped bucket streaming can
        never hand an iteration-t request pre-flip weights. The (params,
        version) pair is one atomic snapshot — the version returned is
        provably the version sampled from."""
        if self.paged_engine is not None:
            return self._generate_group_paged(prompts, key, min_version)
        # group-at-a-time: serialised per instance — models single-instance
        # occupancy / continuous batching slot limits.
        with self._lock:
            # gate BEFORE the busy clock starts: time blocked waiting for
            # the weight flip is the boundary's sync-gap, not inference
            # occupancy — folding it into busy_time would contaminate
            # IterationStats.infer_time exactly the way producer waits
            # were once folded into train_time
            params, version = self.store.wait_version(min_version)
            t0 = time.perf_counter()
            if self.scripted_fn is not None:
                out = self.scripted_fn(prompts, key)
                if self.latency_fn is not None:
                    time.sleep(self.latency_fn(out))
                t1 = time.perf_counter()
                with self._busy_lock:
                    self.busy_time += t1 - t0
                otrace.complete("producer.busy", t0, t1, busy=t1 - t0,
                                inst=self.inst_id,
                                track=f"producer/inst{self.inst_id}")
            else:
                assert self.sampler is not None and params is not None
                out = self.sampler.generate(params, prompts, key)
                # busy-clock charge is DEFERRED: the settle thread blocks
                # on the arrays so this hot path doesn't serialize the
                # dispatch stream; the boundary read (pool.busy_time)
                # flushes pending settles first
                self._defer_busy(t0, out.response_ids)
            return out, version

    def _defer_busy(self, t0: float, arrays) -> None:
        """Charge the busy clock off the dispatch path: a daemon settle
        thread waits for ``arrays`` and adds the exact dispatch->ready
        interval under the busy lock. A completed settle deregisters
        itself, so only genuinely in-flight settles remain for
        ``flush_busy`` to join at the iteration boundary (where the queue
        is already drained, so those joins return immediately)."""
        def settle():
            # repro: allow(host-sync): busy-clock barrier DELIBERATELY
            # moved off the dispatch path into this settle thread — the
            # hot path no longer blocks (§Device-resident-decode)
            jax.block_until_ready(arrays)
            t1 = time.perf_counter()
            with self._busy_lock:
                self.busy_time += t1 - t0
                self._settles.remove(th)  # deregister: nothing to rejoin
            # producer busy span from the deferred clock's own endpoints —
            # no new barrier, no timestamp invented on the dispatch path
            otrace.complete("producer.busy", t0, t1, busy=t1 - t0,
                            inst=self.inst_id,
                            track=f"producer/inst{self.inst_id}")
        th = threading.Thread(target=settle, daemon=True,
                              name=f"busy-settle-{self.inst_id}")
        with self._busy_lock:
            self._settles.append(th)
        th.start()

    def flush_busy(self) -> None:
        """Join pending busy-clock settles (boundary accounting barrier —
        NOT on the per-request path). Settles that already completed have
        deregistered themselves, so between boundaries this is a single
        lock acquisition and an empty-list check."""
        while True:
            with self._busy_lock:
                if not self._settles:
                    return
                th = self._settles[-1]
                self.settle_joins += 1
            th.join()

    def status(self) -> dict:
        """Live introspection for the ops plane: identity, weight-plane
        version (atomic via the store), and the busy clock read under
        its own lock — one consistent row of ``/status``'s per-instance
        table."""
        with self._busy_lock:
            busy = self.busy_time
            in_flight_settles = len(self._settles)
        out = {"inst_id": self.inst_id,
               "weight_version": self.store.version,
               "busy_s": busy,
               "in_flight_settles": in_flight_settles,
               "mode": ("paged" if self.paged_engine is not None else
                        "simulated" if self.scripted_fn is not None
                        else "group")}
        if self.paged_engine is not None:
            out["engine"] = self.paged_engine.status_snapshot()
        return out

    def _generate_group_paged(self, prompts: List[np.ndarray], key,
                              min_version: Optional[int] = None) -> tuple:
        """Token-level path: submit the group, then help drive the shared
        engine until it completes. Concurrent callers' groups share decode
        steps — the engine lock serialises single steps, not whole groups."""
        eng = self.paged_engine
        assert len(prompts) == eng.G, \
            f"group size {len(prompts)} != engine group_size {eng.G}"
        # the paged engine stores ONE physical prompt per group — a GRPO
        # group is G rollouts of the same prompt, so reject anything else
        # rather than silently decoding G copies of prompts[0]
        assert all(np.array_equal(p, prompts[0]) for p in prompts[1:]), \
            "paged engine serves GRPO groups: all prompts in a group must " \
            "be identical (heterogeneous requests go through separate groups)"
        # the engine holds the flipped params; set_params asserts quiescence,
        # so the version cannot change while this group is in flight
        _, version = self.store.wait_version(min_version)
        handle = eng.submit(prompts[0], key)
        drive0 = None   # first step this caller took; busy = its step time
        busy = 0.0
        t1 = 0.0
        while not handle.done():
            with self._lock:
                if handle.done():
                    break
                t0 = time.perf_counter()
                eng.step()
                t1 = time.perf_counter()
                if drive0 is None:
                    drive0 = t0
                busy += t1 - t0
                self.busy_time += t1 - t0
        if drive0 is not None:
            # convoy driving interleaves callers, so the span's wall extent
            # includes lock waits — the charged occupancy rides in `busy`
            # (what the analyzer sums to reproduce infer_time)
            otrace.complete("producer.busy", drive0, t1, busy=busy,
                            inst=self.inst_id,
                            track=f"producer/inst{self.inst_id}")
        return handle.result(), version


class InferencePool:
    """Evenly distributes incoming prompt groups across instances
    (paper §4.2.1: 'evenly distributes incoming prompts across available
    instances')."""

    def __init__(self, instances: List[InferenceInstance]):
        self.instances = instances
        self._rr = 0
        self._rr_lock = threading.Lock()

    def __len__(self) -> int:
        return len(self.instances)

    @property
    def token_level(self) -> bool:
        """True when instances batch at token level (paged engines) — the
        generator then benefits from more concurrent groups per instance."""
        return any(i.paged_engine is not None for i in self.instances)

    def pick(self) -> InferenceInstance:
        with self._rr_lock:
            inst = self.instances[self._rr % len(self.instances)]
            self._rr += 1
            return inst

    def sync_weights(self, params, version: int) -> None:
        """Eager per-instance publish (legacy/tests; the scheduler's
        boundary goes through ``WeightTransferService.ensure``)."""
        for inst in self.instances:
            inst.sync_weights(params, version)

    def generate_group(self, prompts, key, min_version: Optional[int] = None):
        return self.pick().generate_group(prompts, key, min_version)

    def reset_stats(self) -> None:
        for inst in self.instances:
            inst.flush_busy()    # a late settle must not leak into the
            inst.busy_time = 0.0  # next accounting window
            if inst.paged_engine is not None:
                inst.paged_engine.reset_stats()

    def engine_stats(self) -> dict:
        """Aggregated paged-engine counters across instances (atomic per
        engine). Zeros when no instance runs a paged engine, so callers
        can diff snapshots unconditionally."""
        agg = {"decode_steps": 0, "generated_tokens": 0,
               "reclaimed_pages": 0, "spec_steps": 0, "drafted_tokens": 0,
               "accepted_tokens": 0, "prefix_hit_pages": 0,
               "prefix_miss_pages": 0, "prefix_evicted_pages": 0}
        for inst in self.instances:
            if inst.paged_engine is not None:
                for k, v in inst.paged_engine.stats_snapshot().items():
                    agg[k] += v
        return agg

    def status(self) -> dict:
        """Per-instance status rows + pool aggregate for ``/status``.
        Does NOT flush the deferred busy clocks (that is a boundary
        barrier) — a mid-iteration scrape reads the busy time charged so
        far, which is exactly what "live" means here."""
        rows = [inst.status() for inst in self.instances]
        return {"num_instances": len(rows),
                "token_level": self.token_level,
                "instances": rows,
                "busy_s": sum(r["busy_s"] for r in rows)}

    @property
    def busy_time(self) -> float:
        """Aggregate producer busy-time across instances (the quantity
        ``IterationStats.infer_time`` reports). Flushes the deferred busy
        clocks first — this is the boundary read, after the queue drain,
        so pending settles resolve immediately; settles that already
        completed have deregistered themselves, making repeated reads
        between boundaries O(1)."""
        for inst in self.instances:
            inst.flush_busy()
        return sum(inst.busy_time for inst in self.instances)
