"""Inference service: a pool of independent engine instances with
iteration-boundary weight synchronisation (the decoupled deployment of
paper §4.1 — 'vLLM for inference, Megatron for training').

Two execution modes per instance:
  * real   — the jitted Sampler actually generates tokens (JAX releases the
             GIL during compute, so producer threads overlap with the
             consumer's training compute);
  * simulated — the instance sleeps according to a latency model and returns
             scripted responses. This is the trainer's-eye view of a REMOTE
             inference deployment (inference on separate devices), and is
             what the throughput benchmarks use so results reflect pipeline
             structure rather than this container's single CPU core.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, List, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.rl.rollout import RolloutBatch, Sampler


class InferenceInstance:
    def __init__(self, inst_id: int, cfg: ModelConfig, sampler: Optional[Sampler],
                 latency_fn: Optional[Callable] = None,
                 scripted_fn: Optional[Callable] = None):
        self.inst_id = inst_id
        self.cfg = cfg
        self.sampler = sampler
        self.latency_fn = latency_fn
        self.scripted_fn = scripted_fn
        self._params = None
        self._version = -1
        self._lock = threading.Lock()  # one request in flight per instance
        self.busy_time = 0.0

    def sync_weights(self, params, version: int) -> None:
        # device_put models the trainer -> rollout-worker weight broadcast
        self._params = jax.tree.map(jax.device_put, params)
        self._version = version

    @property
    def version(self) -> int:
        return self._version

    def generate_group(self, prompts: List[np.ndarray], key) -> tuple:
        """Returns (RolloutBatch, weight_version). Serialised per instance —
        models single-instance occupancy / continuous batching slot limits."""
        with self._lock:
            t0 = time.perf_counter()
            version = self._version
            if self.scripted_fn is not None:
                out = self.scripted_fn(prompts, key)
                if self.latency_fn is not None:
                    time.sleep(self.latency_fn(out))
            else:
                assert self.sampler is not None and self._params is not None
                out = self.sampler.generate(self._params, prompts, key)
                jax.block_until_ready(out.response_ids)
            self.busy_time += time.perf_counter() - t0
            return out, version


class InferencePool:
    """Evenly distributes incoming prompt groups across instances
    (paper §4.2.1: 'evenly distributes incoming prompts across available
    instances')."""

    def __init__(self, instances: List[InferenceInstance]):
        self.instances = instances
        self._rr = 0
        self._rr_lock = threading.Lock()

    def __len__(self) -> int:
        return len(self.instances)

    def pick(self) -> InferenceInstance:
        with self._rr_lock:
            inst = self.instances[self._rr % len(self.instances)]
            self._rr += 1
            return inst

    def sync_weights(self, params, version: int) -> None:
        for inst in self.instances:
            inst.sync_weights(params, version)

    def generate_group(self, prompts, key):
        return self.pick().generate_group(prompts, key)

    def reset_stats(self) -> None:
        for inst in self.instances:
            inst.busy_time = 0.0
