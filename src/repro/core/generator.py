"""The temporary data generator — the paper's core new component (§4.2):
a background thread running parallel worker 'coroutines' that dispatch
prompts to the inference service, score returned rollouts with the reward
module, and enqueue (advantage, rollout) into the shared queue.

It sits between the data loader and the trainer and is what converts the
synchronous pipeline into a producer-consumer one without touching the RL
algorithm.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, List, Optional

import jax
import numpy as np

from repro.core.engine import InferencePool
from repro.core.queue import RolloutGroup, RolloutQueue


class TemporaryDataGenerator:
    def __init__(self, pool: InferencePool, queue: RolloutQueue,
                 reward_fn: Callable, group_size: int,
                 num_workers: Optional[int] = None):
        self.pool = pool
        self.queue = queue
        self.reward_fn = reward_fn
        self.group_size = group_size
        # Group-at-a-time instances serialise one request each, so one
        # worker per instance saturates the pool. Token-level (paged)
        # instances decode concurrent groups together — enough workers to
        # fill every decode slot (ceil(slots/group) groups, +1 so a group
        # is waiting when another drains) turn into deeper continuous
        # batches, not lock contention.
        def _workers_for(inst) -> int:
            eng = inst.paged_engine
            return 1 if eng is None else -(-eng.B // eng.G) + 1
        per_inst = max(_workers_for(i) for i in pool.instances)
        self.num_workers = num_workers or max(2, per_inst * len(pool))
        self._threads: list = []

    # ------------------------------------------------------------------
    def submit_batch(self, batch: List[tuple], base_key,
                     weight_version: int) -> None:
        """batch: list of (problem, prompt_ids). Registers all groups with
        the queue *before* the background thread starts, then dispatches
        asynchronously (Algorithm 1 line 5)."""
        self.queue.register_pending(len(batch))
        keys = jax.random.split(base_key, len(batch))

        def produce_one(item, key):
            problem, prompt_ids = item
            prompts = [prompt_ids] * self.group_size          # G rollouts/group
            try:
                # version gate (DESIGN.md §Weight-plane): the request blocks
                # until the instance's active buffer holds at least the
                # iteration's weights, so overlapped bucket streaming can
                # never serve pre-flip params to this batch
                out, version = self.pool.generate_group(
                    prompts, key, min_version=weight_version)
                # repro: allow(host-sync): completed-rollout readback for
                # host-side reward scoring, once per finished group
                resp = np.asarray(out.response_ids)
                # repro: allow(host-sync): same completed-group readback
                lens = np.asarray(out.response_len)
                lps = getattr(out, "response_logprobs", None)
                lps = None if lps is None else np.asarray(lps, np.float32)
                rewards = np.asarray(
                    [self.reward_fn(resp[g, : lens[g]], problem.answer)
                     for g in range(self.group_size)], np.float32)
                self.queue.put(RolloutGroup(
                    uid=problem.uid, prompt_ids=np.asarray(prompt_ids, np.int32),
                    response_ids=resp, response_len=lens, rewards=rewards,
                    weight_version=version, response_logprobs=lps,
                    answer=problem.answer))
            except BaseException as exc:  # surface in the consumer, no deadlock
                self.queue.put_error(exc)
                raise

        def run():
            with ThreadPoolExecutor(max_workers=self.num_workers) as ex:
                futures = [ex.submit(produce_one, item, k)
                           for item, k in zip(batch, keys)]
                for f in futures:
                    # wait without re-raising: produce_one already forwarded
                    # the failure to the consumer via put_error, and a dying
                    # daemon thread would only trip the unraisable hook
                    f.exception()

        th = threading.Thread(target=run, daemon=True)
        self._threads.append(th)
        th.start()

    def join(self, timeout: Optional[float] = None) -> bool:
        """Wait for outstanding producer threads. Returns True when every
        thread has drained, False on timeout with producers still alive —
        mirroring ``RolloutQueue.wait_empty`` so callers can tell "drained"
        from "hung producer". ``timeout`` is one overall deadline shared by
        all threads, not per-thread. Still-alive threads stay tracked for
        the next call."""
        deadline = None if timeout is None else time.monotonic() + timeout
        for th in self._threads:
            th.join(timeout=None if deadline is None
                    else max(0.0, deadline - time.monotonic()))
        self._threads = [t for t in self._threads if t.is_alive()]
        return not self._threads
