"""On-policy invariant enforcement (Proposition 1).

Every rollout group is tagged with the weight version under which it was
generated. In periodic-async (and sync) mode the trainer asserts that every
group consumed during iteration t carries version t — turning the paper's
proof obligation into a runtime check. The off-policy baseline instead
*measures* staleness, which is what its algorithm tolerates.
"""
from __future__ import annotations

import dataclasses

from repro.core.queue import RolloutGroup


class OnPolicyViolation(AssertionError):
    pass


@dataclasses.dataclass
class OnPolicyMonitor:
    strict: bool = True
    checked: int = 0
    max_staleness_seen: int = 0

    def check(self, group: RolloutGroup, current_version: int) -> int:
        staleness = current_version - group.weight_version
        self.checked += 1
        self.max_staleness_seen = max(self.max_staleness_seen, staleness)
        if self.strict and staleness != 0:
            raise OnPolicyViolation(
                f"rollout group {group.uid} generated under version "
                f"{group.weight_version} but consumed at version "
                f"{current_version} — Proposition 1 violated")
        return staleness
