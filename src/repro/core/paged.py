"""Token-level paged continuous-batching decode engine for the rollout pool
(DESIGN.md §Continuous-batching).

The group-at-a-time path (``rl/rollout.py``) decodes ``max_new`` steps for
every row of every group and serialises whole groups per instance; this
engine decodes ONE token per step for a pool of slots that mixes rows from
many GRPO groups, admitting pending rows the step a slot frees (the
admission/eviction policy is ``core/cbatch.py``'s ``SlotScheduler``).

The KV cache is paged (``models/attention.py make_paged_kv_cache``):

  * one physical page pool per layer, stitched into logical sequences by a
    per-slot page table — vLLM's block table, JAX-native with fixed shapes;
  * a GRPO group's K rows list the SAME prompt pages, so the shared prompt
    is stored once per group — the cache-level extension of SPA
    (``core/spa.py``), which shares the prompt's *compute* in training while
    this shares its *memory* (and prefill compute) in inference;
  * pages are refcounted: response pages free when their row completes,
    prompt pages when the whole group has (eviction = completion).

Sampling is token-identical to the group-at-a-time ``Sampler`` under the
same PRNG key — greedy and sampled (``rl/rollout.py stepwise_keys`` +
``_sample_token_rows``); ``tests/test_paged_pool.py`` proves it. Page 0 is
the null page (pos 2^30, masked everywhere), page 1 the trash page inactive
slots write into.
"""
from __future__ import annotations

import dataclasses
import math
import threading
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.cbatch import Completed, SlotScheduler
from repro.data.tokenizer import Tokenizer
from repro.models import forward_hidden, init_caches, init_paged_caches
from repro.models.attention import INVALID_POS
from repro.models.layers import lm_head_weight
from repro.rl.rollout import (RolloutBatch, _sample_token_rows,
                              sampled_token_logprob, stepwise_keys)

NULL_PAGE = 0
TRASH_PAGE = 1
FIRST_PAGE = 2


class PageAllocator:
    """Host-side freelist + refcounts over the physical page pool.

    Prompt pages are allocated with refcount G (one per group row) and
    release once per completed row; response pages are single-owner."""

    def __init__(self, num_pages: int):
        assert num_pages > FIRST_PAGE, "page pool smaller than its reserves"
        self._free = list(range(num_pages - 1, FIRST_PAGE - 1, -1))
        self._ref: Dict[int, int] = {}

    @property
    def num_free(self) -> int:
        return len(self._free)

    def alloc(self, n: int, refcount: int = 1) -> Optional[List[int]]:
        if len(self._free) < n:
            return None
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._ref[p] = refcount
        return pages

    def release(self, pages: List[int]) -> None:
        for p in pages:
            self._ref[p] -= 1
            if self._ref[p] == 0:
                del self._ref[p]
                self._free.append(p)


@dataclasses.dataclass
class _Group:
    gid: int
    prompt: np.ndarray               # (Lp,) int32, already truncated
    G: int
    keys: np.ndarray                 # (max_new, 2) uint32 step keys
    max_new: int
    prompt_pages: Optional[List[int]] = None
    prompt_logits: Optional[jax.Array] = None   # (V,) f32 last-prompt logits
    done_rows: Dict[int, np.ndarray] = dataclasses.field(default_factory=dict)
    done_lps: Dict[int, np.ndarray] = dataclasses.field(default_factory=dict)
    finish_step: int = 0


@dataclasses.dataclass
class _Row:
    group: _Group
    idx: int                         # row index within the group (PRNG row)
    toks: list = dataclasses.field(default_factory=list)
    lps: list = dataclasses.field(default_factory=list)
    pages: Optional[List[int]] = None


class GroupHandle:
    """Future for a submitted group; resolves to (RolloutBatch, finish_step)."""

    def __init__(self, group: _Group):
        self._group = group
        self._event = threading.Event()
        self._result: Optional[RolloutBatch] = None

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> RolloutBatch:
        if not self._event.wait(timeout):
            raise TimeoutError(f"group {self._group.gid} not complete")
        return self._result


class PagedGroupEngine:
    """Continuous-batching decode over a shared paged KV pool.

    Thread-safe: ``submit`` registers a group's rows; any thread may drive
    ``step`` (the inference-instance convoy in ``core/engine.py`` does), so
    concurrently submitted groups batch together at token level."""

    def __init__(self, cfg: ModelConfig, *, num_slots: int, page_size: int,
                 num_pages: int, max_prompt_len: int, max_new_tokens: int,
                 group_size: int, temperature: float = 1.0, top_p: float = 1.0,
                 eos_id: int = Tokenizer.EOS, pad_id: int = Tokenizer.PAD,
                 capture_logprobs: bool = True):
        if num_slots < 1 or page_size < 1:
            raise ValueError(f"paged engine needs num_slots >= 1 and "
                             f"page_size >= 1, got {num_slots}/{page_size}")
        # fail at construction, not first weight sync (same rule
        # init_paged_caches enforces)
        assert cfg.family in ("dense", "moe") and not cfg.use_mla \
            and not cfg.is_encoder_decoder and not cfg.vision_prefix_len, \
            f"{cfg.name}: paged engine targets decoder-only GQA families " \
            "(see DESIGN.md §Arch-applicability)"
        assert cfg.sliding_window is None, \
            "paged engine does not reclaim windowed pages yet (DESIGN.md " \
            "§Known-issues)"
        self.cfg = cfg
        self.B = num_slots
        self.page = page_size
        self.Lp = max_prompt_len
        self.T = max_new_tokens
        self.G = group_size
        self.temperature = temperature
        self.top_p = top_p
        self.eos_id = eos_id
        self.pad_id = pad_id
        self.capture_logprobs = capture_logprobs
        self.n_prompt_pages = -(-max_prompt_len // page_size)
        self.n_resp_pages = -(-max_new_tokens // page_size)
        self.n_max = self.n_prompt_pages + self.n_resp_pages
        if num_pages == 0:      # auto-size: two full groups resident
            num_pages = FIRST_PAGE + 2 * (self.n_prompt_pages
                                          + group_size * self.n_resp_pages)
        self.P = num_pages
        if FIRST_PAGE + self.n_prompt_pages + self.n_resp_pages > num_pages:
            raise ValueError(
                f"page pool too small: {num_pages} pages cannot hold one "
                f"prompt ({self.n_prompt_pages}) + one response "
                f"({self.n_resp_pages}) + {FIRST_PAGE} reserved")

        self.params = None
        self.caches = None           # built lazily at first set_params
        self.logits = None           # (B, V) f32 per-slot next-token logits
        self.alloc = PageAllocator(num_pages)
        self.sched = SlotScheduler(num_slots)
        self._ptab = np.zeros((num_slots, self.n_max), np.int32)  # NULL rows
        self._mutex = threading.RLock()
        self._next_gid = 0
        self._handles: Dict[int, GroupHandle] = {}
        self.decode_steps = 0
        self.generated_tokens = 0

        self._prefill = jax.jit(self._prefill_group, donate_argnums=(1,))
        self._decode = jax.jit(self._decode_step, donate_argnums=(1,))
        self._invalidate = jax.jit(self._invalidate_pages, donate_argnums=(0,))

    # -- jitted cores -------------------------------------------------------

    def _prefill_group(self, params, caches, row, length, dest_pages):
        """Run the shared prompt ONCE (row: (1, Lp_pad) right-padded) and
        splice its per-layer KV into the pool at ``dest_pages`` — one
        physical prompt copy serves every row of the group. Returns
        (caches, last-token logits (V,))."""
        cfg = self.cfg
        Lp_pad = self.n_prompt_pages * self.page
        ar = jnp.arange(Lp_pad, dtype=jnp.int32)[None, :]
        real = ar < length
        positions = jnp.where(real, ar, 0).astype(jnp.int32)
        segments = jnp.where(real, 0, -1).astype(jnp.int32)
        tmp = init_caches(params, cfg, 1, Lp_pad)
        h, tmp, _, _ = forward_hidden(params, cfg, row, positions=positions,
                                      segments=segments, caches=tmp,
                                      cache_offset=0)
        W = lm_head_weight(params["embed"], cfg)
        h_last = jnp.take_along_axis(
            h, (length - 1)[None, :, None], axis=1)[:, 0]
        logits = jnp.einsum("bd,dv->bv", h_last.astype(jnp.float32),
                            W.astype(jnp.float32))[0]
        pos_write = jnp.where(real[0], ar[0], INVALID_POS).reshape(
            self.n_prompt_pages, self.page)

        new_caches = {}
        for grp in caches:           # "layers" (+ "prelude" for first-k-dense)
            pools, t = caches[grp]["kv"], tmp[grp]["kv"]
            nL = pools["k_pages"].shape[0]
            shp = (nL, self.n_prompt_pages, self.page) + t["k"].shape[-2:]
            new_caches[grp] = {"kv": {
                "k_pages": pools["k_pages"].at[:, dest_pages].set(
                    t["k"][:, 0].reshape(shp)),
                "v_pages": pools["v_pages"].at[:, dest_pages].set(
                    t["v"][:, 0].reshape(shp)),
                "pos_pages": pools["pos_pages"].at[:, dest_pages].set(
                    jnp.broadcast_to(pos_write, (nL,) + pos_write.shape)),
            }}
        return new_caches, logits

    def _decode_step(self, params, caches, logits, keys, rows, positions,
                     wslot, ptab, active):
        """One token for every slot: sample from the slot's current logits
        with its row's own step key, then advance through the paged cache.
        Inactive slots feed PAD at pos 2^30 and write into the trash page.
        With capture enabled, also returns log p(sampled id) under the raw
        distribution — the rollout-time behavior logprob
        (DESIGN.md §Tri-model-capture); disabled engines skip both the
        log-softmax and the extra device->host transfer."""
        cfg = self.cfg
        tok = _sample_token_rows(keys, logits, rows, self.G,
                                 self.temperature, self.top_p)
        tok = jnp.where(active, tok, self.pad_id)
        lp = (jnp.where(active, sampled_token_logprob(logits, tok), 0.0)
              if self.capture_logprobs else None)
        seg = jnp.where(active, 0, -1).astype(jnp.int32)[:, None]
        h, caches, _, _ = forward_hidden(
            params, cfg, tok[:, None], positions=positions[:, None],
            segments=seg, caches=caches, cache_offset=wslot, page_table=ptab)
        W = lm_head_weight(params["embed"], cfg)
        logits_next = jnp.einsum("bd,dv->bv", h[:, 0].astype(jnp.float32),
                                 W.astype(jnp.float32))
        return tok, lp, caches, logits_next

    def _invalidate_pages(self, caches, pages):
        """Mark freshly allocated response pages invalid — they may hold a
        previous sequence's stale (pos, kv) entries, which would otherwise
        pass the causal mask."""
        out = {}
        for grp in caches:
            pools = dict(caches[grp]["kv"])
            pools["pos_pages"] = pools["pos_pages"].at[:, pages].set(
                INVALID_POS)
            out[grp] = {"kv": pools}
        return out

    # -- host API -----------------------------------------------------------

    def set_params(self, params) -> None:
        """Swap weights (iteration-boundary sync). Must be quiescent —
        periodic asynchrony guarantees the queue is drained first."""
        with self._mutex:
            assert self.sched.idle, \
                "weight sync while rollouts in flight breaks Proposition 1"
            self.params = params
            if self.caches is None:
                self.caches = init_paged_caches(params, self.cfg, self.P,
                                                self.page)
                self.logits = jnp.zeros((self.B, self.cfg.vocab_size),
                                        jnp.float32)

    def submit(self, prompt, key, *, max_new: Optional[int] = None
               ) -> GroupHandle:
        """Register one GRPO group (G rollouts of one prompt). Returns a
        handle; drive ``step`` until it resolves."""
        assert self.params is not None, "set_params before submit"
        p = np.asarray(prompt, np.int32)[-self.Lp:]   # Sampler keeps the tail
        max_new = self.T if max_new is None else min(max_new, self.T)
        keys = np.asarray(stepwise_keys(key, max_new))
        with self._mutex:
            g = _Group(gid=self._next_gid, prompt=p, G=self.G, keys=keys,
                       max_new=max_new)
            self._next_gid += 1
            h = GroupHandle(g)
            self._handles[g.gid] = h
            for i in range(self.G):
                self.sched.submit(_Row(group=g, idx=i))
            return h

    @property
    def idle(self) -> bool:
        with self._mutex:
            return self.sched.idle

    def reset_stats(self) -> None:
        self.decode_steps = 0
        self.generated_tokens = 0

    # -- engine step --------------------------------------------------------

    def _admission_gate(self, row: _Row) -> bool:
        need = self.n_resp_pages
        if row.group.prompt_pages is None:
            need += -(-len(row.group.prompt) // self.page)
        return self.alloc.num_free >= need

    def _admit_row(self, slot: int, row: _Row) -> None:
        g = row.group
        if g.prompt_pages is None:
            n_pp = -(-len(g.prompt) // self.page)
            g.prompt_pages = self.alloc.alloc(n_pp, refcount=g.G)
            assert g.prompt_pages is not None, "admission gate let a row in "\
                "without pages for its prompt"
            dest = np.full((self.n_prompt_pages,), TRASH_PAGE, np.int32)
            dest[:n_pp] = g.prompt_pages
            row_arr = np.full((1, self.n_prompt_pages * self.page),
                              self.pad_id, np.int32)
            row_arr[0, : len(g.prompt)] = g.prompt
            self.caches, g.prompt_logits = self._prefill(
                self.params, self.caches, jnp.asarray(row_arr),
                jnp.asarray([len(g.prompt)], jnp.int32), jnp.asarray(dest))
        row.pages = self.alloc.alloc(self.n_resp_pages)
        assert row.pages is not None, "admission gate let a row in without "\
            "pages for its response"
        self.caches = self._invalidate(self.caches,
                                       jnp.asarray(row.pages, jnp.int32))
        tab = np.zeros((self.n_max,), np.int32)        # NULL padding
        tab[: len(g.prompt_pages)] = g.prompt_pages
        tab[len(g.prompt_pages): len(g.prompt_pages) + self.n_resp_pages] = \
            row.pages
        self._ptab[slot] = tab
        self.logits = self.logits.at[slot].set(g.prompt_logits)
        row.toks = []
        row.lps = []

    def _finish_row(self, slot: int, row: _Row, step: int) -> None:
        g = row.group
        g.done_rows[row.idx] = np.asarray(row.toks, np.int32)
        if self.capture_logprobs:
            g.done_lps[row.idx] = np.asarray(row.lps, np.float32)
        g.finish_step = step
        self.alloc.release(row.pages)
        self.alloc.release(g.prompt_pages)             # refcount G -> 0
        self.sched.evict(slot)
        self._ptab[slot] = 0
        if len(g.done_rows) == g.G:
            resp = np.full((g.G, self.T), self.pad_id, np.int32)
            lens = np.zeros((g.G,), np.int32)
            lps = np.zeros((g.G, self.T), np.float32)
            for i, r in g.done_rows.items():
                resp[i, : len(r)] = r
                lens[i] = len(r)
                if self.capture_logprobs:
                    lps[i, : len(r)] = g.done_lps[i]
            h = self._handles.pop(g.gid)
            h._result = RolloutBatch(
                response_ids=jnp.asarray(resp),
                response_len=jnp.asarray(lens),
                response_logprobs=(jnp.asarray(lps)
                                   if self.capture_logprobs else None))
            h._event.set()

    def step(self) -> bool:
        """One admission pass + one decode step for every slot. Returns
        False (and does nothing) when the engine is idle."""
        with self._mutex:
            # admit one row at a time: _admit_row consumes pages, and the
            # gate must see the freelist as it actually is for the NEXT row
            while True:
                admitted = self.sched.admit(self._admission_gate, limit=1)
                if not admitted:
                    break
                self._admit_row(*admitted[0])
            act = self.sched.active_slots()
            if not act:
                return False
            B = self.B
            keys = np.zeros((B, 2), np.uint32)
            rows = np.zeros((B,), np.int32)
            pos = np.full((B,), INVALID_POS, np.int32)
            wslot = np.full((B,), TRASH_PAGE * self.page, np.int32)
            active = np.zeros((B,), bool)
            for s in act:
                row = self.sched.slot_req[s]
                t = len(row.toks)
                keys[s] = row.group.keys[t]
                rows[s] = row.idx
                pos[s] = len(row.group.prompt) + t
                wslot[s] = (row.pages[t // self.page] * self.page
                            + t % self.page)
                active[s] = True
            tok, lp, self.caches, self.logits = self._decode(
                self.params, self.caches, self.logits, jnp.asarray(keys),
                jnp.asarray(rows), jnp.asarray(pos), jnp.asarray(wslot),
                jnp.asarray(self._ptab), jnp.asarray(active))
            # one host transfer for the step's outputs (lp is None when
            # capture is off) — this sync sits in the per-token hot loop
            tok, lp = jax.device_get((tok, lp))
            step = self.sched.tick()
            self.decode_steps += 1
            self.generated_tokens += len(act)
            for s in act:
                row = self.sched.slot_req[s]
                row.toks.append(int(tok[s]))
                if self.capture_logprobs:
                    row.lps.append(float(lp[s]))
                if (tok[s] == self.eos_id
                        or len(row.toks) >= row.group.max_new):
                    self._finish_row(s, row, step)
            return True

    # -- standalone serving -------------------------------------------------

    def serve(self, params, prompts: List[np.ndarray], key
              ) -> List[Completed]:
        """Serve independent requests (engine built with group_size=1; each
        prompt is its own group). Returns completions in completion order,
        mirroring ``ContinuousBatchingSampler.run``."""
        assert self.G == 1, "serve() treats each request as a 1-row group"
        self.set_params(params)
        keys = jax.random.split(key, len(prompts))
        handles = [self.submit(p, k) for p, k in zip(prompts, keys)]
        while self.step():
            pass
        done = []
        for rid, h in enumerate(handles):
            out = h.result(timeout=0)
            n = int(np.asarray(out.response_len)[0])
            done.append(Completed(
                request_id=rid,
                response_ids=np.asarray(out.response_ids)[0, :n],
                finish_step=h._group.finish_step))
        done.sort(key=lambda c: c.finish_step)
        return done
