"""Token-level paged continuous-batching decode engine for the rollout pool
(DESIGN.md §Continuous-batching, §Cache-backends).

The group-at-a-time path (``rl/rollout.py``) decodes ``max_new`` steps for
every row of every group and serialises whole groups per instance; this
engine decodes ONE token per step for a pool of slots that mixes rows from
many GRPO groups, admitting pending rows the step a slot frees (the
admission/eviction policy is ``core/cbatch.py``'s ``SlotScheduler``).

The KV cache is paged (``models/attention.py PagedCacheBackend``):

  * one physical page pool per layer, stitched into logical sequences by a
    per-slot page table — vLLM's block table, JAX-native with fixed shapes;
  * pages hold whatever the family caches per token (``cache_streams``):
    per-head K/V rows for GQA, compressed ``(ckv, kr)`` latent rows for MLA
    — absorbed MLA decode gathers latent pages directly;
  * a GRPO group's K rows list the SAME prompt pages, so the shared prompt
    is stored once per group — the cache-level extension of SPA
    (``core/spa.py``), which shares the prompt's *compute* in training while
    this shares its *memory* (and prefill compute) in inference;
  * pages are refcounted: response pages free when their row completes,
    prompt pages when the whole group has (eviction = completion);
  * response pages are allocated LAZILY, one page ahead of the write
    cursor, against a per-row page *credit* reserved at admission — the
    admission gate reads ``free - outstanding_credit``, so a row that is
    admitted can always take its next page (no mid-decode stall, no
    deadlock);
  * sliding-window configs RECLAIM out-of-window pages: once every live
    query position of a row has slid past a page's last token
    (``q_pos - last_pos >= window``) the page leaves the row's table and
    its reference returns to the freelist (refcount-aware for shared
    prompt pages — a page another row still sees stays resident). A 500k
    decode therefore occupies O(window) pages per row, not O(context);
  * ``prefix_cache=True`` layers the radix prefix cache (``core/radix.py``,
    DESIGN.md §Radix-prefix-cache) over the pool: admission walks the tree
    for the longest cached page-aligned prefix, retains the matched pages
    into the group's table, prefills ONLY the suffix into private pages,
    and inserts the completed prompt pages back — page sharing across
    byte-identical prompts becomes sharing across any common token-span
    prefix, across groups and across time. LRU eviction of idle cached
    pages rides the admission gate, so the page-credit deadlock-freedom
    argument is unchanged.

Sampling is token-identical to the group-at-a-time ``Sampler`` under the
same PRNG key — greedy and sampled (``rl/rollout.py stepwise_keys`` +
``_sample_token_rows``); ``tests/test_paged_pool.py`` proves it. Page 0 is
the null page (pos 2^30, masked everywhere), page 1 the trash page inactive
slots write into.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, require_engine_support
from repro.core.cbatch import Completed, SlotScheduler
from repro.data.tokenizer import Tokenizer
from repro.models import forward_hidden, init_caches, init_paged_caches
from repro.models.attention import INVALID_POS, cache_streams
from repro.models.layers import lm_head_weight
from repro.obs import trace as otrace
from repro.obs.metrics import metrics
from repro.rl.rollout import (RolloutBatch, _sample_token_rows,
                              sampled_token_logprob, stepwise_keys)

NULL_PAGE = 0
TRASH_PAGE = 1
FIRST_PAGE = 2


class PageAllocator:
    """Host-side freelist + refcounts over the physical page pool.

    Prompt pages are allocated with refcount G (one per group row) and
    release once per row (at completion, or earlier when the row's window
    slides past the page); response pages are single-owner."""

    def __init__(self, num_pages: int):
        assert num_pages > FIRST_PAGE, "page pool smaller than its reserves"
        self._free = list(range(num_pages - 1, FIRST_PAGE - 1, -1))
        self._ref: Dict[int, int] = {}
        self.min_free = len(self._free)      # high-water occupancy marker

    @property
    def num_free(self) -> int:
        return len(self._free)

    def alloc(self, n: int, refcount: int = 1) -> Optional[List[int]]:
        if len(self._free) < n:
            return None
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._ref[p] = refcount
        self.min_free = min(self.min_free, len(self._free))
        return pages

    def retain(self, pages: List[int], n: int = 1) -> None:
        """Add ``n`` references to already-live pages — the radix prefix
        cache shares a cached prompt page into a new group's table (one
        reference per row, plus the tree's own at insert)."""
        for p in pages:
            assert p in self._ref, f"retain of dead page {p}"
            self._ref[p] += n

    def refcount(self, page: int) -> int:
        return self._ref.get(page, 0)

    @property
    def num_live(self) -> int:
        """Pages currently referenced (freelist + live == pool capacity —
        the conservation invariant tests/test_radix_property.py checks)."""
        return len(self._ref)

    def release(self, pages: List[int]) -> int:
        """Drop one reference per page; returns how many pages actually
        went back to the freelist (a shared prompt page frees only when
        its last reference drops)."""
        freed = 0
        for p in pages:
            self._ref[p] -= 1
            assert self._ref[p] >= 0, f"negative refcount on page {p}"
            if self._ref[p] == 0:
                del self._ref[p]
                self._free.append(p)
                freed += 1
        return freed


@dataclasses.dataclass
class _Group:
    gid: int
    prompt: np.ndarray               # (Lp,) int32, already truncated
    G: int
    keys: np.ndarray                 # (max_new, 2) uint32 step keys
    max_new: int
    prompt_pages: Optional[List[int]] = None    # LIVE pages (window-visible)
    prompt_last: Optional[List[int]] = None     # last token pos per live page
    prompt_logits: Optional[jax.Array] = None   # (V,) f32 last-prompt logits
    # radix-cache match stashed by the admission gate for _admit_row:
    # (m, pages) — prompt page indices j0..m-1 already cached as `pages`
    match: Optional[tuple] = None
    # streaming delivery: called as on_token(row_idx, token_id) for every
    # committed token, in commit order (launch/serve.py RequestDriver)
    on_token: Optional[object] = None
    done_rows: Dict[int, np.ndarray] = dataclasses.field(default_factory=dict)
    done_lps: Dict[int, np.ndarray] = dataclasses.field(default_factory=dict)
    finish_step: int = 0


@dataclasses.dataclass
class _Row:
    group: _Group
    idx: int                         # row index within the group (PRNG row)
    toks: list = dataclasses.field(default_factory=list)
    lps: list = dataclasses.field(default_factory=list)
    pages: list = dataclasses.field(default_factory=list)  # resp page k -> id
    credit: int = 0                  # future page allocations reserved
    # live pages in logical order: (last_pos, table_idx, page_id, is_prompt)
    live: deque = dataclasses.field(default_factory=deque)
    # spec-decode state (DESIGN.md §Spec-decode): a fresh row still holds
    # its prefill logits in hand; a steady row's last committed token is
    # unfed and rides into the next verify block
    fresh: bool = True
    # scheduled frontier: device steps DISPATCHED for this row (>= the
    # committed len(toks) while a block is in flight) — the host-side
    # cursor of the device-resident decode loop
    sched_t: int = 0


@dataclasses.dataclass
class _Block:
    """One in-flight fused decode block (DESIGN.md
    §Device-resident-decode): the device accumulates its (D, B) token /
    logprob buffers while the host keeps only this plan of what was
    scheduled; ``_drain_block`` turns the buffers into commits once the
    async transfer lands."""
    plan: list                       # [(slot, row, t0, n_row), ...]
    base: int                        # engine step counter at dispatch
    toks: jax.Array                  # (D, B) int32 sampled tokens
    lps: Optional[jax.Array]         # (D, B) f32 raw logprobs (capture)


class GroupHandle:
    """Future for a submitted group; resolves to (RolloutBatch, finish_step)."""

    def __init__(self, group: _Group):
        self._group = group
        self._event = threading.Event()
        self._result: Optional[RolloutBatch] = None

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> RolloutBatch:
        if not self._event.wait(timeout):
            raise TimeoutError(f"group {self._group.gid} not complete")
        return self._result

    def host_rows(self) -> List[np.ndarray]:
        """Per-row committed tokens as host numpy arrays (row order) —
        the same arrays the RolloutBatch was assembled from, so serving
        paths can read completions with no device transfer. Completed
        groups only (call after ``result``)."""
        g = self._group
        return [g.done_rows[i] for i in range(g.G)]

    @property
    def finish_step(self) -> int:
        return self._group.finish_step


class PagedGroupEngine:
    """Continuous-batching decode over a shared paged KV/latent pool.

    Thread-safe: ``submit`` registers a group's rows; any thread may drive
    ``step`` (the inference-instance convoy in ``core/engine.py`` does), so
    concurrently submitted groups batch together at token level."""

    def __init__(self, cfg: ModelConfig, *, num_slots: int, page_size: int,
                 num_pages: int, max_prompt_len: int, max_new_tokens: int,
                 group_size: int, temperature: float = 1.0, top_p: float = 1.0,
                 eos_id: int = Tokenizer.EOS, pad_id: int = Tokenizer.PAD,
                 capture_logprobs: bool = True, spec_k: int = 0,
                 spec_draft: str = "prompt_lookup", spec_ngram: int = 3,
                 prefix_cache: bool = False, drain_interval: int = 1,
                 seed: int = 0):
        if num_slots < 1 or page_size < 1:
            raise ValueError(f"paged engine needs num_slots >= 1 and "
                             f"page_size >= 1, got {num_slots}/{page_size}")
        if drain_interval < 1:
            raise ValueError(f"drain_interval must be >= 1, "
                             f"got {drain_interval}")
        # fail at construction, not first weight sync (same matrix
        # init_paged_caches enforces — configs/base.py engine_support)
        require_engine_support(cfg, "paged")
        self.cfg = cfg
        self.B = num_slots
        self.page = page_size
        self.Lp = max_prompt_len
        self.T = max_new_tokens
        self.G = group_size
        self.window = cfg.sliding_window
        self.temperature = temperature
        self.top_p = top_p
        self.eos_id = eos_id
        self.pad_id = pad_id
        self.capture_logprobs = capture_logprobs
        # fused decode-block length D (DESIGN.md §Device-resident-decode):
        # one jitted lax.scan advances every slot D tokens and the host
        # drains the (D, B) buffers once per block. D == 1 drains every
        # block synchronously (legacy admission/eviction cadence); D > 1
        # pipelines one block deep — block n+1 is dispatched before block
        # n's transfer is read, so the host never sits on a device fence
        self.drain = drain_interval
        self.spec_k = spec_k
        if spec_k:
            require_engine_support(cfg, "spec")
            from repro.spec.draft import make_draft_provider
            self._draft = make_draft_provider(
                spec_draft, cfg, num_slots, spec_k=spec_k, ngram=spec_ngram,
                max_prompt_len=max_prompt_len,
                max_new_tokens=max_new_tokens, pad_id=pad_id, seed=seed)
        self.n_prompt_pages = -(-max_prompt_len // page_size)
        self.n_resp_pages = -(-max_new_tokens // page_size)
        self.n_max = self.n_prompt_pages + self.n_resp_pages
        j0_max, _ = self._prompt_page_range(max_prompt_len)
        live_pp_max = self.n_prompt_pages - j0_max
        if num_pages == 0:      # auto-size: two full groups resident
            num_pages = FIRST_PAGE + 2 * (live_pp_max
                                          + group_size
                                          * self._row_budget(max_new_tokens))
        self.P = num_pages
        if FIRST_PAGE + live_pp_max + 1 > num_pages:
            raise ValueError(
                f"page pool too small: {num_pages} pages cannot hold one "
                f"max-length prompt ({live_pp_max} window-visible pages) + "
                f"one response page + {FIRST_PAGE} reserved")

        self.params = None
        self.caches = None           # built lazily at first set_params
        self.logits = None           # (B, V) f32 per-slot next-token logits
        self.alloc = PageAllocator(num_pages)
        self.radix = None
        if prefix_cache:
            require_engine_support(cfg, "prefix")
            from repro.core.radix import RadixCache
            self.radix = RadixCache(page_size, self.alloc)
        self.sched = SlotScheduler(num_slots)
        self._ptab = np.zeros((num_slots, self.n_max), np.int32)  # NULL rows
        self._mutex = threading.RLock()
        self._next_gid = 0
        self._handles: Dict[int, GroupHandle] = {}
        self._outstanding = 0        # sum of row credits; free >= this always
        self.decode_steps = 0
        self.generated_tokens = 0
        self.reclaimed_pages = 0

        self._pending: Optional[_Block] = None   # in-flight fused block
        self._done = None            # (B,) bool device-resident stop flags
        self._prefill = jax.jit(self._prefill_group, donate_argnums=(1,))
        self._prefill_sfx = jax.jit(self._prefill_suffix, donate_argnums=(1,))
        self._decode = jax.jit(self._decode_block, donate_argnums=(1,))
        self._invalidate = jax.jit(self._invalidate_pages, donate_argnums=(0,))
        self._verify = jax.jit(self._verify_step, donate_argnums=(1,))
        self.reset_spec_stats()
        self.reset_prefix_stats()
        # registry metrics, cached once; pushed at BLOCK granularity from
        # the drain/commit paths, never per token (§Observability)
        _m = metrics()
        self._m_drain_blocks = _m.counter("paged.drain_blocks")
        self._m_reclaimed = _m.counter("paged.pages_reclaimed")
        self._m_pages_live = _m.gauge("paged.pages_live")
        self._m_drafted = _m.counter("spec.drafted_tokens")
        self._m_accepted = _m.counter("spec.accepted_tokens")
        self._m_prefix_hit = _m.counter("prefix.hit_pages")
        self._m_prefix_miss = _m.counter("prefix.miss_pages")
        self._m_prefix_evicted = _m.counter("prefix.evicted_pages")
        self._pushed_reclaimed = 0   # registry high-water for the counter

    def reset_spec_stats(self) -> None:
        with self._mutex:   # counters race with step() from other threads
            self.spec_steps = 0          # verify forwards x live rows
            self.drafted_tokens = 0      # drafts proposed
            self.accepted_tokens = 0     # drafts that survived verify
            self.rolled_back_pages = 0   # spec pages returned on reject

    def reset_prefix_stats(self) -> None:
        with self._mutex:
            self.prefix_hit_pages = 0     # prompt pages from the tree
            self.prefix_miss_pages = 0    # prompt pages prefilled cold
            self.prefix_inserted_pages = 0  # pages newly cached
            self.prefix_evicted_pages = 0   # cached pages reclaimed

    @property
    def acceptance_rate(self) -> float:
        with self._mutex:
            return (self.accepted_tokens / self.drafted_tokens
                    if self.drafted_tokens else 0.0)

    @property
    def prefix_hit_rate(self) -> float:
        """Fraction of cacheable prompt pages served from the radix tree."""
        with self._mutex:
            tot = self.prefix_hit_pages + self.prefix_miss_pages
            return self.prefix_hit_pages / tot if tot else 0.0

    def stats_snapshot(self) -> dict:
        """Atomic copy of the engine counters (one mutex hold — the
        scheduler diffs two snapshots for per-iteration metrics)."""
        with self._mutex:
            return {
                "decode_steps": self.decode_steps,
                "generated_tokens": self.generated_tokens,
                "reclaimed_pages": self.reclaimed_pages,
                "spec_steps": self.spec_steps,
                "drafted_tokens": self.drafted_tokens,
                "accepted_tokens": self.accepted_tokens,
                "prefix_hit_pages": self.prefix_hit_pages,
                "prefix_miss_pages": self.prefix_miss_pages,
                "prefix_evicted_pages": self.prefix_evicted_pages,
            }

    def status_snapshot(self) -> dict:
        """Live occupancy + counters for the ops plane's ``/status``
        (obs/server.py), in ONE mutex hold so a concurrent drive thread
        can never produce a torn multi-field view (pages_live consistent
        with slots_active, peak consistent with min_free). Derived rates
        are computed from the same hold."""
        with self._mutex:
            hit, miss = self.prefix_hit_pages, self.prefix_miss_pages
            drafted, accepted = self.drafted_tokens, self.accepted_tokens
            return {
                "slots_total": self.sched.num_slots,
                "slots_active": len(self.sched.active_slots()),
                "pending_requests": self.sched.num_pending,
                "pages_total": self.P - FIRST_PAGE,
                "pages_live": self.alloc.num_live,
                "pages_free": self.alloc.num_free,
                "peak_pages_used": (self.P - FIRST_PAGE)
                                   - self.alloc.min_free,
                "decode_steps": self.decode_steps,
                "generated_tokens": self.generated_tokens,
                "reclaimed_pages": self.reclaimed_pages,
                "prefix_hit_rate": hit / (hit + miss) if hit + miss else 0.0,
                "spec_acceptance": accepted / drafted if drafted else 0.0,
            }

    # -- page geometry ------------------------------------------------------

    def _n_total(self, max_new: int) -> int:
        """Response pages a row writes over its whole decode."""
        return -(-max_new // self.page)

    def _row_budget(self, max_new: int) -> int:
        """Worst-case SIMULTANEOUSLY-resident response pages for one row —
        the page credit the admission gate reserves. Without a window every
        written page stays (budget = all of them); with one, reclamation
        each step bounds the live span to `window` positions, which straddle
        at most window//page + 2 pages (+1 slack for the step's new page).
        Spec decode writes up to k tokens past the frontier before the
        window slides, so speculative pages widen the windowed budget by
        ceil(k/page) + 1 (never past the total — positions >= max_new are
        clamped to the trash page). A fused decode block (D > 1) writes
        up to D-1 tokens past the position reclamation last ran at, so
        the lookahead widens the windowed budget the same way."""
        n = self._n_total(max_new)
        if self.window is None:
            return n
        spec = ((self.spec_k + self.page - 1) // self.page + 1
                if self.spec_k else 0)
        look = ((self.drain - 1) // self.page + 1 if self.drain > 1 else 0)
        return min(n, self.window // self.page + 3 + spec + look)

    def _suffix_bucket(self, n_sfx_pages: int) -> int:
        """Pad a radix-miss suffix to a power-of-two page count so the
        suffix-prefill jit cache holds O(log n_prompt_pages) traces while a
        warm hit still prefills genuinely fewer tokens than a cold start
        (padding to the full prompt length would erase the FLOP saving)."""
        b = 1
        while b < n_sfx_pages:
            b *= 2
        return min(b, self.n_prompt_pages)

    def _prompt_page_range(self, plen: int):
        """(j0, n_pp): prompt pages j0..n_pp-1 are window-visible to at
        least the first response query (q_pos = plen); pages before j0 are
        dead on arrival and never allocated."""
        n_pp = -(-plen // self.page) if plen else 0
        j0 = 0 if self.window is None else max(0, (plen - self.window)
                                               // self.page)
        return j0, n_pp

    # -- jitted cores -------------------------------------------------------

    def _prefill_group(self, params, caches, row, length, dest_pages):
        """Run the shared prompt ONCE (row: (1, Lp_pad) right-padded) and
        splice its per-layer cache streams into the pool at ``dest_pages``
        — one physical prompt copy serves every row of the group. Returns
        (caches, last-token logits (V,)). The temporary prefill cache is
        full-length even for sliding-window configs (``ring=False``) so
        every prompt token is addressable for the splice; dead out-of-window
        pages land in the trash slot of ``dest_pages``."""
        cfg = self.cfg
        Lp_pad = self.n_prompt_pages * self.page
        ar = jnp.arange(Lp_pad, dtype=jnp.int32)[None, :]
        real = ar < length
        positions = jnp.where(real, ar, 0).astype(jnp.int32)
        segments = jnp.where(real, 0, -1).astype(jnp.int32)
        tmp = init_caches(params, cfg, 1, Lp_pad, ring=False)
        h, tmp, _, _ = forward_hidden(params, cfg, row, positions=positions,
                                      segments=segments, caches=tmp,
                                      cache_offset=0)
        W = lm_head_weight(params["embed"], cfg)
        h_last = jnp.take_along_axis(
            h, (length - 1)[None, :, None], axis=1)[:, 0]
        logits = jnp.einsum("bd,dv->bv", h_last.astype(jnp.float32),
                            W.astype(jnp.float32))[0]
        pos_write = jnp.where(real[0], ar[0], INVALID_POS).reshape(
            self.n_prompt_pages, self.page)

        streams = cache_streams(cfg)
        new_caches = {}
        for grp in caches:           # "layers" (+ "prelude" for first-k-dense)
            pools, t = caches[grp]["kv"], tmp[grp]["kv"]
            nL = pools["pos_pages"].shape[0]
            new = {}
            for name, shp in streams:
                arr = t[name][:, 0]          # (nL, Lp_pad, *shp)
                new[name + "_pages"] = pools[name + "_pages"].at[
                    :, dest_pages].set(arr.reshape(
                        (nL, self.n_prompt_pages, self.page) + shp))
            new["pos_pages"] = pools["pos_pages"].at[:, dest_pages].set(
                jnp.broadcast_to(pos_write, (nL,) + pos_write.shape))
            new_caches[grp] = {"kv": new}
        return new_caches, logits

    def _prefill_suffix(self, params, caches, tokens, positions, segs,
                        wslots, ptab, last):
        """Prefill ONLY a prompt's uncached suffix through the paged pool
        (radix-cache warm admission): the (1, S) block writes into the
        group's freshly allocated private pages via flat write slots while
        attending through the page table — which already lists the matched
        cached pages, so the suffix conditions on the shared prefix
        exactly as a cold full prefill would (attention.py routes S > 1 +
        per-token slots through the same multi-token decode path the spec
        verify block uses). Returns (caches, last-real-token logits)."""
        cfg = self.cfg
        h, caches, _, _ = forward_hidden(
            params, cfg, tokens, positions=positions, segments=segs,
            caches=caches, cache_offset=wslots, page_table=ptab)
        W = lm_head_weight(params["embed"], cfg)
        h_last = jnp.take_along_axis(h, last[:, None, None], axis=1)[:, 0]
        logits = jnp.einsum("bd,dv->bv", h_last.astype(jnp.float32),
                            W.astype(jnp.float32))[0]
        return caches, logits

    def _decode_block(self, params, caches, logits, done, keys, wslots,
                      valid, rows, pos0, active, ptab):
        """D fused decode steps for every slot (the device-resident decode
        loop, DESIGN.md §Device-resident-decode): one ``lax.scan`` samples,
        commits to the paged cache, stop-checks, and accumulates the (D, B)
        token/logprob buffers entirely on device — the host sees nothing
        until it drains the buffers.

        Per step j, a slot is LIVE when the host scheduled it (``active``,
        ``valid[j]``) and its device-resident ``done`` flag is clear; a row
        that samples EOS mid-block sets ``done`` and its remaining steps
        degrade to the inactive-slot convention (PAD at pos 2^30 into the
        trash page), so optimistically dispatched steps past a stop are
        harmless. ``done`` persists across blocks (reset at admission),
        which is what makes pipelined dispatch of block n+1 before block
        n's drain exact. With capture enabled the buffers also carry
        log p(emitted id) under the raw distribution — the rollout-time
        behavior logprob (§Tri-model-capture); disabled engines skip the
        log-softmax."""
        cfg = self.cfg
        W = lm_head_weight(params["embed"], cfg)

        def body(carry, xs):
            caches, logits, done = carry
            k_j, w_j, v_j, j = xs
            tok = _sample_token_rows(k_j, logits, rows, self.G,
                                     self.temperature, self.top_p)
            live = active & ~done & v_j
            tok = jnp.where(live, tok, self.pad_id)
            lp = (jnp.where(live, sampled_token_logprob(logits, tok), 0.0)
                  if self.capture_logprobs
                  else jnp.zeros((self.B,), jnp.float32))
            done = done | (live & (tok == self.eos_id))
            pos = jnp.where(live, pos0 + j, INVALID_POS).astype(jnp.int32)
            wsl = jnp.where(live, w_j, TRASH_PAGE * self.page).astype(
                jnp.int32)
            seg = jnp.where(live, 0, -1).astype(jnp.int32)[:, None]
            h, caches, _, _ = forward_hidden(
                params, cfg, tok[:, None], positions=pos[:, None],
                segments=seg, caches=caches, cache_offset=wsl,
                page_table=ptab)
            logits = jnp.einsum("bd,dv->bv", h[:, 0].astype(jnp.float32),
                                W.astype(jnp.float32))
            return (caches, logits, done), (tok, lp)

        D = keys.shape[0]
        (caches, logits, done), (toks, lps) = jax.lax.scan(
            body, (caches, logits, done),
            (keys, wslots, valid, jnp.arange(D, dtype=jnp.int32)))
        return toks, lps, caches, logits, done

    def _verify_step(self, params, caches, logits, tokens, positions, segs,
                     wslots, ptab, keys, folds, fresh, draft):
        """One k+1-token spec verify forward for every slot (DESIGN.md
        §Spec-decode): the block (the unfed committed token + k drafts, or
        k drafts + a masked pad slot for fresh rows) writes into its
        speculative pages and attends through the pool; ``fresh`` rows use
        their prefill logits as p_0. Masked slots point at the trash page
        with pos 2^30. The accept/commit walk runs ON DEVICE
        (``commit_block``), so the step returns one right-padded
        (B, k+1) commit buffer + per-row counts instead of verdicts the
        host would have to walk (§Device-resident-decode)."""
        from repro.spec.verify import commit_block, verify_block
        cfg = self.cfg
        h, caches, _, _ = forward_hidden(
            params, cfg, tokens, positions=positions, segments=segs,
            caches=caches, cache_offset=wslots, page_table=ptab)
        W = lm_head_weight(params["embed"], cfg)
        out = jnp.einsum("bsd,dv->bsv", h.astype(jnp.float32),
                         W.astype(jnp.float32))
        p = jnp.where(fresh[:, None, None],
                      jnp.concatenate([logits[:, None], out[:, :-1]],
                                      axis=1),
                      out)
        accept, alt, lp_d, lp_a = verify_block(
            p, draft, keys, folds, temperature=self.temperature,
            top_p=self.top_p, capture=self.capture_logprobs)
        toks, lps, count = commit_block(accept, alt, draft, lp_d, lp_a)
        return toks, lps, count, caches

    def _invalidate_pages(self, caches, pages):
        """Mark freshly allocated response pages invalid — they may hold a
        previous sequence's stale (pos, kv) entries, which would otherwise
        pass the causal mask."""
        out = {}
        for grp in caches:
            pools = dict(caches[grp]["kv"])
            pools["pos_pages"] = pools["pos_pages"].at[:, pages].set(
                INVALID_POS)
            out[grp] = {"kv": pools}
        return out

    # -- host API -----------------------------------------------------------

    def set_params(self, params) -> None:
        """Swap weights (iteration-boundary sync). Must be quiescent —
        periodic asynchrony guarantees the queue is drained first."""
        with self._mutex:
            assert self.sched.idle, \
                "weight sync while rollouts in flight breaks Proposition 1"
            self.params = params
            if self.caches is None:
                self.caches = init_paged_caches(params, self.cfg, self.P,
                                                self.page)
                self.logits = jnp.zeros((self.B, self.cfg.vocab_size),
                                        jnp.float32)
                self._done = jnp.zeros((self.B,), bool)

    def submit(self, prompt, key, *, max_new: Optional[int] = None,
               on_token=None) -> GroupHandle:
        """Register one GRPO group (G rollouts of one prompt). Returns a
        handle; drive ``step`` until it resolves. Raises immediately when
        the group could never be admitted — a prompt whose window-visible
        pages plus one row's page budget exceed what the pool can EVER free
        would otherwise sit in the admission queue forever.

        ``on_token(row_idx, token_id)`` streams every committed token in
        commit order (the serving tier's per-token delivery — TTFT/TPOT
        are measured at these calls); it runs under the engine mutex, so
        keep it cheap."""
        p = np.asarray(prompt, np.int32)[-self.Lp:]   # Sampler keeps the tail
        max_new = self.T if max_new is None else min(max_new, self.T)
        j0, n_pp = self._prompt_page_range(len(p))
        need = (n_pp - j0) + self._row_budget(max_new)
        avail = self.P - FIRST_PAGE
        if need > avail:
            raise ValueError(
                f"group can never be admitted: prompt of {len(p)} tokens "
                f"needs {n_pp - j0} pages + {self._row_budget(max_new)} "
                f"response pages per row = {need}, but the pool only ever "
                f"frees {avail} of its {self.P} pages")
        # repro: allow(host-sync): one key-table transfer per group
        # submission (admission bookkeeping is host-side), not per token
        keys = np.asarray(stepwise_keys(key, max_new))
        with self._mutex:
            # params is swapped by set_params under the mutex — read it
            # under the same lock (torn-read discipline)
            assert self.params is not None, "set_params before submit"
            g = _Group(gid=self._next_gid, prompt=p, G=self.G, keys=keys,
                       max_new=max_new, on_token=on_token)
            self._next_gid += 1
            h = GroupHandle(g)
            self._handles[g.gid] = h
            for i in range(self.G):
                self.sched.submit(_Row(group=g, idx=i))
            return h

    @property
    def idle(self) -> bool:
        with self._mutex:
            return self.sched.idle

    @property
    def peak_pages_used(self) -> int:
        """High-water physical page occupancy (excludes the reserves)."""
        return (self.P - FIRST_PAGE) - self.alloc.min_free

    def reset_stats(self) -> None:
        with self._mutex:   # RLock: the nested resets re-enter
            self.decode_steps = 0
            self.generated_tokens = 0
            self.reclaimed_pages = 0
            # registry high-water follows the local counter it diffs
            # against — left stale it would push a NEGATIVE delta into
            # the monotone registry counter on the next drain
            self._pushed_reclaimed = 0
            self.alloc.min_free = self.alloc.num_free
            self.reset_spec_stats()
            self.reset_prefix_stats()

    # -- engine step --------------------------------------------------------

    def _admission_gate(self, row: _Row) -> bool:
        """The freelist must cover this row's worst-case resident pages ON
        TOP of every admitted row's outstanding credit — credits make lazy
        allocation deadlock-free (an admitted row can always take its next
        page), so the gate reads free - outstanding, not raw free.

        With the radix prefix cache, matched pages cost nothing (they are
        retained, not allocated — the gate stashes the match on the group
        for ``_admit_row``, which runs back-to-back under the mutex with
        ``admit(limit=1)``), and a deficit first evicts idle cached pages
        — cached-but-unreferenced pages are as good as free."""
        need = self._row_budget(row.group.max_new)
        mpages = []
        if row.group.prompt_pages is None:
            j0, n_pp = self._prompt_page_range(len(row.group.prompt))
            m = j0
            if self.radix is not None:
                m, mpages = self.radix.lookup(row.group.prompt, j0=j0)
                row.group.match = (m, mpages)
            need += n_pp - m
        free = self.alloc.num_free - self._outstanding
        if free < need and self.radix is not None:
            evicted = len(self.radix.evict(need - free, protect=set(mpages)))
            self.prefix_evicted_pages += evicted
            self._m_prefix_evicted.add(evicted)
            free = self.alloc.num_free - self._outstanding
        return free >= need

    def _warm_prefill(self, g: _Group, m: int, new: List[int],
                      j0: int, n_pp: int) -> None:
        """Prefill a radix-hit prompt's uncached tail (page indices
        ``m..n_pp-1``) into its freshly allocated private pages ``new``,
        attending through the matched cached pages via the group's page
        table. The block is padded to a power-of-two page count
        (``_suffix_bucket``) so the jit cache stays warm without erasing
        the FLOP saving; pad slots are masked (segment -1, trash page)."""
        page = self.page
        m_tok = m * page
        sfx = g.prompt[m_tok:]
        S = max(2, self._suffix_bucket(len(new)) * page)
        ar = np.arange(S)
        real = ar < len(sfx)
        toks = np.full((1, S), self.pad_id, np.int32)
        toks[0, : len(sfx)] = sfx
        pos = np.where(real, m_tok + ar, 0).astype(np.int32)[None]
        segs = np.where(real, 0, -1).astype(np.int32)[None]
        wsl = np.full((S,), TRASH_PAGE * page, np.int32)
        for t in range(len(sfx)):
            a = m_tok + t
            wsl[t] = new[a // page - m] * page + a % page
        tab = np.zeros((1, self.n_max), np.int32)
        tab[0, : n_pp - j0] = g.prompt_pages
        inval = np.full((self.n_max,), TRASH_PAGE, np.int32)
        inval[: len(new)] = new
        self.caches = self._invalidate(self.caches, jnp.asarray(inval))
        self.caches, g.prompt_logits = self._prefill_sfx(
            self.params, self.caches, jnp.asarray(toks), jnp.asarray(pos),
            jnp.asarray(segs), jnp.asarray(wsl[None]), jnp.asarray(tab),
            jnp.asarray([len(sfx) - 1], jnp.int32))

    def _admit_row(self, slot: int, row: _Row) -> None:
        g = row.group
        if g.prompt_pages is None:
            j0, n_pp = self._prompt_page_range(len(g.prompt))
            m, mpages = g.match if g.match is not None else (j0, [])
            g.match = None
            if m > j0:
                # radix warm start: the matched cached pages join the
                # group's table with one reference per row (their KV is
                # bitwise what a cold prefill would write — core/radix.py);
                # only the uncached suffix is prefilled, into private pages
                self.alloc.retain(mpages, n=g.G)
                new = self.alloc.alloc(n_pp - m, refcount=g.G)
                assert new is not None, "admission gate let a row in " \
                    "without pages for its prompt suffix"
                g.prompt_pages = list(mpages) + new
                self.prefix_hit_pages += m - j0
                self.prefix_miss_pages += n_pp - m
                self._m_prefix_hit.add(m - j0)
                self._m_prefix_miss.add(n_pp - m)
            else:
                g.prompt_pages = self.alloc.alloc(n_pp - j0, refcount=g.G)
                assert g.prompt_pages is not None, "admission gate let a " \
                    "row in without pages for its prompt"
                if self.radix is not None:
                    self.prefix_miss_pages += n_pp - j0
                    self._m_prefix_miss.add(n_pp - j0)
            g.prompt_last = [min((j + 1) * self.page, len(g.prompt)) - 1
                             for j in range(j0, n_pp)]
            if m > j0:
                # span measures host-side dispatch (the prefill itself is
                # asynchronous; its device time surfaces at the next drain)
                with otrace.span("paged.prefill", gid=g.gid, warm=True):
                    self._warm_prefill(g, m, g.prompt_pages[m - j0:],
                                       j0, n_pp)
            else:
                with otrace.span("paged.prefill", gid=g.gid, warm=False):
                    dest = np.full((self.n_prompt_pages,), TRASH_PAGE,
                                   np.int32)
                    dest[j0:n_pp] = g.prompt_pages
                    row_arr = np.full((1, self.n_prompt_pages * self.page),
                                      self.pad_id, np.int32)
                    row_arr[0, : len(g.prompt)] = g.prompt
                    self.caches, g.prompt_logits = self._prefill(
                        self.params, self.caches, jnp.asarray(row_arr),
                        jnp.asarray([len(g.prompt)], jnp.int32),
                        jnp.asarray(dest))
            if self.radix is not None:
                # cache every COMPLETE prompt page (cold and warm alike —
                # insert skips spans already cached); a trailing partial
                # page is row-private and never enters the tree
                self.prefix_inserted_pages += self.radix.insert(
                    g.prompt, {j: g.prompt_pages[j - j0]
                               for j in range(j0, len(g.prompt) // self.page)})
        row.pages = []
        row.credit = self._row_budget(g.max_new)
        self._outstanding += row.credit
        row.live = deque((last, i, pid, True) for i, (last, pid)
                         in enumerate(zip(g.prompt_last, g.prompt_pages)))
        tab = np.zeros((self.n_max,), np.int32)        # NULL padding
        tab[: len(g.prompt_pages)] = g.prompt_pages
        self._ptab[slot] = tab
        # both updates are dispatched AFTER any in-flight fused block, so
        # they land on its OUTPUT state: the pending block saw this slot
        # masked (its previous row's done flag), the next block samples
        # from the prompt logits with a cleared stop flag
        self.logits = self.logits.at[slot].set(g.prompt_logits)
        self._done = self._done.at[slot].set(False)
        row.toks = []
        row.lps = []
        row.fresh = True
        row.sched_t = 0
        if self.spec_k:
            self._draft.start(slot, g.prompt)

    def _alloc_resp_page(self, slot: int, row: _Row, k: int) -> int:
        """Lazily take response page k (the write cursor just crossed a
        page boundary) out of the row's reserved credit; returns the page
        id (the step batches all fresh pages into ONE invalidation call)."""
        g = row.group
        assert row.credit > 0, "page-credit invariant violated: row admitted "\
            "without enough budget for its next page"
        pages = self.alloc.alloc(1)
        assert pages is not None, "freelist below outstanding credit"
        row.credit -= 1
        self._outstanding -= 1
        pid = pages[0]
        row.pages.append(pid)
        ti = len(g.prompt_pages) + k
        self._ptab[slot, ti] = pid
        row.live.append((len(g.prompt) + (k + 1) * self.page - 1, ti, pid,
                         False))
        if not self.spec_k and len(row.pages) == self._n_total(g.max_new):
            # last page this row will ever write: return unused credit.
            # Spec engines skip the early return — a speculative final
            # page may be ROLLED BACK and re-allocated later, so its
            # credit must stay symmetric (alloc -1 / rollback +1) until
            # the row finishes (_finish_row releases the remainder).
            self._outstanding -= row.credit
            row.credit = 0
        return pid

    def _rollback_row(self, slot: int, row: _Row, vf_rp: int) -> None:
        """Return speculative response pages holding ONLY rejected drafts
        to the freelist (DESIGN.md §Spec-decode): after a commit that fed
        through response position ``vf_rp``, any page whose first slot is
        past it contains nothing a future query may see — pop it off the
        row's table, release it, and re-arm the row's page credit (the
        exact inverse of ``_alloc_resp_page``, so the admission-gate
        invariant resident + credit == budget is untouched). Partially
        valid pages stay: their stale tail slots are overwritten by the
        next verify block before any read."""
        keep = vf_rp // self.page if vf_rp >= 0 else -1
        while len(row.pages) - 1 > keep:
            pid = row.pages.pop()
            last, ti, pid_live, is_prompt = row.live.pop()
            assert pid_live == pid and not is_prompt, \
                "rollback must pop the most recent speculative page"
            self._ptab[slot, ti] = NULL_PAGE
            self.alloc.release([pid])
            row.credit += 1
            self._outstanding += 1
            self.rolled_back_pages += 1

    def _reclaim_row(self, slot: int, row: _Row, q_pos: int) -> None:
        """Sliding-window page reclamation: positions only grow, so once
        ``q_pos - last_pos >= window`` no present or future query of this
        row can see the page — drop it from the row's table and release the
        row's reference (a prompt page shared with rows that can still see
        it stays resident via its refcount)."""
        w = self.window
        n_total = self._n_total(row.group.max_new)
        while row.live and q_pos - row.live[0][0] >= w:
            _, ti, pid, is_prompt = row.live.popleft()
            self._ptab[slot, ti] = NULL_PAGE
            # count pages actually returned to the freelist — a shared
            # prompt page frees once, not once per row that drops it
            self.reclaimed_pages += self.alloc.release([pid])
            if not is_prompt and len(row.pages) < n_total:
                # the freed page re-arms this row's credit: resident +
                # credit stays equal to the admission-time budget
                row.credit += 1
                self._outstanding += 1

    def _finish_row(self, slot: int, row: _Row, step: int) -> None:
        g = row.group
        g.done_rows[row.idx] = np.asarray(row.toks, np.int32)
        if self.capture_logprobs:
            g.done_lps[row.idx] = np.asarray(row.lps, np.float32)
        g.finish_step = step
        for _, _, pid, _ in row.live:   # resident resp pages + prompt refs
            self.alloc.release([pid])
        row.live.clear()
        self._outstanding -= row.credit
        row.credit = 0
        self.sched.evict(slot)
        self._ptab[slot] = 0
        if len(g.done_rows) == g.G:
            resp = np.full((g.G, self.T), self.pad_id, np.int32)
            lens = np.zeros((g.G,), np.int32)
            lps = np.zeros((g.G, self.T), np.float32)
            for i, r in g.done_rows.items():
                resp[i, : len(r)] = r
                lens[i] = len(r)
                if self.capture_logprobs:
                    lps[i, : len(r)] = g.done_lps[i]
            h = self._handles.pop(g.gid)
            h._result = RolloutBatch(
                response_ids=jnp.asarray(resp),
                response_len=jnp.asarray(lens),
                response_logprobs=(jnp.asarray(lps)
                                   if self.capture_logprobs else None))
            h._event.set()

    def step(self) -> bool:
        """One admission pass + one fused D-step decode block for every
        slot (spec engines verify a k+1-token block instead —
        §Spec-decode). Returns False (and does nothing) when the engine is
        idle and no block is in flight.

        ``drain_interval == 1`` dispatches and drains synchronously — the
        legacy admission/eviction cadence, one drain per token step.
        ``drain_interval > 1`` runs the one-deep pipeline: block n+1 is
        built and dispatched BEFORE block n's buffers are read, so block
        n's device->host transfer (started asynchronously at dispatch)
        overlaps block n+1's host-side build and device compute. The
        optimistic dispatch assumes no row stopped inside the in-flight
        block; the device-resident ``done`` flags make that exact (a
        stopped row's extra steps are masked to the trash page), and the
        drain simply skips plan entries whose slot was re-assigned."""
        with self._mutex:
            # admit one row at a time: _admit_row consumes pages, and the
            # gate must see the freelist as it actually is for the NEXT row
            while True:
                admitted = self.sched.admit(self._admission_gate, limit=1)
                if not admitted:
                    break
                self._admit_row(*admitted[0])
            act = self.sched.active_slots()
            if self.spec_k:
                return self._spec_step(act) if act else False
            nxt = self._dispatch_block(act) if act else None
            if self.drain == 1:
                if nxt is not None:
                    self._drain_block(nxt)
                return nxt is not None
            prev, self._pending = self._pending, nxt
            if prev is not None:
                self._drain_block(prev)
            return nxt is not None or prev is not None

    def _dispatch_block(self, act: List[int]) -> Optional[_Block]:
        """Build one fused decode block for the active slots and dispatch
        it: per slot, schedule up to D steps from its frontier
        (``row.sched_t`` — NOT the committed length, which lags while a
        block is in flight), allocating the response pages those steps
        write and reclaiming out-of-window pages at the block's first
        query position. All page bookkeeping stays host-side; the device
        receives the per-step keys/write-slots/valid masks as (D, B)
        arrays and runs free."""
        B, D, page = self.B, self.drain, self.page
        t_disp = time.perf_counter()
        keys = np.zeros((D, B, 2), np.uint32)
        wsl = np.full((D, B), TRASH_PAGE * page, np.int32)
        valid = np.zeros((D, B), bool)
        rows = np.zeros((B,), np.int32)
        pos0 = np.full((B,), INVALID_POS, np.int32)
        active = np.zeros((B,), bool)
        # fixed worst-case shape: each slot crosses at most D//page + 1
        # page boundaries per block (trash-padding keeps the jit cache at
        # one trace)
        fresh = np.full((B * (D // page + 2),), TRASH_PAGE, np.int32)
        n_fresh = 0
        plan = []
        for s in act:
            row = self.sched.slot_req[s]
            g = row.group
            t0 = row.sched_t
            if t0 >= g.max_new:      # fully scheduled; awaiting drain
                continue
            q0 = len(g.prompt) + t0
            if self.window is not None:
                self._reclaim_row(s, row, q0)
            n_row = min(D, g.max_new - t0)
            for t in range(t0, t0 + n_row):
                k = t // page
                if k == len(row.pages):       # crossed a page boundary
                    fresh[n_fresh] = self._alloc_resp_page(s, row, k)
                    n_fresh += 1
                keys[t - t0, s] = g.keys[t]
                wsl[t - t0, s] = row.pages[k] * page + t % page
                valid[t - t0, s] = True
            rows[s] = row.idx
            pos0[s] = q0
            active[s] = True
            row.sched_t = t0 + n_row
            plan.append((s, row, t0, n_row))
        if not plan:
            return None
        if n_fresh:
            # one fixed-shape invalidation for every page freshly
            # allocated this block — stale (pos, kv) from a previous
            # occupant would otherwise pass the causal mask
            self.caches = self._invalidate(self.caches, jnp.asarray(fresh))
        base = self.sched.step
        self.sched.step += D
        toks, lps, self.caches, self.logits, self._done = self._decode(
            self.params, self.caches, self.logits, self._done,
            jnp.asarray(keys), jnp.asarray(wsl), jnp.asarray(valid),
            jnp.asarray(rows), jnp.asarray(pos0), jnp.asarray(active),
            jnp.asarray(self._ptab))
        self.decode_steps += D
        # start the device->host transfer NOW so it overlaps the next
        # block's build + compute; the drain then finds it landed
        for buf in (toks, lps):
            if hasattr(buf, "copy_to_host_async"):
                buf.copy_to_host_async()
        # host-side build+dispatch span (the device runs free; its time
        # surfaces in the matching paged.drain span)
        otrace.complete("paged.dispatch", t_disp, time.perf_counter(),
                        slots=len(plan), steps=D)
        return _Block(plan=plan, base=base, toks=toks, lps=lps)

    def _drain_block(self, blk: _Block) -> None:
        """Commit one drained block into host bookkeeping — the ONLY
        device->host touch of the non-spec decode path, once per D steps
        (or per row completion) instead of per token."""
        t_drain = time.perf_counter()
        g0 = self.generated_tokens
        # repro: allow(host-sync): one buffered readback per drained
        # D-step block (transfer started async at dispatch), not per
        # token — DESIGN.md §Device-resident-decode drain protocol
        toks, lps = jax.device_get((blk.toks, blk.lps))
        for s, row, t0, n_row in blk.plan:
            if self.sched.slot_req[s] is not row:
                # row finished inside an EARLIER block; these optimistic
                # steps ran device-masked (done flag) and wrote nothing
                continue
            g = row.group
            assert len(row.toks) == t0, "drain out of order"
            for j in range(n_row):
                tv = int(toks[j, s])
                row.toks.append(tv)
                if self.capture_logprobs:
                    row.lps.append(float(lps[j, s]))
                self.generated_tokens += 1
                if g.on_token is not None:
                    g.on_token(row.idx, tv)
                if tv == self.eos_id or len(row.toks) >= g.max_new:
                    self._finish_row(s, row, blk.base + j + 1)
                    break
        otrace.complete("paged.drain", t_drain, time.perf_counter(),
                        slots=len(blk.plan),
                        tokens=self.generated_tokens - g0)
        self._push_block_metrics()

    def _push_block_metrics(self) -> None:
        """Flush block-granularity deltas into the metrics registry (one
        counter add per drained block, not per page event)."""
        self._m_drain_blocks.add(1)
        self._m_pages_live.set(self.alloc.num_live)
        d = self.reclaimed_pages - self._pushed_reclaimed
        if d:
            self._m_reclaimed.add(d)
            self._pushed_reclaimed = self.reclaimed_pages
        otrace.counter("paged.pages_live", self.alloc.num_live)

    def _drain_verify(self, ctoks, clps, count):
        """Drain one fused verify block's commit buffers (the spec plane's
        analogue of ``_drain_block``): the accept/commit walk already ran
        on device (``spec/verify.py commit_block``), so the host reads one
        right-padded buffer per block."""
        for buf in (ctoks, clps, count):
            if hasattr(buf, "copy_to_host_async"):
                buf.copy_to_host_async()
        # repro: allow(host-sync): one buffered readback per verify block
        # (device-side commit walk) — DESIGN.md §Device-resident-decode
        return jax.device_get((ctoks, clps, count))

    def _spec_step(self, act: List[int]) -> bool:
        """One spec-decode engine step (DESIGN.md §Spec-decode), called
        under the mutex with ``act`` the live slots: draft k tokens per
        row, pre-allocate the block's speculative pages against the row
        credits, run ONE k+1-token verify forward whose device-side commit
        walk yields 1..k+1 committed tokens per row, drain the commit
        buffers, and roll rejected speculative pages back to the
        freelist."""
        B, k, page = self.B, self.spec_k, self.page
        t_draft = time.perf_counter()
        drafts = self._draft.propose(act, k)
        otrace.complete("spec.draft", t_draft, time.perf_counter(),
                        slots=len(act), k=k)
        tokens = np.full((B, k + 1), self.pad_id, np.int32)
        positions = np.full((B, k + 1), INVALID_POS, np.int32)
        segs = np.full((B, k + 1), -1, np.int32)
        wslots = np.full((B, k + 1), TRASH_PAGE * page, np.int32)
        keys = np.zeros((B, 2), np.uint32)
        folds = np.zeros((B,), np.int32)
        fresh_m = np.zeros((B,), bool)
        fresh_pages = np.full((B * (k + 1),), TRASH_PAGE, np.int32)
        n_fresh = 0
        for s in act:
            row = self.sched.slot_req[s]
            g = row.group
            rc = len(row.toks)
            start_rp = rc if row.fresh else rc - 1
            if self.window is not None:
                self._reclaim_row(s, row, len(g.prompt) + start_rp)
            if row.fresh:
                blk = [(int(drafts[s, j]), rc + j) for j in range(k)] \
                    + [(self.pad_id, None)]
            else:
                blk = [(row.toks[-1], rc - 1)] \
                    + [(int(drafts[s, j]), rc + j) for j in range(k)]
            for j, (tv, rp) in enumerate(blk):
                if rp is None or rp >= g.max_new:
                    continue                    # masked slot: trash page
                pidx = rp // page
                while pidx >= len(row.pages):
                    fresh_pages[n_fresh] = self._alloc_resp_page(
                        s, row, len(row.pages))
                    n_fresh += 1
                tokens[s, j] = tv
                positions[s, j] = len(g.prompt) + rp
                segs[s, j] = 0
                wslots[s, j] = row.pages[pidx] * page + rp % page
            keys[s] = g.keys[rc]
            folds[s] = row.idx
            fresh_m[s] = row.fresh
        if n_fresh:
            self.caches = self._invalidate(self.caches,
                                           jnp.asarray(fresh_pages))
        t_verify = time.perf_counter()
        ctoks, clps, count, self.caches = self._verify(
            self.params, self.caches, self.logits, jnp.asarray(tokens),
            jnp.asarray(positions), jnp.asarray(segs), jnp.asarray(wslots),
            jnp.asarray(self._ptab), jnp.asarray(keys), jnp.asarray(folds),
            jnp.asarray(fresh_m), jnp.asarray(drafts))
        # host-side dispatch only — the verify block's device time (and
        # its one buffered readback) lands inside the spec.commit span
        otrace.complete("spec.verify", t_verify, time.perf_counter(),
                        slots=len(act))
        self._commit_spec_rows(act, ctoks, clps, count)
        return True

    def _commit_spec_rows(self, act, ctoks, clps, count) -> None:
        """Drain one verify block and commit its rows -- the host half
        of the spec step, one frame below the hot entry point so the hot
        tier itself stays sync-free (DESIGN.md §Device-resident-decode).
        After the buffered drain the walk touches only host numpy."""
        from repro.spec.sampler import truncate_commit
        k = self.spec_k
        t_commit = time.perf_counter()
        g0 = self.generated_tokens
        ctoks, clps, count = self._drain_verify(ctoks, clps, count)
        step = self.sched.tick()
        self.decode_steps += 1
        for s in act:
            row = self.sched.slot_req[s]
            g = row.group
            rc = len(row.toks)
            n = int(count[s])
            ct = [int(t) for t in ctoks[s, :n]]
            cl = [float(x) for x in clps[s, :n]]
            self.spec_steps += 1
            self.drafted_tokens += k
            self.accepted_tokens += n - 1
            ct, cl, row_done = truncate_commit(ct, cl, g.max_new - rc,
                                               self.eos_id)
            row.toks.extend(ct)
            if self.capture_logprobs:
                row.lps.extend(cl)
            if g.on_token is not None:
                for tv in ct:
                    g.on_token(row.idx, int(tv))
            self._draft.commit(s, ct)
            self.generated_tokens += len(ct)
            row.fresh = False
            if row_done:
                self._finish_row(s, row, step)
                self._draft.stop(s)
            else:
                # speculative pages past the committed-and-fed frontier
                # hold only rejected drafts — roll them back
                self._rollback_row(s, row, len(row.toks) - 2)
        committed = self.generated_tokens - g0
        otrace.complete("spec.commit", t_commit, time.perf_counter(),
                        slots=len(act), tokens=committed)
        self._m_drafted.add(k * len(act))
        self._m_accepted.add(max(0, committed - len(act)))
        self._push_block_metrics()

    # -- standalone serving -------------------------------------------------

    def serve(self, params, prompts: List[np.ndarray], key
              ) -> List[Completed]:
        """Serve independent requests (engine built with group_size=1; each
        prompt is its own group). Returns completions in completion order,
        mirroring ``ContinuousBatchingSampler.run``."""
        assert self.G == 1, "serve() treats each request as a 1-row group"
        self.set_params(params)
        keys = jax.random.split(key, len(prompts))
        handles = [self.submit(p, k) for p, k in zip(prompts, keys)]
        while self.step():
            pass
        done = []
        for rid, h in enumerate(handles):
            h.result(timeout=0)       # completion check (raises if not)
            g = h._group
            # the committed tokens already live host-side in done_rows —
            # no device readback needed to assemble completions
            done.append(Completed(
                request_id=rid,
                response_ids=g.done_rows[0],
                finish_step=g.finish_step))
        done.sort(key=lambda c: c.finish_step)
        return done
