"""Prefix-state sharing — the SSM analogue of Shared-Prompt Attention
(DESIGN.md §Arch-applicability).

SPA is an *attention-mask* optimisation and cannot apply to attention-free
architectures. For SSMs the equivalent holds through the state: all K
responses of a GRPO group continue from the SAME prompt state, so the
prompt's O(Lp) recurrent scan is computed ONCE and its (SSD state, conv
tail) pair is broadcast to the K response continuations.

Complexity: standard per-sample training computes the prompt K times —
O(K·(Lp+Lr)) SSD steps; prefix sharing computes O(Lp + K·Lr): the same
K-fold prompt-compute elimination as SPA's Eq. 5, in the linear-time
regime. Exactness: the continuation is token-exact (`tests/test_prefix.py`)
— the conv boundary is carried explicitly (pre-conv tail), and gradients
flow through the shared prompt pass once, which equals the sum of the K
per-sample prompt gradients by linearity of autodiff accumulation.

Layout convention matches ``core/spa.py``: each response row starts with a
copy of the LAST prompt token (its hidden state predicts r_0), so the
prompt pass covers prompt[:-1].
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import forward_hidden, token_logprobs
from repro.models.ssm import make_ssm_cache


def zero_ssm_states(params: dict, cfg: ModelConfig, batch: int) -> dict:
    """Per-layer zero continuation states {state, conv}, stacked over the
    scanned body layers (leading L axis) — the body_init trigger for
    forward_hidden(initial_ssm_states=...)."""
    # repro: allow(support-matrix): the INVERSE of an engine-matrix row —
    # prefix-state sharing exists only for the SSM families the paged
    # planes exclude; the assert documents that scope
    assert cfg.family == "ssm", "prefix-state sharing targets SSM archs"
    n_body = cfg.num_layers
    one = make_ssm_cache(cfg, batch, jnp.float32)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a, (n_body,) + a.shape), one)


def prompt_states(params: dict, cfg: ModelConfig, prompt_ids: jax.Array
                  ) -> Tuple[jax.Array, dict]:
    """Run the shared prompt ONCE (minus its last token). prompt_ids:
    (1, Lp). Returns (last_hidden (1, d), per-layer states pytree)."""
    B, Lp = prompt_ids.shape
    h, _, _, states = forward_hidden(
        params, cfg, prompt_ids[:, :-1],
        initial_ssm_states=zero_ssm_states(params, cfg, B))
    return h[:, -1], states


def broadcast_states(states: dict, k: int) -> dict:
    """(L, 1, ...) per-layer states -> (L, K, ...) for K response rows."""
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a, a.shape[:1] + (k,) + a.shape[2:]),
        states)


def shared_prompt_logprobs(params: dict, cfg: ModelConfig,
                           prompt_ids: jax.Array, resp_rows: jax.Array,
                           labels: jax.Array) -> jax.Array:
    """Per-token log-probs for K responses sharing one prompt.

    prompt_ids: (1, Lp); resp_rows: (K, 1+Lr) where resp_rows[:, 0] ==
    prompt_ids[0, -1] (the SPA row convention); labels: (K, 1+Lr) with
    labels[:, i] = the token predicted FROM position i (r_0..r_{Lr-1}, then
    anything/ignored at the final slot).

    Returns (K, 1+Lr) f32 log-probs; caller applies its own loss mask.
    """
    B, Lp = prompt_ids.shape
    K, S = resp_rows.shape
    _, states = prompt_states(params, cfg, prompt_ids)
    states_k = broadcast_states(states, K)
    positions = jnp.broadcast_to(
        jnp.arange(Lp - 1, Lp - 1 + S, dtype=jnp.int32)[None], (K, S))
    h, _, _, _ = forward_hidden(
        params, cfg, resp_rows, positions=positions,
        initial_ssm_states=states_k)
    return token_logprobs(params, cfg, h, labels)
