"""The shared rollout queue (paper Figure 1, Algorithm 1 line 1).

Producer coroutines enqueue completed rollout *groups* (one prompt, G
responses, rewards); the consumer (main thread) dequeues in completion-time
order. Every item is tagged with the weight version that generated it so the
on-policy invariant (Proposition 1) can be asserted, not assumed.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import List, Optional

import numpy as np


@dataclasses.dataclass
class RolloutGroup:
    uid: int                       # problem uid
    prompt_ids: np.ndarray         # (Lp,) int32
    response_ids: np.ndarray       # (G, T) int32, PAD after EOS
    response_len: np.ndarray       # (G,) int32
    rewards: np.ndarray            # (G,) float32
    weight_version: int            # policy iteration t that generated this
    # (G, T) float32 rollout-captured log p(sampled id) under the raw model
    # distribution — the behavior/old-policy logprobs the trainer would
    # otherwise recompute (DESIGN.md §Tri-model-capture). None when the
    # producing instance does not capture (scripted/simulated).
    response_logprobs: Optional[np.ndarray] = None
    answer: Optional[int] = None
    meta: Optional[dict] = None


class RolloutQueue:
    """Thread-safe FIFO with wait-empty support (Algorithm 1 line 3)."""

    def __init__(self, maxsize: int = 0):
        self._q: queue.Queue = queue.Queue(maxsize=maxsize)
        self._outstanding = 0
        self._lock = threading.Condition()

    def register_pending(self, n: int = 1) -> None:
        """Producer declares n groups that WILL be enqueued — wait_empty
        blocks until they are consumed, closing the enqueue race."""
        with self._lock:
            self._outstanding += n
            self._lock.notify_all()

    def put(self, item: RolloutGroup) -> None:
        self._q.put(item)

    def put_error(self, exc: BaseException) -> None:
        """Producer-side failure: unblocks the consumer, which re-raises —
        a dead producer must not deadlock the pipeline."""
        self._q.put(exc)

    def get(self, timeout: Optional[float] = None) -> RolloutGroup:
        item = self._q.get(timeout=timeout)
        with self._lock:
            self._outstanding -= 1
            self._lock.notify_all()
        if isinstance(item, BaseException):
            raise item
        return item

    def wait_empty(self, timeout: Optional[float] = None) -> bool:
        """Blocks until all registered groups have been consumed."""
        with self._lock:
            return self._lock.wait_for(lambda: self._outstanding == 0,
                                       timeout=timeout)

    @property
    def outstanding(self) -> int:
        with self._lock:
            return self._outstanding

    def qsize(self) -> int:
        return self._q.qsize()
