"""Radix prefix cache over the paged KV pool (DESIGN.md §Radix-prefix-cache).

vLLM-style automatic prefix caching, JAX-native: a radix tree keyed by
PAGE-ALIGNED token-id spans maps each cached span to the physical page
holding its KV (or MLA latent) rows. Two requests that share a token
prefix share the prefix's pages — across groups, across time — because a
paged cache entry is purely per-token: k_t = W_k emb(tok_t) rotated by
pos t (MLA: ckv_t, kr_t likewise), independent of what follows. A page
cached by an earlier request is therefore BITWISE the page a cold prefill
would write, which is what lets the serving tier keep the repo's
exactness contract while skipping redundant prefill compute
(tests/test_radix.py proves token identity empirically).

Layering on ``core/paged.py``'s refcount machinery:

  * the tree holds ONE allocator reference per cached page (taken via
    ``PageAllocator.retain`` at insert) on top of whatever references
    in-flight rows hold — so a row finishing (or a sliding window
    reclaiming) never frees a cached page out from under the tree;
  * a page is EVICTABLE exactly when its allocator refcount is 1 (tree
    only — the "zero-ref" of the issue statement: no row references it)
    and no cached descendant would be orphaned; eviction is LRU over a
    monotone lookup/insert clock (deterministic — no wall time);
  * the engine's admission gate calls ``evict`` on a page deficit, so
    cached-but-idle pages are exactly as reclaimable as free pages and
    the page-credit deadlock-freedom argument is unchanged.

Nodes are page-granularity (one node = one ``page_size`` token span), so
a lookup is O(prompt pages) dict hops. A node may be a PLACEHOLDER
(``page is None``): sliding-window prompts never allocate their dead
leading pages (``_prompt_page_range`` j0) but the tree still needs the
token path to reach the cached tail; eviction likewise leaves a
placeholder only while descendants still hold pages, pruning empty
chains upward. The matched run handed to the engine is the longest
CONTIGUOUS live run starting at the requester's own j0 — suffix prefill
cannot skip over a hole.

``core/prefix.py`` is this module's SSM analogue (prefix-state sharing
for O(1) recurrent state); this tree is for families with per-token
paged KV.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np


class _Node:
    """One page-aligned token span. ``page is None`` marks a placeholder
    (never cached, or evicted while descendants remain)."""

    __slots__ = ("key", "page", "parent", "children", "last_use")

    def __init__(self, key: Tuple[int, ...], parent: Optional["_Node"]):
        self.key = key
        self.page: Optional[int] = None
        self.parent = parent
        self.children: Dict[Tuple[int, ...], "_Node"] = {}
        self.last_use = 0


class RadixCache:
    """Token-span radix tree mapping page-aligned prompt prefixes to the
    physical pages that hold them. Not thread-safe on its own — the owning
    engine serialises access under its mutex."""

    def __init__(self, page_size: int, alloc):
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.page = page_size
        self.alloc = alloc
        self.root = _Node((), None)
        self.cached_pages = 0        # nodes currently holding a page
        self._clock = 0              # monotone LRU clock (no wall time)

    # -- internals ----------------------------------------------------------

    def _span(self, tokens: np.ndarray, j: int) -> Tuple[int, ...]:
        return tuple(int(t) for t in
                     tokens[j * self.page:(j + 1) * self.page])

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    # -- queries ------------------------------------------------------------

    def lookup(self, tokens, *, j0: int = 0) -> Tuple[int, List[int]]:
        """Longest cached prefix of ``tokens`` usable by a row whose first
        live page index is ``j0`` (sliding-window geometry). Returns
        ``(m, pages)``: page indices ``j0..m-1`` are cached as ``pages``
        (contiguous, live); ``m == j0`` means no usable match. The walk is
        capped at ``(len(tokens) - 1) // page_size`` so at least the last
        prompt token is always recomputed — the engine needs its logits.
        Touches every matched node's LRU stamp."""
        tokens = np.asarray(tokens)
        limit = max(0, (len(tokens) - 1) // self.page)
        now = self._tick()
        node = self.root
        m, pages, run = j0, [], []
        for j in range(limit):
            child = node.children.get(self._span(tokens, j))
            if child is None:
                break
            child.last_use = now
            node = child
            if j < j0:
                continue                      # dead-on-arrival page index
            if child.page is None:
                break                         # hole: contiguous run ends
            run.append(child.page)
        if run:
            m, pages = j0 + len(run), run
        return m, pages

    def insert(self, tokens, pages: Dict[int, int]) -> int:
        """Cache ``pages`` (page index -> page id) for ``tokens``, creating
        placeholder nodes along the path (window-dead leading indices, or
        gaps the caller does not own). A span already cached keeps its
        incumbent page — the newcomer's copy stays private to its rows and
        frees with them (concurrent duplicate prefills resolve without a
        leak). Each newly cached page takes one allocator reference for
        the tree. Returns how many pages were newly cached."""
        if not pages:
            return 0
        tokens = np.asarray(tokens)
        top = max(pages) + 1
        assert top * self.page <= len(tokens), \
            "insert may only cache COMPLETE page spans"
        now = self._tick()
        node = self.root
        stored = 0
        for j in range(top):
            key = self._span(tokens, j)
            child = node.children.get(key)
            if child is None:
                child = _Node(key, node)
                node.children[key] = child
            child.last_use = now
            node = child
            if j in pages and child.page is None:
                child.page = pages[j]
                self.alloc.retain([pages[j]])
                self.cached_pages += 1
                stored += 1
        return stored

    # -- eviction -----------------------------------------------------------

    def _collect(self, protect) -> List[_Node]:
        """Evictable nodes: hold a page with allocator refcount 1 (the
        tree's own — no in-flight row sees it), no cached descendant (the
        tree never orphans a reachable suffix), not protected (the pages
        an in-progress admission just matched)."""
        out, sub = [], {}        # id(node) -> subtree holds any page
        stack = [(self.root, False)]
        while stack:
            node, visited = stack.pop()
            if not visited:
                stack.append((node, True))
                stack.extend((c, False) for c in node.children.values())
                continue
            below = any(sub[id(c)] for c in node.children.values())
            sub[id(node)] = (node.page is not None) or below
            if (node.page is not None and not below
                    and node.page not in protect
                    and self.alloc.refcount(node.page) == 1):
                out.append(node)
        return out

    def evict(self, n_pages: int, protect=frozenset()) -> List[int]:
        """Free up to ``n_pages`` cached pages, least-recently-used first,
        restricted to zero-row-ref leaf pages. Returns the freed page ids
        (each goes straight back to the allocator freelist — the tree held
        their last reference). Empty placeholder chains prune upward."""
        freed: List[int] = []
        while len(freed) < n_pages:
            cands = self._collect(protect)
            if not cands:
                break
            victim = min(cands, key=lambda nd: nd.last_use)
            self.alloc.release([victim.page])
            freed.append(victim.page)
            victim.page = None
            self.cached_pages -= 1
            node = victim
            while (node is not self.root and node.page is None
                   and not node.children):
                parent = node.parent
                del parent.children[node.key]
                node = parent
        return freed

    # -- introspection (tests) ----------------------------------------------

    def pages(self) -> List[int]:
        """Every page id the tree currently holds a reference to."""
        out, stack = [], [self.root]
        while stack:
            node = stack.pop()
            if node.page is not None:
                out.append(node.page)
            stack.extend(node.children.values())
        return out

    @property
    def num_nodes(self) -> int:
        n, stack = 0, [self.root]
        while stack:
            node = stack.pop()
            n += len(node.children)
            stack.extend(node.children.values())
        return n
