"""Periodic-asynchrony scheduler — Algorithm 1 of the paper.

Modes:
  * ``sync``            — paper's synchronous decoupled baseline: dispatch all
                          rollouts, wait for the full batch, then train in the
                          original prompt order (Figure 3a).
  * ``async``           — periodic asynchrony (ours): the consumer trains on
                          rollouts in completion-time order while the producer
                          is still generating; weights sync only at iteration
                          boundaries (Figure 3b). Strictly on-policy —
                          asserted at runtime per group.
  * ``async_offpolicy`` — AReaL-like fully-asynchronous baseline with
                          staleness threshold eta: the producer runs ahead of
                          the trainer by up to eta iterations, so consumed
                          rollouts may be stale (off-policy).

TPSPD (tokens trained per second per device) is the paper's primary metric.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig, RLConfig
from repro.core.generator import TemporaryDataGenerator
from repro.core.onpolicy import OnPolicyMonitor
from repro.core.queue import RolloutGroup, RolloutQueue
from repro.core.spa import PAD, pack_plain, pack_spa
from repro.core.trimodel import TriModelState
from repro.optim.accumulate import GradAccumulator
from repro.rl.grpo import (MicroBatch, group_advantages, make_apply_update,
                           make_grad_step)


@dataclasses.dataclass
class IterationStats:
    iteration: int
    wall_time: float
    infer_time: float   # producer busy-time aggregated over pool instances
    train_time: float
    trained_tokens: int
    reward_mean: float
    tpspd: float
    max_staleness: int
    metrics: dict


def _pad_rows(mb: MicroBatch, m: int) -> MicroBatch:
    """Pad a micro-batch to exactly m rows (dummy rows carry zero weight) so
    jitted step shapes stay static."""
    have = mb.tokens.shape[0]
    if have == m:
        return mb
    pad_n = m - have
    S = mb.tokens.shape[1]
    z_i = np.zeros((pad_n, S), np.int32)
    z_f = np.zeros((pad_n, S), np.float32)
    return MicroBatch(
        tokens=np.concatenate([mb.tokens, np.full((pad_n, S), PAD, np.int32)]),
        labels=np.concatenate([mb.labels, z_i]),
        positions=np.concatenate([mb.positions, z_i]),
        segments=np.concatenate([mb.segments, np.full((pad_n, S), -1, np.int32)]),
        loss_mask=np.concatenate([mb.loss_mask, z_f]),
        advantages=np.concatenate([mb.advantages, z_f]),
        n_samples=mb.n_samples,
    )


class PeriodicAsyncScheduler:
    def __init__(self, cfg: ModelConfig, rl: RLConfig, tri: TriModelState,
                 generator: TemporaryDataGenerator, queue: RolloutQueue,
                 loader, *, num_devices: int = 1):
        self.cfg = cfg
        self.rl = rl
        self.tri = tri
        self.generator = generator
        self.queue = queue
        self.loader = loader
        self.num_devices = num_devices
        self.grad_step = make_grad_step(cfg, rl)
        self.apply_update = make_apply_update(cfg, rl)
        self.monitor = OnPolicyMonitor(strict=(rl.mode != "async_offpolicy"))
        self.history: List[IterationStats] = []
        self._batches = None
        self._next_batch_idx = 0

    # ------------------------------------------------------------------
    def _micro_batches(self, group: RolloutGroup):
        adv = np.asarray(group_advantages(group.rewards))
        rl = self.rl
        if rl.shared_prompt_attention:
            if self.cfg.attention_free:
                # SPA is an attention-MASK optimisation: packed responses
                # would leak into each other through an SSM's recurrence.
                # The state-space analogue is prefix-state sharing
                # (core/prefix.py) — see DESIGN.md §Arch-applicability.
                raise ValueError(
                    f"{self.cfg.name} is attention-free; shared-prompt "
                    "attention packing does not apply — use prefix-state "
                    "sharing (repro.core.prefix) instead")
            mb = pack_spa(group, adv, rl.max_prompt_len, rl.max_response_len,
                          responses_per_row=rl.group_size,
                          align=rl.spa_align)
            yield _pad_rows(mb, mb.tokens.shape[0]), float(mb.n_samples)
        else:
            mb = pack_plain([group], [adv], rl.max_prompt_len,
                            rl.max_response_len)
            m = rl.micro_batch
            rows = mb.tokens.shape[0]
            for lo in range(0, rows, m):
                hi = min(lo + m, rows)
                sub = MicroBatch(*(a[lo:hi] for a in mb[:-2]),
                                 n_samples=np.float32(hi - lo))
                yield _pad_rows(sub, m), float(hi - lo)

    def _train_group(self, group: RolloutGroup, acc: GradAccumulator) -> int:
        tokens = 0
        for mb, weight in self._micro_batches(group):
            grads, metrics = self.grad_step(self.tri.policy, self.tri.old,
                                            self.tri.ref, mb)
            jax.block_until_ready(jax.tree.leaves(grads)[0])
            acc.add(grads, weight)
            tokens += int((np.asarray(mb.tokens) != PAD).sum())
        return tokens

    def _finish_iteration(self, acc: GradAccumulator) -> None:
        self.tri.refresh_old()                       # line 10
        new_params, new_opt, _ = self.apply_update(
            self.tri.policy, self.tri.opt, acc.mean())
        jax.block_until_ready(jax.tree.leaves(new_params)[0])
        self.tri.apply_update(new_params, new_opt)   # line 11

    # ------------------------------------------------------------------
    def run(self, num_iterations: int, *, key=None) -> List[IterationStats]:
        """Run ``num_iterations`` and return THEIR stats (self.history keeps
        the full cumulative record across calls)."""
        start = len(self.history)
        key = jax.random.PRNGKey(self.rl.seed + start) if key is None else key
        batches = self.loader.batches(num_iterations +
                                      (self.rl.staleness_eta
                                       if self.rl.mode == "async_offpolicy" else 0))
        batches = list(batches)
        mode = self.rl.mode
        pool = self.generator.pool
        next_submit = 0

        for t in range(num_iterations):
            it_start = time.perf_counter()
            busy0 = pool.busy_time
            acc = GradAccumulator()
            rewards_seen: List[float] = []
            trained_tokens = 0
            self.monitor.max_staleness_seen = 0

            if mode in ("sync", "async"):
                # Algorithm 1 line 3: wait until Q empty, then sync weights
                self.queue.wait_empty()
                pool.sync_weights(self.tri.policy, self.tri.version)
                key, k_t = jax.random.split(key)
                self.generator.submit_batch(batches[t], k_t, self.tri.version)
                next_submit = t + 1
                n_expect = len(batches[t])
                if mode == "sync":
                    self.generator.join()            # full-batch barrier
                train_t0 = time.perf_counter()
                groups = []
                for _ in range(n_expect):
                    groups.append(self.queue.get())
                    if mode == "async":
                        g = groups[-1]
                        self.monitor.check(g, self.tri.version)
                        rewards_seen.extend(g.rewards.tolist())
                        trained_tokens += self._train_group(g, acc)
                if mode == "sync":
                    groups.sort(key=lambda g: g.uid)  # original prompt order
                    for g in groups:
                        self.monitor.check(g, self.tri.version)
                        rewards_seen.extend(g.rewards.tolist())
                        trained_tokens += self._train_group(g, acc)
            else:  # async_offpolicy (AReaL-like, staleness <= eta)
                pool.sync_weights(self.tri.policy, self.tri.version)
                while (next_submit <= t + self.rl.staleness_eta
                       and next_submit < len(batches)):
                    key, k_t = jax.random.split(key)
                    self.generator.submit_batch(batches[next_submit], k_t,
                                                self.tri.version)
                    next_submit += 1
                train_t0 = time.perf_counter()
                for _ in range(len(batches[t])):
                    g = self.queue.get()
                    self.monitor.check(g, self.tri.version)
                    rewards_seen.extend(g.rewards.tolist())
                    trained_tokens += self._train_group(g, acc)

            self._finish_iteration(acc)
            wall = time.perf_counter() - it_start
            train_time = time.perf_counter() - train_t0
            stats = IterationStats(
                iteration=t, wall_time=wall,
                # producer busy-time delta over this iteration — in async
                # modes the wall clock overlaps inference with training, so
                # only the instances' own occupancy measures inference cost
                infer_time=pool.busy_time - busy0,
                train_time=train_time, trained_tokens=trained_tokens,
                reward_mean=float(np.mean(rewards_seen)) if rewards_seen else 0.0,
                tpspd=trained_tokens / wall / self.num_devices,
                max_staleness=self.monitor.max_staleness_seen,
                metrics={})
            self.history.append(stats)
        self.generator.join()
        return self.history[start:]
