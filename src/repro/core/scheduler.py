"""Periodic-asynchrony scheduler — Algorithm 1 of the paper.

Modes:
  * ``sync``            — paper's synchronous decoupled baseline: dispatch all
                          rollouts, wait for the full batch, then train in the
                          original prompt order (Figure 3a).
  * ``async``           — periodic asynchrony (ours): the consumer trains on
                          rollouts in completion-time order while the producer
                          is still generating; weights sync only at iteration
                          boundaries (Figure 3b). Strictly on-policy —
                          asserted at runtime per group.
  * ``async_offpolicy`` — AReaL-like fully-asynchronous baseline with
                          staleness threshold eta: the producer runs ahead of
                          the trainer by up to eta iterations, so consumed
                          rollouts may be stale (off-policy).

TPSPD (tokens trained per second per device) is the paper's primary metric.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig, RLConfig
from repro.core.generator import TemporaryDataGenerator
from repro.core.onpolicy import OnPolicyMonitor
from repro.core.queue import RolloutGroup, RolloutQueue
from repro.core.spa import PAD, pack_plain, pack_spa
from repro.core.trimodel import TriModelState
from repro.obs import trace as otrace
from repro.obs.metrics import metrics
from repro.optim.accumulate import GradAccumulator
from repro.rl.grpo import (MicroBatch, group_advantages, make_apply_update,
                           make_grad_step, make_grad_step_captured)
from repro.transfer.service import WeightTransferService


@dataclasses.dataclass
class IterationStats:
    iteration: int
    wall_time: float
    infer_time: float   # producer busy-time aggregated over pool instances
    # consumer BUSY-time: grad micro-steps + the iteration-boundary update
    # only. Time the consumer spends blocked on queue.get() waiting for the
    # producer is excluded — that wait is precisely what the async/sync
    # TPSPD comparison must not fold into training cost.
    train_time: float
    trained_tokens: int
    reward_mean: float
    tpspd: float
    max_staleness: int
    metrics: dict


def _pad_rows(mb: MicroBatch, m: int) -> MicroBatch:
    """Pad a micro-batch to exactly m rows (dummy rows carry zero weight) so
    jitted step shapes stay static."""
    have = mb.tokens.shape[0]
    if have == m:
        return mb
    pad_n = m - have
    S = mb.tokens.shape[1]
    z_i = np.zeros((pad_n, S), np.int32)
    z_f = np.zeros((pad_n, S), np.float32)
    return MicroBatch(
        tokens=np.concatenate([mb.tokens, np.full((pad_n, S), PAD, np.int32)]),
        labels=np.concatenate([mb.labels, z_i]),
        positions=np.concatenate([mb.positions, z_i]),
        segments=np.concatenate([mb.segments, np.full((pad_n, S), -1, np.int32)]),
        loss_mask=np.concatenate([mb.loss_mask, z_f]),
        advantages=np.concatenate([mb.advantages, z_f]),
        n_samples=mb.n_samples,
        logp_behavior=(None if mb.logp_behavior is None
                       else np.concatenate([mb.logp_behavior, z_f])),
    )


class PeriodicAsyncScheduler:
    def __init__(self, cfg: ModelConfig, rl: RLConfig, tri: TriModelState,
                 generator: TemporaryDataGenerator, queue: RolloutQueue,
                 loader, *, num_devices: int = 1,
                 transfer: Optional[WeightTransferService] = None):
        self.cfg = cfg
        self.rl = rl
        self.tri = tri
        self.generator = generator
        self.queue = queue
        self.loader = loader
        self.num_devices = num_devices
        # the weight-plane (DESIGN.md §Weight-plane): versioned bucket
        # streaming trainer -> pool, replacing the old serial per-instance
        # whole-tree pool.sync_weights at the boundary
        self.transfer = transfer if transfer is not None else \
            WeightTransferService(
                generator.pool,
                bucket_bytes=rl.transfer_bucket_bytes,
                wire_dtype=rl.transfer_wire_dtype or None,
                use_pallas_cast=rl.transfer_pallas_cast,
                overlap=rl.transfer_overlap)
        self.grad_step = make_grad_step(cfg, rl)
        self.grad_step_captured = make_grad_step_captured(cfg, rl)
        # micro-step accounting: captured = ratio from rollout-time behavior
        # logprobs (single ref no-grad forward); recomputed = stacked
        # old+ref tri-model forward (capture off, or rollouts without
        # captured logprobs, e.g. scripted/simulated instances)
        self.captured_micro_steps = 0
        self.recomputed_micro_steps = 0
        self.apply_update = make_apply_update(cfg, rl)
        self.monitor = OnPolicyMonitor(strict=(rl.mode != "async_offpolicy"))
        self.history: List[IterationStats] = []
        # submitted-but-unconsumed batches carried across run() calls — the
        # async_offpolicy producer runs up to eta iterations ahead, so a
        # run() boundary is NOT a drained pipeline; re-fetching and
        # re-submitting from scratch would double-submit and train leftover
        # groups against mismatched counts.
        self._inflight: List = []
        self._key = None
        self._train_busy = 0.0
        # registry metrics for the live ops plane (/metrics): cached
        # handles, pushed once per iteration at the boundary
        _m = metrics()
        self._m_iteration = _m.gauge("scheduler.iteration")
        self._m_trained_tokens = _m.counter("scheduler.trained_tokens")
        self._m_tpspd = _m.gauge("scheduler.tpspd")
        # set when a run() unwound mid-iteration: gradients were half-
        # accumulated and the failed iteration's groups are partially
        # consumed, so re-entering run() cannot resume soundly — it would
        # deadlock on wait_empty (strict modes) or train shifted batch
        # boundaries (off-policy). Subsequent run() calls refuse loudly.
        self._failed = False

    # ------------------------------------------------------------------
    def _micro_batches(self, group: RolloutGroup):
        adv = np.asarray(group_advantages(group.rewards))
        rl = self.rl
        if rl.shared_prompt_attention:
            # repro: allow(support-matrix): SPA packing is a training-side
            # attention-mask feature, not a decode engine — its SSM
            # exclusion is not an engine-matrix row (DESIGN.md §SPA)
            if self.cfg.attention_free:
                # SPA is an attention-MASK optimisation: packed responses
                # would leak into each other through an SSM's recurrence.
                # The state-space analogue is prefix-state sharing
                # (core/prefix.py) — see DESIGN.md §Arch-applicability.
                raise ValueError(
                    f"{self.cfg.name} is attention-free; shared-prompt "
                    "attention packing does not apply — use prefix-state "
                    "sharing (repro.core.prefix) instead")
            mb = pack_spa(group, adv, rl.max_prompt_len, rl.max_response_len,
                          responses_per_row=rl.group_size,
                          align=rl.spa_align)
            if not rl.capture_logprobs:
                mb = mb._replace(logp_behavior=None)
            yield _pad_rows(mb, mb.tokens.shape[0]), float(mb.n_samples)
        else:
            mb = pack_plain([group], [adv], rl.max_prompt_len,
                            rl.max_response_len)
            if not rl.capture_logprobs:
                mb = mb._replace(logp_behavior=None)
            m = rl.micro_batch
            rows = mb.tokens.shape[0]
            for lo in range(0, rows, m):
                hi = min(lo + m, rows)
                sub = MicroBatch(
                    tokens=mb.tokens[lo:hi], labels=mb.labels[lo:hi],
                    positions=mb.positions[lo:hi],
                    segments=mb.segments[lo:hi],
                    loss_mask=mb.loss_mask[lo:hi],
                    advantages=mb.advantages[lo:hi],
                    n_samples=np.float32(hi - lo),
                    logp_behavior=(None if mb.logp_behavior is None
                                   else mb.logp_behavior[lo:hi]))
                yield _pad_rows(sub, m), float(hi - lo)

    def _train_group(self, group: RolloutGroup, acc: GradAccumulator) -> int:
        """Consumer busy work for one group — timed into ``_train_busy``
        (the quantity ``IterationStats.train_time`` reports)."""
        tokens = 0
        t0 = time.perf_counter()
        for mb, weight in self._micro_batches(group):
            if mb.logp_behavior is not None:
                self.captured_micro_steps += 1
                step = self.grad_step_captured
            else:
                self.recomputed_micro_steps += 1
                step = self.grad_step
            with otrace.span("train.grad_step",
                             captured=mb.logp_behavior is not None):
                grads, metrics = step(self.tri.policy, self.tri.old,
                                      self.tri.ref, mb)
                # repro: allow(host-sync): trainer-side busy-time measurement
                # barrier (paper Table 7 timing); not a decode path
                jax.block_until_ready(jax.tree.leaves(grads)[0])
                acc.add(grads, weight)
            tokens += int((np.asarray(mb.tokens) != PAD).sum())
        t1 = time.perf_counter()
        self._train_busy += t1 - t0
        # the span reuses the busy stopwatch's own endpoints, so the
        # analyzer's train_time reproduces IterationStats.train_time
        otrace.complete("train.group", t0, t1, uid=group.uid, tokens=tokens)
        return tokens

    def _finish_iteration(self, acc: GradAccumulator) -> None:
        t0 = time.perf_counter()
        new_params, new_opt, _ = self.apply_update(
            self.tri.policy, self.tri.opt, acc.mean())
        # repro: allow(host-sync): update must materialise before the
        # version flip (Proposition 1 boundary); trainer-side, once per
        # iteration
        jax.block_until_ready(jax.tree.leaves(new_params)[0])
        self.tri.apply_update(new_params, new_opt)   # line 11
        t1 = time.perf_counter()
        self._train_busy += t1 - t0
        otrace.complete("train.update", t0, t1, version=self.tri.version)
        # overlap: start streaming the NEW version's buckets to the pool's
        # back buffers the moment the update materialises — the wire time
        # hides under the iteration tail instead of extending the next
        # boundary; flips stay version-gated (no-op when overlap is off)
        self.transfer.publish_async(self.tri.policy, self.tri.version)

    def _sync_boundary(self, submit) -> None:
        """THE iteration boundary (Algorithm 1 lines 3 + 10) — the one
        place the Proposition-1 invariant 'rollout weights == old-policy
        weights' is established: drain (strict modes), dispatch the
        iteration's submissions, flip every instance to the policy's
        version via the weight-plane barrier, then old <- policy. The
        residual block time is the pool's sync-gap
        (``IterationStats.metrics['sync_gap']``).

        ``submit`` runs BETWEEN the drain and the flip barrier: every
        request it dispatches version-gates on ``tri.version``, so
        correctness never depends on flip-before-submit ordering — and the
        stream tail overlaps the generator's worker spin-up instead of
        extending the boundary. Paged engines stay quiescent through their
        deferred flip because the gates hold every new request back until
        the flip lands."""
        if self.rl.mode in ("sync", "async"):
            # Algorithm 1 line 3: wait until Q empty BEFORE submitting
            # (a new submission registers pending groups) and before the
            # weights move — also guarantees paged engines are quiescent
            # for their deferred flips
            with otrace.span("boundary.drain"):
                self.queue.wait_empty()
        with otrace.span("boundary.submit"):
            submit()
        flipped = self.transfer.ensure(self.tri.policy, self.tri.version)
        # Algorithm 1 line 10 at the BOUNDARY, before training: old <-
        # policy == the weights just flipped to the pool, so old-policy
        # weights equal rollout weights at consumption (Proposition 1's
        # equality — refreshing at iteration END left old one optimizer
        # step stale during iteration t's grad steps; see DESIGN.md
        # §Tri-model-capture). The flipped version is passed through so
        # the equality is asserted, not assumed.
        self.tri.refresh_old(expected_rollout_version=flipped)

    # ------------------------------------------------------------------
    def status(self) -> dict:
        """Live pipeline introspection for the ops plane (``/status`` via
        ``launch/train.py --metrics-port``): iteration progress, policy
        version, micro-step mix, and the pool's per-instance rows (each
        snapshotted atomically by its owner). Safe to call from a scrape
        thread mid-``run()`` — every field is a single read or delegated
        to a lock-holding snapshot."""
        out = {
            "mode": self.rl.mode,
            "iterations_completed": len(self.history),
            "policy_version": self.tri.version,
            "failed": self._failed,
            "captured_micro_steps": self.captured_micro_steps,
            "recomputed_micro_steps": self.recomputed_micro_steps,
            "pool": self.generator.pool.status(),
        }
        if self.history:
            out["last_iteration"] = dataclasses.asdict(self.history[-1])
        return out

    def run(self, num_iterations: int, *, key=None) -> List[IterationStats]:
        """Run ``num_iterations`` and return THEIR stats (self.history keeps
        the full cumulative record across calls).

        Safe to call repeatedly: in ``async_offpolicy`` mode up to
        ``staleness_eta`` submitted-but-unconsumed batches from the previous
        call are still in flight at a run() boundary — they carry over in
        ``self._inflight`` and are consumed FIRST, and only the shortfall is
        fetched from the loader (no double-submit).

        NOT safe to call again after a previous run() raised mid-iteration:
        the pipeline state is unrecoverable by re-entry (half-accumulated
        gradients, partially consumed batches) and this method refuses with
        a RuntimeError instead of deadlocking or double-submitting —
        rebuild the pipeline to recover."""
        if self._failed:
            raise RuntimeError(
                "scheduler state is inconsistent: a previous run() raised "
                "mid-iteration (groups from the failed iteration may still "
                "be queued and gradients were discarded half-accumulated). "
                "Rebuild the pipeline instead of retrying run().")
        start = len(self.history)
        if key is None:
            key = (self._key if self._key is not None
                   else jax.random.PRNGKey(self.rl.seed))
        mode = self.rl.mode
        pool = self.generator.pool
        eta = self.rl.staleness_eta if mode == "async_offpolicy" else 0
        # consume-order batch list: in-flight leftovers first, then exactly
        # enough fresh batches for this call's consumption + eta lookahead
        need = num_iterations + eta - len(self._inflight)
        batches = self._inflight + list(self.loader.batches(max(need, 0)))
        next_submit = len(self._inflight)
        consumed_upto = 0   # first batch index NOT fully consumed yet

        try:
            for t in range(num_iterations):
                it_start = time.perf_counter()
                busy0 = pool.busy_time
                engine0 = pool.engine_stats()
                self._train_busy = 0.0
                acc = GradAccumulator()
                rewards_seen: List[float] = []
                trained_tokens = 0
                self.monitor.max_staleness_seen = 0

                if mode in ("sync", "async"):
                    def submit():
                        nonlocal key, next_submit
                        key, k_t = jax.random.split(key)
                        self.generator.submit_batch(batches[t], k_t,
                                                    self.tri.version)
                        next_submit = t + 1

                    self._sync_boundary(submit)
                    n_expect = len(batches[t])
                    if mode == "sync":
                        self.generator.join()        # full-batch barrier
                    groups = []
                    for _ in range(n_expect):
                        groups.append(self.queue.get())
                        if mode == "async":
                            g = groups[-1]
                            self.monitor.check(g, self.tri.version)
                            rewards_seen.extend(g.rewards.tolist())
                            trained_tokens += self._train_group(g, acc)
                    if mode == "sync":
                        groups.sort(key=lambda g: g.uid)  # prompt order
                        for g in groups:
                            self.monitor.check(g, self.tri.version)
                            rewards_seen.extend(g.rewards.tolist())
                            trained_tokens += self._train_group(g, acc)
                else:  # async_offpolicy (AReaL-like, staleness <= eta)
                    def submit():
                        nonlocal key, next_submit
                        while (next_submit <= t + eta
                               and next_submit < len(batches)):
                            key, k_t = jax.random.split(key)
                            self.generator.submit_batch(
                                batches[next_submit], k_t, self.tri.version)
                            next_submit += 1

                    self._sync_boundary(submit)
                    for _ in range(len(batches[t])):
                        g = self.queue.get()
                        self.monitor.check(g, self.tri.version)
                        rewards_seen.extend(g.rewards.tolist())
                        trained_tokens += self._train_group(g, acc)

                self._finish_iteration(acc)
                wall = time.perf_counter() - it_start
                otrace.complete("iteration", it_start, it_start + wall,
                                iteration=start + t, mode=mode)
                # per-iteration engine-stat deltas (spec acceptance, prefix
                # hit rate, page reclamation) surfaced through the same
                # metrics path as sync_gap — zero when no paged engine runs
                engine1 = pool.engine_stats()
                d = {k: engine1[k] - engine0[k] for k in engine1}
                spec_acceptance = (d["accepted_tokens"] / d["drafted_tokens"]
                                   if d["drafted_tokens"] else 0.0)
                prefix_probes = d["prefix_hit_pages"] + d["prefix_miss_pages"]
                prefix_hit_rate = (d["prefix_hit_pages"] / prefix_probes
                                   if prefix_probes else 0.0)
                stats = IterationStats(
                    iteration=start + t, wall_time=wall,
                    # producer busy-time delta over this iteration — in
                    # async modes the wall clock overlaps inference with
                    # training, so only the instances' own occupancy
                    # measures inference cost
                    infer_time=pool.busy_time - busy0,
                    # consumer busy-time only (grad steps + boundary
                    # update) — NOT wall-since-first-get, which in async
                    # mode counts time spent blocked on the producer
                    # inside queue.get()
                    train_time=self._train_busy,
                    trained_tokens=trained_tokens,
                    reward_mean=(float(np.mean(rewards_seen))
                                 if rewards_seen else 0.0),
                    tpspd=trained_tokens / wall / self.num_devices,
                    max_staleness=self.monitor.max_staleness_seen,
                    # boundary sync-gap: time the pool sat idle waiting for
                    # this iteration's weight flip (weight-plane barrier)
                    metrics={"sync_gap": self.transfer.last_gap,
                             "spec_acceptance": spec_acceptance,
                             "prefix_hit_rate": prefix_hit_rate,
                             "pages_reclaimed": d["reclaimed_pages"]})
                self.history.append(stats)
                self._m_iteration.set(start + t + 1)
                self._m_trained_tokens.add(trained_tokens)
                self._m_tpspd.set(stats.tpspd)
                consumed_upto = t + 1
        except BaseException:
            # mid-iteration unwind (producer put_error surfaced by
            # queue.get, staleness assert, ...): the pipeline cannot be
            # resumed by another run() — poison re-entry (see __init__)
            self._failed = True
            raise
        finally:
            # record submitted-but-unconsumed batches: on the happy path
            # this is the eta-lookahead tail the next call consumes first;
            # after an error it is diagnostic only (run() refuses re-entry)
            self._inflight = batches[consumed_upto:next_submit]
            self._key = key
            # join any background bucket stream BEFORE unwinding — a
            # daemon thread mid-device_put at interpreter teardown aborts
            # the runtime. On the happy path a failed stream's error
            # surfaces here AND poisons re-entry (groups of in-flight
            # eta-lookahead batches may already be queued — resubmitting
            # them would double-train); when already unwinding, the
            # original exception wins.
            try:
                self.transfer.drain()
            except Exception:
                if not self._failed:
                    self._failed = True
                    raise
        self.generator.join()
        return self.history[start:]
