"""Shared-Prompt Attention packing (paper §4.3).

A GRPO group's K responses share one prompt. We pack
    x = [ prompt[:-1],  (prompt[-1], r_1),  (prompt[-1], r_2), ... ]
with (paper's four modifications):
  (1) input construction — one row carries the shared prompt + K responses;
  (2) position indices    — every response restarts at |prompt| - 1;
  (3) attention mask      — segment ids drive the shared-prompt mask
                            (kv_seg == 0 OR kv_seg == q_seg, causal by pos);
  (4) loss                — only response-label positions contribute.

Exactness note (vs the paper's Fig. 4): each response segment *begins with a
copy of the last prompt token*. The hidden state at that copy predicts the
response's first token — without it, r_j[0] would have no loss term, because
the single shared last-prompt position can only carry one label. With it,
packed gradients equal the sum of per-sample gradients exactly
(tests/test_spa.py asserts allclose at fp32).

Per-token loss weights are 1/len(sample) so the packed loss reproduces
GRPO's per-sample token-mean regardless of how samples share rows.

Both packers also scatter rollout-captured ``response_logprobs`` (when the
group carries them) onto the label positions, producing
``MicroBatch.logp_behavior`` — the old-policy/behavior logprobs the grad
step consumes instead of recomputing (DESIGN.md §Tri-model-capture).
"""
from __future__ import annotations

import math
from typing import List, Sequence

import numpy as np

from repro.core.queue import RolloutGroup
from repro.data.tokenizer import Tokenizer
from repro.rl.grpo import MicroBatch

PAD = Tokenizer.PAD


def _np(x):
    return np.asarray(x)


def pack_plain(groups: Sequence[RolloutGroup], advantages: Sequence[np.ndarray],
               max_prompt_len: int, max_response_len: int) -> MicroBatch:
    """One row per (prompt, response) sample — standard (non-SPA) layout.

    When every group carries rollout-captured ``response_logprobs``, they are
    scattered onto the label positions (the position predicting r[j] gets
    log p(r[j])) and the micro-batch gains ``logp_behavior`` — the trainer
    then skips the old-policy recompute (DESIGN.md §Tri-model-capture)."""
    rows_t, rows_y, rows_p, rows_s, rows_w, rows_a = [], [], [], [], [], []
    rows_lb = []
    capture = all(g.response_logprobs is not None for g in groups)
    S = max_prompt_len + max_response_len
    for g, adv in zip(groups, advantages):
        p = _np(g.prompt_ids)[:max_prompt_len]
        Lp = len(p)
        for j in range(g.response_ids.shape[0]):
            # repro: allow(host-sync): RolloutGroup fields are host numpy
            # arrays — same field names as the device RolloutBatch
            r = _np(g.response_ids)[j, : int(g.response_len[j])][:max_response_len]
            lr = len(r)
            toks = np.full((S,), PAD, np.int32)
            toks[:Lp] = p
            toks[Lp:Lp + lr] = r
            labels = np.full((S,), 0, np.int32)
            labels[:Lp + lr - 1] = toks[1:Lp + lr]
            pos = np.zeros((S,), np.int32)
            pos[:Lp + lr] = np.arange(Lp + lr)
            seg = np.full((S,), -1, np.int32)
            seg[:Lp + lr] = 0
            w = np.zeros((S,), np.float32)
            w[Lp - 1: Lp + lr - 1] = 1.0 / lr       # predicts r[0..lr-1]
            a = np.full((S,), float(adv[j]), np.float32)
            rows_t.append(toks); rows_y.append(labels); rows_p.append(pos)
            rows_s.append(seg); rows_w.append(w); rows_a.append(a)
            if capture:
                lb = np.zeros((S,), np.float32)
                lb[Lp - 1: Lp + lr - 1] = \
                    _np(g.response_logprobs)[j, :lr]  # same positions as w
                rows_lb.append(lb)
    n = len(rows_t)
    return MicroBatch(
        tokens=np.stack(rows_t), labels=np.stack(rows_y),
        positions=np.stack(rows_p), segments=np.stack(rows_s),
        loss_mask=np.stack(rows_w), advantages=np.stack(rows_a),
        n_samples=np.float32(n),
        logp_behavior=np.stack(rows_lb) if capture else None,
    )


def pack_spa(group: RolloutGroup, advantages: np.ndarray,
             max_prompt_len: int, max_response_len: int,
             responses_per_row: int, align: int = 0) -> tuple:
    """Pack one group into ceil(G/K) SPA rows of K responses each.

    ``align > 0`` (beyond-paper, TPU-structural): round the prompt block and
    the per-response slot stride up to a multiple of ``align`` (the Pallas
    tile size, 128 on the MXU). Slot boundaries then coincide with tile
    boundaries, so every response_i x response_j (i != j) tile is pruned by
    the kernel's block map *exactly* instead of conservatively surviving in
    straddled tiles — measured live-tile fraction drops accordingly (see
    EXPERIMENTS.md §Perf). Padding positions carry pos=2^30-1 / seg=-1 and
    zero loss weight, so the packed loss is unchanged."""
    K = responses_per_row
    p = _np(group.prompt_ids)[:max_prompt_len]
    Lp = len(p)
    G = group.response_ids.shape[0]
    capture = group.response_logprobs is not None
    up = lambda n: n if align <= 0 else -(-n // align) * align
    prompt_block = up(Lp - 1)
    stride = up(1 + max_response_len)
    S = prompt_block + K * stride
    n_rows = math.ceil(G / K)
    rows = dict(t=[], y=[], pos=[], seg=[], w=[], a=[], lb=[])
    n_samples = 0
    PAD_POS = 2 ** 30 - 1
    for row_i in range(n_rows):
        toks = np.full((S,), PAD, np.int32)
        labels = np.zeros((S,), np.int32)
        pos = np.full((S,), PAD_POS, np.int32)
        seg = np.full((S,), -1, np.int32)
        w = np.zeros((S,), np.float32)
        a = np.zeros((S,), np.float32)
        lb = np.zeros((S,), np.float32)
        toks[:Lp - 1] = p[:-1]
        pos[:Lp - 1] = np.arange(Lp - 1)
        seg[:Lp - 1] = 0
        off = prompt_block
        for k in range(K):
            j = row_i * K + k
            if j >= G:
                break
            # repro: allow(host-sync): RolloutGroup fields are host numpy
            # arrays — same field names as the device RolloutBatch
            r = _np(group.response_ids)[j, : int(group.response_len[j])]
            r = r[:max_response_len]
            lr = len(r)
            sl = slice(off, off + 1 + lr)
            toks[sl] = np.concatenate([[p[-1]], r])
            pos[sl] = np.arange(Lp - 1, Lp + lr)     # restart at |prompt|-1
            seg[sl] = k + 1
            labels[off: off + lr] = r                # predict r[0..lr-1]
            w[off: off + lr] = 1.0 / lr
            a[off: off + 1 + lr] = float(advantages[j])
            if capture:                              # same positions as w
                lb[off: off + lr] = _np(group.response_logprobs)[j, :lr]
            n_samples += 1
            off += stride                            # fixed stride per slot
        rows["t"].append(toks); rows["y"].append(labels); rows["pos"].append(pos)
        rows["seg"].append(seg); rows["w"].append(w); rows["a"].append(a)
        rows["lb"].append(lb)
    return MicroBatch(
        tokens=np.stack(rows["t"]), labels=np.stack(rows["y"]),
        positions=np.stack(rows["pos"]), segments=np.stack(rows["seg"]),
        loss_mask=np.stack(rows["w"]), advantages=np.stack(rows["a"]),
        n_samples=np.float32(n_samples),
        logp_behavior=np.stack(rows["lb"]) if capture else None,
    )


def spa_reduction_ratio(Lp: int, Lr: float, K: int) -> float:
    """Paper Eq. 5: rho = (Lp^2 + K Lr (Lp + Lr)) / (K (Lp + Lr)^2)."""
    return (Lp ** 2 + K * Lr * (Lp + Lr)) / (K * (Lp + Lr) ** 2)
