"""Unified tri-model state (paper §4.2.1, Figure 2).

Policy, old-policy and reference parameters share one layout (identical
pytrees, identical shardings). ``refresh_old`` implements Algorithm 1
line 10 — the current policy weights move to the old policy *before* the
optimizer update is applied, so the old policy always reflects the
distribution that generated the current batch's rollouts.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax

from repro.optim.adam import AdamState, adam_init


@dataclasses.dataclass
class TriModelState:
    policy: Any
    old: Any
    ref: Any
    opt: AdamState
    version: int = 0          # iteration t whose weights the policy holds

    @classmethod
    def create(cls, params) -> "TriModelState":
        copy = lambda t: jax.tree.map(lambda a: a + 0, t)  # materialised copies
        return cls(policy=params, old=copy(params), ref=copy(params),
                   opt=adam_init(params), version=0)

    def refresh_old(self) -> None:
        """Algorithm 1 line 10: old <- policy (pre-update)."""
        self.old = self.policy

    def apply_update(self, new_params, new_opt) -> None:
        """Algorithm 1 line 11: the accumulated-gradient update."""
        self.policy = new_params
        self.opt = new_opt
        self.version += 1
