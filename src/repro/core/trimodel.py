"""Unified tri-model state (paper §4.2.1, Figure 2).

Policy, old-policy and reference parameters share one layout (identical
pytrees, identical shardings). ``refresh_old`` implements Algorithm 1
line 10; the scheduler invokes it at the ITERATION BOUNDARY — right after
syncing the (pre-update) policy weights to the rollout pool and before any
grad step — so during iteration t the old policy holds exactly the weights
generating (strict modes: and consumed with) iteration t's rollouts.
Proposition 1's "rollout weights == old-policy weights at consumption" is
then an identity the tri-model enforces, not just asserts; refreshing at
iteration END instead would leave old one optimizer step stale while
iteration t trains (see DESIGN.md §Tri-model-capture).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax

from repro.optim.adam import AdamState, adam_init


@dataclasses.dataclass
class TriModelState:
    policy: Any
    old: Any
    ref: Any
    opt: AdamState
    version: int = 0          # iteration t whose weights the policy holds

    @classmethod
    def create(cls, params) -> "TriModelState":
        copy = lambda t: jax.tree.map(lambda a: a + 0, t)  # materialised copies
        return cls(policy=params, old=copy(params), ref=copy(params),
                   opt=adam_init(params), version=0)

    def refresh_old(self, expected_rollout_version: Optional[int] = None
                    ) -> None:
        """Algorithm 1 line 10: old <- policy (pre-update). Called at the
        iteration boundary, after the pool weight sync (see module doc).

        ``expected_rollout_version`` is the version the weight-plane just
        flipped the pool to; passing it turns the boundary invariant
        "rollout weights == old-policy weights" into an assertion — if the
        pool serves any other version, old <- policy would NOT equal the
        behavior weights and Proposition 1's equality breaks."""
        assert (expected_rollout_version is None
                or expected_rollout_version == self.version), \
            f"boundary invariant broken: pool flipped to version " \
            f"{expected_rollout_version} but policy holds {self.version}"
        self.old = self.policy

    def apply_update(self, new_params, new_opt) -> None:
        """Algorithm 1 line 11: the accumulated-gradient update."""
        self.policy = new_params
        self.opt = new_opt
        self.version += 1
