from repro.data.tokenizer import Tokenizer
from repro.data.tasks import ArithmeticTask
from repro.data.loader import PromptLoader

__all__ = ["Tokenizer", "ArithmeticTask", "PromptLoader"]
