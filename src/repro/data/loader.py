"""Prompt data loader: batches of tokenized prompts for the RL pipeline.

This is the 'data source' box of the paper's Figure 1: it only hands
prompt batches to the temporary data generator; everything downstream
(inference dispatch, rewards, queueing) lives in repro.core."""
from __future__ import annotations

from typing import Iterator, List

import numpy as np

from repro.data.tasks import ArithmeticTask, Problem
from repro.data.tokenizer import Tokenizer


class PromptLoader:
    def __init__(self, task: ArithmeticTask, tokenizer: Tokenizer,
                 batch_size: int, max_prompt_len: int):
        self.task = task
        self.tok = tokenizer
        self.batch_size = batch_size
        self.max_prompt_len = max_prompt_len

    def encode_prompt(self, p: Problem) -> np.ndarray:
        ids = self.tok.encode(p.prompt)[: self.max_prompt_len]
        return np.asarray(ids, np.int32)

    def batches(self, num_batches: int) -> Iterator[List[tuple]]:
        """Yields lists of (problem, prompt_ids)."""
        for _ in range(num_batches):
            probs = self.task.batch(self.batch_size)
            yield [(p, self.encode_prompt(p)) for p in probs]
