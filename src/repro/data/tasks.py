"""Synthetic math reasoning task — the offline stand-in for GSM8K /
DeepScaleR: integer arithmetic word problems with a rule-based
extract-and-match reward (paper §6.1)."""
from __future__ import annotations

import dataclasses
import random
from typing import List, Optional

from repro.data.tokenizer import Tokenizer

_FILLER = ("carefully ", "step by step ", "using arithmetic ",
           "with full working shown ", "precisely ")


@dataclasses.dataclass
class Problem:
    prompt: str
    answer: int
    uid: int


class ArithmeticTask:
    """Deterministic problem stream. ``prompt_pad`` inflates the prompt with
    redundant instruction text — used to study the long-prompt/short-response
    regime where shared-prompt attention gives its K-fold win (§4.3)."""

    def __init__(self, seed: int = 0, max_operand: int = 99,
                 n_ops: int = 2, prompt_pad: int = 0):
        self.rng = random.Random(seed)
        self.max_operand = max_operand
        self.n_ops = n_ops
        self.prompt_pad = prompt_pad
        self._uid = 0

    def sample(self) -> Problem:
        ops = [self.rng.choice("+-*") for _ in range(self.n_ops)]
        vals = [self.rng.randint(1, self.max_operand)
                for _ in range(self.n_ops + 1)]
        expr = str(vals[0])
        for o, v in zip(ops, vals[1:]):
            if o == "*":
                v = self.rng.randint(2, 9)  # keep magnitudes tame
            expr += o + str(v)
        answer = eval(expr)  # trusted generator-side arithmetic only
        pad = ""
        while len(pad) < self.prompt_pad:
            pad += self.rng.choice(_FILLER)
        prompt = f"Solve {pad}: {expr} = "
        self._uid += 1
        return Problem(prompt=prompt, answer=answer, uid=self._uid)

    def batch(self, n: int) -> List[Problem]:
        return [self.sample() for _ in range(n)]


def extract_answer(text: str) -> Optional[int]:
    """Rule-based extraction: first integer (with optional sign) in the
    response; mirrors the paper's 'accurately extracted and matches' rule."""
    num = ""
    for ch in text:
        if ch == "-" and not num:
            num = "-"
        elif ch.isdigit():
            num += ch
        elif num and num != "-":
            break
        else:
            num = ""
    if num in ("", "-"):
        return None
    try:
        return int(num)
    except ValueError:
        return None
