"""Deterministic byte-level tokenizer.

Offline container -> no pretrained BPE; a byte tokenizer is exact,
reversible, and enough for the rule-based math rewards the paper uses
(GSM8K-style answer extraction)."""
from __future__ import annotations


class Tokenizer:
    PAD = 0
    BOS = 1
    EOS = 2
    _SPECIALS = 3

    def __init__(self, vocab_size: int = 512):
        assert vocab_size >= 256 + self._SPECIALS, "byte tokenizer needs >= 259"
        self.vocab_size = vocab_size

    def encode(self, text: str, *, bos: bool = True, eos: bool = False) -> list[int]:
        ids = [b + self._SPECIALS for b in text.encode("utf-8")]
        if bos:
            ids = [self.BOS] + ids
        if eos:
            ids = ids + [self.EOS]
        return ids

    def decode(self, ids) -> str:
        # ids >= 256 + _SPECIALS can occur when models sample from an
        # inflated vocab (configs keep the source model's vocab size);
        # they decode to nothing, like specials.
        bs = bytes(b for b in (int(i) - self._SPECIALS for i in ids)
                   if 0 <= b < 256)
        return bs.decode("utf-8", errors="replace")
