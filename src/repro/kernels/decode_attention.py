"""Flash-decode Pallas kernel: one new token's GQA attention against a long
KV cache, blocked over cache length with an online-softmax accumulator in
VMEM — the serving-side hot spot of the decoupled deployment (decode_32k /
long_500k shapes).

Layout: grid = (B, Hkv, nL) with the cache-length axis innermost; the
(G, Dv) accumulator for the Hkv head's G query heads lives in VMEM scratch.
Invalid cache slots carry pos >= 2**30 and are masked by the causal rule.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -1e30


def _kernel(qpos_ref, q_ref, k_ref, v_ref, kpos_ref,
            o_ref,
            acc_ref, m_ref, l_ref,
            *, scale: float, window: Optional[int], nL: int):
    li = pl.program_id(2)

    @pl.when(li == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # (G, D)
    k = k_ref[0, :, 0].astype(jnp.float32)         # (bL, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale  # (G, bL)
    qp = qpos_ref[0]                               # scalar-ish (1,)
    kp = kpos_ref[0]                               # (bL,)
    ok = kp[None, :] <= qp[:, None]
    if window is not None:
        ok &= (qp[:, None] - kp[None, :]) < window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
    v = v_ref[0, :, 0].astype(jnp.float32)         # (bL, Dv)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * corr + pv
    m_ref[...] = m_new

    @pl.when(li == nL - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("scale", "window", "block_l", "interpret"))
def decode_attention(q, k, v, kv_pos, q_pos, *,
                     scale: Optional[float] = None,
                     window: Optional[int] = None,
                     block_l: int = 256, interpret: bool = False):
    """q: (B, H, D) one token per row; k/v: (B, L, Hkv, Dv); kv_pos: (B, L);
    q_pos: (B,). Returns (B, H, Dv) in q.dtype."""
    B, H, D = q.shape
    _, L, Hkv, Dv = v.shape
    G = H // Hkv
    scale = D ** -0.5 if scale is None else scale

    bL = min(block_l, L)
    pad = (-L) % bL
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=2**30)
    L_p = L + pad
    nL = L_p // bL

    qr = q.reshape(B, Hkv, G, D)
    grid = (B, Hkv, nL)
    kernel = functools.partial(_kernel, scale=scale, window=window, nL=nL)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, li: (b, 0)),          # q_pos
            pl.BlockSpec((1, 1, G, D), lambda b, h, li: (b, h, 0, 0)),
            pl.BlockSpec((1, bL, 1, D), lambda b, h, li: (b, li, h, 0)),
            pl.BlockSpec((1, bL, 1, Dv), lambda b, h, li: (b, li, h, 0)),
            pl.BlockSpec((1, bL), lambda b, h, li: (b, li)),        # kv_pos
        ],
        out_specs=pl.BlockSpec((1, 1, G, Dv), lambda b, h, li: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, Dv), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q_pos.reshape(B, 1), qr, k, v, kv_pos)
    return out.reshape(B, H, Dv)


@functools.partial(
    jax.jit, static_argnames=("scale", "window", "block_l", "interpret"))
def paged_decode_attention(q, k_pages, v_pages, pos_pages, page_table, q_pos,
                           *, scale: Optional[float] = None,
                           window: Optional[int] = None,
                           block_l: int = 256, interpret: bool = False):
    """Flash decode over a paged KV pool (DESIGN.md §Continuous-batching).

    q: (B, H, D); k_pages/v_pages: (P, page, Hkv, Dv); pos_pages: (P, page);
    page_table: (B, n_max) page ids per row (null page 0 carries pos 2^30,
    masked by the causal rule). The gather assembles each row's logical
    context — one shared physical prompt copy per GRPO group — and the
    blocked online-softmax kernel above consumes it unchanged.
    """
    B = q.shape[0]
    P, page, Hkv, Dv = v_pages.shape
    n_max = page_table.shape[1]
    L = n_max * page
    k = k_pages[page_table].reshape(B, L, Hkv, k_pages.shape[-1])
    v = v_pages[page_table].reshape(B, L, Hkv, Dv)
    kv_pos = pos_pages[page_table].reshape(B, L)
    return decode_attention(q, k, v, kv_pos, q_pos, scale=scale,
                            window=window, block_l=block_l,
                            interpret=interpret)


@functools.partial(
    jax.jit, static_argnames=("scale", "window", "block_l", "interpret"))
def paged_mla_decode_attention(q, ckv_pages, kr_pages, pos_pages, page_table,
                               q_pos, *, scale: Optional[float] = None,
                               window: Optional[int] = None,
                               block_l: int = 256, interpret: bool = False):
    """Flash decode over a paged MLA LATENT pool (DESIGN.md
    §Cache-backends): pages hold compressed ``(ckv, kr)`` latent rows
    instead of per-head K/V.

    q: (B, H, r + rd) absorbed latent-space queries (w_uk folded in);
    ckv_pages: (P, page, r); kr_pages: (P, page, rd); pos_pages: (P, page);
    page_table: (B, n_max); q_pos: (B,). Returns (B, H, r) latent outputs —
    the caller applies w_uv. Absorbed MLA decode is exactly MQA with
    Dk = r + rd and Dv = r, so after the latent gather the blocked
    online-softmax kernel above consumes it unchanged (Hkv = 1, G = H).
    """
    B = q.shape[0]
    P, page, r = ckv_pages.shape
    n_max = page_table.shape[1]
    L = n_max * page
    ckv = ckv_pages[page_table].reshape(B, L, r)
    kr = kr_pages[page_table].reshape(B, L, kr_pages.shape[-1])
    k = jnp.concatenate([ckv, kr], axis=-1)[:, :, None, :]   # (B, L, 1, r+rd)
    v = ckv[:, :, None, :]                                   # (B, L, 1, r)
    kv_pos = pos_pages[page_table].reshape(B, L)
    return decode_attention(q, k, v, kv_pos, q_pos, scale=scale,
                            window=window, block_l=block_l,
                            interpret=interpret)
