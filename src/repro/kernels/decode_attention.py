"""Flash-decode Pallas kernel: new tokens' GQA attention against a long KV
cache, blocked over cache length with an online-softmax accumulator in VMEM
— the serving-side hot spot of the decoupled deployment (decode_32k /
long_500k shapes).

Two query shapes share one kernel body:

  * q_len = 1 (``decode_attention``): one new token per row — the plain
    continuous-batching decode step;
  * q_len = k+1 (``verify_attention``): the spec-decode verify block
    (DESIGN.md §Spec-decode) — k drafted tokens plus the unfed committed
    token attend in ONE pass, each query row masked by its OWN position, so
    intra-block causality needs no extra machinery.

Layout: grid = (B, Hkv, nL) with the cache-length axis innermost; queries
are flattened to R = q_len * G rows per Hkv head and the (R, Dv)
accumulator lives in VMEM scratch. Invalid cache slots carry pos >= 2**30
and are masked by the causal rule.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -1e30


def _kernel(qpos_ref, q_ref, k_ref, v_ref, kpos_ref,
            o_ref,
            acc_ref, m_ref, l_ref,
            *, scale: float, window: Optional[int], nL: int):
    li = pl.program_id(2)

    @pl.when(li == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # (R, D)
    k = k_ref[0, :, 0].astype(jnp.float32)         # (bL, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale  # (R, bL)
    qp = qpos_ref[0]                               # (R,) per-query positions
    kp = kpos_ref[0]                               # (bL,)
    ok = kp[None, :] <= qp[:, None]
    if window is not None:
        ok &= (qp[:, None] - kp[None, :]) < window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
    v = v_ref[0, :, 0].astype(jnp.float32)         # (bL, Dv)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * corr + pv
    m_ref[...] = m_new

    @pl.when(li == nL - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def _flash_rows(qr, k, v, kv_pos, q_pos_rows, *, scale: float,
                window: Optional[int], block_l: int, interpret: bool):
    """Blocked online-softmax attention for R query rows per Hkv head.

    qr: (B, Hkv, R, D) flattened query rows; q_pos_rows: (B, R) each row's
    own position (decode broadcasts one position over G rows; verify
    interleaves q_len positions x G). k/v: (B, L, Hkv, Dv); kv_pos: (B, L).
    Returns (B, Hkv, R, Dv) in qr.dtype.
    """
    B, Hkv, R, D = qr.shape
    _, L, _, Dv = v.shape

    bL = min(block_l, L)
    pad = (-L) % bL
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=2**30)
    L_p = L + pad
    nL = L_p // bL

    grid = (B, Hkv, nL)
    kernel = functools.partial(_kernel, scale=scale, window=window, nL=nL)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, R), lambda b, h, li: (b, 0)),           # q_pos
            pl.BlockSpec((1, 1, R, D), lambda b, h, li: (b, h, 0, 0)),
            pl.BlockSpec((1, bL, 1, D), lambda b, h, li: (b, li, h, 0)),
            pl.BlockSpec((1, bL, 1, Dv), lambda b, h, li: (b, li, h, 0)),
            pl.BlockSpec((1, bL), lambda b, h, li: (b, li)),         # kv_pos
        ],
        out_specs=pl.BlockSpec((1, 1, R, Dv), lambda b, h, li: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, R, Dv), qr.dtype),
        scratch_shapes=[
            pltpu.VMEM((R, Dv), jnp.float32),
            pltpu.VMEM((R, 1), jnp.float32),
            pltpu.VMEM((R, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q_pos_rows, qr, k, v, kv_pos)


@functools.partial(
    jax.jit, static_argnames=("scale", "window", "block_l", "interpret"))
def decode_attention(q, k, v, kv_pos, q_pos, *,
                     scale: Optional[float] = None,
                     window: Optional[int] = None,
                     block_l: int = 256, interpret: bool = False):
    """q: (B, H, D) one token per row; k/v: (B, L, Hkv, Dv); kv_pos: (B, L);
    q_pos: (B,). Returns (B, H, Dv) in q.dtype."""
    B, H, D = q.shape
    _, L, Hkv, Dv = v.shape
    G = H // Hkv
    scale = D ** -0.5 if scale is None else scale
    qr = q.reshape(B, Hkv, G, D)
    qp = jnp.broadcast_to(q_pos[:, None], (B, G))
    out = _flash_rows(qr, k, v, kv_pos, qp, scale=scale, window=window,
                      block_l=block_l, interpret=interpret)
    return out.reshape(B, H, Dv)


@functools.partial(
    jax.jit, static_argnames=("scale", "window", "block_l", "interpret"))
def verify_attention(q, k, v, kv_pos, q_pos, *,
                     scale: Optional[float] = None,
                     window: Optional[int] = None,
                     block_l: int = 256, interpret: bool = False):
    """Multi-token verify block (DESIGN.md §Spec-decode): q: (B, S, H, D)
    where S = k+1 drafted-plus-unfed tokens; q_pos: (B, S) each token's own
    position (the cache already holds the block's K/V, so causality within
    the block is the ordinary position mask); k/v: (B, L, Hkv, Dv);
    kv_pos: (B, L). Returns (B, S, H, Dv) in q.dtype."""
    B, S, H, D = q.shape
    _, L, Hkv, Dv = v.shape
    G = H // Hkv
    scale = D ** -0.5 if scale is None else scale
    # flatten to R = S*G query rows per Hkv head, position repeated per G
    qr = q.reshape(B, S, Hkv, G, D).transpose(0, 2, 1, 3, 4).reshape(
        B, Hkv, S * G, D)
    qp = jnp.repeat(q_pos, G, axis=1)                          # (B, S*G)
    out = _flash_rows(qr, k, v, kv_pos, qp, scale=scale, window=window,
                      block_l=block_l, interpret=interpret)
    out = out.reshape(B, Hkv, S, G, Dv).transpose(0, 2, 1, 3, 4)
    return out.reshape(B, S, H, Dv)


def decode_partial_stats(q, k, v, q_pos, kv_pos, q_seg, kv_seg, *,
                         window: Optional[int] = None,
                         scale: Optional[float] = None):
    """Single-pass decode attention PARTIAL stats over one KV shard — the
    per-device half of the shard_map'd dense-GQA decode step
    (models/attention.py ``_shmap_gqa_decode``, DESIGN.md
    §Device-resident-decode). Scores are normalised against the LOCAL max
    only; ``combine_partial_stats`` merges shards exactly (the flash
    online-softmax identity, applied once across devices instead of
    across chunks).

    q: (B, Sq, H, D); k/v: (B, L_loc, Hkv, Dv); q_pos/q_seg: (B, Sq);
    kv_pos/kv_seg: (B, L_loc). Returns f32 (pv, m, l):
    pv (B, Hkv, G, Sq, Dv) exp-weighted values, m (B, Hkv, G, Sq) local
    max, l (B, Hkv, G, Sq) local exp-sum. A shard with zero visible slots
    yields m == NEG_INF and garbage pv/l — the combine's exp(m - m_g)
    factor underflows to exactly 0.0, so the garbage never contributes."""
    B, Sq, H, D = q.shape
    _, L, Hkv, Dv = v.shape
    G = H // Hkv
    scale = D ** -0.5 if scale is None else scale
    qr = q.reshape(B, Sq, Hkv, G, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qr, k,
                   preferred_element_type=jnp.float32) * scale
    qp, kp = q_pos[:, :, None], kv_pos[:, None, :]
    qs, ks = q_seg[:, :, None], kv_seg[:, None, :]
    ok = (kp <= qp) & ((ks == 0) | (ks == qs))
    if window is not None:
        ok &= (qp - kp) < window
    s = jnp.where(ok[:, None, None, :, :], s, NEG_INF)
    m = s.max(axis=-1)                                 # (B, Hkv, G, Sq)
    p = jnp.exp(s - m[..., None])
    l = p.sum(axis=-1)
    pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(v.dtype), v,
                    preferred_element_type=jnp.float32)
    return pv, m, l


def combine_partial_stats(pv, m, l, axis_name: str):
    """Merge per-shard flash partials across ``axis_name`` (inside a
    shard_map): one pmax on the (B, Hkv, G, Sq) max plus two psums on the
    rescaled sum/value partials — the only collectives the shard_map'd
    decode step pays, all of them (B, H)-sized instead of cache-sized.
    Returns the normalised (B, Hkv, G, Sq, Dv) attention output."""
    m_g = jax.lax.pmax(m, axis_name)
    c = jnp.exp(m - m_g)
    l_g = jax.lax.psum(l * c, axis_name)
    pv_g = jax.lax.psum(pv * c[..., None], axis_name)
    return pv_g / jnp.maximum(l_g, 1e-30)[..., None]


def _gather_pages(k_pages, v_pages, pos_pages, page_table):
    """(P, page, Hkv, D) pools + (B, n_max) tables -> each row's logical
    (B, L, Hkv, D) context (null page 0 carries pos 2^30, masked)."""
    B, n_max = page_table.shape
    P, page = pos_pages.shape
    L = n_max * page
    k = k_pages[page_table].reshape(B, L, k_pages.shape[2],
                                    k_pages.shape[-1])
    v = v_pages[page_table].reshape(B, L, v_pages.shape[2],
                                    v_pages.shape[-1])
    kv_pos = pos_pages[page_table].reshape(B, L)
    return k, v, kv_pos


def _gather_latent_pages(ckv_pages, kr_pages, pos_pages, page_table):
    """Latent pools -> MQA-shaped (B, L, 1, r+rd) keys / (B, L, 1, r)
    values (absorbed MLA decode is MQA with Dk = r + rd, Dv = r)."""
    B, n_max = page_table.shape
    P, page, r = ckv_pages.shape
    L = n_max * page
    ckv = ckv_pages[page_table].reshape(B, L, r)
    kr = kr_pages[page_table].reshape(B, L, kr_pages.shape[-1])
    k = jnp.concatenate([ckv, kr], axis=-1)[:, :, None, :]
    v = ckv[:, :, None, :]
    kv_pos = pos_pages[page_table].reshape(B, L)
    return k, v, kv_pos


@functools.partial(
    jax.jit, static_argnames=("scale", "window", "block_l", "interpret"))
def paged_decode_attention(q, k_pages, v_pages, pos_pages, page_table, q_pos,
                           *, scale: Optional[float] = None,
                           window: Optional[int] = None,
                           block_l: int = 256, interpret: bool = False):
    """Flash decode over a paged KV pool (DESIGN.md §Continuous-batching).

    q: (B, H, D); k_pages/v_pages: (P, page, Hkv, Dv); pos_pages: (P, page);
    page_table: (B, n_max) page ids per row (null page 0 carries pos 2^30,
    masked by the causal rule). The gather assembles each row's logical
    context — one shared physical prompt copy per GRPO group — and the
    blocked online-softmax kernel above consumes it unchanged.
    """
    k, v, kv_pos = _gather_pages(k_pages, v_pages, pos_pages, page_table)
    return decode_attention(q, k, v, kv_pos, q_pos, scale=scale,
                            window=window, block_l=block_l,
                            interpret=interpret)


@functools.partial(
    jax.jit, static_argnames=("scale", "window", "block_l", "interpret"))
def paged_verify_attention(q, k_pages, v_pages, pos_pages, page_table,
                           q_pos, *, scale: Optional[float] = None,
                           window: Optional[int] = None,
                           block_l: int = 256, interpret: bool = False):
    """Spec-decode verify over a paged KV pool: q: (B, S, H, D) with the
    k+1-token block already written into the pool (speculative pages), so
    the gathered context + per-token position mask give exact causality."""
    k, v, kv_pos = _gather_pages(k_pages, v_pages, pos_pages, page_table)
    return verify_attention(q, k, v, kv_pos, q_pos, scale=scale,
                            window=window, block_l=block_l,
                            interpret=interpret)


@functools.partial(
    jax.jit, static_argnames=("scale", "window", "block_l", "interpret"))
def paged_mla_decode_attention(q, ckv_pages, kr_pages, pos_pages, page_table,
                               q_pos, *, scale: Optional[float] = None,
                               window: Optional[int] = None,
                               block_l: int = 256, interpret: bool = False):
    """Flash decode over a paged MLA LATENT pool (DESIGN.md
    §Cache-backends): pages hold compressed ``(ckv, kr)`` latent rows
    instead of per-head K/V.

    q: (B, H, r + rd) absorbed latent-space queries (w_uk folded in);
    ckv_pages: (P, page, r); kr_pages: (P, page, rd); pos_pages: (P, page);
    page_table: (B, n_max); q_pos: (B,). Returns (B, H, r) latent outputs —
    the caller applies w_uv. Absorbed MLA decode is exactly MQA with
    Dk = r + rd and Dv = r, so after the latent gather the blocked
    online-softmax kernel above consumes it unchanged (Hkv = 1, G = H).
    """
    k, v, kv_pos = _gather_latent_pages(ckv_pages, kr_pages, pos_pages,
                                        page_table)
    return decode_attention(q, k, v, kv_pos, q_pos, scale=scale,
                            window=window, block_l=block_l,
                            interpret=interpret)


@functools.partial(
    jax.jit, static_argnames=("scale", "window", "block_l", "interpret"))
def paged_mla_verify_attention(q, ckv_pages, kr_pages, pos_pages,
                               page_table, q_pos, *,
                               scale: Optional[float] = None,
                               window: Optional[int] = None,
                               block_l: int = 256, interpret: bool = False):
    """Spec-decode verify over the paged MLA latent pool: q: (B, S, H,
    r + rd) absorbed queries for the k+1-token block; q_pos: (B, S).
    Returns (B, S, H, r) latent outputs."""
    k, v, kv_pos = _gather_latent_pages(ckv_pages, kr_pages, pos_pages,
                                        page_table)
    return verify_attention(q, k, v, kv_pos, q_pos, scale=scale,
                            window=window, block_l=block_l,
                            interpret=interpret)
