"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode; on TPU the
same calls compile to Mosaic. ``auto_interpret()`` picks per backend.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.kernels.transfer_cast import transfer_cast as _transfer_cast
from repro.kernels.decode_attention import decode_attention as _decode
from repro.kernels.decode_attention import verify_attention as _verify
from repro.kernels.decode_attention import paged_decode_attention as _paged
from repro.kernels.decode_attention import (paged_mla_decode_attention
                                            as _paged_mla)
from repro.kernels.decode_attention import (paged_verify_attention
                                            as _paged_verify)
from repro.kernels.decode_attention import (paged_mla_verify_attention
                                            as _paged_mla_verify)
from repro.kernels.spa_attention import spa_attention as _spa, block_map


def auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


def spa_attention(q, k, v, q_pos, kv_pos, q_seg, kv_seg, *,
                  scale: Optional[float] = None,
                  window: Optional[int] = None,
                  block_q: int = 128, block_k: int = 128,
                  interpret: Optional[bool] = None):
    """Block-sparse shared-prompt flash attention (see spa_attention.py)."""
    itp = auto_interpret() if interpret is None else interpret
    return _spa(q, k, v, q_pos, kv_pos, q_seg, kv_seg, scale=scale,
                window=window, block_q=block_q, block_k=block_k,
                interpret=itp)


def decode_attention(q, k, v, kv_pos, q_pos, *,
                     scale: Optional[float] = None,
                     window: Optional[int] = None,
                     block_l: int = 256,
                     interpret: Optional[bool] = None):
    """Flash-decode GQA attention (see decode_attention.py)."""
    itp = auto_interpret() if interpret is None else interpret
    return _decode(q, k, v, kv_pos, q_pos, scale=scale, window=window,
                   block_l=block_l, interpret=itp)


def paged_decode_attention(q, k_pages, v_pages, pos_pages, page_table, q_pos,
                           *, scale: Optional[float] = None,
                           window: Optional[int] = None,
                           block_l: int = 256,
                           interpret: Optional[bool] = None):
    """Flash-decode over a paged KV pool (see decode_attention.py)."""
    itp = auto_interpret() if interpret is None else interpret
    return _paged(q, k_pages, v_pages, pos_pages, page_table, q_pos,
                  scale=scale, window=window, block_l=block_l, interpret=itp)


def paged_mla_decode_attention(q, ckv_pages, kr_pages, pos_pages, page_table,
                               q_pos, *, scale: Optional[float] = None,
                               window: Optional[int] = None,
                               block_l: int = 256,
                               interpret: Optional[bool] = None):
    """Flash-decode over a paged MLA latent pool (see decode_attention.py)."""
    itp = auto_interpret() if interpret is None else interpret
    return _paged_mla(q, ckv_pages, kr_pages, pos_pages, page_table, q_pos,
                      scale=scale, window=window, block_l=block_l,
                      interpret=itp)


def verify_attention(q, k, v, kv_pos, q_pos, *,
                     scale: Optional[float] = None,
                     window: Optional[int] = None,
                     block_l: int = 256,
                     interpret: Optional[bool] = None):
    """Multi-token spec-decode verify attention (decode_attention.py)."""
    itp = auto_interpret() if interpret is None else interpret
    return _verify(q, k, v, kv_pos, q_pos, scale=scale, window=window,
                   block_l=block_l, interpret=itp)


def paged_verify_attention(q, k_pages, v_pages, pos_pages, page_table, q_pos,
                           *, scale: Optional[float] = None,
                           window: Optional[int] = None,
                           block_l: int = 256,
                           interpret: Optional[bool] = None):
    """Spec-decode verify over a paged KV pool (decode_attention.py)."""
    itp = auto_interpret() if interpret is None else interpret
    return _paged_verify(q, k_pages, v_pages, pos_pages, page_table, q_pos,
                         scale=scale, window=window, block_l=block_l,
                         interpret=itp)


def paged_mla_verify_attention(q, ckv_pages, kr_pages, pos_pages, page_table,
                               q_pos, *, scale: Optional[float] = None,
                               window: Optional[int] = None,
                               block_l: int = 256,
                               interpret: Optional[bool] = None):
    """Spec-decode verify over a paged MLA latent pool
    (decode_attention.py)."""
    itp = auto_interpret() if interpret is None else interpret
    return _paged_mla_verify(q, ckv_pages, kr_pages, pos_pages, page_table,
                             q_pos, scale=scale, window=window,
                             block_l=block_l, interpret=itp)


def transfer_cast(x, dtype, *, block_rows: int = 256,
                  interpret: Optional[bool] = None):
    """Fused cast+copy for the weight-plane wire path (transfer_cast.py)."""
    itp = auto_interpret() if interpret is None else interpret
    return _transfer_cast(x, dtype, block_rows=block_rows, interpret=itp)


__all__ = ["spa_attention", "decode_attention", "verify_attention",
           "paged_decode_attention", "paged_mla_decode_attention",
           "paged_verify_attention", "paged_mla_verify_attention",
           "block_map", "auto_interpret", "transfer_cast"]
