"""Pure-jnp oracles for the Pallas kernels (dense masked attention).

These are the ground truth for tests/test_kernels.py: every kernel sweep
asserts allclose against these at f32.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

NEG_INF = -1e30


def allow_mask(q_pos, kv_pos, q_seg, kv_seg, window: Optional[int] = None):
    """(B, Sq), (B, Skv) -> (B, Sq, Skv) boolean shared-prompt/causal mask:
    kv visible iff kv_pos <= q_pos AND (kv_seg == 0 OR kv_seg == q_seg),
    optionally windowed."""
    qp = q_pos[:, :, None]
    kp = kv_pos[:, None, :]
    qs = q_seg[:, :, None]
    ks = kv_seg[:, None, :]
    allow = (kp <= qp) & ((ks == 0) | (ks == qs))
    if window is not None:
        allow &= (qp - kp) < window
    return allow


def spa_attention_ref(q, k, v, q_pos, kv_pos, q_seg, kv_seg, *,
                      window: Optional[int] = None,
                      scale: Optional[float] = None):
    """Dense shared-prompt attention.

    q: (B, Sq, H, D); k/v: (B, Skv, Hkv, D). Returns (B, Sq, H, Dv) f32.
    """
    B, Sq, H, D = q.shape
    _, Skv, Hkv, Dv = v.shape
    G = H // Hkv
    scale = D ** -0.5 if scale is None else scale
    qf = q.astype(jnp.float32).reshape(B, Sq, Hkv, G, D)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kf) * scale
    ok = allow_mask(q_pos, kv_pos, q_seg, kv_seg, window)
    s = jnp.where(ok[:, None, None, :, :], s, NEG_INF)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / jnp.maximum(p.sum(axis=-1, keepdims=True), 1e-30)
    o = jnp.einsum("bhgqk,bkhd->bhgqd", p, vf)
    return jnp.moveaxis(o, 3, 1).reshape(B, Sq, H, Dv)


def decode_attention_ref(q, k, v, kv_pos, q_pos, *,
                         window: Optional[int] = None,
                         scale: Optional[float] = None):
    """Single-token GQA decode attention against a cache.

    q: (B, H, D) (one new token per row); k/v: (B, L, Hkv, D);
    kv_pos: (B, L) int32 with INVALID slots marked by a huge position;
    q_pos: (B,) the new token's position. Returns (B, H, Dv) f32.
    """
    B, H, D = q.shape
    _, L, Hkv, Dv = v.shape
    G = H // Hkv
    scale = D ** -0.5 if scale is None else scale
    qf = q.astype(jnp.float32).reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bkhd->bhgk", qf, k.astype(jnp.float32)) * scale
    ok = kv_pos <= q_pos[:, None]
    if window is not None:
        ok &= (q_pos[:, None] - kv_pos) < window
    s = jnp.where(ok[:, None, None, :], s, NEG_INF)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / jnp.maximum(p.sum(axis=-1, keepdims=True), 1e-30)
    o = jnp.einsum("bhgk,bkhd->bhgd", p, v.astype(jnp.float32))
    return o.reshape(B, H, Dv)


def verify_attention_ref(q, k, v, kv_pos, q_pos, *,
                         window: Optional[int] = None,
                         scale: Optional[float] = None):
    """Multi-token spec-decode verify attention against a cache that
    already holds the block's K/V (DESIGN.md §Spec-decode).

    q: (B, S, H, D) the k+1-token verify block; k/v: (B, L, Hkv, D);
    kv_pos: (B, L) with INVALID slots marked by a huge position;
    q_pos: (B, S) each block token's own position — causality within the
    block is the ordinary position mask. Returns (B, S, H, Dv) f32.
    """
    B, S, H, D = q.shape
    _, L, Hkv, Dv = v.shape
    G = H // Hkv
    scale = D ** -0.5 if scale is None else scale
    qf = q.astype(jnp.float32).reshape(B, S, Hkv, G, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k.astype(jnp.float32)) * scale
    ok = kv_pos[:, None, :] <= q_pos[:, :, None]               # (B, S, L)
    if window is not None:
        ok &= (q_pos[:, :, None] - kv_pos[:, None, :]) < window
    s = jnp.where(ok[:, None, None, :, :], s, NEG_INF)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / jnp.maximum(p.sum(axis=-1, keepdims=True), 1e-30)
    o = jnp.einsum("bhgqk,bkhd->bhgqd", p, v.astype(jnp.float32))
    return jnp.moveaxis(o, 3, 1).reshape(B, S, H, Dv)
