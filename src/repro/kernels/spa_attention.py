"""Block-sparse shared-prompt flash attention — the TPU-native counterpart of
the paper's ``npu_fusion_attention`` custom-mask kernel (§5, §4.3).

TPU adaptation (see DESIGN.md §3): instead of a dense masked kernel, the
shared-prompt mask is evaluated per 128x128 tile from (position, segment)
arrays, and tiles where *no* query can see *any* key — response_i x
response_j blocks with i != j, and fully-non-causal blocks — are skipped
entirely via a host-precomputed block map. That realises the paper's
O(Lp^2 + K*Lr*Lp + K*Lr^2) complexity *structurally* on the MXU, with the
online-softmax running max/sum held in VMEM scratch.

Layout: q/k/v are head-folded to (BH, S, D); grid = (BH, nq, nk) with the
kv axis innermost so the (bq, D) accumulator lives in VMEM scratch across
kv steps.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -1e30


def _kernel(bmap_ref, qpos_ref, kpos_ref, qseg_ref, kseg_ref,
            q_ref, k_ref, v_ref,            # inputs
            o_ref,                          # output
            acc_ref, m_ref, l_ref,          # VMEM scratch
            *, scale: float, window: Optional[int], nk: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    @pl.when(bmap_ref[0, 0, 0] != 0)
    def _compute():
        q = q_ref[0].astype(jnp.float32)            # (bq, D)
        k = k_ref[0].astype(jnp.float32)            # (bk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale      # (bq, bk)
        qp = qpos_ref[0][:, None]                   # (bq, 1)
        kp = kpos_ref[0][None, :]                   # (1, bk)
        qs = qseg_ref[0][:, None]
        ks = kseg_ref[0][None, :]
        allow = (kp <= qp) & ((ks == 0) | (ks == qs))
        if window is not None:
            allow &= (qp - kp) < window
        s = jnp.where(allow, s, NEG_INF)
        m_prev = m_ref[...]                          # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)               # (bq, 1)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)             # (bk, Dv)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr + pv
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def block_map(q_pos, kv_pos, q_seg, kv_seg, bq: int, bk: int,
              window: Optional[int] = None):
    """Host/jnp-side conservative tile visibility map -> (B, nq, nk) int32.

    A tile is live iff some (q, kv) pair in it could be visible: causal
    (min kv_pos <= max q_pos), window (max kv_pos > min q_pos - window) and
    segment-compatible (kv tile touches segment 0, or the segment ranges
    intersect). Over-approximation is safe — the in-kernel mask is exact."""
    B, Sq = q_pos.shape
    Skv = kv_pos.shape[1]
    nq, nk = Sq // bq, Skv // bk
    qp = q_pos.reshape(B, nq, bq)
    kp = kv_pos.reshape(B, nk, bk)
    qs = q_seg.reshape(B, nq, bq)
    ks = kv_seg.reshape(B, nk, bk)
    causal = kp.min(-1)[:, None, :] <= qp.max(-1)[:, :, None]   # (B, nq, nk)
    if window is not None:
        causal &= kp.max(-1)[:, None, :] > (qp.min(-1)[:, :, None] - window)
    ks_min, ks_max = ks.min(-1), ks.max(-1)
    qs_min, qs_max = qs.min(-1), qs.max(-1)
    seg_ok = (ks_min[:, None, :] <= 0) | (
        (ks_min[:, None, :] <= qs_max[:, :, None])
        & (ks_max[:, None, :] >= qs_min[:, :, None]))
    return (causal & seg_ok).astype(jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("scale", "window", "block_q", "block_k",
                              "interpret"))
def spa_attention(q, k, v, q_pos, kv_pos, q_seg, kv_seg, *,
                  scale: Optional[float] = None,
                  window: Optional[int] = None,
                  block_q: int = 128, block_k: int = 128,
                  interpret: bool = False):
    """Shared-prompt flash attention.

    q: (B, Sq, H, D); k/v: (B, Skv, Hkv, Dv) with H % Hkv == 0 (GQA: kv heads
    are repeated to H on the host side of the fold). pos/seg: (B, S) int32.
    Returns (B, Sq, H, Dv) in q.dtype.
    """
    B, Sq, H, D = q.shape
    _, Skv, Hkv, Dv = v.shape
    scale = D ** -0.5 if scale is None else scale
    G = H // Hkv
    if G != 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)

    bq, bk = min(block_q, Sq), min(block_k, Skv)
    pad_q, pad_k = (-Sq) % bq, (-Skv) % bk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad_q)))
        q_seg = jnp.pad(q_seg, ((0, 0), (0, pad_q)), constant_values=-1)
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad_k)), constant_values=2**30)
        kv_seg = jnp.pad(kv_seg, ((0, 0), (0, pad_k)), constant_values=-2)
    Sq_p, Skv_p = Sq + pad_q, Skv + pad_k
    nq, nk = Sq_p // bq, Skv_p // bk

    bmap = block_map(q_pos, kv_pos, q_seg, kv_seg, bq, bk, window)

    # fold heads into batch: (B, S, H, D) -> (B*H, S, D)
    qf = jnp.moveaxis(q, 2, 1).reshape(B * H, Sq_p, D)
    kf = jnp.moveaxis(k, 2, 1).reshape(B * H, Skv_p, D)
    vf = jnp.moveaxis(v, 2, 1).reshape(B * H, Skv_p, Dv)

    grid = (B * H, nq, nk)
    kernel = functools.partial(_kernel, scale=scale, window=window, nk=nk)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1), lambda b, qi, ki: (b // H, qi, ki)),
            pl.BlockSpec((1, bq), lambda b, qi, ki: (b // H, qi)),
            pl.BlockSpec((1, bk), lambda b, qi, ki: (b // H, ki)),
            pl.BlockSpec((1, bq), lambda b, qi, ki: (b // H, qi)),
            pl.BlockSpec((1, bk), lambda b, qi, ki: (b // H, ki)),
            pl.BlockSpec((1, bq, D), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, bk, D), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, bk, Dv), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, Dv), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq_p, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, Dv), jnp.float32),   # acc
            pltpu.VMEM((bq, 1), jnp.float32),    # running max
            pltpu.VMEM((bq, 1), jnp.float32),    # running sum
        ],
        interpret=interpret,
    )(bmap, q_pos, kv_pos, q_seg, kv_seg, qf, kf, vf)

    out = out.reshape(B, H, Sq_p, Dv)[:, :, :Sq]
    return jnp.moveaxis(out, 1, 2)
