"""Pallas fused cast+copy for the weight-plane wire path.

When the transfer service streams an fp32-mastered tree as a bf16 payload
(``RLConfig.transfer_wire_dtype``), the naive path materialises an fp32
copy in HBM and then a second pass casts it. This kernel fuses the two:
one read of the source tile, one write of the down-cast tile — the copy IS
the cast, so the wire staging buffer is written exactly once at the
payload dtype.

Layout: the leaf is viewed as a (rows, 128) lane grid. When the element
count is lane-aligned and the row count tiles evenly (every power-of-two
weight matrix — the weight-plane's common case), the source is fed to the
kernel AS IS: no padding copy, total traffic = one source read + one
payload write (half the HBM traffic of copy-then-cast for fp32->bf16).
Ragged leaves (norm vectors, odd tails) fall back to a zero-padded
staging copy first — strictly worse than ``astype`` for them, but they
are a rounding error of the tree's bytes. Rounding is XLA's convert
(round to nearest even), so the result is bitwise-identical to
``x.astype(dtype)`` — asserted in tests/test_transfer.py against the
pure-JAX path.

On CPU (this container) the kernel runs in interpret mode; on TPU the same
call compiles to Mosaic.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_LANES = 128
_MIN_SUBLANES = 8


def _cast_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...].astype(o_ref.dtype)


def _cast_call(x2d, dtype, bm: int, interpret: bool):
    rows = x2d.shape[0]
    return pl.pallas_call(
        _cast_kernel,
        grid=(rows // bm,),
        in_specs=[pl.BlockSpec((bm, _LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, _LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, _LANES), dtype),
        interpret=interpret,
    )(x2d)


@partial(jax.jit, static_argnames=("dtype", "block_rows", "interpret"))
def transfer_cast(x, dtype, *, block_rows: int = 256,
                  interpret: bool = True):
    """Fused cast+copy of one pytree leaf: ``x`` -> ``dtype``.

    Any shape/dtype in; value-equal to ``x.astype(dtype)`` out. No-op
    dtypes and 0-element leaves short-circuit.
    """
    dtype = jnp.dtype(dtype)
    if x.dtype == dtype:
        return x
    n = x.size
    if n == 0:
        return x.astype(dtype)
    flat = x.reshape(-1)
    if n % _LANES == 0:
        rows = n // _LANES
        bm = math.gcd(rows, block_rows)
        if bm >= _MIN_SUBLANES:
            # aligned fast path: the source IS the kernel input — no
            # staging copy, no output slice
            out = _cast_call(flat.reshape(rows, _LANES), dtype, bm,
                             interpret)
            return out.reshape(x.shape)
    rows = -(-n // _LANES)
    rows = -(-rows // block_rows) * block_rows
    padded = jnp.zeros((rows * _LANES,), x.dtype).at[:n].set(flat)
    out = _cast_call(padded.reshape(rows, _LANES), dtype, block_rows,
                     interpret)
    return out.reshape(-1)[:n].reshape(x.shape)
