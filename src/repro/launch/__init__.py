"""Launch layer: production mesh, dry-run lowering, train/serve drivers."""
