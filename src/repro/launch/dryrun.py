"""Multi-pod dry-run: prove the distribution config is coherent without
real hardware.

For every (architecture x input shape) pair this lowers + compiles the
matching step function (train_step / prefill_step / serve_step) against the
production mesh — 16x16 single-pod and 2x16x16 multi-pod — records
``memory_analysis()`` / ``cost_analysis()``, and parses per-device collective
bytes from the optimised HLO. Results land in
``benchmarks/results/dryrun/<arch>__<shape>__<mesh>.json`` and feed the
roofline analysis (EXPERIMENTS.md §Roofline).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-3b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--skip-existing]
"""
from __future__ import annotations

# The placeholder-device flag must be set before ANY jax import — jax locks
# the device count on first init. This module is the only place it is set
# (smoke tests and benches must see 1 device).
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import re
import time
import traceback

import jax

from repro.configs import (ARCH_IDS, SHAPES, get_config, long_context_variant)
from repro.configs.base import RLConfig
from repro.launch import inputs as inputs_mod
from repro.launch.hlo_analysis import analyze
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (make_prefill_step_fn, make_serve_step_fn,
                                make_train_step_fn)
from repro.sharding.specs import use_mesh

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "benchmarks", "results", "dryrun")

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """'f32[16,512]' -> bytes."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Sum per-device result bytes of every collective op in the optimised
    HLO (async ops counted at -start only)."""
    out = {k: {"count": 0, "bytes": 0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(.+?)\s+([\w\-]+)\(", line)
        if not m:
            continue
        result_shape, op = m.groups()
        base = op
        for suffix in ("-start", "-done"):
            if base.endswith(suffix):
                base = base[: -len(suffix)]
        if base not in _COLLECTIVES:
            continue
        if op.endswith("-done"):
            continue  # counted at -start
        out[base]["count"] += 1
        out[base]["bytes"] += _shape_bytes(result_shape)
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items()
                             if isinstance(v, dict))
    out["total_count"] = sum(v["count"] for k, v in out.items()
                             if isinstance(v, dict))
    return out


def applicable(cfg, shape) -> tuple[bool, str]:
    if shape.name == "long_500k":
        c = long_context_variant(cfg)
        if not c.supports_long_decode:
            return False, ("decoder context bound (448) makes a 524288-token "
                           "decode out of family scope — see DESIGN.md")
    return True, ""


def build_step(cfg, shape, mesh, rl: RLConfig,
               num_microbatches: int | None = None):
    if shape.kind == "train":
        from repro.launch.steps import default_microbatches
        if num_microbatches is None:
            num_microbatches = default_microbatches(cfg, shape.global_batch)
        fn = make_train_step_fn(cfg, rl, num_microbatches=num_microbatches)
        si = inputs_mod.train_inputs(cfg, shape, rl, mesh)
    elif shape.kind == "prefill":
        fn = make_prefill_step_fn(cfg)
        si = inputs_mod.prefill_inputs(cfg, shape, mesh)
    else:
        fn = make_serve_step_fn(cfg)
        si = inputs_mod.decode_inputs(cfg, shape, mesh)
    return fn, si


def run_one(arch: str, shape_name: str, *, multi_pod: bool,
            rl: RLConfig | None = None, profile: str = "baseline",
            num_microbatches: int | None = None) -> dict:
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    if shape.name == "long_500k":
        cfg = long_context_variant(cfg)
    ok, why = applicable(get_config(arch), shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "kind": shape.kind, "status": "skipped", "skip_reason": why}
    if not ok:
        return rec
    rl = rl or RLConfig()
    from repro.sharding.specs import set_profile
    set_profile(profile)
    rec["profile"] = profile
    mesh = make_production_mesh(multi_pod=multi_pod)
    fn, si = build_step(cfg, shape, mesh, rl, num_microbatches=num_microbatches)

    t0 = time.time()
    with use_mesh(mesh):
        jitted = jax.jit(fn, in_shardings=si.shardings,
                         out_shardings=si.out_shardings,
                         donate_argnums=si.donate)
        lowered = jitted.lower(*si.args)
        t_lower = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t1

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo = analyze(compiled.as_text())   # loop-corrected (see hlo_analysis.py)
    n_chips = mesh.size
    rec.update({
        "status": "ok",
        "chips": n_chips,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_estimate_bytes": (ma.argument_size_in_bytes
                                    + ma.output_size_in_bytes
                                    + ma.temp_size_in_bytes
                                    - ma.alias_size_in_bytes),
        },
        # raw XLA numbers (NOTE: CPU cost_analysis counts while bodies once)
        "cost_raw": {k: ca.get(k) for k in
                     ("flops", "bytes accessed", "transcendentals")
                     if k in ca},
        # loop-corrected per-device numbers from the optimised HLO
        "hlo": {
            "dot_flops_executed": hlo["dot_flops_executed"],
            "dot_flops_once": hlo["dot_flops_once"],
            "hbm_bytes_executed": hlo["hbm_bytes_executed"],
            "collective_bytes_executed": hlo["collective_bytes_executed"],
            "collective_bytes_once": hlo["collective_bytes_once"],
            "collectives": hlo["collectives"],
        },
        "model": {
            "params": cfg.param_count(),
            "active_params": cfg.active_param_count(),
        },
    })
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ARCH_IDS) + [None])
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--out", default=OUT_DIR)
    args = ap.parse_args()

    archs = list(ARCH_IDS) if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for multi_pod in meshes:
        mesh_name = "2x16x16" if multi_pod else "16x16"
        for arch in archs:
            for shape in shapes:
                fname = os.path.join(
                    args.out, f"{arch}__{shape}__{mesh_name}.json")
                if args.skip_existing and os.path.exists(fname):
                    print(f"[skip existing] {arch} {shape} {mesh_name}")
                    continue
                t0 = time.time()
                try:
                    rec = run_one(arch, shape, multi_pod=multi_pod)
                except Exception as e:
                    failures += 1
                    rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                           "status": "error", "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()}
                with open(fname, "w") as f:
                    json.dump(rec, f, indent=1)
                msg = rec["status"]
                if rec["status"] == "ok":
                    gib = rec["memory"]["peak_estimate_bytes"] / 2**30
                    msg += (f" compile={rec['compile_s']:.0f}s "
                            f"peak={gib:.2f}GiB "
                            f"dotflops={rec['hlo']['dot_flops_executed']:.3g} "
                            f"coll={rec['hlo']['collective_bytes_executed']/2**20:.0f}MiB")
                elif rec["status"] == "error":
                    msg += " " + rec["error"][:120]
                print(f"[{arch} {shape} {mesh_name}] {msg} "
                      f"({time.time()-t0:.0f}s)", flush=True)
    if failures:
        raise SystemExit(f"{failures} dry-run failures")


if __name__ == "__main__":
    main()
