"""Loop-aware analysis of optimised HLO text.

XLA's ``compiled.cost_analysis()`` on the CPU backend counts while-loop
bodies ONCE, so any cost inside a ``lax.scan`` (layers, attention chunks,
Eq.-1 micro-batches, decode steps) is under-counted by its trip count, and a
naive grep over the HLO text under-counts collectives the same way.

This module parses the optimised HLO, builds the computation call graph,
recovers scan trip counts from each while-condition's ``compare(iter,
constant)`` bound, and walks the graph with multipliers to produce:

  * per-collective-type executed bytes + counts  (roofline collective term)
  * executed dot FLOPs                           (roofline compute term)
  * executed collective/dot bytes by computation (debugging)

Byte convention: a collective's cost is its per-device RESULT bytes (operand
bytes for reduce-scatter, which shrinks) — a uniform, documented proxy.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "s8": 1, "u8": 1, "pred": 1,
    "s4": 1, "u4": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\(")
_CALLS = re.compile(
    r"(?:calls=|to_apply=|body=|condition=|branch_computations=\{)"
    r"(%?[\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?")
_WHILE_PARTS = re.compile(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CONST = re.compile(r"=\s*s32\[\]\s*constant\((\d+)\)")
_DOT_DIMS = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _shape_list(text: str) -> List[Tuple[str, List[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _bytes_of(text: str) -> int:
    total = 0
    for dt, dims in _shape_list(text):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Instr:
    name: str
    result: str          # result-shape text (may be a tuple)
    op: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    is_entry: bool = False


def parse_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        hdr = _COMP_HDR.match(line) if not line.startswith(" ") else None
        if hdr and stripped.endswith("{"):
            cur = Computation(name=hdr.group(1), instrs=[],
                              is_entry=line.startswith("ENTRY"))
            comps[cur.name] = cur
            continue
        if stripped == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR.match(line)
        if m:
            cur.instrs.append(Instr(name=m.group(1), result=m.group(2),
                                    op=m.group(3), line=stripped))
    return comps


_KNOWN_TRIP = re.compile(r'known_trip_count..\{."n":"(\d+)"')


def _trip_count(while_line: str, cond: Optional[Computation]) -> int:
    """Prefer XLA's own ``backend_config known_trip_count`` annotation;
    fall back to the max s32[] constant in the condition computation
    (lax.scan lowers to iter=0; while(iter < N)). Defaults to 1."""
    m = _KNOWN_TRIP.search(while_line)
    if m:
        return int(m.group(1))
    best = 1
    if cond is not None:
        for ins in cond.instrs:
            for c in _CONST.finditer(ins.line):
                best = max(best, int(c.group(1)))
    return best


_OPERANDS = re.compile(r"\(([^)]*)\)")


def _dot_flops(ins: Instr, shapes_by_name: Dict[str, List[int]]) -> int:
    """2 * prod(result dims) * prod(contracting dims of lhs).

    CPU optimised HLO prints operands by NAME only, so the lhs shape comes
    from a per-computation name -> result-shape map."""
    res = _shape_list(ins.result)
    if not res:
        return 0
    n_out = 1
    for d in res[0][1]:
        n_out *= d
    operands = ins.line.split(" dot(", 1)
    if len(operands) < 2:
        return 0
    first = operands[1].split(",")[0].split(")")[0].strip().lstrip("%")
    lhs_dims = shapes_by_name.get(first)
    if lhs_dims is None:
        return 0
    mdims = _DOT_DIMS.search(ins.line)
    k = 1
    if mdims:
        for idx in (int(i) for i in mdims.group(1).split(",") if i):
            if idx < len(lhs_dims):
                k *= lhs_dims[idx]
    return 2 * n_out * k


def analyze(hlo: str) -> dict:
    comps = parse_computations(hlo)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:  # fall back: biggest computation
        entry = max(comps.values(), key=lambda c: len(c.instrs))

    coll = {k: {"count": 0, "bytes": 0, "executed_bytes": 0}
            for k in COLLECTIVES}
    totals = {"dot_flops": 0, "dot_flops_executed": 0,
              "hbm_bytes_executed": 0}

    # ops whose result is not a fresh HBM materialisation
    _NO_TRAFFIC = {"parameter", "constant", "get-tuple-element", "tuple",
                   "bitcast", "while", "call", "conditional",
                   "after-all", "token"}

    seen_stack: List[str] = []

    def walk(comp: Computation, mult: int, fused: bool = False):
        if comp.name in seen_stack:   # defensive: no recursion in HLO
            return
        seen_stack.append(comp.name)
        shapes_by_name = {i.name: s[0][1]
                          for i in comp.instrs
                          for s in [_shape_list(i.result)] if s}
        for ins in comp.instrs:
            # HBM-traffic proxy: every top-level (non-fused) op writes its
            # result to HBM once per execution; reads ~= writes, so the
            # roofline memory term doubles this sum. Fusion interiors stay
            # in registers/VMEM and are skipped.
            if not fused and ins.op not in _NO_TRAFFIC:
                b = _bytes_of(ins.result)
                if ins.op == "dynamic-update-slice":
                    # writes only the update operand, not the whole buffer
                    ops = ins.line.split("dynamic-update-slice(", 1)
                    if len(ops) == 2:
                        upd = ops[1].split(",")[1].strip().lstrip("%")
                        dims = shapes_by_name.get(upd)
                        if dims is not None:
                            n = 1
                            for d in dims:
                                n *= d
                            dt = _shape_list(ins.result)
                            if dt:
                                b = n * _DTYPE_BYTES[dt[0][0]]
                totals["hbm_bytes_executed"] += b * mult
            base = ins.op
            for suffix in ("-start", "-done"):
                if base.endswith(suffix):
                    base = base[: -len(suffix)]
            if base in COLLECTIVES and not ins.op.endswith("-done"):
                b = _bytes_of(ins.result)
                coll[base]["count"] += 1
                coll[base]["bytes"] += b
                coll[base]["executed_bytes"] += b * mult
            if ins.op == "dot":
                f = _dot_flops(ins, shapes_by_name)
                totals["dot_flops"] += f
                totals["dot_flops_executed"] += f * mult
            if ins.op == "while":
                wp = _WHILE_PARTS.search(ins.line)
                if wp and wp.group(2) in comps:
                    trips = _trip_count(ins.line, comps.get(wp.group(1)))
                    walk(comps[wp.group(2)], mult * trips, fused)
            elif ins.op in ("fusion", "call", "conditional", "map",
                            "reduce", "reduce-window", "scatter", "sort",
                            "all-reduce", "reduce-scatter", "custom-call",
                            "async-start"):
                cm = _CALLS.search(ins.line)
                if cm:
                    for callee in re.split(r",\s*", cm.group(1)):
                        callee = callee.lstrip("%")
                        # reducers of all-reduce etc. are trivial adders —
                        # walking them is harmless (no dots/collectives).
                        if callee in comps:
                            walk(comps[callee], mult,
                                 fused or ins.op == "fusion")
        seen_stack.pop()

    walk(entry, 1)

    coll_exec = sum(v["executed_bytes"] for v in coll.values())
    coll_once = sum(v["bytes"] for v in coll.values())
    return {
        "collectives": coll,
        "collective_bytes_executed": coll_exec,
        "collective_bytes_once": coll_once,
        "dot_flops_once": totals["dot_flops"],
        "dot_flops_executed": totals["dot_flops_executed"],
        "hbm_bytes_executed": 2 * totals["hbm_bytes_executed"],
    }
