"""ShapeDtypeStruct stand-ins + shardings for every (arch x input-shape) pair.

No device allocation happens here — everything is a ShapeDtypeStruct, the
same pattern used for `.lower()` dry-runs. Modality frontends are stubs per
the assignment carve-out: ``vision_embeds`` / ``enc_embeds`` arrive as
precomputed patch/frame embeddings of the right shape.

Contract for VLM train/prefill inputs: ``tokens``/``labels``/``loss_mask``/
``advantages`` cover only the text part (S - vision_prefix_len), while
``positions``/``segments`` cover the full packed sequence (vision prefix +
text) — matching forward_hidden's concatenated input row.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig, RLConfig
from repro.models import init, init_caches
from repro.models.layers import dtype_of
from repro.optim.adam import adam_init
from repro.rl.grpo import MicroBatch
from repro.sharding.specs import cache_specs, param_specs, spec_for

SDS = jax.ShapeDtypeStruct


class StepInputs(NamedTuple):
    kind: str            # train | prefill | decode
    args: tuple          # ShapeDtypeStruct pytrees, positional
    shardings: tuple     # matching NamedSharding pytrees
    donate: tuple = ()   # argnums donated (decode caches / consumed state)
    out_shardings: Any = None  # without this XLA may replicate grads/caches


def _batch_spec(mesh: Mesh, shape, seq_axis: int | None = None):
    """Batch over ("pod","data"); optionally the seq dim over "model"."""
    logical = [None] * len(shape)
    logical[0] = "batch"
    if seq_axis is not None:
        logical[seq_axis] = "seq"
    return NamedSharding(mesh, spec_for(mesh, shape, tuple(logical)))


def param_shapes(cfg: ModelConfig):
    return jax.eval_shape(lambda k: init(k, cfg), jax.random.PRNGKey(0))


def _extras(cfg: ModelConfig, B: int, mesh: Mesh):
    """Stub-frontend embeddings (assignment carve-out)."""
    cdt = dtype_of(cfg.compute_dtype)
    ex, ex_spec = {}, {}
    if cfg.vision_prefix_len:
        shp = (B, cfg.vision_prefix_len, cfg.d_model)
        ex["vision_embeds"] = SDS(shp, cdt)
        ex_spec["vision_embeds"] = _batch_spec(mesh, shp)
    if cfg.is_encoder_decoder:
        shp = (B, cfg.encoder_seq_len, cfg.d_model)
        ex["enc_embeds"] = SDS(shp, cdt)
        ex_spec["enc_embeds"] = _batch_spec(mesh, shp)
    return ex, ex_spec


def train_inputs(cfg: ModelConfig, shape: InputShape, rl: RLConfig,
                 mesh: Mesh) -> StepInputs:
    B, S = shape.global_batch, shape.seq_len
    S_tok = S - cfg.vision_prefix_len
    ex, ex_spec = _extras(cfg, B, mesh)
    mb = MicroBatch(
        tokens=SDS((B, S_tok), jnp.int32), labels=SDS((B, S_tok), jnp.int32),
        positions=SDS((B, S), jnp.int32), segments=SDS((B, S), jnp.int32),
        loss_mask=SDS((B, S_tok), jnp.float32),
        advantages=SDS((B, S_tok), jnp.float32),
        n_samples=SDS((), jnp.float32), extras=ex)
    tok_spec = _batch_spec(mesh, (B, S_tok), seq_axis=1)
    full_spec = _batch_spec(mesh, (B, S), seq_axis=1)
    mb_spec = MicroBatch(
        tokens=tok_spec, labels=tok_spec, positions=full_spec,
        segments=full_spec, loss_mask=tok_spec, advantages=tok_spec,
        n_samples=NamedSharding(mesh, P()), extras=ex_spec)
    pshape = param_shapes(cfg)
    pspec = param_specs(pshape, mesh)
    opt = jax.eval_shape(adam_init, pshape)
    opt_spec = param_specs(opt, mesh)
    return StepInputs(
        kind="train",
        args=(pshape, pshape, pshape, opt, mb),
        shardings=(pspec, pspec, pspec, opt_spec, mb_spec),
        donate=(0, 3),   # policy params + opt state are consumed
        out_shardings=(pspec, opt_spec, None))


def prefill_inputs(cfg: ModelConfig, shape: InputShape,
                   mesh: Mesh) -> StepInputs:
    B, S = shape.global_batch, shape.seq_len
    S_tok = S - cfg.vision_prefix_len
    ex, ex_spec = _extras(cfg, B, mesh)
    args = (param_shapes(cfg),
            SDS((B, S_tok), jnp.int32),     # tokens
            SDS((B, S), jnp.int32),         # positions (full row)
            SDS((B, S), jnp.int32),         # segments
            ex)
    pspec = param_specs(args[0], mesh)
    shardings = (pspec,
                 _batch_spec(mesh, (B, S_tok), seq_axis=1),
                 _batch_spec(mesh, (B, S), seq_axis=1),
                 _batch_spec(mesh, (B, S), seq_axis=1),
                 ex_spec)
    caches = jax.eval_shape(lambda: init_caches(args[0], cfg, B, S))
    return StepInputs(kind="prefill", args=args, shardings=shardings,
                      out_shardings=(cache_specs(caches, mesh),
                                     _batch_spec(mesh, (B, cfg.vocab_size))))


def decode_inputs(cfg: ModelConfig, shape: InputShape,
                  mesh: Mesh) -> StepInputs:
    """ONE new token against a cache holding ``seq_len`` tokens. ``cfg``
    should already be the long-context variant for long_500k."""
    B, S = shape.global_batch, shape.seq_len
    pshape = param_shapes(cfg)
    cache_len = min(S, cfg.sliding_window) if cfg.sliding_window else S
    caches = jax.eval_shape(
        lambda: init_caches(pshape, cfg, B, cache_len))
    ex, ex_spec = {}, {}
    if cfg.is_encoder_decoder:
        cdt = dtype_of(cfg.compute_dtype)
        shp = (B, cfg.encoder_seq_len, cfg.d_model)
        ex["enc_out"] = SDS(shp, cdt)       # precomputed encoder states
        ex_spec["enc_out"] = _batch_spec(mesh, shp)
    args = (pshape, caches,
            SDS((B, 1), jnp.int32),         # token
            SDS((B, 1), jnp.int32),         # positions
            SDS((), jnp.int32),             # offset
            ex)
    cspec = cache_specs(caches, mesh)
    shardings = (param_specs(pshape, mesh),
                 cspec,
                 _batch_spec(mesh, (B, 1)),
                 _batch_spec(mesh, (B, 1)),
                 NamedSharding(mesh, P()),
                 ex_spec)
    return StepInputs(kind="decode", args=args, shardings=shardings,
                      donate=(1,),
                      out_shardings=(_batch_spec(mesh, (B, cfg.vocab_size)),
                                     cspec))
