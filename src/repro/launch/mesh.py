"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run sets
XLA_FLAGS before any jax import and only then builds the mesh.

Single pod:  (16, 16)      axes ("data", "model")   = 256 chips (TPU v5e pod)
Multi pod:   (2, 16, 16)   axes ("pod", "data", "model") = 512 chips

Across pods we run pure data parallelism: parameters are replicated per pod
("data"/"model" logical axes never map to "pod"), activations' batch dim is
sharded over ("pod", "data"), and the gradient all-reduce is the only
collective that crosses the pod axis (DCN-friendly).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh() -> jax.sharding.Mesh:
    """Degenerate 1x1 mesh over whatever devices exist — used by CPU-scale
    examples so the same pjit code path runs everywhere."""
    n = len(jax.devices())
    return jax.make_mesh(
        (n, 1), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
