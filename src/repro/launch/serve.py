"""Batched serving driver: the inference half of the decoupled deployment,
runnable standalone.

Engines (DESIGN.md §Continuous-batching):
  * fixed  — the jitted group-at-a-time Sampler (every row decodes max_new
             steps; finished rows ride along as PAD);
  * paged  — token-level continuous batching over the paged KV pool: slots
             free at EOS and admit the next request the same step.

Usage:
    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b \
        --num-requests 8 --max-new 24 [--engine paged --slots 4]
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config, reduced_config
from repro.data.tasks import ArithmeticTask
from repro.data.tokenizer import Tokenizer
from repro.models import init
from repro.rl.rollout import Sampler


def serve_batch(cfg, prompts, *, max_prompt_len: int, max_new: int,
                temperature: float = 0.7, seed: int = 0):
    """Serve a batch of requests; returns (responses, stats)."""
    params = init(jax.random.PRNGKey(seed), cfg)
    # serving has no trainer consuming behavior logprobs — skip capture
    sampler = Sampler(cfg, max_prompt_len, max_new, temperature=temperature,
                      capture_logprobs=False)
    t0 = time.time()
    out = sampler.generate(params, prompts, jax.random.PRNGKey(seed + 1))
    jax.block_until_ready(out.response_ids)
    wall = time.time() - t0
    toks = int(np.asarray(out.response_len).sum())
    return out, {"wall_s": wall, "generated_tokens": toks,
                 "tok_per_s": toks / wall}


def serve_paged(cfg, prompts, *, max_prompt_len: int, max_new: int,
                num_slots: int = 4, page_size: int = 16,
                temperature: float = 0.7, seed: int = 0,
                spec_k: int = 0, spec_draft: str = "prompt_lookup"):
    """Serve independent requests through the token-level paged engine
    (each request is its own group of size 1); returns (completions in
    completion order, stats). ``spec_k`` > 0 turns on speculative decode
    (DESIGN.md §Spec-decode): k drafted tokens verified per target
    forward, distribution-exact, acceptance rate in the stats."""
    from repro.core.paged import FIRST_PAGE, PagedGroupEngine
    if num_slots < 1 or page_size < 1:
        raise ValueError(f"serve_paged needs num_slots >= 1 and "
                         f"page_size >= 1, got {num_slots}/{page_size}")
    params = init(jax.random.PRNGKey(seed), cfg)
    # enough pages for every slot to hold a full prompt + response
    pages = FIRST_PAGE + num_slots * (-(-max_prompt_len // page_size)
                                      + -(-max_new // page_size))
    eng = PagedGroupEngine(cfg, num_slots=num_slots, page_size=page_size,
                           num_pages=pages, max_prompt_len=max_prompt_len,
                           max_new_tokens=max_new, group_size=1,
                           temperature=temperature,
                           capture_logprobs=False,   # serving: no consumer
                           spec_k=spec_k, spec_draft=spec_draft, seed=seed)
    t0 = time.time()
    done = eng.serve(params, prompts, jax.random.PRNGKey(seed + 1))
    wall = time.time() - t0
    toks = sum(len(c.response_ids) for c in done)
    stats = {"wall_s": wall, "generated_tokens": toks,
             "tok_per_s": toks / wall, "decode_steps": eng.decode_steps}
    if spec_k:
        # tokens committed per PER-ROW verify forward (1.0 = no spec win;
        # up to k+1 on a clean sweep) — engine steps batch many rows, so
        # decode_steps alone would conflate batching with speculation
        stats.update(spec_k=spec_k, acceptance_rate=eng.acceptance_rate,
                     tokens_per_forward=(toks / eng.spec_steps
                                         if eng.spec_steps else 0.0))
    return done, stats


def serve_shared(cfg, system_prompt, suffixes, *, max_prompt_len: int,
                 max_new: int, page_size: int = 16,
                 temperature: float = 0.7, seed: int = 0,
                 spec_k: int = 0, spec_draft: str = "prompt_lookup"):
    """Serve N requests that share one system prompt through REFCOUNTED
    shared pages: the prompt prefills once, its pages enter every row's
    table with refcount N, then each row teacher-forces its own request
    suffix and decodes freely — the serving analogue of a GRPO group
    (DESIGN.md §Continuous-batching, §Spec-decode).

    Returns (completions with the forced suffix stripped, stats incl. the
    pages the sharing saved vs N private prompt copies)."""
    from repro.core.cbatch import Completed
    from repro.core.paged import PagedGroupEngine
    N = len(suffixes)
    params = init(jax.random.PRNGKey(seed), cfg)
    eng = PagedGroupEngine(cfg, num_slots=N, page_size=page_size,
                           num_pages=0,      # auto-size
                           max_prompt_len=max_prompt_len,
                           max_new_tokens=max_new, group_size=N,
                           temperature=temperature, capture_logprobs=False,
                           spec_k=spec_k, spec_draft=spec_draft, seed=seed)
    eng.set_params(params)
    t0 = time.time()
    handle = eng.submit(np.asarray(system_prompt, np.int32),
                        jax.random.PRNGKey(seed + 1), forced=suffixes)
    while eng.step():
        pass
    out = handle.result(timeout=0)
    wall = time.time() - t0
    ids = np.asarray(out.response_ids)
    lens = np.asarray(out.response_len)
    done = []
    for i, suf in enumerate(suffixes):
        done.append(Completed(request_id=i,
                              response_ids=ids[i, len(suf): lens[i]],
                              finish_step=handle._group.finish_step))
    # forced suffixes are request INPUTS (stripped from the completions):
    # count only freely generated tokens, comparable to serve_paged
    forced = sum(len(s) for s in suffixes)
    toks = int(lens.sum()) - forced
    n_prompt_pages = -(-len(system_prompt) // page_size)
    stats = {"wall_s": wall, "generated_tokens": toks,
             "forced_tokens": forced,
             "tok_per_s": toks / wall, "decode_steps": eng.decode_steps,
             "prompt_pages_stored": n_prompt_pages,
             "prompt_pages_saved": (N - 1) * n_prompt_pages,
             "peak_pages": eng.peak_pages_used}
    if spec_k:
        stats.update(spec_k=spec_k, acceptance_rate=eng.acceptance_rate)
    return done, stats


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b", choices=ARCH_IDS)
    ap.add_argument("--engine", default="fixed", choices=["fixed", "paged"])
    ap.add_argument("--num-requests", type=int, default=8)
    ap.add_argument("--max-prompt-len", type=int, default=48)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--slots", type=int, default=4,
                    help="decode slots (paged engine)")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--spec", action="store_true",
                    help="speculative decode (paged engine; DESIGN.md "
                         "§Spec-decode) — stats report acceptance rate")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="drafted tokens per verify step")
    ap.add_argument("--spec-draft", default="prompt_lookup",
                    choices=["prompt_lookup", "model"])
    ap.add_argument("--shared-system", type=int, default=0, metavar="N",
                    help="serve N requests sharing one system prompt "
                         "through refcounted shared pages (each request "
                         "teacher-forces its own suffix, then decodes)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = reduced_config(get_config(args.arch))
    tok = Tokenizer(cfg.vocab_size)
    task = ArithmeticTask(seed=args.seed)
    spec_k = args.spec_k if args.spec else 0
    if spec_k and args.engine != "paged" and not args.shared_system:
        raise SystemExit("--spec rides the paged engine here; add "
                         "--engine paged (or --shared-system N)")

    if args.shared_system:
        # shared-system-prompt scenario: one refcounted prompt page set
        # serves every request; suffixes are the per-request questions
        system = np.asarray(
            tok.encode("You are a terse arithmetic solver. ")[
                : args.max_prompt_len], np.int32)
        problems = task.batch(args.shared_system)
        suffixes = [np.asarray(tok.encode(p.prompt)[: args.max_new // 2],
                               np.int32) for p in problems]
        done, stats = serve_shared(
            cfg, system, suffixes, max_prompt_len=args.max_prompt_len,
            max_new=args.max_new, page_size=args.page_size, seed=args.seed,
            spec_k=spec_k, spec_draft=args.spec_draft)
        extra = (f", accept={stats['acceptance_rate']:.2f}"
                 if spec_k else "")
        print(f"{args.arch} (shared-system x{args.shared_system}): "
              f"{stats['generated_tokens']} tokens in "
              f"{stats['wall_s']:.2f}s ({stats['tok_per_s']:.1f} tok/s, "
              f"{stats['decode_steps']} decode steps, "
              f"{stats['prompt_pages_saved']} prompt pages saved by "
              f"sharing{extra})")
        for c in done[:4]:
            print(f"  req {c.request_id}: "
                  f"{tok.decode(c.response_ids.tolist())!r}")
        return

    problems = task.batch(args.num_requests)
    prompts = [np.asarray(tok.encode(p.prompt)[: args.max_prompt_len],
                          np.int32) for p in problems]

    if args.engine == "paged":
        done, stats = serve_paged(
            cfg, prompts, max_prompt_len=args.max_prompt_len,
            max_new=args.max_new, num_slots=args.slots,
            page_size=args.page_size, seed=args.seed,
            spec_k=spec_k, spec_draft=args.spec_draft)
        extra = (f", accept={stats['acceptance_rate']:.2f}, "
                 f"{stats['tokens_per_forward']:.2f} tok/forward"
                 if spec_k else "")
        print(f"{args.arch} (paged x{args.slots}"
              f"{f' spec k={spec_k}' if spec_k else ''}): {len(done)} "
              f"requests in completion order, "
              f"{stats['generated_tokens']} tokens in "
              f"{stats['wall_s']:.2f}s ({stats['tok_per_s']:.1f} tok/s, "
              f"{stats['decode_steps']} decode steps{extra})")
        for c in done[:4]:
            print(f"  req {c.request_id} finished at step {c.finish_step}: "
                  f"{tok.decode(c.response_ids.tolist())!r}")
        return

    out, stats = serve_batch(cfg, prompts, max_prompt_len=args.max_prompt_len,
                             max_new=args.max_new, seed=args.seed)
    print(f"{args.arch}: served {args.num_requests} requests, "
          f"{stats['generated_tokens']} tokens in {stats['wall_s']:.2f}s "
          f"({stats['tok_per_s']:.1f} tok/s)")
    resp = np.asarray(out.response_ids)
    lens = np.asarray(out.response_len)
    for i in range(min(4, len(problems))):
        text = tok.decode(resp[i, : lens[i]])
        print(f"  [{problems[i].prompt!r}] -> {text!r}")


if __name__ == "__main__":
    main()
