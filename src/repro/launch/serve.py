"""Batched serving driver: the inference half of the decoupled deployment,
runnable standalone (continuous-batching-style slot scheduler over the jitted
prefill + decode steps).

Usage:
    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b \
        --num-requests 8 --max-new 24
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config, reduced_config
from repro.data.tasks import ArithmeticTask
from repro.data.tokenizer import Tokenizer
from repro.models import init
from repro.rl.rollout import Sampler


def serve_batch(cfg, prompts, *, max_prompt_len: int, max_new: int,
                temperature: float = 0.7, seed: int = 0):
    """Serve a batch of requests; returns (responses, stats)."""
    params = init(jax.random.PRNGKey(seed), cfg)
    sampler = Sampler(cfg, max_prompt_len, max_new, temperature=temperature)
    t0 = time.time()
    out = sampler.generate(params, prompts, jax.random.PRNGKey(seed + 1))
    jax.block_until_ready(out.response_ids)
    wall = time.time() - t0
    toks = int(np.asarray(out.response_len).sum())
    return out, {"wall_s": wall, "generated_tokens": toks,
                 "tok_per_s": toks / wall}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b", choices=ARCH_IDS)
    ap.add_argument("--num-requests", type=int, default=8)
    ap.add_argument("--max-prompt-len", type=int, default=48)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = reduced_config(get_config(args.arch))
    tok = Tokenizer(cfg.vocab_size)
    task = ArithmeticTask(seed=args.seed)
    problems = task.batch(args.num_requests)
    prompts = [np.asarray(tok.encode(p.prompt)[: args.max_prompt_len],
                          np.int32) for p in problems]

    out, stats = serve_batch(cfg, prompts, max_prompt_len=args.max_prompt_len,
                             max_new=args.max_new, seed=args.seed)
    print(f"{args.arch}: served {args.num_requests} requests, "
          f"{stats['generated_tokens']} tokens in {stats['wall_s']:.2f}s "
          f"({stats['tok_per_s']:.1f} tok/s)")
    resp = np.asarray(out.response_ids)
    lens = np.asarray(out.response_len)
    for i in range(min(4, len(problems))):
        text = tok.decode(resp[i, : lens[i]])
        print(f"  [{problems[i].prompt!r}] -> {text!r}")


if __name__ == "__main__":
    main()
