"""Batched serving driver: the inference half of the decoupled deployment,
runnable standalone.

Engines (DESIGN.md §Continuous-batching):
  * fixed  — the jitted group-at-a-time Sampler (every row decodes max_new
             steps; finished rows ride along as PAD);
  * paged  — token-level continuous batching over the paged KV pool: slots
             free at EOS and admit the next request the same step.

Usage:
    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b \
        --num-requests 8 --max-new 24 [--engine paged --slots 4]
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config, reduced_config
from repro.data.tasks import ArithmeticTask
from repro.data.tokenizer import Tokenizer
from repro.models import init
from repro.rl.rollout import Sampler


def serve_batch(cfg, prompts, *, max_prompt_len: int, max_new: int,
                temperature: float = 0.7, seed: int = 0):
    """Serve a batch of requests; returns (responses, stats)."""
    params = init(jax.random.PRNGKey(seed), cfg)
    # serving has no trainer consuming behavior logprobs — skip capture
    sampler = Sampler(cfg, max_prompt_len, max_new, temperature=temperature,
                      capture_logprobs=False)
    t0 = time.time()
    out = sampler.generate(params, prompts, jax.random.PRNGKey(seed + 1))
    jax.block_until_ready(out.response_ids)
    wall = time.time() - t0
    toks = int(np.asarray(out.response_len).sum())
    return out, {"wall_s": wall, "generated_tokens": toks,
                 "tok_per_s": toks / wall}


def serve_paged(cfg, prompts, *, max_prompt_len: int, max_new: int,
                num_slots: int = 4, page_size: int = 16,
                temperature: float = 0.7, seed: int = 0):
    """Serve independent requests through the token-level paged engine
    (each request is its own group of size 1); returns (completions in
    completion order, stats)."""
    from repro.core.paged import FIRST_PAGE, PagedGroupEngine
    if num_slots < 1 or page_size < 1:
        raise ValueError(f"serve_paged needs num_slots >= 1 and "
                         f"page_size >= 1, got {num_slots}/{page_size}")
    params = init(jax.random.PRNGKey(seed), cfg)
    # enough pages for every slot to hold a full prompt + response
    pages = FIRST_PAGE + num_slots * (-(-max_prompt_len // page_size)
                                      + -(-max_new // page_size))
    eng = PagedGroupEngine(cfg, num_slots=num_slots, page_size=page_size,
                           num_pages=pages, max_prompt_len=max_prompt_len,
                           max_new_tokens=max_new, group_size=1,
                           temperature=temperature,
                           capture_logprobs=False)   # serving: no consumer
    t0 = time.time()
    done = eng.serve(params, prompts, jax.random.PRNGKey(seed + 1))
    wall = time.time() - t0
    toks = sum(len(c.response_ids) for c in done)
    return done, {"wall_s": wall, "generated_tokens": toks,
                  "tok_per_s": toks / wall,
                  "decode_steps": eng.decode_steps}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b", choices=ARCH_IDS)
    ap.add_argument("--engine", default="fixed", choices=["fixed", "paged"])
    ap.add_argument("--num-requests", type=int, default=8)
    ap.add_argument("--max-prompt-len", type=int, default=48)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--slots", type=int, default=4,
                    help="decode slots (paged engine)")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = reduced_config(get_config(args.arch))
    tok = Tokenizer(cfg.vocab_size)
    task = ArithmeticTask(seed=args.seed)
    problems = task.batch(args.num_requests)
    prompts = [np.asarray(tok.encode(p.prompt)[: args.max_prompt_len],
                          np.int32) for p in problems]

    if args.engine == "paged":
        done, stats = serve_paged(
            cfg, prompts, max_prompt_len=args.max_prompt_len,
            max_new=args.max_new, num_slots=args.slots,
            page_size=args.page_size, seed=args.seed)
        print(f"{args.arch} (paged x{args.slots}): {len(done)} requests in "
              f"completion order, {stats['generated_tokens']} tokens in "
              f"{stats['wall_s']:.2f}s ({stats['tok_per_s']:.1f} tok/s, "
              f"{stats['decode_steps']} decode steps)")
        for c in done[:4]:
            print(f"  req {c.request_id} finished at step {c.finish_step}: "
                  f"{tok.decode(c.response_ids.tolist())!r}")
        return

    out, stats = serve_batch(cfg, prompts, max_prompt_len=args.max_prompt_len,
                             max_new=args.max_new, seed=args.seed)
    print(f"{args.arch}: served {args.num_requests} requests, "
          f"{stats['generated_tokens']} tokens in {stats['wall_s']:.2f}s "
          f"({stats['tok_per_s']:.1f} tok/s)")
    resp = np.asarray(out.response_ids)
    lens = np.asarray(out.response_len)
    for i in range(min(4, len(problems))):
        text = tok.decode(resp[i, : lens[i]])
        print(f"  [{problems[i].prompt!r}] -> {text!r}")


if __name__ == "__main__":
    main()
