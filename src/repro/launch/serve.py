"""Request-driven serving tier: the inference half of the decoupled
deployment, runnable standalone.

Engines (DESIGN.md §Continuous-batching):
  * fixed  — the jitted group-at-a-time Sampler (every row decodes max_new
             steps; finished rows ride along as PAD);
  * paged  — token-level continuous batching over the paged KV pool: slots
             free at EOS and admit the next request the same step;
             ``--prefix-cache`` layers the radix prefix cache on top
             (DESIGN.md §Radix-prefix-cache), ``--spec`` the draft/verify
             plane (§Spec-decode).

The ``RequestDriver`` closes the gap between "drive a fixed batch" and the
workload the serving tier exists for: requests ARRIVE over time (Poisson or
an explicit trace), stream their tokens as the engine commits them, and are
measured by the latency metrics serving systems quote — time-to-first-token
(TTFT) and time-per-output-token (TPOT), p50/p99
(``benchmarks/table9_serving.py``).

Usage:
    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b \
        --num-requests 8 --max-new 24 [--engine paged --slots 4] \
        [--prefix-cache] [--rate 4.0]
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from collections import deque
from typing import Dict, List, Optional

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config, reduced_config
from repro.data.tasks import ArithmeticTask
from repro.data.tokenizer import Tokenizer
from repro.models import init
from repro.obs import trace as otrace
from repro.rl.rollout import Sampler


# ---------------------------------------------------------------------
# request stream + latency metrics
# ---------------------------------------------------------------------


@dataclasses.dataclass
class ServedRequest:
    """One request through the driver: its schedule, its streamed tokens,
    and the timestamps the latency metrics are computed from (all times
    are seconds on the driver's clock, origin at ``run`` start)."""
    rid: int
    prompt: np.ndarray
    arrival: float                     # scheduled arrival offset
    max_new: Optional[int] = None
    submit_t: Optional[float] = None   # when the engine accepted it
    tokens: List[int] = dataclasses.field(default_factory=list)
    token_t: List[float] = dataclasses.field(default_factory=list)
    done_t: Optional[float] = None

    @property
    def ttft(self) -> Optional[float]:
        """Time to first token, measured from ARRIVAL (queueing included —
        that is the latency the client observes)."""
        return self.token_t[0] - self.arrival if self.token_t else None

    @property
    def tpot(self) -> Optional[float]:
        """Mean inter-token time after the first token."""
        if len(self.token_t) < 2:
            return None
        return (self.token_t[-1] - self.token_t[0]) / (len(self.token_t) - 1)


def poisson_arrivals(n: int, rate: float, seed: int = 0) -> np.ndarray:
    """Arrival offsets (seconds) for an open-loop Poisson process of
    ``rate`` requests/second; ``rate <= 0`` means all arrive at t=0."""
    if rate <= 0:
        return np.zeros(n)
    rng = np.random.RandomState(seed)
    return np.cumsum(rng.exponential(1.0 / rate, size=n))


def compute_latency_metrics(reqs: List[ServedRequest]) -> Dict[str, float]:
    """p50/p99 TTFT and TPOT + throughput over a finished request set.
    Pure numpy over the recorded timestamps — tests/test_serving.py checks
    it against an independent recomputation on a scripted trace."""
    ttft = np.asarray([r.ttft for r in reqs if r.ttft is not None])
    tpot = np.asarray([r.tpot for r in reqs if r.tpot is not None])

    def pct(xs, q):
        return float(np.percentile(xs, q)) if len(xs) else 0.0

    done = [r.done_t for r in reqs if r.done_t is not None]
    toks = sum(len(r.tokens) for r in reqs)
    makespan = max(done) if done else 0.0
    return {
        "n_requests": len(reqs),
        "generated_tokens": toks,
        "makespan_s": makespan,
        "tok_per_s": toks / makespan if makespan > 0 else 0.0,
        "ttft_mean_s": float(ttft.mean()) if len(ttft) else 0.0,
        "ttft_p50_s": pct(ttft, 50), "ttft_p99_s": pct(ttft, 99),
        "tpot_mean_s": float(tpot.mean()) if len(tpot) else 0.0,
        "tpot_p50_s": pct(tpot, 50), "tpot_p99_s": pct(tpot, 99),
    }


class _WallClock:
    def time(self) -> float:
        return time.time()

    def sleep(self, seconds: float) -> None:
        time.sleep(seconds)


class RequestDriver:
    """Open-loop request-queue driver over a paged engine built with
    ``group_size=1``: submits each request when its arrival time comes due,
    steps the engine (continuous batching admits into free slots), and
    records per-token delivery times through the engine's ``on_token``
    streaming hook — tokens arrive in commit order, so TTFT/TPOT read
    straight off the timestamp lists.

    ``clock`` is injectable (``time``/``sleep``) so tests drive a virtual
    clock over a scripted trace; the default is the wall clock. Per-request
    sampling keys are ``fold_in(key, rid)`` — scheduling-order-invariant,
    like every engine here (DESIGN.md §Exactness)."""

    def __init__(self, engine, *, clock=None):
        assert engine.G == 1, "RequestDriver serves 1-row groups"
        self.eng = engine
        self.clock = clock if clock is not None else _WallClock()

    def run(self, requests: List[ServedRequest], key) -> List[ServedRequest]:
        reqs = sorted(requests, key=lambda r: (r.arrival, r.rid))
        pending = deque(reqs)
        handles: Dict[int, object] = {}
        t0 = self.clock.time()

        def now() -> float:
            return self.clock.time() - t0

        def sink(r: ServedRequest):
            def deliver(row_idx: int, token_id: int) -> None:
                r.tokens.append(int(token_id))
                r.token_t.append(now())
                # lifecycle instant per committed token (serving only —
                # fires from the engine's drain, already off the hot tier)
                otrace.instant("request.token", rid=r.rid)
            return deliver

        while pending or not self.eng.idle:
            while pending and pending[0].arrival <= now():
                r = pending.popleft()
                r.submit_t = now()
                # async span: opens at submit, closes (possibly from the
                # completion sweep below) when the request finishes; the
                # driver-clock offsets let the analyzer walk TTFT back to
                # the open-loop arrival, queueing included
                otrace.begin("request", uid=r.rid, rid=r.rid,
                             arrival=r.arrival, submit=r.submit_t)
                handles[r.rid] = self.eng.submit(
                    r.prompt, jax.random.fold_in(key, r.rid),
                    max_new=r.max_new, on_token=sink(r))
            if not self.eng.step() and pending:
                # engine drained before the next arrival: sleep up to it
                self.clock.sleep(max(0.0, pending[0].arrival - now()))
        t_end = now()
        for r in reqs:
            h = handles[r.rid]
            h.result(timeout=0)       # completion check (raises if not)
            r.done_t = r.token_t[-1] if r.token_t else t_end
            otrace.end("request", uid=r.rid, rid=r.rid, done=r.done_t,
                       tokens=len(r.tokens))
            # the committed tokens are already host-side (the same arrays
            # the RolloutBatch was assembled from) — no device readback
            # needed for the streamed==final identity check
            final = list(map(int, h.host_rows()[0]))
            assert final == r.tokens, \
                f"streaming delivery diverged from the final response " \
                f"for request {r.rid}"
        return reqs


# ---------------------------------------------------------------------
# batch entry points
# ---------------------------------------------------------------------


def _settle_batch(out, t0: float):
    """Boundary settle for one served batch: barrier on the responses and
    read the token count — the syncs live HERE, one call frame below the
    serving entry point, so the hot tier itself stays sync-free
    (DESIGN.md §Device-resident-decode)."""
    # repro: allow(host-sync): wall-clock measurement barrier — tok/s is
    # meaningless unless the batch actually finished
    jax.block_until_ready(out.response_ids)
    wall = time.time() - t0
    # repro: allow(host-sync): once per served batch, for the stats dict
    toks = int(np.asarray(out.response_len).sum())
    return wall, toks


def serve_batch(cfg, prompts, *, max_prompt_len: int, max_new: int,
                temperature: float = 0.7, seed: int = 0):
    """Serve a batch of requests; returns (responses, stats)."""
    params = init(jax.random.PRNGKey(seed), cfg)
    # serving has no trainer consuming behavior logprobs — skip capture
    sampler = Sampler(cfg, max_prompt_len, max_new, temperature=temperature,
                      capture_logprobs=False)
    t0 = time.time()
    out = sampler.generate(params, prompts, jax.random.PRNGKey(seed + 1))
    wall, toks = _settle_batch(out, t0)
    return out, {"wall_s": wall, "generated_tokens": toks,
                 "tok_per_s": toks / wall}


def build_paged_engine(cfg, *, max_prompt_len: int, max_new: int,
                       num_slots: int = 4, page_size: int = 16,
                       temperature: float = 0.7, seed: int = 0,
                       spec_k: int = 0, spec_draft: str = "prompt_lookup",
                       prefix_cache: bool = False, extra_pages: int = 0,
                       drain_interval: int = 1):
    """One serving-shaped paged engine (group_size=1, no capture): enough
    pages for every slot to hold a full prompt + response, plus headroom
    for the radix tree to keep cached prompt pages resident (idle cached
    pages are LRU-evicted on a deficit either way)."""
    from repro.core.paged import FIRST_PAGE, PagedGroupEngine
    if num_slots < 1 or page_size < 1:
        raise ValueError(f"serving needs num_slots >= 1 and "
                         f"page_size >= 1, got {num_slots}/{page_size}")
    n_pp = -(-max_prompt_len // page_size)
    n_rp = -(-max_new // page_size)
    pages = FIRST_PAGE + num_slots * (n_pp + n_rp) + extra_pages
    if prefix_cache:
        pages += n_pp            # headroom: one cached prompt stays resident
    return PagedGroupEngine(cfg, num_slots=num_slots, page_size=page_size,
                            num_pages=pages, max_prompt_len=max_prompt_len,
                            max_new_tokens=max_new, group_size=1,
                            temperature=temperature,
                            capture_logprobs=False,   # serving: no consumer
                            spec_k=spec_k, spec_draft=spec_draft,
                            prefix_cache=prefix_cache,
                            drain_interval=drain_interval, seed=seed)


def serve_paged(cfg, prompts, *, max_prompt_len: int, max_new: int,
                num_slots: int = 4, page_size: int = 16,
                temperature: float = 0.7, seed: int = 0,
                spec_k: int = 0, spec_draft: str = "prompt_lookup",
                prefix_cache: bool = False):
    """Serve independent requests through the token-level paged engine
    (each request is its own group of size 1); returns (completions in
    completion order, stats). ``spec_k`` > 0 turns on speculative decode
    (DESIGN.md §Spec-decode); ``prefix_cache`` the radix prefix cache
    (§Radix-prefix-cache) — stats then report hit rate."""
    params = init(jax.random.PRNGKey(seed), cfg)
    eng = build_paged_engine(
        cfg, max_prompt_len=max_prompt_len, max_new=max_new,
        num_slots=num_slots, page_size=page_size, temperature=temperature,
        seed=seed, spec_k=spec_k, spec_draft=spec_draft,
        prefix_cache=prefix_cache)
    t0 = time.time()
    done = eng.serve(params, prompts, jax.random.PRNGKey(seed + 1))
    wall = time.time() - t0
    toks = sum(len(c.response_ids) for c in done)
    stats = {"wall_s": wall, "generated_tokens": toks,
             "tok_per_s": toks / wall, "decode_steps": eng.decode_steps}
    if spec_k:
        # tokens committed per PER-ROW verify forward (1.0 = no spec win;
        # up to k+1 on a clean sweep) — engine steps batch many rows, so
        # decode_steps alone would conflate batching with speculation
        stats.update(spec_k=spec_k, acceptance_rate=eng.acceptance_rate,
                     tokens_per_forward=(toks / eng.spec_steps
                                         if eng.spec_steps else 0.0))
    if prefix_cache:
        stats.update(prefix_hit_rate=eng.prefix_hit_rate,
                     prefix_hit_pages=eng.prefix_hit_pages,
                     prefix_evicted_pages=eng.prefix_evicted_pages)
    return done, stats


def serve_shared(cfg, system_prompt, suffixes, *, max_prompt_len: int,
                 max_new: int, page_size: int = 16,
                 temperature: float = 0.7, seed: int = 0,
                 spec_k: int = 0, spec_draft: str = "prompt_lookup"):
    """Serve N requests that share one system prompt through the RADIX
    PREFIX CACHE (DESIGN.md §Radix-prefix-cache): each request submits its
    FULL prompt (system + its own suffix) as an independent 1-row group;
    the first admission prefills the system pages cold and inserts them
    into the tree, every later request retains those cached pages with a
    refcount bump and prefills only its own suffix into private pages — a
    real suffix prefill, replacing the old teacher-forced-token workaround
    (the suffix is prompt, not forced "response"; tests/test_radix.py
    keeps the regression proof that both emit identical tokens greedily).

    Returns (completions, stats incl. the prompt pages the cache saved vs
    N private prompt copies)."""
    from repro.core.cbatch import Completed
    N = len(suffixes)
    params = init(jax.random.PRNGKey(seed), cfg)
    full_mpl = max(len(system_prompt) + len(s) for s in suffixes)
    eng = build_paged_engine(
        cfg, max_prompt_len=max(max_prompt_len, full_mpl), max_new=max_new,
        num_slots=N, page_size=page_size, temperature=temperature,
        seed=seed, spec_k=spec_k, spec_draft=spec_draft, prefix_cache=True)
    eng.set_params(params)
    system = np.asarray(system_prompt, np.int32)
    t0 = time.time()
    keys = jax.random.split(jax.random.PRNGKey(seed + 1), N)
    handles = [eng.submit(np.concatenate([system,
                                          np.asarray(suf, np.int32)]), k)
               for suf, k in zip(suffixes, keys)]
    while eng.step():
        pass
    wall = time.time() - t0
    done = []
    for i, h in enumerate(handles):
        h.result(timeout=0)           # completion check (raises if not)
        # committed tokens are already host-side in host_rows — no device
        # readback needed to assemble completions
        done.append(Completed(request_id=i,
                              response_ids=h.host_rows()[0],
                              finish_step=h.finish_step))
    toks = sum(len(c.response_ids) for c in done)
    stats = {"wall_s": wall, "generated_tokens": toks,
             "tok_per_s": toks / wall, "decode_steps": eng.decode_steps,
             "prefix_hit_rate": eng.prefix_hit_rate,
             # pages served from the tree = prompt pages NOT re-prefilled
             # (the analogue of the old forced path's pages-saved stat)
             "prompt_pages_saved": eng.prefix_hit_pages,
             "peak_pages": eng.peak_pages_used}
    if spec_k:
        stats.update(spec_k=spec_k, acceptance_rate=eng.acceptance_rate)
    return done, stats


def serve_requests(cfg, prompts, *, max_prompt_len: int, max_new: int,
                   num_slots: int = 4, page_size: int = 16,
                   temperature: float = 0.7, seed: int = 0,
                   spec_k: int = 0, spec_draft: str = "prompt_lookup",
                   prefix_cache: bool = False, rate: float = 0.0,
                   arrivals: Optional[np.ndarray] = None,
                   params=None, engine=None):
    """Serve ``prompts`` as a TIMED request stream through the
    ``RequestDriver`` (Poisson arrivals at ``rate`` req/s, or an explicit
    ``arrivals`` offset trace); returns (requests with per-token
    timestamps, latency metrics, engine stats). The workload
    ``benchmarks/table9_serving.py`` measures."""
    if params is None:
        params = init(jax.random.PRNGKey(seed), cfg)
    if engine is None:
        engine = build_paged_engine(
            cfg, max_prompt_len=max_prompt_len, max_new=max_new,
            num_slots=num_slots, page_size=page_size,
            temperature=temperature, seed=seed, spec_k=spec_k,
            spec_draft=spec_draft, prefix_cache=prefix_cache)
    engine.set_params(params)
    if arrivals is None:
        arrivals = poisson_arrivals(len(prompts), rate, seed=seed)
    reqs = [ServedRequest(rid=i, prompt=np.asarray(p, np.int32),
                          arrival=float(t))
            for i, (p, t) in enumerate(zip(prompts, arrivals))]
    driver = RequestDriver(engine)
    driver.run(reqs, jax.random.PRNGKey(seed + 1))
    metrics = compute_latency_metrics(reqs)
    stats = {"decode_steps": engine.decode_steps,
             "peak_pages": engine.peak_pages_used}
    if engine.radix is not None:
        stats.update(prefix_hit_rate=engine.prefix_hit_rate,
                     prefix_hit_pages=engine.prefix_hit_pages,
                     prefix_evicted_pages=engine.prefix_evicted_pages)
    if spec_k:
        stats.update(spec_k=spec_k, acceptance_rate=engine.acceptance_rate)
    return reqs, metrics, stats


def main(argv: Optional[list] = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b", choices=ARCH_IDS)
    ap.add_argument("--engine", default="fixed", choices=["fixed", "paged"])
    ap.add_argument("--num-requests", type=int, default=8)
    ap.add_argument("--max-prompt-len", type=int, default=48)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--slots", type=int, default=4,
                    help="decode slots (paged engine)")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--spec", action="store_true",
                    help="speculative decode (paged engine; DESIGN.md "
                         "§Spec-decode) — stats report acceptance rate")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="drafted tokens per verify step")
    ap.add_argument("--spec-draft", default="prompt_lookup",
                    choices=["prompt_lookup", "model"])
    ap.add_argument("--prefix-cache", action="store_true",
                    help="radix prefix cache over the paged pool "
                         "(DESIGN.md §Radix-prefix-cache) — requests "
                         "sharing a token prefix share its pages")
    ap.add_argument("--rate", type=float, default=0.0,
                    help="Poisson arrival rate (req/s) — run the request "
                         "driver and report TTFT/TPOT p50/p99 (paged "
                         "engine; 0 = all requests arrive at once, "
                         "batch mode)")
    ap.add_argument("--shared-system", type=int, default=0, metavar="N",
                    help="serve N requests sharing one system prompt "
                         "through the radix prefix cache (suffix-only "
                         "prefill into private pages)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", default="",
                    help="write a Chrome/Perfetto trace of the run to this "
                         "path (request lifecycle, drain blocks, spec "
                         "steps); inspect with `repro-trace report`")
    ap.add_argument("--trace-dir", default="",
                    help="streaming trace export: rotate JSONL segments "
                         "into this directory (bounded tracer memory); "
                         "analyze with `repro-trace report <dir>`")
    ap.add_argument("--serve-port", type=int, default=None,
                    help="boot the live ops front-end on this port "
                         "(0 = ephemeral) and serve real socket requests: "
                         "POST /v1/generate streams tokens via SSE, "
                         "GET /metrics is Prometheus-scrapeable, "
                         "/healthz + /status introspect the engine "
                         "(obs/server.py; ctrl-C to stop)")
    args = ap.parse_args(argv)
    if not (args.trace or args.trace_dir):
        _cli_run(args)
        return
    # tracing wraps the whole run so every early-return (and crash) path
    # still exports — flush-on-crash for serving runs
    if args.trace_dir:
        otrace.install(process_name="repro-serve", stream_dir=args.trace_dir)
    else:
        otrace.install(process_name="repro-serve")
    try:
        _cli_run(args)
    finally:
        if args.trace_dir:
            otrace.export()
            print(f"trace segments written to {args.trace_dir}")
        else:
            otrace.export(args.trace)
            print(f"trace written to {args.trace}")
        otrace.uninstall()


def _cli_run(args) -> None:
    cfg = reduced_config(get_config(args.arch))
    tok = Tokenizer(cfg.vocab_size)
    task = ArithmeticTask(seed=args.seed)
    spec_k = args.spec_k if args.spec else 0
    if spec_k and args.engine != "paged" and not args.shared_system:
        raise SystemExit("--spec rides the paged engine here; add "
                         "--engine paged (or --shared-system N)")
    if (args.prefix_cache or args.rate) and args.engine != "paged" \
            and not args.shared_system:
        raise SystemExit("--prefix-cache/--rate ride the paged engine; "
                         "add --engine paged (or --shared-system N)")

    if args.serve_port is not None:
        # the live ops front-end (DESIGN.md §Observability): real socket
        # requests stream through the same paged engine + fold_in(key,
        # rid) derivation as the in-process RequestDriver, so a served
        # request is bitwise-identical to the driver path
        from repro.obs.server import OpsServer
        params = init(jax.random.PRNGKey(args.seed), cfg)
        eng = build_paged_engine(
            cfg, max_prompt_len=args.max_prompt_len, max_new=args.max_new,
            num_slots=args.slots, page_size=args.page_size, seed=args.seed,
            spec_k=spec_k, spec_draft=args.spec_draft,
            prefix_cache=args.prefix_cache)
        eng.set_params(params)
        srv = OpsServer(engine=eng, key=jax.random.PRNGKey(args.seed + 1),
                        port=args.serve_port)
        srv.start()
        print(f"serving {args.arch} on {srv.url}\n"
              f"  POST {srv.url}/v1/generate "
              f'{{"prompt": [1,2,3], "max_new": {args.max_new}}} (SSE)\n'
              f"  GET  {srv.url}/metrics /healthz /status\n"
              f"ctrl-C to stop")
        try:
            while True:
                time.sleep(1.0)
        except KeyboardInterrupt:
            pass
        finally:
            srv.stop()
        return

    if args.shared_system:
        # shared-system-prompt scenario: the radix tree serves every
        # request's system pages from cache after the first admission
        system = np.asarray(
            tok.encode("You are a terse arithmetic solver. ")[
                : args.max_prompt_len], np.int32)
        problems = task.batch(args.shared_system)
        suffixes = [np.asarray(tok.encode(p.prompt)[: args.max_new // 2],
                               np.int32) for p in problems]
        done, stats = serve_shared(
            cfg, system, suffixes, max_prompt_len=args.max_prompt_len,
            max_new=args.max_new, page_size=args.page_size, seed=args.seed,
            spec_k=spec_k, spec_draft=args.spec_draft)
        extra = (f", accept={stats['acceptance_rate']:.2f}"
                 if spec_k else "")
        print(f"{args.arch} (shared-system x{args.shared_system}): "
              f"{stats['generated_tokens']} tokens in "
              f"{stats['wall_s']:.2f}s ({stats['tok_per_s']:.1f} tok/s, "
              f"{stats['decode_steps']} decode steps, "
              f"prefix hit rate {stats['prefix_hit_rate']:.2f}, "
              f"{stats['prompt_pages_saved']} prompt pages saved by "
              f"the cache{extra})")
        for c in done[:4]:
            print(f"  req {c.request_id}: "
                  f"{tok.decode(c.response_ids.tolist())!r}")
        return

    problems = task.batch(args.num_requests)
    prompts = [np.asarray(tok.encode(p.prompt)[: args.max_prompt_len],
                          np.int32) for p in problems]

    if args.engine == "paged" and args.rate > 0:
        reqs, metrics, stats = serve_requests(
            cfg, prompts, max_prompt_len=args.max_prompt_len,
            max_new=args.max_new, num_slots=args.slots,
            page_size=args.page_size, seed=args.seed, spec_k=spec_k,
            spec_draft=args.spec_draft, prefix_cache=args.prefix_cache,
            rate=args.rate)
        hit = (f", prefix hit rate {stats['prefix_hit_rate']:.2f}"
               if args.prefix_cache else "")
        print(f"{args.arch} (driver x{args.slots} @ {args.rate} req/s): "
              f"{metrics['generated_tokens']} tokens, "
              f"TTFT p50={metrics['ttft_p50_s'] * 1e3:.0f}ms "
              f"p99={metrics['ttft_p99_s'] * 1e3:.0f}ms, "
              f"TPOT p50={metrics['tpot_p50_s'] * 1e3:.1f}ms "
              f"p99={metrics['tpot_p99_s'] * 1e3:.1f}ms, "
              f"{metrics['tok_per_s']:.1f} tok/s{hit}")
        for r in reqs[:4]:
            print(f"  req {r.rid} arrived {r.arrival:.2f}s "
                  f"ttft {r.ttft:.2f}s: {tok.decode(r.tokens)!r}")
        return

    if args.engine == "paged":
        done, stats = serve_paged(
            cfg, prompts, max_prompt_len=args.max_prompt_len,
            max_new=args.max_new, num_slots=args.slots,
            page_size=args.page_size, seed=args.seed,
            spec_k=spec_k, spec_draft=args.spec_draft,
            prefix_cache=args.prefix_cache)
        extra = (f", accept={stats['acceptance_rate']:.2f}, "
                 f"{stats['tokens_per_forward']:.2f} tok/forward"
                 if spec_k else "")
        if args.prefix_cache:
            extra += f", prefix hit rate {stats['prefix_hit_rate']:.2f}"
        print(f"{args.arch} (paged x{args.slots}"
              f"{f' spec k={spec_k}' if spec_k else ''}): {len(done)} "
              f"requests in completion order, "
              f"{stats['generated_tokens']} tokens in "
              f"{stats['wall_s']:.2f}s ({stats['tok_per_s']:.1f} tok/s, "
              f"{stats['decode_steps']} decode steps{extra})")
        for c in done[:4]:
            print(f"  req {c.request_id} finished at step {c.finish_step}: "
                  f"{tok.decode(c.response_ids.tolist())!r}")
        return

    out, stats = serve_batch(cfg, prompts, max_prompt_len=args.max_prompt_len,
                             max_new=args.max_new, seed=args.seed)
    print(f"{args.arch}: served {args.num_requests} requests, "
          f"{stats['generated_tokens']} tokens in {stats['wall_s']:.2f}s "
          f"({stats['tok_per_s']:.1f} tok/s)")
    # repro: allow(host-sync): final result printing after the run
    resp = np.asarray(out.response_ids)
    # repro: allow(host-sync): final result printing after the run
    lens = np.asarray(out.response_len)
    for i in range(min(4, len(problems))):
        text = tok.decode(resp[i, : lens[i]])
        print(f"  [{problems[i].prompt!r}] -> {text!r}")


if __name__ == "__main__":
    main()
