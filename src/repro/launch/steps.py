"""The three lowered step functions of the dry-run contract.

  train_4k     -> train_step   (tri-model GRPO micro-step + Adam update)
  prefill_32k  -> prefill_step (forward over the full prompt, emit KV cache
                                + last-token logits)
  decode_32k / long_500k -> serve_step (ONE new token against a KV cache of
                                seq_len; sliding-window ring buffer for the
                                sub-quadratic dense variant, SSM state for
                                attention-free archs)
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RLConfig
from repro.models import forward_hidden, init_caches
from repro.models.layers import lm_head_weight
from repro.rl.grpo import MicroBatch, make_train_step


def default_microbatches(cfg: ModelConfig, global_batch: int) -> int:
    """Eq.-1 in-step micro-batching schedule: bigger resident state (3x
    params + fp32 Adam) -> less HBM left for activations -> more micros."""
    p = cfg.param_count()
    for threshold, m in ((100e9, 16), (50e9, 8), (25e9, 4), (15e9, 2)):
        if p > threshold:
            return min(m, global_batch)
    return 1


def make_train_step_fn(cfg: ModelConfig, rl: RLConfig,
                       num_microbatches: int = 1):
    """(policy, old, ref, opt, mb) -> (new_params, new_opt, metrics)."""
    return make_train_step(cfg, rl, num_microbatches=num_microbatches)


def make_prefill_step_fn(cfg: ModelConfig):
    """(params, tokens, positions, segments, extras) -> (caches, last_logits).

    The cache is created inside the step (its length = the padded prompt
    length, i.e. tokens+vision prefix), so prefill lowers as a single
    program: embed -> layers -> cache writes -> last-token logits.
    """

    def prefill_step(params, tokens, positions, segments, extras):
        B, S_tok = tokens.shape
        S = S_tok + cfg.vision_prefix_len
        caches = init_caches(params, cfg, B, S)
        h, caches, _, _ = forward_hidden(
            params, cfg, tokens, positions=positions, segments=segments,
            caches=caches, cache_offset=0, **extras)
        W = lm_head_weight(params["embed"], cfg)
        last = jnp.einsum("bd,dv->bv", h[:, -1].astype(jnp.float32),
                          W.astype(jnp.float32))
        return caches, last

    return prefill_step


def make_serve_step_fn(cfg: ModelConfig):
    """(params, caches, token, positions, offset, extras) -> (logits, caches).

    ONE new token per call. ``offset`` is the number of tokens already in the
    cache (traced scalar); sliding-window caches are ring buffers indexed by
    ``offset % window``.
    """

    def serve_step(params, caches, token, positions, offset, extras):
        B, _ = token.shape
        h, caches, _, _ = forward_hidden(
            params, cfg, token, positions=positions,
            segments=jnp.zeros((B, 1), jnp.int32),
            caches=caches, cache_offset=offset, **extras)
        W = lm_head_weight(params["embed"], cfg)
        logits = jnp.einsum("bd,dv->bv", h[:, 0].astype(jnp.float32),
                            W.astype(jnp.float32))
        return logits, caches

    return serve_step
