"""End-to-end GRPO training driver (the paper's workload at CPU scale).

Wires the full periodic-asynchrony pipeline (paper Figure 1):

    PromptLoader -> TemporaryDataGenerator -> InferencePool
                          |  RolloutQueue  |
    PeriodicAsyncScheduler (consumer: tri-model GRPO + grad accumulation)

Usage:
    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b \
        --mode async --iterations 4 [--spa] [--prompt-pad 256]

Any assigned architecture id is accepted; the model is reduced to its
CPU-smoke variant unless --full is given (full configs are for the dry-run).
"""
from __future__ import annotations

import argparse
import json
import time

import jax

from repro.configs import ARCH_IDS, get_config, reduced_config
from repro.configs.base import RLConfig
from repro.core import (InferenceInstance, InferencePool, PeriodicAsyncScheduler,
                        RolloutQueue, TemporaryDataGenerator, TriModelState)
from repro.data.loader import PromptLoader
from repro.data.tasks import ArithmeticTask
from repro.data.tokenizer import Tokenizer
from repro.models import init
from repro.obs import trace as otrace
from repro.rl.reward import RuleBasedReward
from repro.rl.rollout import Sampler
from repro.transfer.service import WeightTransferService


def build_pipeline(cfg, rl: RLConfig, *, seed: int = 0, prompt_pad: int = 0,
                   latency_fn=None, scripted_fn=None):
    """Returns (scheduler, components dict). With ``scripted_fn`` the
    inference instances run in simulated-latency mode (remote-service view);
    otherwise they run the real jitted sampler — group-at-a-time, or the
    token-level paged engine when ``rl.rollout_engine == "paged"``."""
    tok = Tokenizer(cfg.vocab_size)
    task = ArithmeticTask(seed=seed, prompt_pad=prompt_pad)
    loader = PromptLoader(task, tok, rl.batch_prompts, rl.max_prompt_len)
    params = init(jax.random.PRNGKey(seed), cfg)
    tri = TriModelState.create(params)
    sampler = None
    if scripted_fn is None:
        if rl.spec_decode and rl.rollout_engine == "group":
            # speculative group engine (DESIGN.md §Spec-decode): same
            # generate() surface, k+1 tokens per target forward; greedy
            # decode token-identical, sampled decode distribution-exact,
            # captured logprobs come from the verify pass
            from repro.configs.base import require_engine_support
            require_engine_support(cfg, "spec")
            from repro.spec import SpecSampler
            sampler = SpecSampler(
                cfg, rl.max_prompt_len, rl.max_response_len,
                spec_k=rl.spec_k, draft=rl.spec_draft, ngram=rl.spec_ngram,
                temperature=rl.temperature, top_p=rl.top_p,
                capture_logprobs=rl.capture_logprobs, seed=seed)
        else:
            sampler = Sampler(cfg, rl.max_prompt_len, rl.max_response_len,
                              temperature=rl.temperature, top_p=rl.top_p,
                              capture_logprobs=rl.capture_logprobs)

    def paged_engine():
        if rl.rollout_engine != "paged" or scripted_fn is not None:
            return None
        if rl.mode == "async_offpolicy":
            raise ValueError(
                "rollout_engine='paged' needs a quiescent engine at weight "
                "sync; the off-policy baseline syncs mid-flight — use the "
                "group engine (DESIGN.md §Continuous-batching)")
        # engine x family validation matrix (configs/base.py): GQA and MLA
        # families page, sliding-window configs reclaim; SSM/enc-dec/VLM
        # are rejected here with the architectural reason.
        from repro.configs.base import require_engine_support
        require_engine_support(cfg, "paged")
        from repro.core.paged import PagedGroupEngine
        return PagedGroupEngine(
            cfg, num_slots=rl.cbatch_slots, page_size=rl.kv_page_size,
            num_pages=rl.kv_pages, max_prompt_len=rl.max_prompt_len,
            max_new_tokens=rl.max_response_len, group_size=rl.group_size,
            temperature=rl.temperature, top_p=rl.top_p,
            capture_logprobs=rl.capture_logprobs,
            spec_k=rl.spec_k if rl.spec_decode else 0,
            spec_draft=rl.spec_draft, spec_ngram=rl.spec_ngram,
            prefix_cache=rl.prefix_cache,
            drain_interval=rl.decode_drain_interval, seed=seed)

    instances = [InferenceInstance(i, cfg, sampler, latency_fn=latency_fn,
                                   scripted_fn=scripted_fn,
                                   paged_engine=paged_engine())
                 for i in range(rl.num_inference_instances)]
    pool = InferencePool(instances)
    queue = RolloutQueue()
    gen = TemporaryDataGenerator(pool, queue, RuleBasedReward(tok),
                                 rl.group_size)
    # the weight-plane (DESIGN.md §Weight-plane): when a mesh is installed
    # the reshard plan carries trainer-profile -> inference-profile
    # (infer_tp: TP-sharded, data-replicated) placements per leaf; on a
    # single device both spec trees resolve to unplaced device_puts.
    from repro.sharding.specs import current_mesh, param_specs, \
        param_specs_for_profile
    mesh = current_mesh()
    transfer = WeightTransferService(
        pool,
        bucket_bytes=rl.transfer_bucket_bytes,
        wire_dtype=rl.transfer_wire_dtype or None,
        use_pallas_cast=rl.transfer_pallas_cast,
        overlap=rl.transfer_overlap,
        src_specs=None if mesh is None else param_specs(params, mesh),
        dst_specs=None if mesh is None else param_specs_for_profile(
            params, mesh, "infer_tp"))
    sched = PeriodicAsyncScheduler(cfg, rl, tri, gen, queue, loader,
                                   transfer=transfer)
    return sched, {"tokenizer": tok, "task": task, "loader": loader,
                   "pool": pool, "queue": queue, "generator": gen,
                   "tri": tri, "transfer": transfer}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b", choices=ARCH_IDS)
    ap.add_argument("--mode", default="async",
                    choices=["sync", "async", "async_offpolicy"])
    ap.add_argument("--iterations", type=int, default=4)
    ap.add_argument("--batch-prompts", type=int, default=4)
    ap.add_argument("--group-size", type=int, default=4)
    ap.add_argument("--micro-batch", type=int, default=2)
    ap.add_argument("--instances", type=int, default=2)
    ap.add_argument("--rollout-engine", default="group",
                    choices=["group", "paged"],
                    help="rollout decode path: group-at-a-time sampler or "
                         "token-level paged continuous batching")
    ap.add_argument("--cbatch-slots", type=int, default=8,
                    help="decode slots per paged instance")
    ap.add_argument("--kv-page-size", type=int, default=16)
    ap.add_argument("--drain-interval", type=int, default=1,
                    help="fused decode-block length D for the paged engine "
                         "(DESIGN.md §Device-resident-decode): the host "
                         "drains device token buffers once per D steps; "
                         "1 = legacy per-token cadence")
    ap.add_argument("--spec", action="store_true",
                    help="speculative decode for rollouts (DESIGN.md "
                         "§Spec-decode): k drafted tokens verified per "
                         "target forward, distribution-exact (Proposition "
                         "1 intact)")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="drafted tokens per verify step")
    ap.add_argument("--spec-draft", default="prompt_lookup",
                    choices=["prompt_lookup", "model"],
                    help="draft provider: n-gram prompt lookup (no extra "
                         "model) or a small resident draft model")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="radix prefix cache on the paged rollout engine "
                         "(DESIGN.md §Radix-prefix-cache): prompts sharing "
                         "a token prefix across groups/iterations share "
                         "its pages, suffix-only prefill")
    ap.add_argument("--max-prompt-len", type=int, default=48)
    ap.add_argument("--max-response-len", type=int, default=16)
    ap.add_argument("--prompt-pad", type=int, default=0)
    ap.add_argument("--no-capture-logprobs", action="store_true",
                    help="disable rollout-time logprob capture — the trainer "
                         "recomputes old-policy logprobs via the stacked "
                         "old+ref tri-model forward (DESIGN.md "
                         "§Tri-model-capture)")
    ap.add_argument("--no-transfer-overlap", action="store_true",
                    help="disable weight-plane overlap: publish+flip "
                         "eagerly inside the iteration boundary instead of "
                         "streaming buckets under the trainer's iteration "
                         "tail (DESIGN.md §Weight-plane)")
    ap.add_argument("--transfer-bucket-bytes", type=int, default=1 << 22,
                    help="wire bytes coalesced per weight-plane bucket")
    ap.add_argument("--transfer-wire-dtype", default="",
                    choices=["", "bfloat16", "float32"],
                    help="weight-plane payload dtype ('' = storage dtype, "
                         "bitwise)")
    ap.add_argument("--transfer-pallas-cast", action="store_true",
                    help="wire cast via the Pallas fused cast+copy kernel")
    ap.add_argument("--spa", action="store_true",
                    help="enable shared-prompt attention packing")
    ap.add_argument("--spa-align", type=int, default=0,
                    help="round SPA slot stride to this tile size "
                         "(128 on TPU; 0 = paper layout)")
    ap.add_argument("--profile", default="baseline",
                    choices=["baseline", "dp2", "dp2_zero1", "sp_heads"],
                    help="sharding profile (see sharding/specs.py SPerf)")
    ap.add_argument("--full", action="store_true",
                    help="use the full (non-reduced) config — dry-run scale")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json-out", default=None)
    ap.add_argument("--trace", default="",
                    help="write a Chrome/Perfetto trace of the pipeline to "
                         "this path (iterations, producer busy spans, train "
                         "steps, weight-plane buckets); analyze with "
                         "`repro-trace report`")
    ap.add_argument("--trace-dir", default="",
                    help="streaming trace export: rotate JSONL segments "
                         "into this directory (bounded tracer memory; "
                         "multi-hour-run safe); analyze with "
                         "`repro-trace report <dir>`")
    ap.add_argument("--trace-segment-events", type=int, default=8192,
                    help="events per trace segment before rotation")
    ap.add_argument("--trace-flush-events", type=int, default=256,
                    help="per-thread buffered events before a segment "
                         "flush (the crash-durability granularity)")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve GET /metrics (Prometheus), /healthz and "
                         "/status on this port for the duration of the run "
                         "(0 = ephemeral; the ops plane, DESIGN.md "
                         "§Observability)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if not args.full:
        cfg = reduced_config(cfg)
    rl = RLConfig(
        mode=args.mode, batch_prompts=args.batch_prompts,
        group_size=args.group_size, micro_batch=args.micro_batch,
        num_inference_instances=args.instances,
        max_prompt_len=args.max_prompt_len,
        max_response_len=args.max_response_len,
        shared_prompt_attention=args.spa, spa_align=args.spa_align,
        rollout_engine=args.rollout_engine, cbatch_slots=args.cbatch_slots,
        kv_page_size=args.kv_page_size,
        decode_drain_interval=args.drain_interval,
        spec_decode=args.spec, spec_k=args.spec_k,
        spec_draft=args.spec_draft, prefix_cache=args.prefix_cache,
        capture_logprobs=not args.no_capture_logprobs,
        transfer_overlap=not args.no_transfer_overlap,
        transfer_bucket_bytes=args.transfer_bucket_bytes,
        transfer_wire_dtype=args.transfer_wire_dtype,
        transfer_pallas_cast=args.transfer_pallas_cast, trace=args.trace,
        trace_dir=args.trace_dir,
        trace_segment_events=args.trace_segment_events,
        trace_flush_events=args.trace_flush_events,
        seed=args.seed)
    if rl.trace_dir:
        otrace.install(process_name="repro-train", stream_dir=rl.trace_dir,
                       flush_events=rl.trace_flush_events,
                       segment_events=rl.trace_segment_events)
    elif rl.trace:
        otrace.install(process_name="repro-train")

    from repro.sharding.specs import set_profile
    set_profile(args.profile)
    sched, _ = build_pipeline(cfg, rl, seed=args.seed,
                              prompt_pad=args.prompt_pad)
    server = None
    if args.metrics_port is not None:
        from repro.obs.server import OpsServer
        server = OpsServer(status_fn=sched.status,
                           port=args.metrics_port).start()
        print(f"ops server on {server.url} "
              f"(/metrics /healthz /status)")
    t0 = time.time()
    try:
        history = sched.run(args.iterations)
    except BaseException:
        # flush-on-crash: a mid-iteration failure must not lose the
        # timeline — streamed segments flush to disk, a monolithic
        # buffer exports what it has (the partial trace is exactly the
        # evidence a post-mortem needs)
        if rl.trace_dir:
            otrace.export()
            print(f"partial trace flushed to {rl.trace_dir}")
        elif rl.trace:
            otrace.export(rl.trace)
            print(f"partial trace written to {rl.trace}")
        otrace.uninstall()
        if server is not None:
            server.stop()
        raise
    wall = time.time() - t0

    total_tokens = sum(s.trained_tokens for s in history)
    print(f"\n{args.arch} mode={args.mode} spa={args.spa}: "
          f"{args.iterations} iterations, {total_tokens} tokens, "
          f"{wall:.1f}s wall, TPSPD={total_tokens / wall:.1f}")
    for s in history:
        m = s.metrics or {}
        extra = ""
        if "sync_gap" in m:
            extra += f" gap={m['sync_gap'] * 1e3:.0f}ms"
        if m.get("spec_acceptance"):
            extra += f" accept={m['spec_acceptance']:.2f}"
        if m.get("prefix_hit_rate"):
            extra += f" prefix_hit={m['prefix_hit_rate']:.2f}"
        if m.get("pages_reclaimed"):
            extra += f" reclaimed={m['pages_reclaimed']}"
        print(f"  iter {s.iteration}: wall={s.wall_time:.2f}s "
              f"tokens={s.trained_tokens} reward={s.reward_mean:.3f} "
              f"staleness={s.max_staleness}{extra}")
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump([s.__dict__ for s in history], f, indent=1, default=str)
    if server is not None:
        server.stop()
    if rl.trace_dir:
        otrace.export()
        otrace.uninstall()
        print(f"trace segments written to {rl.trace_dir}")
    elif rl.trace:
        otrace.export(rl.trace)
        otrace.uninstall()
        print(f"trace written to {rl.trace}")


if __name__ == "__main__":
    main()
