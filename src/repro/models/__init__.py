from repro.models.model import (forward, forward_hidden, init, init_caches,
                                init_paged_caches, logits, token_logprobs)

__all__ = ["forward", "forward_hidden", "init", "init_caches",
           "init_paged_caches", "logits", "token_logprobs"]
