"""Attention: GQA and MLA (DeepSeek-V2 latent attention), with a pure-JAX
chunked flash implementation (lax.scan over KV chunks + online softmax) so
activation memory stays bounded at 32k-500k contexts in the compiled HLO.

Masking is driven by (position, segment) arrays, which uniformly express:
  * causal:            kv_pos <= q_pos
  * sliding window:    q_pos - kv_pos < window
  * shared-prompt:     kv_seg == 0 (shared prompt)  OR  kv_seg == q_seg
Padding uses seg == -1 (tokens only attend within their own padding run via
the diagonal) and invalid cache slots use pos == INVALID_POS (masked by the
causal rule).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import apply_rope, dense_init
from repro.sharding.specs import constrain, profile_has

INVALID_POS = jnp.int32(2**30)
NEG_INF = -1e30


def allow_mask(q_pos, kv_pos, q_seg, kv_seg, window: Optional[int]):
    """(B, Sq), (B, Skv) -> (B, Sq, Skv) boolean allow mask."""
    qp = q_pos[:, :, None]
    kp = kv_pos[:, None, :]
    qs = q_seg[:, :, None]
    ks = kv_seg[:, None, :]
    allow = kp <= qp
    allow &= (ks == 0) | (ks == qs)
    if window is not None:
        allow &= (qp - kp) < window
    return allow


def chunked_attention(q, k, v, q_pos, kv_pos, q_seg, kv_seg, *,
                      window: Optional[int] = None, chunk_size: int = 512,
                      scale: Optional[float] = None):
    """Flash-style attention with online softmax over KV chunks.

    q: (B, Sq, H, Dk); k: (B, Skv, Hkv, Dk); v: (B, Skv, Hkv, Dv).
    Returns (B, Sq, H, Dv) in q.dtype.
    """
    B, Sq, H, Dk = q.shape
    _, Skv, Hkv, Dv = v.shape
    G = H // Hkv
    scale = Dk ** -0.5 if scale is None else scale
    C = min(chunk_size, Skv)
    pad = (-Skv) % C
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=2**30)
        kv_seg = jnp.pad(kv_seg, ((0, 0), (0, pad)), constant_values=-2)
    n = k.shape[1] // C

    qr = q.reshape(B, Sq, Hkv, G, Dk)

    if Sq == 1:
        # Decode fast path (SPerf, deepseek-v2-lite decode hillclimb): a
        # single-token query needs no KV-chunk scan -- scanning makes the
        # chunk index the leading dim, and dynamic-slicing that dim forces
        # SPMD to ALL-GATHER the whole seq-sharded cache every layer
        # (measured: 27.6 GiB/step on dsv2-lite decode_32k). The dense
        # single-pass form keeps the contraction over the sharded cache
        # dim local: softmax stats and the PV product decompose into local
        # partials + (B, H)-sized reductions. Score tile is only
        # (B, Hkv, G, 1, L) f32.
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qr, k,
                       preferred_element_type=jnp.float32) * scale
        ok = allow_mask(q_pos, kv_pos, q_seg, kv_seg, window)  # (B, 1, L)
        s = jnp.where(ok[:, None, None, :, :], s, NEG_INF)
        m = s.max(axis=-1, keepdims=True)
        p = jnp.exp(s - m)
        out = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(v.dtype), v,
                         preferred_element_type=jnp.float32)
        out = out / jnp.maximum(p.sum(axis=-1)[..., None], 1e-30)
        out = jnp.moveaxis(out, 3, 1).reshape(B, Sq, H, Dv)
        return out.astype(q.dtype)

    @jax.checkpoint
    def body(carry, xs):
        # checkpointed: backward recomputes the (B, Hkv, G, Sq, C) score /
        # probability tiles per chunk instead of saving every chunk's —
        # the flash-attention memory property in reverse mode.
        acc, m, l = carry
        kc, vc, kpc, ksc = xs
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qr, kc,
                       preferred_element_type=jnp.float32) * scale
        ok = allow_mask(q_pos, kpc, q_seg, ksc, window)        # (B, Sq, C)
        s = jnp.where(ok[:, None, None, :, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        # NOTE (§Perf iter 2, refuted): materialising p in bf16 does NOT cut
        # HBM traffic — the f32 score chain (dot -> mask -> exp) dominates
        # and dots are fusion barriers; only the fused Pallas kernel
        # (kernels/spa_attention.py) removes that traffic structurally.
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(vc.dtype), vc,
                        preferred_element_type=jnp.float32)
        acc_new = acc * corr[..., None] + pv
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((B, Hkv, G, Sq, Dv), jnp.float32)
    m0 = jnp.full((B, Hkv, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Sq), jnp.float32)

    xs = (
        jnp.moveaxis(k.reshape(B, n, C, Hkv, Dk), 1, 0),
        jnp.moveaxis(v.reshape(B, n, C, Hkv, Dv), 1, 0),
        jnp.moveaxis(kv_pos.reshape(B, n, C), 1, 0),
        jnp.moveaxis(kv_seg.reshape(B, n, C), 1, 0),
    )
    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), xs)
    out = acc / jnp.maximum(l, 1e-30)[..., None]               # (B,Hkv,G,Sq,Dv)
    out = jnp.moveaxis(out, 3, 1).reshape(B, Sq, H, Dv)
    return out.astype(q.dtype)


# ==========================================================================
# GQA attention block
# ==========================================================================

def init_gqa(key, cfg: ModelConfig, dtype) -> dict:
    d, H, Hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, (d, H * hd), 0, dtype),
        "wk": dense_init(k2, (d, Hkv * hd), 0, dtype),
        "wv": dense_init(k3, (d, Hkv * hd), 0, dtype),
        "wo": dense_init(k4, (H * hd, d), 0, dtype),
    }


def make_kv_cache(cfg: ModelConfig, batch: int, length: int, dtype) -> dict:
    """length = window size when cfg.sliding_window is set (ring buffer)."""
    Hkv, hd = cfg.num_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, length, Hkv, hd), dtype),
        "v": jnp.zeros((batch, length, Hkv, hd), dtype),
        "pos": jnp.full((batch, length), INVALID_POS, jnp.int32),
        "seg": jnp.full((batch, length), -2, jnp.int32),
    }


def make_paged_kv_cache(cfg: ModelConfig, num_pages: int, page_size: int,
                        dtype) -> dict:
    """One physical page pool shared by every sequence on the engine
    (DESIGN.md §Continuous-batching). Logical sequences are stitched
    together by a per-slot page table; a GRPO group's rows list the same
    prompt pages, so the shared prompt is stored once per group — the
    cache-level counterpart of SPA's shared-prompt packing."""
    Hkv, hd = cfg.num_kv_heads, cfg.head_dim
    return {
        "k_pages": jnp.zeros((num_pages, page_size, Hkv, hd), dtype),
        "v_pages": jnp.zeros((num_pages, page_size, Hkv, hd), dtype),
        "pos_pages": jnp.full((num_pages, page_size), INVALID_POS, jnp.int32),
    }


def _paged_decode(params, cfg: ModelConfig, q, k, v, positions, cache,
                  cache_offset, page_table):
    """Single-token decode against the paged pool.

    cache_offset: (B,) flat slot index (page_id * page_size + slot) where
    this step's k/v land — the engine points inactive rows at the trash
    page. page_table: (B, n_max) page ids per row (null page 0 pads).
    Returns (out (B,1,H,Dv), new_cache)."""
    B, _, H, hd = q.shape
    P, page, Hkv, _ = cache["k_pages"].shape
    flat = lambda a: a.reshape((P * page,) + a.shape[2:])
    idx = jnp.asarray(cache_offset)
    new_cache = {
        "k_pages": flat(cache["k_pages"]).at[idx].set(k[:, 0]).reshape(
            cache["k_pages"].shape),
        "v_pages": flat(cache["v_pages"]).at[idx].set(v[:, 0]).reshape(
            cache["v_pages"].shape),
        "pos_pages": flat(cache["pos_pages"]).at[idx].set(
            positions[:, 0]).reshape(cache["pos_pages"].shape),
    }
    if cfg.use_pallas_attention:
        # flash-decode Pallas kernel over the page pool (§Perf): the kernel
        # wrapper owns the page-table gather; causal masking comes from kv
        # pos (invalid slots carry 2^30).
        from repro.kernels.ops import paged_decode_attention as _flash_paged
        out = _flash_paged(q[:, 0], new_cache["k_pages"],
                           new_cache["v_pages"], new_cache["pos_pages"],
                           page_table, positions[:, 0],
                           window=cfg.sliding_window)[:, None]
        return out, new_cache
    # pure-JAX path: gather each row's logical context,
    # (B, n_max, page, ...) -> (B, L, ...), then single-pass decode
    n_max = page_table.shape[1]
    L = n_max * page
    kk = new_cache["k_pages"][page_table].reshape(B, L, Hkv, hd)
    vv = new_cache["v_pages"][page_table].reshape(B, L, Hkv, hd)
    kp = new_cache["pos_pages"][page_table].reshape(B, L)
    zeros = jnp.zeros((B, 1), jnp.int32)
    out = chunked_attention(q, kk, vv, positions, kp, zeros,
                            jnp.zeros((B, L), jnp.int32),
                            window=cfg.sliding_window,
                            chunk_size=cfg.attn_chunk_size)
    return out, new_cache


def gqa_attention(params, cfg: ModelConfig, x, positions, segments, *,
                  cache: Optional[dict] = None, cache_offset=None,
                  page_table=None):
    """x: (B, S, d). Training/prefill when cache is None or being filled;
    decode when S == 1 and cache holds history. A paged cache (leaves
    ``k_pages``/``v_pages``/``pos_pages`` + a ``page_table``) routes decode
    through the shared page pool instead of per-row contiguous caches.

    Returns (out, new_cache)."""
    B, S, d = x.shape
    H, Hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    if profile_has("heads") and S > 1:
        # Megatron-SP: gather seq once per layer; projections below then
        # emit head-sharded q (column parallel) instead of forcing a full
        # weight gather against seq-sharded activations.
        x = constrain(x, "batch", None, None)
    q = jnp.einsum("bsd,dh->bsh", x, params["wq"]).reshape(B, S, H, hd)
    k = jnp.einsum("bsd,dh->bsh", x, params["wk"]).reshape(B, S, Hkv, hd)
    v = jnp.einsum("bsd,dh->bsh", x, params["wv"]).reshape(B, S, Hkv, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if cache is not None and "k_pages" in cache:
        assert S == 1, "paged KV cache is a decode-only path"
        out, new_cache = _paged_decode(params, cfg, q, k, v, positions,
                                       cache, cache_offset, page_table)
        out = jnp.einsum("bsh,hd->bsd", out.reshape(B, S, H * hd),
                         params["wo"])
        return out, new_cache
    if cache is None:
        kk, vv, kp, ks = k, v, positions, segments
    else:
        L = cache["k"].shape[1]
        if S == 1:
            # NOTE (SPerf, refuted): a mask-based (iota==idx select) write
            # does NOT avoid the SPMD cache gather here -- XLA computes the
            # select replicated and the gather just moves to the sharding
            # constraint (measured identical 2.16 s bound on internlm2
            # decode_32k), while a full-cache rewrite would be strictly
            # worse on real hardware than an in-place DUS. The single-slot
            # write on a seq-sharded dim remains the documented residual
            # collective of dense-GQA decode; the structural fix is a
            # shard_map'd decode step (future lever).
            off = jnp.asarray(cache_offset)
            if off.ndim == 1:
                # per-row offsets (continuous batching: each slot is at a
                # different position) -> per-row one-hot masked write.
                idx = off % L if cfg.sliding_window is not None else off
                sel = (jnp.arange(L, dtype=jnp.int32)[None, :]
                       == idx[:, None])                      # (B, L)
                sel4 = sel[..., None, None]
                new_cache = {
                    "k": jnp.where(sel4, k, cache["k"]),
                    "v": jnp.where(sel4, v, cache["v"]),
                    "pos": jnp.where(sel, positions, cache["pos"]),
                    "seg": jnp.where(sel, segments, cache["seg"]),
                }
            else:
                idx = (cache_offset % L if cfg.sliding_window is not None
                       else cache_offset)
                new_cache = {
                    "k": jax.lax.dynamic_update_slice(cache["k"], k, (0, idx, 0, 0)),
                    "v": jax.lax.dynamic_update_slice(cache["v"], v, (0, idx, 0, 0)),
                    "pos": jax.lax.dynamic_update_slice(cache["pos"], positions, (0, idx)),
                    "seg": jax.lax.dynamic_update_slice(cache["seg"], segments, (0, idx)),
                }
        elif S > L:
            # windowed prefill (S > window): attend against the full fresh
            # K/V (the window mask handles visibility) and ring-write only
            # the trailing L tokens — token i lands in slot i % L so later
            # decode steps (idx = offset % L) find it.
            assert cfg.sliding_window is not None, "prefill exceeds cache"
            r = S % L
            ring = lambda a: jnp.roll(a[:, -L:], r, axis=1)
            new_cache = {"k": ring(k), "v": ring(v),
                         "pos": ring(positions), "seg": ring(segments)}
            out = chunked_attention(q, k, v, positions, positions,
                                    segments, segments,
                                    window=cfg.sliding_window,
                                    chunk_size=cfg.attn_chunk_size)
            out = jnp.einsum("bsh,hd->bsd", out.reshape(B, S, H * hd),
                             params["wo"])
            return out, new_cache
        else:  # prefill into an empty cache (L >= S, offset 0)
            new_cache = {
                "k": jax.lax.dynamic_update_slice(cache["k"], k, (0, 0, 0, 0)),
                "v": jax.lax.dynamic_update_slice(cache["v"], v, (0, 0, 0, 0)),
                "pos": jax.lax.dynamic_update_slice(cache["pos"], positions, (0, 0)),
                "seg": jax.lax.dynamic_update_slice(cache["seg"], segments, (0, 0)),
            }
        kk, vv = new_cache["k"], new_cache["v"]
        kp, ks = new_cache["pos"], new_cache["seg"]

    # Under the "sp_heads" profile (§Perf): reshard once per layer — q to
    # head-sharded, k/v replicated over the model axis — so the KV-chunk
    # scan below is collective-free. No-op when heads don't divide the
    # model axis or under other profiles ("heads" unmapped).
    q = constrain(q, "batch", None, "heads", None)
    kk = constrain(kk, "batch", None, None, None)
    vv = constrain(vv, "batch", None, None, None)
    if cfg.use_pallas_attention and S > 1:
        # production TPU path: fused block-sparse shared-prompt flash
        # kernel — scores/probs never leave VMEM (§Perf iter A5), dead
        # response x response tiles are skipped via the block map.
        from repro.kernels.ops import spa_attention as _spa_kernel
        out = _spa_kernel(q, kk, vv, positions, kp, segments, ks,
                          window=cfg.sliding_window)
    else:
        out = chunked_attention(q, kk, vv, positions, kp, segments, ks,
                                window=cfg.sliding_window,
                                chunk_size=cfg.attn_chunk_size)
    out = jnp.einsum("bsh,hd->bsd", out.reshape(B, S, H * hd), params["wo"])
    return out, new_cache


# ==========================================================================
# MLA (multi-head latent attention, DeepSeek-V2)
# ==========================================================================

def init_mla(key, cfg: ModelConfig, dtype) -> dict:
    d, H = cfg.d_model, cfg.num_heads
    r, nd, rd, vd = cfg.kv_lora_rank, cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq": dense_init(ks[0], (d, H * (nd + rd)), 0, dtype),
        "w_dkv": dense_init(ks[1], (d, r), 0, dtype),
        "w_kr": dense_init(ks[2], (d, rd), 0, dtype),
        "ckv_norm": jnp.ones((r,), dtype),
        "w_uk": dense_init(ks[3], (r, H * nd), 0, dtype),
        "w_uv": dense_init(ks[4], (r, H * vd), 0, dtype),
        "wo": dense_init(ks[5], (H * vd, d), 0, dtype),
    }


def make_mla_cache(cfg: ModelConfig, batch: int, length: int, dtype) -> dict:
    return {
        "ckv": jnp.zeros((batch, length, cfg.kv_lora_rank), dtype),
        "kr": jnp.zeros((batch, length, cfg.qk_rope_head_dim), dtype),
        "pos": jnp.full((batch, length), INVALID_POS, jnp.int32),
        "seg": jnp.full((batch, length), -2, jnp.int32),
    }


def _mla_qckv(params, cfg: ModelConfig, x, positions):
    from repro.models.layers import rmsnorm
    B, S, _ = x.shape
    H = cfg.num_heads
    nd, rd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    q = jnp.einsum("bsd,dh->bsh", x, params["wq"]).reshape(B, S, H, nd + rd)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    ckv = rmsnorm({"scale": params["ckv_norm"]},
                  jnp.einsum("bsd,dr->bsr", x, params["w_dkv"]), cfg.norm_eps)
    kr = jnp.einsum("bsd,dr->bsr", x, params["w_kr"])[:, :, None, :]
    kr = apply_rope(kr, positions, cfg.rope_theta)[:, :, 0, :]
    return q_nope, q_rope, ckv, kr


def mla_attention(params, cfg: ModelConfig, x, positions, segments, *,
                  cache: Optional[dict] = None, cache_offset=None,
                  page_table=None):
    """Expanded path for train/prefill; absorbed path for decode (S == 1):
    scores and values live in the (rank + rope) latent space so the KV cache
    stores only ckv + shared rope key — the MLA memory win."""
    B, S, d = x.shape
    H = cfg.num_heads
    nd, rd, vd, r = (cfg.qk_nope_head_dim, cfg.qk_rope_head_dim,
                     cfg.v_head_dim, cfg.kv_lora_rank)
    assert page_table is None, \
        "paged KV cache targets GQA; MLA decode keeps per-row latent caches"
    q_nope, q_rope, ckv, kr = _mla_qckv(params, cfg, x, positions)
    scale = (nd + rd) ** -0.5

    new_cache = None
    if cache is not None:
        L = cache["ckv"].shape[1]
        if S > 1 and S > L:
            # windowed prefill: ring-write trailing window, attend full
            # (mirrors gqa_attention's windowed-prefill path).
            assert cfg.sliding_window is not None, "prefill exceeds cache"
            r = S % L
            ring = lambda a: jnp.roll(a[:, -L:], r, axis=1)
            new_cache = {"ckv": ring(ckv), "kr": ring(kr),
                         "pos": ring(positions), "seg": ring(segments)}
            ckv_all, kr_all = ckv, kr
            kp, ks = positions, segments
        else:
            if S == 1:
                off = jnp.asarray(cache_offset)
                if off.ndim == 1:    # per-row offsets (continuous batching)
                    idx = off % L if cfg.sliding_window is not None else off
                    sel = (jnp.arange(L, dtype=jnp.int32)[None, :]
                           == idx[:, None])
                    new_cache = {
                        "ckv": jnp.where(sel[..., None], ckv, cache["ckv"]),
                        "kr": jnp.where(sel[..., None], kr, cache["kr"]),
                        "pos": jnp.where(sel, positions, cache["pos"]),
                        "seg": jnp.where(sel, segments, cache["seg"]),
                    }
                else:
                    idx = (cache_offset % L if cfg.sliding_window is not None
                           else cache_offset)
                    at = (0, idx)
                    new_cache = {
                        "ckv": jax.lax.dynamic_update_slice(cache["ckv"], ckv, at + (0,)),
                        "kr": jax.lax.dynamic_update_slice(cache["kr"], kr, at + (0,)),
                        "pos": jax.lax.dynamic_update_slice(cache["pos"], positions, at),
                        "seg": jax.lax.dynamic_update_slice(cache["seg"], segments, at),
                    }
            else:
                at = (0, 0)
                new_cache = {
                    "ckv": jax.lax.dynamic_update_slice(cache["ckv"], ckv, at + (0,)),
                    "kr": jax.lax.dynamic_update_slice(cache["kr"], kr, at + (0,)),
                    "pos": jax.lax.dynamic_update_slice(cache["pos"], positions, at),
                    "seg": jax.lax.dynamic_update_slice(cache["seg"], segments, at),
                }
            ckv_all, kr_all = new_cache["ckv"], new_cache["kr"]
            kp, ks = new_cache["pos"], new_cache["seg"]
    else:
        ckv_all, kr_all, kp, ks = ckv, kr, positions, segments

    if S == 1 and cache is not None:
        # absorbed decode: fold w_uk into q, attend in latent space.
        w_uk = params["w_uk"].reshape(r, H, nd)
        q_lat = jnp.einsum("bshn,rhn->bshr", q_nope, w_uk)     # (B,1,H,r)
        q_cat = jnp.concatenate([q_lat, q_rope], axis=-1)       # (B,1,H,r+rd)
        k_cat = jnp.concatenate([ckv_all, kr_all], axis=-1)[:, :, None, :]
        o_lat = chunked_attention(q_cat, k_cat,
                                  ckv_all[:, :, None, :],
                                  positions, kp, segments, ks,
                                  window=cfg.sliding_window,
                                  chunk_size=cfg.attn_chunk_size,
                                  scale=scale)                  # (B,1,H,r)
        w_uv = params["w_uv"].reshape(r, H, vd)
        out = jnp.einsum("bshr,rhv->bshv", o_lat, w_uv)
    else:
        # expanded: materialise per-head k/v from the latent (chunk-bounded
        # activations come from scanning layers; S*H*(nd+rd) is one layer's).
        k_nope = jnp.einsum("bsr,rh->bsh", ckv_all, params["w_uk"]).reshape(
            B, -1, H, nd)
        v = jnp.einsum("bsr,rh->bsh", ckv_all, params["w_uv"]).reshape(
            B, -1, H, vd)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(kr_all[:, :, None, :],
                                      k_nope.shape[:3] + (rd,))], axis=-1)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = chunked_attention(q, k, v, positions, kp, segments, ks,
                                window=cfg.sliding_window,
                                chunk_size=cfg.attn_chunk_size, scale=scale)
    out = jnp.einsum("bsh,hd->bsd", out.reshape(B, S, H * vd), params["wo"])
    return out, new_cache


def init_attention(key, cfg: ModelConfig, dtype) -> dict:
    return init_mla(key, cfg, dtype) if cfg.use_mla else init_gqa(key, cfg, dtype)


def attention(params, cfg: ModelConfig, x, positions, segments, **kw):
    fn = mla_attention if cfg.use_mla else gqa_attention
    return fn(params, cfg, x, positions, segments, **kw)


def make_cache(cfg: ModelConfig, batch: int, length: int, dtype) -> dict:
    if cfg.use_mla:
        return make_mla_cache(cfg, batch, length, dtype)
    return make_kv_cache(cfg, batch, length, dtype)
