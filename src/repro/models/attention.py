"""Attention: GQA and MLA (DeepSeek-V2 latent attention), with a pure-JAX
chunked flash implementation (lax.scan over KV chunks + online softmax) so
activation memory stays bounded at 32k-500k contexts in the compiled HLO.

Masking is driven by (position, segment) arrays, which uniformly express:
  * causal:            kv_pos <= q_pos
  * sliding window:    q_pos - kv_pos < window
  * shared-prompt:     kv_seg == 0 (shared prompt)  OR  kv_seg == q_seg
Padding uses seg == -1 (tokens only attend within their own padding run via
the diagonal) and invalid cache slots use pos == INVALID_POS (masked by the
causal rule).

KV caches are built and stepped through the :class:`CacheBackend` layer
(DESIGN.md §Cache-backends): one *layout* policy (dense-contiguous,
ring/sliding-window, paged) over one *content* spec (``cache_streams`` —
per-head K/V rows for GQA, ``(ckv, kr)`` latent rows for MLA), so every
decode engine constructs and advances its cache through the same interface
instead of per-engine ad-hoc dicts.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import apply_rope, dense_init
from repro.sharding.specs import (LOGICAL_TO_MESH, constrain, current_mesh,
                                  profile_has, shard_map, spec_for)

INVALID_POS = jnp.int32(2**30)
NEG_INF = -1e30


def allow_mask(q_pos, kv_pos, q_seg, kv_seg, window: Optional[int]):
    """(B, Sq), (B, Skv) -> (B, Sq, Skv) boolean allow mask."""
    qp = q_pos[:, :, None]
    kp = kv_pos[:, None, :]
    qs = q_seg[:, :, None]
    ks = kv_seg[:, None, :]
    allow = kp <= qp
    allow &= (ks == 0) | (ks == qs)
    if window is not None:
        allow &= (qp - kp) < window
    return allow


def chunked_attention(q, k, v, q_pos, kv_pos, q_seg, kv_seg, *,
                      window: Optional[int] = None, chunk_size: int = 512,
                      scale: Optional[float] = None):
    """Flash-style attention with online softmax over KV chunks.

    q: (B, Sq, H, Dk); k: (B, Skv, Hkv, Dk); v: (B, Skv, Hkv, Dv).
    Returns (B, Sq, H, Dv) in q.dtype.
    """
    B, Sq, H, Dk = q.shape
    _, Skv, Hkv, Dv = v.shape
    G = H // Hkv
    scale = Dk ** -0.5 if scale is None else scale
    C = min(chunk_size, Skv)
    pad = (-Skv) % C
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=2**30)
        kv_seg = jnp.pad(kv_seg, ((0, 0), (0, pad)), constant_values=-2)
    n = k.shape[1] // C

    qr = q.reshape(B, Sq, Hkv, G, Dk)

    if Sq == 1:
        # Decode fast path (SPerf, deepseek-v2-lite decode hillclimb): a
        # single-token query needs no KV-chunk scan -- scanning makes the
        # chunk index the leading dim, and dynamic-slicing that dim forces
        # SPMD to ALL-GATHER the whole seq-sharded cache every layer
        # (measured: 27.6 GiB/step on dsv2-lite decode_32k). The dense
        # single-pass form keeps the contraction over the sharded cache
        # dim local: softmax stats and the PV product decompose into local
        # partials + (B, H)-sized reductions. Score tile is only
        # (B, Hkv, G, 1, L) f32.
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qr, k,
                       preferred_element_type=jnp.float32) * scale
        ok = allow_mask(q_pos, kv_pos, q_seg, kv_seg, window)  # (B, 1, L)
        s = jnp.where(ok[:, None, None, :, :], s, NEG_INF)
        m = s.max(axis=-1, keepdims=True)
        p = jnp.exp(s - m)
        out = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(v.dtype), v,
                         preferred_element_type=jnp.float32)
        out = out / jnp.maximum(p.sum(axis=-1)[..., None], 1e-30)
        out = jnp.moveaxis(out, 3, 1).reshape(B, Sq, H, Dv)
        return out.astype(q.dtype)

    @jax.checkpoint
    def body(carry, xs):
        # checkpointed: backward recomputes the (B, Hkv, G, Sq, C) score /
        # probability tiles per chunk instead of saving every chunk's —
        # the flash-attention memory property in reverse mode.
        acc, m, l = carry
        kc, vc, kpc, ksc = xs
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qr, kc,
                       preferred_element_type=jnp.float32) * scale
        ok = allow_mask(q_pos, kpc, q_seg, ksc, window)        # (B, Sq, C)
        s = jnp.where(ok[:, None, None, :, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        # NOTE (§Perf iter 2, refuted): materialising p in bf16 does NOT cut
        # HBM traffic — the f32 score chain (dot -> mask -> exp) dominates
        # and dots are fusion barriers; only the fused Pallas kernel
        # (kernels/spa_attention.py) removes that traffic structurally.
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(vc.dtype), vc,
                        preferred_element_type=jnp.float32)
        acc_new = acc * corr[..., None] + pv
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((B, Hkv, G, Sq, Dv), jnp.float32)
    m0 = jnp.full((B, Hkv, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Sq), jnp.float32)

    xs = (
        jnp.moveaxis(k.reshape(B, n, C, Hkv, Dk), 1, 0),
        jnp.moveaxis(v.reshape(B, n, C, Hkv, Dv), 1, 0),
        jnp.moveaxis(kv_pos.reshape(B, n, C), 1, 0),
        jnp.moveaxis(kv_seg.reshape(B, n, C), 1, 0),
    )
    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), xs)
    out = acc / jnp.maximum(l, 1e-30)[..., None]               # (B,Hkv,G,Sq,Dv)
    out = jnp.moveaxis(out, 3, 1).reshape(B, Sq, H, Dv)
    return out.astype(q.dtype)


# ==========================================================================
# CacheBackend — the unified KV-cache layer (DESIGN.md §Cache-backends)
# ==========================================================================

def cache_streams(cfg: ModelConfig) -> Tuple[Tuple[str, tuple], ...]:
    """What one cached token consists of, per attention family:
    (name, per-token trailing shape) for each stream. GQA caches per-head
    K/V rows; MLA caches the compressed latent + shared rope key — the
    layout backends below are agnostic to which."""
    if cfg.use_mla:
        return (("ckv", (cfg.kv_lora_rank,)),
                ("kr", (cfg.qk_rope_head_dim,)))
    return (("k", (cfg.num_kv_heads, cfg.head_dim)),
            ("v", (cfg.num_kv_heads, cfg.head_dim)))


def is_paged_cache(cache: dict) -> bool:
    return "pos_pages" in cache


class DenseCacheBackend:
    """Contiguous per-row cache of ``length`` slots; doubles as the
    sliding-window RING buffer when ``cfg.sliding_window`` is set (write
    index ``offset % length``, windowed prefill ring-writes the trailing
    window). Used by the group Sampler and the dense-slot engine."""

    paged = False

    def __init__(self, cfg: ModelConfig, length: int):
        self.cfg = cfg
        self.L = length
        self.ring = cfg.sliding_window is not None

    def init(self, batch: int, dtype) -> dict:
        state = {n: jnp.zeros((batch, self.L) + shp, dtype)
                 for n, shp in cache_streams(self.cfg)}
        state["pos"] = jnp.full((batch, self.L), INVALID_POS, jnp.int32)
        state["seg"] = jnp.full((batch, self.L), -2, jnp.int32)
        return state

    def write_decode(self, state: dict, vals: tuple, positions, segments,
                     cache_offset) -> dict:
        """Decode-time write of S tokens per row (vals are (B, S, *shp);
        S == 1 for plain decode, S == k+1 for the spec-decode verify block
        — DESIGN.md §Spec-decode); ``cache_offset`` is a scalar (lock-step
        engines) or (B,) per-row START offsets (slot engines): row b's
        token j lands at slot ``off[b] + j`` (mod L on ring caches)."""
        L = self.L
        off = jnp.asarray(cache_offset)
        new = {}
        if off.ndim == 1:
            # per-row offsets (continuous batching: each slot is at a
            # different position) -> batched scatter at off[b] + j.
            S = positions.shape[1]
            idx = off[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]
            if self.ring:
                idx = idx % L
            b_idx = jnp.arange(off.shape[0], dtype=jnp.int32)[:, None]
            for (n, shp), val in zip(cache_streams(self.cfg), vals):
                new[n] = state[n].at[b_idx, idx].set(val)
            new["pos"] = state["pos"].at[b_idx, idx].set(positions)
            new["seg"] = state["seg"].at[b_idx, idx].set(segments)
        else:
            idx = cache_offset % L if self.ring else cache_offset
            for (n, shp), val in zip(cache_streams(self.cfg), vals):
                new[n] = jax.lax.dynamic_update_slice(
                    state[n], val, (0, idx) + (0,) * len(shp))
            new["pos"] = jax.lax.dynamic_update_slice(
                state["pos"], positions, (0, idx))
            new["seg"] = jax.lax.dynamic_update_slice(
                state["seg"], segments, (0, idx))
        return new

    def write_prefill(self, state: dict, vals: tuple, positions,
                      segments) -> dict:
        """Prompt prefill. S <= L writes at offset 0; S > L (legal only on
        ring caches) ring-writes the trailing window — token i lands in slot
        ``i % L`` so later decode steps (``idx = offset % L``) find it."""
        S = positions.shape[1]
        new = {}
        if S > self.L:
            assert self.ring, "prefill exceeds cache"
            rr = S % self.L
            ring = lambda a: jnp.roll(a[:, -self.L:], rr, axis=1)
            for (n, _), val in zip(cache_streams(self.cfg), vals):
                new[n] = ring(val)
            new["pos"] = ring(positions)
            new["seg"] = ring(segments)
            return new
        for (n, shp), val in zip(cache_streams(self.cfg), vals):
            new[n] = jax.lax.dynamic_update_slice(
                state[n], val, (0, 0) + (0,) * len(shp))
        new["pos"] = jax.lax.dynamic_update_slice(
            state["pos"], positions, (0, 0))
        new["seg"] = jax.lax.dynamic_update_slice(
            state["seg"], segments, (0, 0))
        return new

    def read(self, state: dict) -> tuple:
        """-> (*streams, kv_pos, kv_seg), each full-length."""
        return tuple(state[n] for n, _ in cache_streams(self.cfg)) \
            + (state["pos"], state["seg"])


class PagedCacheBackend:
    """One physical page pool shared by every sequence on the engine
    (DESIGN.md §Continuous-batching). Logical sequences are stitched
    together by a per-slot page table; a GRPO group's rows list the same
    prompt pages, so the shared prompt is stored once per group — the
    cache-level counterpart of SPA's shared-prompt packing. For MLA the
    pages hold ``(ckv, kr)`` latent rows (cache_streams), ~10x smaller than
    a GQA page — absorbed decode gathers latent pages directly."""

    paged = True

    def __init__(self, cfg: ModelConfig, page_size: int):
        self.cfg = cfg
        self.page = page_size

    def init(self, num_pages: int, dtype) -> dict:
        state = {n + "_pages": jnp.zeros((num_pages, self.page) + shp, dtype)
                 for n, shp in cache_streams(self.cfg)}
        state["pos_pages"] = jnp.full((num_pages, self.page), INVALID_POS,
                                      jnp.int32)
        return state

    def write_decode(self, state: dict, vals: tuple, positions,
                     cache_offset) -> dict:
        """cache_offset: (B, S) flat slot indices (page_id * page_size +
        slot) where this step's S tokens per row land (S == 1 for plain
        decode, k+1 for the spec verify block) — engines point inactive
        rows and masked speculative slots at the trash page, so duplicate
        trash indices across rows are harmless garbage."""
        P, page = state["pos_pages"].shape
        flat = lambda a: a.reshape((P * page,) + a.shape[2:])
        idx = jnp.asarray(cache_offset)                        # (B, S)
        new = {}
        for (n, _), val in zip(cache_streams(self.cfg), vals):
            pool = state[n + "_pages"]
            new[n + "_pages"] = flat(pool).at[idx].set(val).reshape(
                pool.shape)
        new["pos_pages"] = flat(state["pos_pages"]).at[idx].set(
            positions).reshape(state["pos_pages"].shape)
        return new

    def gather(self, state: dict, page_table) -> tuple:
        """(B, n_max) page table -> (*streams (B, L, *shp), kv_pos (B, L))
        logical contexts; null page 0 carries pos 2^30 (masked)."""
        B, n_max = page_table.shape
        L = n_max * self.page
        outs = tuple(
            state[n + "_pages"][page_table].reshape((B, L) + shp)
            for n, shp in cache_streams(self.cfg))
        kv_pos = state["pos_pages"][page_table].reshape(B, L)
        return outs + (kv_pos,)


def cache_backend(cfg: ModelConfig, *, length: Optional[int] = None,
                  page_size: Optional[int] = None):
    """The single construction point every decode path goes through:
    ``page_size`` selects the paged pool backend, otherwise a dense /
    ring cache of ``length`` slots."""
    if page_size is not None:
        return PagedCacheBackend(cfg, page_size)
    assert length is not None, "dense cache backend needs a length"
    return DenseCacheBackend(cfg, length)


def backend_of(cfg: ModelConfig, cache: dict):
    """Recover the layout backend from a cache state's leaves."""
    if is_paged_cache(cache):
        return PagedCacheBackend(cfg, cache["pos_pages"].shape[1])
    return DenseCacheBackend(cfg, cache["pos"].shape[1])


# ==========================================================================
# GQA attention block
# ==========================================================================

def init_gqa(key, cfg: ModelConfig, dtype) -> dict:
    d, H, Hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, (d, H * hd), 0, dtype),
        "wk": dense_init(k2, (d, Hkv * hd), 0, dtype),
        "wv": dense_init(k3, (d, Hkv * hd), 0, dtype),
        "wo": dense_init(k4, (H * hd, d), 0, dtype),
    }


def make_paged_kv_cache(cfg: ModelConfig, num_pages: int, page_size: int,
                        dtype) -> dict:
    """Paged pool state (see PagedCacheBackend); GQA or MLA-latent pages
    depending on cfg."""
    return PagedCacheBackend(cfg, page_size).init(num_pages, dtype)


def _is_verify(S: int, cache_offset) -> bool:
    """Multi-token DECODE-side write (the spec plane's k+1-token verify
    block, DESIGN.md §Spec-decode) vs prefill: every prefill call passes a
    scalar offset (0), while verify engines pass per-row start offsets."""
    return S > 1 and cache_offset is not None \
        and jnp.asarray(cache_offset).ndim >= 1


def _paged_offsets(cache_offset):
    """Normalise the paged write offsets to (B, S) flat slot indices —
    single-token engines pass (B,), the spec verify block (B, k+1)."""
    off = jnp.asarray(cache_offset)
    return off[:, None] if off.ndim == 1 else off


def _paged_gqa_decode(params, cfg: ModelConfig, q, k, v, positions, cache,
                      cache_offset, page_table):
    """GQA decode against the paged pool: S == 1 plain decode or S == k+1
    spec-decode verify (DESIGN.md §Spec-decode) — the written block then
    attends over the row's full gathered context, so intra-block causality
    falls out of the position mask. Returns (out (B,S,H,Dv), new_cache)."""
    B, S = q.shape[:2]
    be = backend_of(cfg, cache)
    new_cache = be.write_decode(cache, (k, v), positions,
                                _paged_offsets(cache_offset))
    if cfg.use_pallas_attention:
        # flash-decode Pallas kernel over the page pool (§Perf): the kernel
        # wrapper owns the page-table gather; causal masking comes from kv
        # pos (invalid slots carry 2^30).
        if S == 1:
            from repro.kernels.ops import paged_decode_attention as _flash
            out = _flash(q[:, 0], new_cache["k_pages"],
                         new_cache["v_pages"], new_cache["pos_pages"],
                         page_table, positions[:, 0],
                         window=cfg.sliding_window)[:, None]
        else:
            from repro.kernels.ops import paged_verify_attention as _flash
            out = _flash(q, new_cache["k_pages"], new_cache["v_pages"],
                         new_cache["pos_pages"], page_table, positions,
                         window=cfg.sliding_window)
        return out, new_cache
    # pure-JAX path: gather each row's logical context,
    # (B, n_max, page, ...) -> (B, L, ...), then single-pass decode
    kk, vv, kp = be.gather(new_cache, page_table)
    zeros = jnp.zeros((B, S), jnp.int32)
    out = chunked_attention(q, kk, vv, positions, kp, zeros,
                            jnp.zeros(kp.shape, jnp.int32),
                            window=cfg.sliding_window,
                            chunk_size=cfg.attn_chunk_size)
    return out, new_cache


def _shmap_decode_fit(cfg: ModelConfig, cache: dict, mesh, S: int) -> bool:
    """True when the dense-GQA single-token decode step should run under
    the fully-manual shard_map path (``_shmap_gqa_decode``): a mesh is
    installed whose model axis actually shards the cache's length dim
    (the active profile maps "seq" -> "model" and the axis divides L), so
    the GSPMD single-slot write would pay the residual collective this
    path exists to remove. Everything else (no mesh, unsharded cache,
    MLA, paged pool, verify blocks) keeps the GSPMD branch."""
    if mesh is None or "model" not in mesh.axis_names:
        return False
    n = mesh.shape["model"]
    if n <= 1 or S != 1 or cfg.use_mla or is_paged_cache(cache):
        return False
    if "model" not in LOGICAL_TO_MESH.get("seq", ()):
        return False
    return cache["pos"].shape[1] % n == 0


def _shmap_gqa_decode(cfg: ModelConfig, q, k, v, positions, segments,
                      cache: dict, cache_offset, mesh):
    """shard_map'd dense-GQA decode step over the seq-sharded cache
    (DESIGN.md §Device-resident-decode): each model shard writes the new
    K/V row ONLY when the slot falls inside its local L/n range (a masked
    local in-place update — no collective), computes flash partials over
    its local shard, and the shards merge through one pmax + two psums on
    (B, H)-sized softmax stats (``combine_partial_stats``). This is the
    structural fix for dense-GQA decode's residual SPMD collective: the
    cache never moves, only the stats do.

    Handles both offset conventions ``write_decode`` accepts for S == 1:
    a scalar (lock-step engines) and (B,) per-row starts (slot engines).
    Returns (out (B, 1, H, Dv) pre-``wo``, new_cache)."""
    from repro.kernels.decode_attention import (combine_partial_stats,
                                                decode_partial_stats)
    from jax.sharding import PartitionSpec as P

    B = q.shape[0]
    L = cache["pos"].shape[1]
    n = mesh.shape["model"]
    L_loc = L // n
    ring = cfg.sliding_window is not None
    off = jnp.asarray(cache_offset)

    q_spec = spec_for(mesh, q.shape, ("batch", None, None, None))
    row_spec = spec_for(mesh, positions.shape, ("batch", None))
    off_spec = spec_for(mesh, off.shape, ("batch",)) if off.ndim else P()
    ckv_spec = spec_for(mesh, cache["k"].shape, ("batch", "seq", None, None))
    cpos_spec = spec_for(mesh, cache["pos"].shape, ("batch", "seq"))
    out_spec = spec_for(mesh, (B, 1, q.shape[2], v.shape[-1]),
                        ("batch", None, None, None))

    def body(qb, kb, vb, qp, qs, ob, ck, cv, cp, cs):
        base = jax.lax.axis_index("model") * L_loc
        gidx = ob % L if ring else ob
        loc = gidx - base
        ok = (loc >= 0) & (loc < L_loc)
        idx = jnp.clip(loc, 0, L_loc - 1)
        if ob.ndim == 1:
            # per-row slot offsets (dense-slot engine): gather the current
            # row at the clamped local slot, select, scatter back — rows
            # whose slot lives on another shard write their own old value.
            bi = jnp.arange(qb.shape[0], dtype=jnp.int32)
            sel = lambda cur, new: jnp.where(
                ok.reshape((-1,) + (1,) * (new.ndim - 1)), new, cur)
            ck = ck.at[bi, idx].set(sel(ck[bi, idx], kb[:, 0]))
            cv = cv.at[bi, idx].set(sel(cv[bi, idx], vb[:, 0]))
            cp = cp.at[bi, idx].set(jnp.where(ok, qp[:, 0], cp[bi, idx]))
            cs = cs.at[bi, idx].set(jnp.where(ok, qs[:, 0], cs[bi, idx]))
        else:
            # scalar offset (lock-step engines): masked DUS at the local
            # index — off-shard devices rewrite the slot's current value.
            def upd(buf, new):
                cur = jax.lax.dynamic_slice_in_dim(buf, idx, 1, 1)
                return jax.lax.dynamic_update_slice_in_dim(
                    buf, jnp.where(ok, new, cur), idx, 1)
            ck, cv = upd(ck, kb), upd(cv, vb)
            cp, cs = upd(cp, qp), upd(cs, qs)
        pv, m, l = decode_partial_stats(qb, ck, cv, qp, cp, qs, cs,
                                        window=cfg.sliding_window)
        out = combine_partial_stats(pv, m, l, "model")
        out = jnp.moveaxis(out, 3, 1)                  # (B, 1, Hkv, G, Dv)
        out = out.reshape(qb.shape[0], 1, -1, out.shape[-1])
        return out.astype(qb.dtype), ck, cv, cp, cs

    out, nk, nv, npos, nseg = shard_map(
        body, mesh,
        in_specs=(q_spec, q_spec, q_spec, row_spec, row_spec, off_spec,
                  ckv_spec, ckv_spec, cpos_spec, cpos_spec),
        out_specs=(out_spec, ckv_spec, ckv_spec, cpos_spec, cpos_spec))(
            q, k, v, positions, segments, off,
            cache["k"], cache["v"], cache["pos"], cache["seg"])
    return out, {"k": nk, "v": nv, "pos": npos, "seg": nseg}


def gqa_attention(params, cfg: ModelConfig, x, positions, segments, *,
                  cache: Optional[dict] = None, cache_offset=None,
                  page_table=None):
    """x: (B, S, d). Training/prefill when cache is None or being filled;
    decode when S == 1 and cache holds history. A paged cache (leaves
    ``k_pages``/``v_pages``/``pos_pages`` + a ``page_table``) routes decode
    through the shared page pool instead of per-row contiguous caches.

    Returns (out, new_cache)."""
    B, S, d = x.shape
    H, Hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    if profile_has("heads") and S > 1:
        # Megatron-SP: gather seq once per layer; projections below then
        # emit head-sharded q (column parallel) instead of forcing a full
        # weight gather against seq-sharded activations.
        x = constrain(x, "batch", None, None)
    q = jnp.einsum("bsd,dh->bsh", x, params["wq"]).reshape(B, S, H, hd)
    k = jnp.einsum("bsd,dh->bsh", x, params["wk"]).reshape(B, S, Hkv, hd)
    v = jnp.einsum("bsd,dh->bsh", x, params["wv"]).reshape(B, S, Hkv, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if cache is not None and is_paged_cache(cache):
        # S == 1: plain decode; S > 1: spec-decode verify block
        out, new_cache = _paged_gqa_decode(params, cfg, q, k, v, positions,
                                           cache, cache_offset, page_table)
        out = jnp.einsum("bsh,hd->bsd", out.reshape(B, S, H * hd),
                         params["wo"])
        return out, new_cache
    if cache is None:
        kk, vv, kp, ks = k, v, positions, segments
    else:
        be = backend_of(cfg, cache)
        if S == 1 or _is_verify(S, cache_offset):
            mesh = current_mesh()
            if _shmap_decode_fit(cfg, cache, mesh, S):
                out, new_cache = _shmap_gqa_decode(
                    cfg, q, k, v, positions, segments, cache, cache_offset,
                    mesh)
                out = jnp.einsum("bsh,hd->bsd", out.reshape(B, S, H * hd),
                                 params["wo"])
                return out, new_cache
            # NOTE (SPerf): a mask-based (iota==idx select) write was
            # REFUTED as a fix -- XLA computes the select replicated and
            # the gather just moves to the sharding constraint (measured
            # identical 2.16 s bound on internlm2 decode_32k). The
            # structural fix is the shard_map'd decode step above
            # (_shmap_gqa_decode); this GSPMD branch remains for unsharded
            # caches / no-mesh runs / verify blocks, where the single-slot
            # write pays no collective (or the profile leaves seq
            # unsharded).
            new_cache = be.write_decode(cache, (k, v), positions, segments,
                                        cache_offset)
        elif S > be.L:
            # windowed prefill (S > window): attend against the full fresh
            # K/V (the window mask handles visibility) and ring-write only
            # the trailing L tokens.
            new_cache = be.write_prefill(cache, (k, v), positions, segments)
            out = chunked_attention(q, k, v, positions, positions,
                                    segments, segments,
                                    window=cfg.sliding_window,
                                    chunk_size=cfg.attn_chunk_size)
            out = jnp.einsum("bsh,hd->bsd", out.reshape(B, S, H * hd),
                             params["wo"])
            return out, new_cache
        else:  # prefill into an empty cache (L >= S, offset 0)
            new_cache = be.write_prefill(cache, (k, v), positions, segments)
        kk, vv, kp, ks = be.read(new_cache)

    # Under the "sp_heads" profile (§Perf): reshard once per layer — q to
    # head-sharded, k/v replicated over the model axis — so the KV-chunk
    # scan below is collective-free. No-op when heads don't divide the
    # model axis or under other profiles ("heads" unmapped).
    q = constrain(q, "batch", None, "heads", None)
    kk = constrain(kk, "batch", None, None, None)
    vv = constrain(vv, "batch", None, None, None)
    if cfg.use_pallas_attention and S > 1:
        # production TPU path: fused block-sparse shared-prompt flash
        # kernel — scores/probs never leave VMEM (§Perf iter A5), dead
        # response x response tiles are skipped via the block map.
        from repro.kernels.ops import spa_attention as _spa_kernel
        out = _spa_kernel(q, kk, vv, positions, kp, segments, ks,
                          window=cfg.sliding_window)
    else:
        out = chunked_attention(q, kk, vv, positions, kp, segments, ks,
                                window=cfg.sliding_window,
                                chunk_size=cfg.attn_chunk_size)
    out = jnp.einsum("bsh,hd->bsd", out.reshape(B, S, H * hd), params["wo"])
    return out, new_cache


# ==========================================================================
# MLA (multi-head latent attention, DeepSeek-V2)
# ==========================================================================

def init_mla(key, cfg: ModelConfig, dtype) -> dict:
    d, H = cfg.d_model, cfg.num_heads
    r, nd, rd, vd = cfg.kv_lora_rank, cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq": dense_init(ks[0], (d, H * (nd + rd)), 0, dtype),
        "w_dkv": dense_init(ks[1], (d, r), 0, dtype),
        "w_kr": dense_init(ks[2], (d, rd), 0, dtype),
        "ckv_norm": jnp.ones((r,), dtype),
        "w_uk": dense_init(ks[3], (r, H * nd), 0, dtype),
        "w_uv": dense_init(ks[4], (r, H * vd), 0, dtype),
        "wo": dense_init(ks[5], (H * vd, d), 0, dtype),
    }


def _mla_qckv(params, cfg: ModelConfig, x, positions):
    from repro.models.layers import rmsnorm
    B, S, _ = x.shape
    H = cfg.num_heads
    nd, rd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    q = jnp.einsum("bsd,dh->bsh", x, params["wq"]).reshape(B, S, H, nd + rd)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    ckv = rmsnorm({"scale": params["ckv_norm"]},
                  jnp.einsum("bsd,dr->bsr", x, params["w_dkv"]), cfg.norm_eps)
    kr = jnp.einsum("bsd,dr->bsr", x, params["w_kr"])[:, :, None, :]
    kr = apply_rope(kr, positions, cfg.rope_theta)[:, :, 0, :]
    return q_nope, q_rope, ckv, kr


def _absorbed_q(params, cfg: ModelConfig, q_nope, q_rope):
    """Fold w_uk into q: (B, S, H, nd) -> (B, S, H, r + rd) latent-space
    queries — shared by the contiguous and paged absorbed-decode paths."""
    H = cfg.num_heads
    w_uk = params["w_uk"].reshape(cfg.kv_lora_rank, H, cfg.qk_nope_head_dim)
    q_lat = jnp.einsum("bshn,rhn->bshr", q_nope, w_uk)
    return jnp.concatenate([q_lat, q_rope], axis=-1)


def _paged_mla_decode(params, cfg: ModelConfig, q_nope, q_rope, ckv, kr,
                      positions, cache, cache_offset, page_table, scale):
    """Absorbed MLA decode against the paged latent pool (S == 1 plain
    decode, S == k+1 spec verify): pages hold (ckv, kr) rows; scores and
    values stay in the (rank + rope) latent space. Returns
    (o_lat (B,S,H,r), new_cache)."""
    B, S = ckv.shape[:2]
    be = backend_of(cfg, cache)
    new_cache = be.write_decode(cache, (ckv, kr), positions,
                                _paged_offsets(cache_offset))
    q_cat = _absorbed_q(params, cfg, q_nope, q_rope)           # (B,S,H,r+rd)
    if cfg.use_pallas_attention:
        if S == 1:
            from repro.kernels.ops import (paged_mla_decode_attention
                                           as _flash)
            o_lat = _flash(q_cat[:, 0], new_cache["ckv_pages"],
                           new_cache["kr_pages"], new_cache["pos_pages"],
                           page_table, positions[:, 0], scale=scale,
                           window=cfg.sliding_window)[:, None]
        else:
            from repro.kernels.ops import (paged_mla_verify_attention
                                           as _flash)
            o_lat = _flash(q_cat, new_cache["ckv_pages"],
                           new_cache["kr_pages"], new_cache["pos_pages"],
                           page_table, positions, scale=scale,
                           window=cfg.sliding_window)
        return o_lat, new_cache
    ckv_all, kr_all, kp = be.gather(new_cache, page_table)
    k_cat = jnp.concatenate([ckv_all, kr_all], axis=-1)[:, :, None, :]
    zeros = jnp.zeros((B, S), jnp.int32)
    o_lat = chunked_attention(q_cat, k_cat, ckv_all[:, :, None, :],
                              positions, kp, zeros,
                              jnp.zeros(kp.shape, jnp.int32),
                              window=cfg.sliding_window,
                              chunk_size=cfg.attn_chunk_size, scale=scale)
    return o_lat, new_cache


def mla_attention(params, cfg: ModelConfig, x, positions, segments, *,
                  cache: Optional[dict] = None, cache_offset=None,
                  page_table=None):
    """Expanded path for train/prefill; absorbed path for decode (S == 1):
    scores and values live in the (rank + rope) latent space so the KV cache
    stores only ckv + shared rope key — the MLA memory win. A paged cache
    (``ckv_pages``/``kr_pages``/``pos_pages`` + page table) routes absorbed
    decode through the shared latent page pool."""
    B, S, d = x.shape
    H = cfg.num_heads
    nd, rd, vd, r = (cfg.qk_nope_head_dim, cfg.qk_rope_head_dim,
                     cfg.v_head_dim, cfg.kv_lora_rank)
    q_nope, q_rope, ckv, kr = _mla_qckv(params, cfg, x, positions)
    scale = (nd + rd) ** -0.5

    if cache is not None and is_paged_cache(cache):
        # S == 1: plain decode; S > 1: spec-decode verify block
        o_lat, new_cache = _paged_mla_decode(
            params, cfg, q_nope, q_rope, ckv, kr, positions, cache,
            cache_offset, page_table, scale)
        w_uv = params["w_uv"].reshape(r, H, vd)
        out = jnp.einsum("bshr,rhv->bshv", o_lat, w_uv)
        out = jnp.einsum("bsh,hd->bsd", out.reshape(B, S, H * vd),
                         params["wo"])
        return out, new_cache

    new_cache = None
    verify = _is_verify(S, cache_offset)
    if cache is not None:
        be = backend_of(cfg, cache)
        if S > 1 and S > be.L and not verify:
            # windowed prefill: ring-write trailing window, attend full
            # (mirrors gqa_attention's windowed-prefill path).
            new_cache = be.write_prefill(cache, (ckv, kr), positions,
                                         segments)
            ckv_all, kr_all = ckv, kr
            kp, ks = positions, segments
        else:
            if S == 1 or verify:
                new_cache = be.write_decode(cache, (ckv, kr), positions,
                                            segments, cache_offset)
            else:
                new_cache = be.write_prefill(cache, (ckv, kr), positions,
                                             segments)
            ckv_all, kr_all, kp, ks = be.read(new_cache)
    else:
        ckv_all, kr_all, kp, ks = ckv, kr, positions, segments

    if (S == 1 or verify) and cache is not None:
        # absorbed decode: fold w_uk into q, attend in latent space.
        q_cat = _absorbed_q(params, cfg, q_nope, q_rope)        # (B,1,H,r+rd)
        k_cat = jnp.concatenate([ckv_all, kr_all], axis=-1)[:, :, None, :]
        o_lat = chunked_attention(q_cat, k_cat,
                                  ckv_all[:, :, None, :],
                                  positions, kp, segments, ks,
                                  window=cfg.sliding_window,
                                  chunk_size=cfg.attn_chunk_size,
                                  scale=scale)                  # (B,1,H,r)
        w_uv = params["w_uv"].reshape(r, H, vd)
        out = jnp.einsum("bshr,rhv->bshv", o_lat, w_uv)
    else:
        # expanded: materialise per-head k/v from the latent (chunk-bounded
        # activations come from scanning layers; S*H*(nd+rd) is one layer's).
        k_nope = jnp.einsum("bsr,rh->bsh", ckv_all, params["w_uk"]).reshape(
            B, -1, H, nd)
        v = jnp.einsum("bsr,rh->bsh", ckv_all, params["w_uv"]).reshape(
            B, -1, H, vd)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(kr_all[:, :, None, :],
                                      k_nope.shape[:3] + (rd,))], axis=-1)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = chunked_attention(q, k, v, positions, kp, segments, ks,
                                window=cfg.sliding_window,
                                chunk_size=cfg.attn_chunk_size, scale=scale)
    out = jnp.einsum("bsh,hd->bsd", out.reshape(B, S, H * vd), params["wo"])
    return out, new_cache


def init_attention(key, cfg: ModelConfig, dtype) -> dict:
    return init_mla(key, cfg, dtype) if cfg.use_mla else init_gqa(key, cfg, dtype)


def attention(params, cfg: ModelConfig, x, positions, segments, **kw):
    fn = mla_attention if cfg.use_mla else gqa_attention
    return fn(params, cfg, x, positions, segments, **kw)


def make_cache(cfg: ModelConfig, batch: int, length: int, dtype) -> dict:
    return cache_backend(cfg, length=length).init(batch, dtype)
