"""Core layers: norms, embeddings, SwiGLU MLP, RoPE. Pure-functional JAX:
``init_*`` builds a params pytree, ``apply`` functions consume it."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding.specs import constrain, profile_has


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32):
    """Truncated-normal fan-in init (matches Megatron-style scaled init)."""
    fan_in = 1
    for a in (in_axis,) if isinstance(in_axis, int) else in_axis:
        fan_in *= shape[a]
    scale = 1.0 / (fan_in ** 0.5)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


# --------------------------------------------------------------------------
# RMSNorm
# --------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * params["scale"].astype(jnp.float32)).astype(dt)


# --------------------------------------------------------------------------
# SwiGLU MLP
# --------------------------------------------------------------------------

def init_mlp(key, d: int, ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d, ff), 0, dtype),
        "w_up": dense_init(k2, (d, ff), 0, dtype),
        "w_down": dense_init(k3, (ff, d), 0, dtype),
    }


def mlp(params: dict, x: jax.Array) -> jax.Array:
    nd = x.ndim
    if profile_has("ffn") and nd == 3:
        # Megatron-SP (sp_heads profile, §Perf): gather the seq dim once,
        # run column-parallel gate/up (ffn dim on the model axis) and
        # row-parallel down; without this, seq-sharded activations force
        # SPMD to all-gather the FULL layer weights at every use.
        x = constrain(x, "batch", None, None)
    g = jnp.einsum("...d,df->...f", x, params["w_gate"])
    u = jnp.einsum("...d,df->...f", x, params["w_up"])
    if profile_has("ffn") and nd == 3:
        g = constrain(g, "batch", None, "ffn")
        u = constrain(u, "batch", None, "ffn")
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("...f,fd->...d", h, params["w_down"])


# --------------------------------------------------------------------------
# Rotary position embeddings
# --------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    freqs = rope_freqs(x.shape[-1], theta)                       # (D/2,)
    angles = positions.astype(jnp.float32)[..., None] * freqs    # (..., S, D/2)
    angles = angles[..., None, :]                                # (..., S, 1, D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Embedding / LM head
# --------------------------------------------------------------------------

def init_embedding(key, cfg: ModelConfig, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    p = {"embedding": (jax.random.normal(k1, (cfg.vocab_size, cfg.d_model),
                                         jnp.float32) * 0.02).astype(dtype)}
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(k2, (cfg.d_model, cfg.vocab_size), 0, dtype)
    return p


def embed(params: dict, tokens: jax.Array, compute_dtype) -> jax.Array:
    return params["embedding"].astype(compute_dtype)[tokens]


def lm_head_weight(params: dict, cfg: ModelConfig) -> jax.Array:
    """(d_model, vocab)."""
    if cfg.tie_embeddings:
        return params["embedding"].T
    return params["lm_head"]


def logits_from_hidden(params: dict, cfg: ModelConfig, h: jax.Array) -> jax.Array:
    w = lm_head_weight(params, cfg).astype(h.dtype)
    return jnp.einsum("...d,dv->...v", h, w)
