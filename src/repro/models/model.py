"""Public model API: init / forward / per-token log-probs.

``token_logprobs`` computes log p(label) with a scan over sequence chunks so
the (B, S, V) logits tensor is never materialised — at vocab 152k and
4k sequence this is the difference between ~5 GB and ~40 MB of live
activations per device.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dtype_of, lm_head_weight
from repro.models.transformer import (forward_hidden, init_caches, init_model,
                                      init_paged_caches, logits)


def init(key, cfg: ModelConfig) -> dict:
    return init_model(key, cfg)


@jax.custom_vjp
def _chunk_logprob(h_c: jax.Array, W: jax.Array, y_c: jax.Array) -> jax.Array:
    """log p(y | h) for one sequence chunk — vocab-parallel (§Perf iter 4).

    Forward: the label pick is a one-hot masked SUM over the (possibly
    model-sharded) vocab dim, which decomposes into a local partial
    reduction + a (B, C) all-reduce — unlike take_along_axis, which forces
    SPMD to all-gather the f32 logits chunk.

    Backward (custom): d/dlg = g * (onehot(y) - softmax(lg)) computed
    in-place on the SHARDED (B, C, V) chunk (recomputed, flash-style), so
    no (B, C, V) cotangent ever crosses the vocab sharding: dh takes one
    small (B, C, d) reduction, dW stays shard-local. This is the Megatron
    vocab-parallel cross-entropy, derived for logprobs.
    """
    lg = jnp.einsum("bcd,dv->bcv", h_c, W,
                    preferred_element_type=jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    v_iota = jax.lax.broadcasted_iota(jnp.int32, lg.shape, 2)
    picked = jnp.where(v_iota == y_c[..., None], lg, 0.0).sum(axis=-1)
    return picked - lse


def _chunk_logprob_fwd(h_c, W, y_c):
    return _chunk_logprob(h_c, W, y_c), (h_c, W, y_c)


def _chunk_logprob_bwd(res, g):
    h_c, W, y_c = res
    lg = jnp.einsum("bcd,dv->bcv", h_c, W,
                    preferred_element_type=jnp.float32)   # recompute (remat)
    p = jax.nn.softmax(lg, axis=-1)
    v_iota = jax.lax.broadcasted_iota(jnp.int32, lg.shape, 2)
    onehot = (v_iota == y_c[..., None]).astype(jnp.float32)
    dlg = g[..., None] * (onehot - p)                     # (B, C, V) sharded
    dh = jnp.einsum("bcv,dv->bcd", dlg, W.astype(jnp.float32))
    dW = jnp.einsum("bcd,bcv->dv", h_c.astype(jnp.float32), dlg)
    return dh.astype(h_c.dtype), dW.astype(W.dtype), None


_chunk_logprob.defvjp(_chunk_logprob_fwd, _chunk_logprob_bwd)


def token_logprobs(params: dict, cfg: ModelConfig, hidden: jax.Array,
                   labels: jax.Array) -> jax.Array:
    """hidden: (B, S, d); labels: (B, S) next-token ids aligned with hidden
    (i.e. labels[t] is the target predicted *from* hidden[t]).
    Returns (B, S) float32 log-probabilities."""
    B, S, d = hidden.shape
    W = lm_head_weight(params["embed"], cfg).astype(hidden.dtype)
    C = min(cfg.loss_chunk_size, S)
    pad = (-S) % C
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
    n = hidden.shape[1] // C

    def body(_, xs):
        h_c, y_c = xs                                   # (B, C, d), (B, C)
        return None, _chunk_logprob(h_c, W, y_c)

    xs = (jnp.moveaxis(hidden.reshape(B, n, C, d), 1, 0),
          jnp.moveaxis(labels.reshape(B, n, C), 1, 0))
    _, out = jax.lax.scan(body, None, xs)
    out = jnp.moveaxis(out, 0, 1).reshape(B, S + pad)[:, :S]
    return out


def forward(params: dict, cfg: ModelConfig, tokens: jax.Array, **kw):
    """Convenience full-logits forward (small models / tests only)."""
    h, caches, aux, _ = forward_hidden(params, cfg, tokens, **kw)
    return logits(params, cfg, h), caches, aux


__all__ = ["init", "forward", "forward_hidden", "token_logprobs",
           "init_caches", "init_paged_caches", "logits"]
