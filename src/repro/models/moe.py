"""Top-k token-choice MoE with capacity-factor dropping.

Two execution paths:

* **Local** (single device / non-divisible meshes): sort/rank/scatter
  dispatch into an (E, C, d) buffer, batched expert SwiGLU, gather+combine.

* **Expert-parallel** (production meshes): a fully-manual ``shard_map``
  where each device routes its local tokens, builds a local (E, C_loc, d)
  dispatch buffer, and a ``jax.lax.all_to_all`` over the "data" axis moves
  token shards to their expert owners (E_loc = E/data experts per device);
  the per-expert ffn dim is tensor-parallel over "model" with a psum on the
  down-projection. This is the TPU-native adaptation of Megatron-style
  expert parallelism — the all-to-all boundary the paper's NPU stack gets
  from its MoE layers. Dense one-hot dispatch einsums (Switch-style) are
  intractable at 1M-token batches, and a plain GSPMD scatter replicates the
  (E*C, d) buffer on every device; the manual collective is what makes the
  235B config fit.

Both paths share the routing math and a Switch-style auxiliary
load-balance loss; tests assert they agree.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, mlp, init_mlp
from repro.sharding.specs import constrain, current_mesh, shard_map


def init_moe(key, cfg: ModelConfig, dtype) -> dict:
    d, E, ff = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, E), 0, jnp.float32),
        "w_gate": dense_init(ks[1], (E, d, ff), 1, dtype),
        "w_up": dense_init(ks[2], (E, d, ff), 1, dtype),
        "w_down": dense_init(ks[3], (E, ff, d), 1, dtype),
    }
    if cfg.num_shared_experts:
        p["shared"] = init_mlp(ks[4], d, cfg.num_shared_experts * ff, dtype)
    return p


def capacity(num_tokens: int, cfg: ModelConfig) -> int:
    c = math.ceil(num_tokens * cfg.num_experts_per_tok / cfg.num_experts
                  * cfg.capacity_factor)
    return max(8, ((c + 7) // 8) * 8)  # pad to 8 for layout friendliness


# --------------------------------------------------------------------------
# shared routing + dispatch math (operates on a flat local token buffer)
# --------------------------------------------------------------------------

def _route(params, cfg: ModelConfig, xf: jax.Array):
    """xf: (T, d) -> (top_p (T,K), top_i (T,K), aux_stats (me, ce))."""
    E, K = cfg.num_experts, cfg.num_experts_per_tok
    T = xf.shape[0]
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, K)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    me = probs.mean(axis=0)                                    # (E,)
    ce = jnp.zeros((E,), jnp.float32)
    for k in range(K):
        ce = ce + jnp.bincount(top_i[:, k], length=E).astype(jnp.float32)
    ce = ce / (T * K)
    return top_p, top_i, (me, ce)


def _dispatch_slots(top_i: jax.Array, E: int, C: int):
    """Rank of each (token, k) within its expert -> slot ids; E*C = overflow."""
    T, K = top_i.shape
    choice = top_i.reshape(-1)                                 # row-major: k fastest
    order = jnp.argsort(choice, stable=True)
    sorted_choice = choice[order]
    seg_start = jnp.searchsorted(sorted_choice, jnp.arange(E))
    rank_sorted = jnp.arange(T * K) - seg_start[sorted_choice]
    rank = jnp.zeros((T * K,), jnp.int32).at[order].set(
        rank_sorted.astype(jnp.int32))
    rank = rank.reshape(T, K)
    keep = rank < C
    slot = jnp.where(keep, top_i * C + rank, E * C)            # overflow row
    return slot, keep


def _scatter_tokens(xf: jax.Array, slot, keep, E: int, C: int):
    """(T, d) tokens -> (E, C, d) dispatch buffer (+1 overflow row).

    Single vectorised scatter over all T*K (token, choice) pairs — a
    sequential K-loop of scatters leaves K full-buffer cotangents live in
    the backward pass."""
    T, K = slot.shape
    d = xf.shape[1]
    src = (xf[:, None, :] * keep[:, :, None].astype(xf.dtype)).reshape(T * K, d)
    buf = jnp.zeros((E * C + 1, d), xf.dtype).at[slot.reshape(-1)].add(src)
    return buf[: E * C].reshape(E, C, d)


def _combine_tokens(y_e: jax.Array, slot, keep, top_p):
    """(E, C, d) expert outputs -> (T, d) weighted combine (single gather)."""
    E, C, d = y_e.shape
    T, K = slot.shape
    y_flat = jnp.concatenate(
        [y_e.reshape(E * C, d), jnp.zeros((1, d), y_e.dtype)], axis=0)
    g = y_flat[slot.reshape(-1)].reshape(T, K, d)
    w = (top_p * keep).astype(y_e.dtype)
    return jnp.einsum("tkd,tk->td", g, w,
                      preferred_element_type=jnp.float32)


def _expert_ffn(params, buf: jax.Array, dtype):
    """(E, C, d) -> (E, C, d) batched SwiGLU with the given expert weights."""
    g = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(dtype) * u
    return jnp.einsum("ecf,efd->ecd", h, params["w_down"])


# --------------------------------------------------------------------------
# local path
# --------------------------------------------------------------------------

def _moe_ffn_local(params: dict, cfg: ModelConfig, x: jax.Array):
    B, S, d = x.shape
    E, K = cfg.num_experts, cfg.num_experts_per_tok
    T = B * S
    C = capacity(T, cfg)
    xf = x.reshape(T, d)

    top_p, top_i, (me, ce) = _route(params, cfg, xf)
    aux = cfg.router_aux_coef * E * jnp.sum(me * ce)
    slot, keep = _dispatch_slots(top_i, E, C)
    buf = _scatter_tokens(xf, slot, keep, E, C)
    y_e = _expert_ffn(params, buf, x.dtype)
    y = _combine_tokens(y_e, slot, keep, top_p)

    if cfg.num_shared_experts:
        y = y + mlp(params["shared"], xf).astype(jnp.float32)
    return y.astype(x.dtype).reshape(B, S, d), aux


# --------------------------------------------------------------------------
# expert-parallel path (shard_map + all-to-all)
# --------------------------------------------------------------------------

def _ep_axes(mesh):
    """(batch_axes, data_axis, model_axis) present in this mesh."""
    names = mesh.axis_names
    bd = tuple(a for a in ("pod", "data") if a in names)
    return bd, ("data" if "data" in names else None), (
        "model" if "model" in names else None)


def _moe_ffn_ep(params: dict, cfg: ModelConfig, x: jax.Array, mesh):
    """Fully-manual shard_map:

      * tokens stay (batch over pod/data) x (seq over model) — each device
        routes only its local tokens (local capacity C_loc);
      * dispatch all-to-all over "data" moves token shards to their expert
        owners (E_loc = E/n_data experts per device);
      * expert weights are stored sharded over BOTH axes (expert dim on
        "data", a weight dim on "model", ZeRO-3 style) and all-gathered over
        "model" just-in-time — one transient (E_loc, d, ff) buffer per layer
        instead of a psum over expert-capacity-space activations (which is
        ~8x the bytes);
      * combine all-to-all returns expert outputs to token owners; the
        residual add happens outside in the caller's layout.
    """
    B, S, d = x.shape
    E, K = cfg.num_experts, cfg.num_experts_per_tok
    bd_axes, data_ax, model_ax = _ep_axes(mesh)
    n_data = mesh.shape[data_ax]
    n_model = mesh.shape[model_ax] if model_ax else 1
    n_batch = 1
    for a in bd_axes:
        n_batch *= mesh.shape[a]

    shard_seq = model_ax is not None and S % n_model == 0 and S > 1
    seq_spec = model_ax if shard_seq else None
    # weight storage sharding over "model" (gathered at use)
    zero3 = model_ax is not None and d % n_model == 0
    wd_spec = model_ax if zero3 else None

    B_loc = B // n_batch
    S_loc = S // n_model if shard_seq else S
    T_loc = B_loc * S_loc
    C_loc = capacity(T_loc, cfg)
    E_loc = E // n_data

    x_spec = P(bd_axes, seq_spec, None)
    w_spec = {"router": P(None, None),
              "w_gate": P(data_ax, wd_spec, None),   # (E, d, ff)
              "w_up": P(data_ax, wd_spec, None),
              "w_down": P(data_ax, None, wd_spec)}   # (E, ff, d)
    all_axes = tuple(mesh.axis_names)

    def gather_w(w, axis):
        if not zero3:
            return w
        return jax.lax.all_gather(w, model_ax, axis=axis, tiled=True)

    # Decode regime (SPerf, dsv2-lite decode hillclimb): with only a few
    # tokens per device, gathering (E_loc, d, ff) expert weights (44 MiB x
    # layers) costs far more than the math. Instead contract against the
    # model-sharded weight shard directly and psum the tiny
    # (E_loc, tokens, ff) partials -- move tokens to weights, not weights
    # to tokens.
    use_psum = zero3 and (B_loc * S_loc) <= max(64, n_model * 4) and S == 1

    def expert_ffn_psum(wp, buf, dtype):
        d_loc = d // n_model
        idx = jax.lax.axis_index(model_ax)
        buf_loc = jax.lax.dynamic_slice_in_dim(buf, idx * d_loc, d_loc, 2)
        g = jnp.einsum("ecd,edf->ecf", buf_loc, wp["w_gate"])
        u = jnp.einsum("ecd,edf->ecf", buf_loc, wp["w_up"])
        g = jax.lax.psum(g, model_ax)
        u = jax.lax.psum(u, model_ax)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(dtype) * u
        y_loc = jnp.einsum("ecf,efd->ecd", h, wp["w_down"])   # d-sharded out
        return jax.lax.all_gather(y_loc, model_ax, axis=2, tiled=True)

    def body(wp, xb):
        xf = xb.reshape(T_loc, d)
        top_p, top_i, (me, ce) = _route(wp, cfg, xf)
        # exact global load-balance stats: tokens are sharded over every
        # manual axis, so expert stats average over all of them. (When seq is
        # not sharded, model shards hold identical tokens and the pmean is a
        # no-op on identical values.)
        me = jax.lax.pmean(me, all_axes)
        ce = jax.lax.pmean(ce, all_axes)
        aux = cfg.router_aux_coef * E * jnp.sum(me * ce)

        slot, keep = _dispatch_slots(top_i, E, C_loc)
        buf = _scatter_tokens(xf, slot, keep, E, C_loc)        # (E, C_loc, d)
        # ---- all-to-all: token shards -> expert owners -------------------
        buf = jax.lax.all_to_all(buf, data_ax, split_axis=0, concat_axis=1,
                                 tiled=True)                    # (E_loc, n*C_loc, d)
        if use_psum:
            y_e = expert_ffn_psum(wp, buf, xb.dtype)            # (E_loc, n*C_loc, d)
        else:
            w_full = {"w_gate": gather_w(wp["w_gate"], 1),
                      "w_up": gather_w(wp["w_up"], 1),
                      "w_down": gather_w(wp["w_down"], 2)}
            y_e = _expert_ffn(w_full, buf, xb.dtype)            # (E_loc, n*C_loc, d)
        # ---- all-to-all back: expert outputs -> token owners -------------
        y_e = jax.lax.all_to_all(y_e, data_ax, split_axis=1, concat_axis=0,
                                 tiled=True)                    # (E, C_loc, d)
        y = _combine_tokens(y_e, slot, keep, top_p)
        return y.astype(xb.dtype).reshape(B_loc, S_loc, d), aux

    y, aux = shard_map(
        body, mesh, in_specs=(w_spec, x_spec),
        out_specs=(x_spec, P()))(
            {k: params[k] for k in w_spec}, x)

    if cfg.num_shared_experts:
        # shared experts run as a plain dense MLP under GSPMD (their weights
        # follow the standard 2D param rules).
        y = y + mlp(params["shared"], x).astype(y.dtype)
    return y, aux


def moe_ffn(params: dict, cfg: ModelConfig, x: jax.Array):
    """x: (B, S, d) -> (y, aux_loss). Picks the expert-parallel path when the
    active mesh can shard it, else the local path."""
    mesh = current_mesh()
    if mesh is not None:
        bd_axes, data_ax, _ = _ep_axes(mesh)
        n_batch = 1
        for a in bd_axes:
            n_batch *= mesh.shape[a]
        if (data_ax is not None and mesh.shape[data_ax] > 1
                and cfg.num_experts % mesh.shape[data_ax] == 0
                and x.shape[0] % n_batch == 0):
            return _moe_ffn_ep(params, cfg, x, mesh)
    return _moe_ffn_local(params, cfg, x)
