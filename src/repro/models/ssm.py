"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060), TPU-adapted.

The chunked SSD algorithm maps naturally onto the MXU: within a chunk the
recurrence is computed as dense quadratic attention-like matmuls (the
"duality"), across chunks a linear state recurrence is carried by a
`lax.scan`. We scan chunk-by-chunk (rather than materialising all per-chunk
decay matrices) so activation memory is bounded by one chunk regardless of
sequence length — the same reasoning as chunked flash attention.

Supports an initial state (used for decode continuation and for
prefix-state sharing, the SSM analogue of shared-prompt attention).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, rmsnorm
from repro.sharding.specs import constrain


# --------------------------------------------------------------------------
# core SSD scan
# --------------------------------------------------------------------------

def _segsum(dA: jax.Array) -> jax.Array:
    """dA: (..., Q) -> (..., Q, Q) lower-triangular segment sums
    S[i, j] = sum_{k=j+1..i} dA_k for i >= j, -inf above the diagonal."""
    Q = dA.shape[-1]
    cs = jnp.cumsum(dA, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    idx = jnp.arange(Q)
    mask = idx[:, None] >= idx[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd(x, dt, A, B, C, chunk: int, initial_state: Optional[jax.Array] = None
        ) -> Tuple[jax.Array, jax.Array]:
    """Chunked selective-state-space forward.

    x:  (Bb, S, H, P)   inputs (already conv'd + activated)
    dt: (Bb, S, H)      post-softplus step sizes
    A:  (H,)            negative decay rates
    B:  (Bb, S, G, N)   input projections  (G groups, H % G == 0)
    C:  (Bb, S, G, N)   output projections
    returns y (Bb, S, H, P), final_state (Bb, H, P, N).
    """
    Bb, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    R = H // G  # heads per group
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    S_p = S + pad
    n = S_p // Q

    f32 = jnp.float32
    x32, dt32 = x.astype(f32), dt.astype(f32)
    B32, C32 = B.astype(f32), C.astype(f32)
    # tensor-parallel SSD: shard the head dim over "model" so the per-chunk
    # (Bb, H, Q, Q) matrices and their matmuls split across the TP group.
    x32 = constrain(x32, "batch", None, "model", None)
    dt32 = constrain(dt32, "batch", None, "model")
    dA = dt32 * A.astype(f32)[None, None, :]                     # (Bb,S,H)
    dA = constrain(dA, "batch", None, "model")

    def to_chunks(a):
        return jnp.moveaxis(a.reshape((Bb, n, Q) + a.shape[2:]), 1, 0)

    xs = tuple(map(to_chunks, (x32, dt32, dA, B32, C32)))

    h0 = (jnp.zeros((Bb, H, P, N), f32) if initial_state is None
          else initial_state.astype(f32))

    @jax.checkpoint
    def body(h, inp):
        # checkpointed: per-chunk (Bb, H, Q, Q) decay/score matrices are
        # recomputed in backward rather than saved for every chunk.
        xc, dtc, dAc, Bc, Cc = inp            # (Bb,Q,...)
        # intra-chunk (quadratic / "attention" form) ------------------------
        Lmat = jnp.exp(_segsum(jnp.moveaxis(dAc, -1, 1)))        # (Bb,H,Q,Q)
        # scores: C_i . B_j per group, broadcast over heads in group
        CB = jnp.einsum("bqgn,bkgn->bgqk", Cc, Bc)               # (Bb,G,Q,Q)
        CB = jnp.repeat(CB, R, axis=1)                           # (Bb,H,Q,Q)
        M = CB * Lmat * jnp.moveaxis(dtc, -1, 1)[:, :, None, :]  # dt_j weight
        y_diag = jnp.einsum("bhqk,bkhp->bqhp", M, xc)
        # contribution of the carried state ---------------------------------
        dA_cum = jnp.cumsum(dAc, axis=1)                         # (Bb,Q,H)
        state_decay = jnp.exp(dA_cum)                            # decay from chunk start
        Cr = jnp.repeat(Cc, R, axis=2)                           # (Bb,Q,H,N) via groups
        y_off = jnp.einsum("bqhn,bhpn,bqh->bqhp", Cr, h, state_decay)
        # chunk state update --------------------------------------------------
        total = dA_cum[:, -1, :]                                 # (Bb,H)
        decay_to_end = jnp.exp(total[:, None, :] - dA_cum)       # (Bb,Q,H)
        Br = jnp.repeat(Bc, R, axis=2)                           # (Bb,Q,H,N)
        upd = jnp.einsum("bqhn,bqh,bqhp->bhpn", Br, decay_to_end * dtc, xc)
        h_new = h * jnp.exp(total)[:, :, None, None] + upd
        return h_new, y_diag + y_off

    h_final, ys = jax.lax.scan(body, h0, xs)                     # ys (n,Bb,Q,H,P)
    y = jnp.moveaxis(ys, 0, 1).reshape(Bb, S_p, H, P)[:, :S]
    return y.astype(x.dtype), h_final


def ssd_step(h, x, dt, A, B, C):
    """Single-token recurrence. h: (Bb,H,P,N); x: (Bb,H,P); dt: (Bb,H);
    B, C: (Bb,G,N). Returns (y (Bb,H,P), h_new)."""
    G = B.shape[1]
    H = x.shape[1]
    R = H // G
    f32 = jnp.float32
    x32, dt32 = x.astype(f32), dt.astype(f32)
    Br = jnp.repeat(B.astype(f32), R, axis=1)                    # (Bb,H,N)
    Cr = jnp.repeat(C.astype(f32), R, axis=1)
    decay = jnp.exp(dt32 * A.astype(f32)[None, :])               # (Bb,H)
    h_new = (h * decay[:, :, None, None]
             + jnp.einsum("bh,bhp,bhn->bhpn", dt32, x32, Br))
    y = jnp.einsum("bhpn,bhn->bhp", h_new, Cr)
    return y.astype(x.dtype), h_new


# --------------------------------------------------------------------------
# full Mamba-2 mixer block
# --------------------------------------------------------------------------

def init_ssm(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    di = cfg.ssm_d_inner
    G, N, H = cfg.ssm_num_groups, cfg.ssm_state_size, cfg.ssm_num_heads
    conv_ch = di + 2 * G * N
    ks = jax.random.split(key, 4)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di + 2 * G * N + H), 0, dtype),
        "conv_w": dense_init(ks[1], (cfg.ssm_conv_width, conv_ch), 0, dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "gate_norm": jnp.ones((di,), dtype),
        "out_proj": dense_init(ks[3], (di, d), 0, dtype),
    }


def _causal_conv(xBC, w, b, tail=None):
    """Depthwise causal conv. xBC: (Bb, S, ch); w: (W, ch). ``tail`` is the
    previous segment's last W-1 pre-conv inputs (continuation across a
    split point, e.g. prefix-state sharing); zeros when None."""
    W = w.shape[0]
    if tail is None:
        pad = jnp.pad(xBC, ((0, 0), (W - 1, 0), (0, 0)))
    else:
        pad = jnp.concatenate([tail.astype(xBC.dtype), xBC], axis=1)
    out = jax.lax.conv_general_dilated(
        pad.astype(jnp.float32),
        w.astype(jnp.float32)[:, None, :],           # (W, 1, ch)
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=xBC.shape[-1])
    return (out + b.astype(jnp.float32)).astype(xBC.dtype)


def make_ssm_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    di = cfg.ssm_d_inner
    G, N, H = cfg.ssm_num_groups, cfg.ssm_state_size, cfg.ssm_num_heads
    P = di // H
    conv_ch = di + 2 * G * N
    return {
        "state": jnp.zeros((batch, H, P, N), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_ch), dtype),
    }


def _split_zxbcdt(cfg: ModelConfig, zxbcdt):
    di = cfg.ssm_d_inner
    G, N, H = cfg.ssm_num_groups, cfg.ssm_state_size, cfg.ssm_num_heads
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di: 2 * di + 2 * G * N]
    dt = zxbcdt[..., 2 * di + 2 * G * N:]
    return z, xBC, dt, di, G, N, H


def ssm_mixer(params, cfg: ModelConfig, u, *, cache: Optional[dict] = None,
              initial_state=None):
    """Sequence forward. u: (Bb, S, d) -> (out, new_cache_or_None, final_state).

    ``initial_state`` is either the bare SSD state (Bb, H, P, N) or a full
    continuation dict {"state": ..., "conv": (Bb, W-1, ch) pre-conv tail}
    (prefix-state sharing / exact segment continuation). When a dict is
    given, the returned final_state is a dict of the same form."""
    Bb, S, d = u.shape
    init_conv = None
    want_dict = isinstance(initial_state, dict)
    if want_dict:
        init_conv = initial_state.get("conv")
        initial_state = initial_state["state"]
    zxbcdt = jnp.einsum("bsd,dk->bsk", u, params["in_proj"])
    z, xBC, dt, di, G, N, H = _split_zxbcdt(cfg, zxbcdt)
    P = di // H
    xBC_pre = xBC
    xBC = jax.nn.silu(_causal_conv(xBC, params["conv_w"], params["conv_b"],
                                   tail=init_conv)
                      .astype(jnp.float32)).astype(u.dtype)
    x = xBC[..., :di].reshape(Bb, S, H, P)
    Bm = xBC[..., di: di + G * N].reshape(Bb, S, G, N)
    Cm = xBC[..., di + G * N:].reshape(Bb, S, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    y, h_final = ssd(x, dt, A, Bm, Cm, cfg.ssm_chunk_size,
                     initial_state=initial_state)
    y = (y.astype(jnp.float32)
         + params["D"][None, None, :, None] * x.astype(jnp.float32))
    y = y.astype(u.dtype).reshape(Bb, S, di)
    y = rmsnorm({"scale": params["gate_norm"]},
                y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                cfg.norm_eps)
    out = jnp.einsum("bsk,kd->bsd", y, params["out_proj"])
    new_cache = None
    if cache is not None or want_dict:
        W = cfg.ssm_conv_width
        # keep the last (W-1) pre-conv inputs for continuation. With an
        # initial tail the effective stream is [tail, xBC_pre].
        stream = (jnp.pad(xBC_pre, ((0, 0), (W - 1, 0), (0, 0)))
                  if init_conv is None
                  else jnp.concatenate([init_conv.astype(xBC_pre.dtype),
                                        xBC_pre], axis=1))
        tail = jax.lax.dynamic_slice_in_dim(
            stream, stream.shape[1] - (W - 1), W - 1, axis=1)
        new_cache = {"state": h_final, "conv": tail}
    final = {"state": h_final, "conv": new_cache["conv"]} if want_dict else h_final
    return out, (new_cache if cache is not None else None), final


def ssm_mixer_step(params, cfg: ModelConfig, u, cache: dict):
    """Single-token decode. u: (Bb, 1, d) -> (out (Bb,1,d), new_cache)."""
    Bb = u.shape[0]
    zxbcdt = jnp.einsum("bsd,dk->bsk", u, params["in_proj"])[:, 0]
    z, xBC, dt, di, G, N, H = _split_zxbcdt(cfg, zxbcdt)
    P = di // H
    W = cfg.ssm_conv_width
    conv_in = jnp.concatenate([cache["conv"], xBC[:, None, :]], axis=1)
    w = params["conv_w"].astype(jnp.float32)
    xBC_c = jax.nn.silu(
        jnp.einsum("bwc,wc->bc", conv_in.astype(jnp.float32), w)
        + params["conv_b"].astype(jnp.float32)).astype(u.dtype)
    x = xBC_c[..., :di].reshape(Bb, H, P)
    Bm = xBC_c[..., di: di + G * N].reshape(Bb, G, N)
    Cm = xBC_c[..., di + G * N:].reshape(Bb, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    y, h_new = ssd_step(cache["state"], x, dt, A, Bm, Cm)
    y = (y.astype(jnp.float32)
         + params["D"][None, :, None] * x.astype(jnp.float32))
    y = y.astype(u.dtype).reshape(Bb, di)
    y = rmsnorm({"scale": params["gate_norm"]},
                y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                cfg.norm_eps)
    out = jnp.einsum("bk,kd->bd", y, params["out_proj"])[:, None, :]
    return out, {"state": h_new, "conv": conv_in[:, 1:]}
