"""Model assembly: decoder-only LM (dense / MoE / SSM / hybrid), VLM variant
(precomputed patch embeddings prepended) and encoder-decoder (Whisper).

Layers are *stacked* (leading L axis on every leaf) and iterated with
``jax.lax.scan`` so the compiled HLO size is independent of depth — required
for 60-94-layer dry-run compiles and idiomatic for production TPU stacks.
Heterogeneous leading layers (DeepSeek-V2's first-k-dense) run unstacked as a
"prelude" before the scanned body.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import ssm as ssm_mod
from repro.models.attention import attention, init_attention, make_cache
from repro.models.layers import (dtype_of, embed, init_embedding, init_mlp,
                                 init_rmsnorm, logits_from_hidden, mlp,
                                 rmsnorm)
from repro.models.moe import init_moe, moe_ffn
from repro.models.ssm import (init_ssm, make_ssm_cache, ssm_mixer,
                              ssm_mixer_step)
from repro.sharding.specs import constrain


# ==========================================================================
# block init
# ==========================================================================

def init_block(key, cfg: ModelConfig, *, moe: bool, dense_ff: int = 0,
               cross: bool = False, causal: bool = True) -> dict:
    dt = dtype_of(cfg.param_dtype)
    d = cfg.d_model
    ks = iter(jax.random.split(key, 8))
    p: dict = {"ln1": init_rmsnorm(d, dt)}
    if cfg.family == "ssm":
        p["ssm"] = init_ssm(next(ks), cfg, dt)
        return p
    p["attn"] = init_attention(next(ks), cfg, dt)
    if cfg.hybrid:
        p["ssm"] = init_ssm(next(ks), cfg, dt)
        p["attn_out_norm"] = init_rmsnorm(d, dt)
        p["ssm_out_norm"] = init_rmsnorm(d, dt)
    if cross:
        p["ln_cross"] = init_rmsnorm(d, dt)
        p["cross_attn"] = init_attention(next(ks), cfg, dt)
    p["ln2"] = init_rmsnorm(d, dt)
    if moe:
        p["moe"] = init_moe(next(ks), cfg, dt)
    else:
        p["mlp"] = init_mlp(next(ks), d, dense_ff or cfg.d_ff, dt)
    return p


def init_model(key, cfg: ModelConfig) -> dict:
    dt = dtype_of(cfg.param_dtype)
    k_embed, k_pre, k_body, k_enc = jax.random.split(key, 4)
    params: dict = {
        "embed": init_embedding(k_embed, cfg, dt),
        "final_norm": init_rmsnorm(cfg.d_model, dt),
    }
    n_pre = cfg.first_k_dense if cfg.is_moe else 0
    if n_pre:
        pre_keys = jax.random.split(k_pre, n_pre)
        params["prelude"] = [
            init_block(k, cfg, moe=False, dense_ff=cfg.dense_d_ff or cfg.d_ff)
            for k in pre_keys]
    n_body = cfg.num_layers - n_pre
    body_keys = jax.random.split(k_body, n_body)
    params["layers"] = jax.vmap(
        lambda k: init_block(k, cfg, moe=cfg.is_moe,
                             cross=cfg.is_encoder_decoder))(body_keys)
    if cfg.is_encoder_decoder:
        enc_keys = jax.random.split(k_enc, cfg.num_encoder_layers)
        params["enc_layers"] = jax.vmap(
            lambda k: init_block(k, cfg, moe=False, causal=False))(enc_keys)
        params["enc_final_norm"] = init_rmsnorm(cfg.d_model, dt)
    return params


# ==========================================================================
# block forward
# ==========================================================================

def block_forward(bp: dict, cfg: ModelConfig, x, positions, segments, *,
                  cache: Optional[dict] = None, cache_offset=None,
                  page_table=None, enc_out=None, enc_pos=None, enc_seg=None,
                  initial_ssm_state=None):
    """Returns (x_out, new_cache, aux_loss, final_ssm_state)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache: dict = {}
    final_state = None
    B, S, _ = x.shape
    decode = cache is not None and S == 1

    if cfg.family == "ssm":
        h = rmsnorm(bp["ln1"], x, cfg.norm_eps)
        if decode:
            out, nc = ssm_mixer_step(bp["ssm"], cfg, h, cache["ssm"])
        else:
            out, nc, final_state = ssm_mixer(
                bp["ssm"], cfg, h,
                cache=cache["ssm"] if cache is not None else None,
                initial_state=initial_ssm_state)
        if nc is not None:
            new_cache["ssm"] = nc
        x = constrain(x + out, "batch", "seq", None)
        return x, new_cache or None, aux, final_state

    h = rmsnorm(bp["ln1"], x, cfg.norm_eps)
    attn_out, kv_nc = attention(
        bp["attn"], cfg, h, positions, segments,
        cache=None if cache is None else cache["kv"],
        cache_offset=cache_offset, page_table=page_table)
    if kv_nc is not None:
        new_cache["kv"] = kv_nc

    if cfg.hybrid:
        if decode:
            ssm_out, ssm_nc = ssm_mixer_step(bp["ssm"], cfg, h, cache["ssm"])
        else:
            ssm_out, ssm_nc, final_state = ssm_mixer(
                bp["ssm"], cfg, h,
                cache=cache["ssm"] if cache is not None else None,
                initial_state=initial_ssm_state)
        if ssm_nc is not None:
            new_cache["ssm"] = ssm_nc
        mixed = 0.5 * (rmsnorm(bp["attn_out_norm"], attn_out, cfg.norm_eps)
                       + rmsnorm(bp["ssm_out_norm"], ssm_out, cfg.norm_eps))
        x = x + mixed
    else:
        x = x + attn_out

    if "cross_attn" in bp:
        hc = rmsnorm(bp["ln_cross"], x, cfg.norm_eps)
        x = x + _cross_attention(bp["cross_attn"], cfg, hc, enc_out)

    h2 = rmsnorm(bp["ln2"], x, cfg.norm_eps)
    if "moe" in bp:
        ffn_out, aux = moe_ffn(bp["moe"], cfg, h2)
    else:
        ffn_out = mlp(bp["mlp"], h2)
    x = x + ffn_out
    x = constrain(x, "batch", "seq", None)
    return x, new_cache or None, aux, final_state


def _cross_attention(params, cfg: ModelConfig, xq, enc_out):
    """Encoder-decoder cross attention (full, non-causal)."""
    B, S, _ = xq.shape
    Se = enc_out.shape[1]
    H, Hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dh->bsh", xq, params["wq"]).reshape(B, S, H, hd)
    k = jnp.einsum("bsd,dh->bsh", enc_out, params["wk"]).reshape(B, Se, Hkv, hd)
    v = jnp.einsum("bsd,dh->bsh", enc_out, params["wv"]).reshape(B, Se, Hkv, hd)
    # non-causal: all kv positions visible -> kv_pos=0, q_pos=0, segs 0
    zq = jnp.zeros((B, S), jnp.int32)
    zk = jnp.zeros((B, Se), jnp.int32)
    out = attn_mod.chunked_attention(q, k, v, zq, zk, zq, zk,
                                     chunk_size=cfg.attn_chunk_size)
    return jnp.einsum("bsh,hd->bsd", out.reshape(B, S, H * hd), params["wo"])


# ==========================================================================
# whole-model forward
# ==========================================================================

def encode(params: dict, cfg: ModelConfig, enc_embeds: jax.Array) -> jax.Array:
    """Bidirectional encoder over precomputed frame embeddings (stub
    frontend carve-out)."""
    B, Se, _ = enc_embeds.shape
    x = enc_embeds
    zpos = jnp.zeros((B, Se), jnp.int32)

    def body(x, lp):
        h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
        out = _cross_attention(lp["attn"], cfg, h, h)  # self, non-causal
        x = x + out
        h2 = rmsnorm(lp["ln2"], x, cfg.norm_eps)
        return x + mlp(lp["mlp"], h2), None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return rmsnorm(params["enc_final_norm"], x, cfg.norm_eps)


def forward_hidden(params: dict, cfg: ModelConfig, tokens: jax.Array, *,
                   positions=None, segments=None, vision_embeds=None,
                   enc_embeds=None, enc_out=None, caches=None,
                   cache_offset=None, page_table=None,
                   initial_ssm_states=None):
    """Token ids -> final hidden states.

    Returns (hidden (B, S, d), new_caches, aux_loss, final_ssm_states)."""
    B, S_tok = tokens.shape
    cdt = dtype_of(cfg.compute_dtype)
    x = embed(params["embed"], tokens, cdt)
    if vision_embeds is not None:
        x = jnp.concatenate([vision_embeds.astype(cdt), x], axis=1)
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    if segments is None:
        segments = jnp.zeros((B, S), jnp.int32)
    x = constrain(x, "batch", "seq", None)

    if cfg.is_encoder_decoder and enc_out is None:
        # decode steps pass a precomputed ``enc_out`` (engines cache encoder
        # states); prefill/train run the encoder here.
        assert enc_embeds is not None, "encoder-decoder model needs enc_embeds"
        enc_out = encode(params, cfg, enc_embeds)

    aux_total = jnp.zeros((), jnp.float32)

    # prelude (unstacked heterogeneous layers) -------------------------------
    n_pre = len(params.get("prelude", ()))
    pre_caches = caches.get("prelude") if caches else None
    new_pre_caches = []
    for i, bp in enumerate(params.get("prelude", ())):
        x, nc, aux, _ = block_forward(
            bp, cfg, x, positions, segments,
            cache=None if pre_caches is None else jax.tree.map(
                lambda a, i=i: a[i], pre_caches),
            cache_offset=cache_offset, page_table=page_table,
            enc_out=enc_out)
        aux_total = aux_total + aux
        if nc is not None:
            new_pre_caches.append(nc)

    # scanned body -------------------------------------------------------------
    body_caches = caches.get("layers") if caches else None
    new_body_caches, final_states = None, None

    def maybe_remat(fn):
        return jax.checkpoint(fn) if cfg.remat else fn

    if body_caches is None and initial_ssm_states is None:
        @maybe_remat
        def body_plain(carry, lp):
            x, aux_acc = carry
            x, _, aux, _ = block_forward(lp, cfg, x, positions, segments,
                                         enc_out=enc_out)
            return (x, aux_acc + aux), None
        (x, aux_total), _ = jax.lax.scan(body_plain, (x, aux_total),
                                         params["layers"])
    elif body_caches is not None:
        @maybe_remat
        def body_cached(carry, xs2):
            x, aux_acc = carry
            lp, lc = xs2
            x, nc, aux, fin = block_forward(
                lp, cfg, x, positions, segments, cache=lc,
                cache_offset=cache_offset, page_table=page_table,
                enc_out=enc_out)
            return (x, aux_acc + aux), (nc, fin)
        (x, aux_total), (new_body_caches, final_states) = jax.lax.scan(
            body_cached, (x, aux_total), (params["layers"], body_caches))
    else:  # initial SSM states only (prefix-state sharing / continuation)
        @maybe_remat
        def body_init(carry, xs2):
            x, aux_acc = carry
            lp, init_st = xs2
            x, _, aux, fin = block_forward(
                lp, cfg, x, positions, segments, enc_out=enc_out,
                initial_ssm_state=init_st)
            return (x, aux_acc + aux), fin
        (x, aux_total), final_states = jax.lax.scan(
            body_init, (x, aux_total), (params["layers"], initial_ssm_states))

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    new_caches = None
    if caches is not None:
        new_caches = {"layers": new_body_caches}
        if n_pre:
            new_caches["prelude"] = jax.tree.map(
                lambda *ls: jnp.stack(ls), *new_pre_caches) if new_pre_caches else None
    return x, new_caches, aux_total, final_states


def init_caches(params: dict, cfg: ModelConfig, batch: int, length: int, *,
                ring: bool = True, ring_slack: int = 0) -> dict:
    """Build per-layer decode caches, stacked over layers to match scan.

    Sliding-window configs get the ring-buffer backend sized to the window
    (``ring=True``, the decode default); ``ring=False`` forces a full
    ``length`` dense cache regardless — the paged engine's prompt prefill
    uses it so every prompt token's KV is addressable for the page splice
    (window masking still applies inside the attention).

    ``ring_slack`` widens the ring beyond the window: the spec-decode
    verify block writes up to k speculative tokens past the committed
    frontier, and on an exactly-window-sized ring those writes would evict
    entries the block's EARLIER queries can still see (q - pos < window).
    A ring of window + k + 1 slots keeps every in-window entry resident
    for the whole block; the window mask itself is position-driven and
    unchanged (DESIGN.md §Spec-decode)."""
    dt = dtype_of(cfg.compute_dtype)
    kv_len = (min(length, cfg.sliding_window + ring_slack)
              if cfg.sliding_window and ring else length)

    def one_layer(_):
        c = {}
        if cfg.family != "ssm":
            c["kv"] = make_cache(cfg, batch, kv_len, dt)
        if cfg.family == "ssm" or cfg.hybrid:
            c["ssm"] = make_ssm_cache(cfg, batch, dt)
        return c

    n_pre = len(params.get("prelude", ()))
    n_body = cfg.num_layers - n_pre
    caches = {"layers": jax.tree.map(
        lambda a: jnp.broadcast_to(a, (n_body,) + a.shape).copy(),
        one_layer(None))}
    if n_pre:
        caches["prelude"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_pre,) + a.shape).copy(),
            one_layer(None))
    return caches


def init_paged_caches(params: dict, cfg: ModelConfig, num_pages: int,
                      page_size: int) -> dict:
    """Per-layer paged pools (stacked over layers to match the body scan;
    the page table is shared across layers — every layer uses the same
    logical-to-physical page mapping, as in vLLM's block tables). GQA pools
    page per-head K/V rows; MLA pools page (ckv, kr) latent rows
    (DESIGN.md §Cache-backends)."""
    from repro.configs.base import require_engine_support
    require_engine_support(cfg, "paged")
    dt = dtype_of(cfg.compute_dtype)
    from repro.models.attention import make_paged_kv_cache
    one = {"kv": make_paged_kv_cache(cfg, num_pages, page_size, dt)}
    n_pre = len(params.get("prelude", ()))
    n_body = cfg.num_layers - n_pre
    caches = {"layers": jax.tree.map(
        lambda a: jnp.broadcast_to(a, (n_body,) + a.shape).copy(), one)}
    if n_pre:
        caches["prelude"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_pre,) + a.shape).copy(), one)
    return caches


def logits(params: dict, cfg: ModelConfig, hidden: jax.Array) -> jax.Array:
    return logits_from_hidden(params["embed"], cfg, hidden)
