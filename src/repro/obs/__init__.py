"""Observability plane: tracing + metrics + trace analyzer
(DESIGN.md §Observability).

Instrumentation sites import the tracing facade as::

    from repro.obs import trace as otrace

and call ``otrace.span(...)`` / ``otrace.complete(...)`` — near-zero
cost until ``otrace.install()`` activates a tracer. The obs-discipline
checker (``repro-check``) keys off the ``otrace`` alias; keep it.
"""
from repro.obs.metrics import MetricsRegistry, metrics
from repro.obs.trace import Tracer

__all__ = ["MetricsRegistry", "Tracer", "metrics"]
