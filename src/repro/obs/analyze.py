"""Bubble/overlap analyzer over exported traces (DESIGN.md §Observability).

Ingests a Chrome trace-event JSON produced by :mod:`repro.obs.trace` and
derives, per scheduler iteration:

* ``infer_time`` / ``train_time`` / ``sync_gap`` — reproduced from spans
  alone (cross-checked against ``IterationStats`` in tests: the spans
  reuse the pipeline's own clock reads, so the numbers agree to within
  tolerance, not by construction-from-the-same-variable).
* ``bubble_fraction`` — mean stage-idle fraction over the iteration:
  ``1 - (|P| + |C|) / (2 * wall)`` where ``P`` is the union of producer
  busy intervals (any instance busy) and ``C`` the union of consumer
  (train) intervals, both clipped to the iteration window. A perfectly
  serial sync iteration scores 0.5 (each stage idles while the other
  works); a perfectly overlapped async iteration with balanced stages
  scores ~0.
* ``overlap_efficiency`` — ``|P ∩ C| / min(|P|, |C|)``: how much of the
  smaller stage is hidden under the larger one (sync ≈ 0, async → 1).

Serving traces additionally yield TTFT/TPOT percentiles from request
lifecycle events (``request`` begin/end + ``request.token`` instants),
comparable to ``launch/serve.py``'s ``compute_latency_metrics``.

Event names consumed (the span taxonomy is documented in DESIGN.md):
``iteration``, ``producer.busy`` (attr ``busy`` = charged seconds),
``train.group``, ``train.update``, ``transfer.ensure`` (attr ``gap``),
``request`` (args ``rid``/``arrival``/``submit``), ``request.token``.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional, Sequence, Tuple

Interval = Tuple[float, float]

_CONSUMER_SPANS = ("train.group", "train.update")


def _load_jsonl(path: str, final_segment: bool) -> List[dict]:
    """One JSONL trace segment. A crash can truncate the LAST line of the
    last segment mid-write; tolerate exactly that (drop it) and treat a
    malformed line anywhere else as corruption."""
    out: List[dict] = []
    with open(path) as f:
        lines = f.readlines()
    for i, line in enumerate(lines):
        line = line.strip()
        if not line:
            continue
        try:
            out.append(json.loads(line))
        except json.JSONDecodeError:
            if final_segment and i == len(lines) - 1:
                break
            raise
    return out


def load_trace(path: str) -> List[dict]:
    """Read a trace from any of its export formats into one event list:

    * a monolithic Chrome-JSON file (``{"traceEvents": [...]}``),
    * a single ``.jsonl`` segment, or
    * a directory of rotating ``trace-NNNN.jsonl`` segments (streaming
      export), merged in segment order and re-sorted by timestamp so the
      result is indistinguishable from the monolithic export.
    """
    if os.path.isdir(path):
        segs = sorted(glob.glob(os.path.join(path, "trace-*.jsonl")))
        if not segs:
            raise FileNotFoundError(f"no trace-*.jsonl segments in {path}")
        events: List[dict] = []
        for i, seg in enumerate(segs):
            events.extend(_load_jsonl(seg, final_segment=(i == len(segs) - 1)))
        events.sort(key=lambda e: e.get("ts", 0.0))
        return events
    if path.endswith(".jsonl"):
        events = _load_jsonl(path, final_segment=True)
        events.sort(key=lambda e: e.get("ts", 0.0))
        return events
    with open(path) as f:
        doc = json.load(f)
    return doc["traceEvents"] if isinstance(doc, dict) else doc


def _merge(intervals: Sequence[Interval]) -> List[Interval]:
    """Union of intervals as a sorted disjoint list."""
    out: List[Interval] = []
    for lo, hi in sorted(i for i in intervals if i[1] > i[0]):
        if out and lo <= out[-1][1]:
            if hi > out[-1][1]:
                out[-1] = (out[-1][0], hi)
        else:
            out.append((lo, hi))
    return out


def _total(intervals: Sequence[Interval]) -> float:
    return sum(hi - lo for lo, hi in intervals)


def _clip(intervals: Sequence[Interval], lo: float, hi: float) -> List[Interval]:
    return [(max(a, lo), min(b, hi))
            for a, b in intervals if b > lo and a < hi]


def _intersect(a: Sequence[Interval], b: Sequence[Interval]) -> List[Interval]:
    """Intersection of two disjoint sorted interval lists."""
    out: List[Interval] = []
    i = j = 0
    while i < len(a) and j < len(b):
        lo = max(a[i][0], b[j][0])
        hi = min(a[i][1], b[j][1])
        if hi > lo:
            out.append((lo, hi))
        if a[i][1] < b[j][1]:
            i += 1
        else:
            j += 1
    return out


def _x_events(events: Sequence[dict], name: str) -> List[dict]:
    return [e for e in events
            if e.get("ph") == "X" and e.get("name") == name]


def _span_interval(e: dict) -> Interval:
    return (e["ts"] / 1e6, (e["ts"] + e.get("dur", 0.0)) / 1e6)


def _mid(e: dict) -> float:
    return (e["ts"] + e.get("dur", 0.0) / 2.0) / 1e6


def analyze_iterations(events: Sequence[dict]) -> List[dict]:
    iters = sorted(_x_events(events, "iteration"), key=lambda e: e["ts"])
    producers = _x_events(events, "producer.busy")
    consumers = [e for n in _CONSUMER_SPANS for e in _x_events(events, n)]
    ensures = _x_events(events, "transfer.ensure")

    rows: List[dict] = []
    for it in iters:
        lo, hi = _span_interval(it)
        wall = hi - lo
        if wall <= 0:
            continue
        # events belong to the iteration containing their midpoint;
        # intervals are clipped to the window for occupancy math
        pev = [e for e in producers if lo <= _mid(e) < hi]
        cev = [e for e in consumers if lo <= _mid(e) < hi]
        gaps = [e for e in ensures if lo <= _mid(e) < hi]
        p_union = _merge(_clip([_span_interval(e) for e in pev], lo, hi))
        c_union = _merge(_clip([_span_interval(e) for e in cev], lo, hi))
        p_occ = _total(p_union)
        c_occ = _total(c_union)
        overlap = _total(_intersect(p_union, c_union))
        # infer_time sums the *charged* busy seconds (attr set by the
        # deferred clock), which for the paged path differs from the
        # span's wall extent (the drive loop waits on the engine lock)
        infer = sum(e.get("args", {}).get("busy",
                                          e.get("dur", 0.0) / 1e6)
                    for e in pev)
        train = sum(e.get("dur", 0.0) for e in cev) / 1e6
        sync_gap = sum(e.get("args", {}).get("gap", e.get("dur", 0.0) / 1e6)
                       for e in gaps)
        denom = min(p_occ, c_occ)
        rows.append({
            "iteration": it.get("args", {}).get("iteration"),
            "mode": it.get("args", {}).get("mode"),
            "wall_s": wall,
            "infer_time_s": infer,
            "train_time_s": train,
            "sync_gap_s": sync_gap,
            "producer_occupancy_s": p_occ,
            "consumer_occupancy_s": c_occ,
            "overlap_s": overlap,
            "bubble_fraction": 1.0 - (p_occ + c_occ) / (2.0 * wall),
            "overlap_efficiency": (overlap / denom) if denom > 0 else 0.0,
        })
    return rows


def analyze_serving(events: Sequence[dict]) -> Optional[dict]:
    begins = {e["args"]["rid"]: e for e in events
              if e.get("ph") == "b" and e.get("name") == "request"
              and "rid" in e.get("args", {})}
    if not begins:
        return None
    tokens: Dict[object, List[float]] = {}
    for e in events:
        if e.get("ph") == "i" and e.get("name") == "request.token":
            rid = e.get("args", {}).get("rid")
            tokens.setdefault(rid, []).append(e["ts"] / 1e6)
    ttfts: List[float] = []
    tpots: List[float] = []
    for rid, b in begins.items():
        ts = sorted(tokens.get(rid, []))
        if not ts:
            continue
        args = b.get("args", {})
        # the begin event fires at submit; walk it back to the request's
        # open-loop arrival using the driver-clock offsets it carries, so
        # TTFT includes queueing delay exactly as ServedRequest.ttft does
        queue_wait = args.get("submit", 0.0) - args.get("arrival", 0.0)
        arrival_ts = b["ts"] / 1e6 - queue_wait
        ttfts.append(ts[0] - arrival_ts)
        if len(ts) > 1:
            tpots.append((ts[-1] - ts[0]) / (len(ts) - 1))
    if not ttfts:
        return None

    def pct(vals: List[float], q: float) -> float:
        s = sorted(vals)
        return s[min(len(s) - 1, int(q * len(s)))]

    out = {"num_requests": len(ttfts),
           "ttft_p50_s": pct(ttfts, 0.50), "ttft_p99_s": pct(ttfts, 0.99),
           "ttft_mean_s": sum(ttfts) / len(ttfts)}
    if tpots:
        out.update({"tpot_p50_s": pct(tpots, 0.50),
                    "tpot_p99_s": pct(tpots, 0.99)})
    return out


def analyze(events: Sequence[dict]) -> dict:
    rows = analyze_iterations(events)
    report: dict = {"iterations": rows}
    if rows:
        n = len(rows)
        report["summary"] = {
            "iterations": n,
            "mode": rows[0]["mode"],
            "wall_s": sum(r["wall_s"] for r in rows),
            "infer_time_s": sum(r["infer_time_s"] for r in rows),
            "train_time_s": sum(r["train_time_s"] for r in rows),
            "sync_gap_s": sum(r["sync_gap_s"] for r in rows),
            "bubble_fraction":
                sum(r["bubble_fraction"] for r in rows) / n,
            "overlap_efficiency":
                sum(r["overlap_efficiency"] for r in rows) / n,
        }
    serving = analyze_serving(events)
    if serving is not None:
        report["serving"] = serving
    return report


def analyze_file(path: str) -> dict:
    return analyze(load_trace(path))


def render(report: dict) -> str:
    lines: List[str] = []
    rows = report.get("iterations", [])
    if rows:
        lines.append("iter  wall(s)  infer(s)  train(s)  gap(ms)  "
                     "bubble  overlap")
        for r in rows:
            lines.append(
                f"{str(r['iteration']):>4}  {r['wall_s']:7.3f}  "
                f"{r['infer_time_s']:8.3f}  {r['train_time_s']:8.3f}  "
                f"{r['sync_gap_s'] * 1e3:7.1f}  "
                f"{r['bubble_fraction']:6.3f}  "
                f"{r['overlap_efficiency']:7.3f}")
        s = report["summary"]
        lines.append(
            f"mean[mode={s['mode']}]: bubble={s['bubble_fraction']:.3f} "
            f"overlap={s['overlap_efficiency']:.3f} "
            f"infer={s['infer_time_s']:.3f}s train={s['train_time_s']:.3f}s "
            f"gap={s['sync_gap_s'] * 1e3:.1f}ms")
    serving = report.get("serving")
    if serving:
        lines.append(
            f"serving: n={serving['num_requests']} "
            f"ttft_p50={serving['ttft_p50_s'] * 1e3:.1f}ms "
            f"ttft_p99={serving['ttft_p99_s'] * 1e3:.1f}ms"
            + (f" tpot_p50={serving['tpot_p50_s'] * 1e3:.2f}ms"
               if "tpot_p50_s" in serving else ""))
    if not lines:
        lines.append("trace contains no iteration or serving events")
    return "\n".join(lines)
