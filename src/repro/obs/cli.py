"""``repro-trace`` — bubble/overlap reports over exported traces.

    repro-trace report trace.json [--json out.json]
    repro-trace report trace_dir/            # streaming JSONL segments
    repro-trace compare sync.json async_dir/

``report`` prints the per-iteration bubble/overlap table (and serving
latency percentiles when request events are present). ``compare``
asserts the paper's timeline claim on two traces of the same workload:
the async trace's mean bubble fraction must be strictly below the sync
trace's (exit 1 otherwise) — CI runs it on the smoke traces.

Every trace argument accepts a monolithic Chrome-JSON file, a single
``.jsonl`` segment, or a directory of ``trace-NNNN.jsonl`` segments from
the streaming exporter — the report is identical across formats.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.obs.analyze import analyze_file, render


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro-trace")
    sub = ap.add_subparsers(dest="cmd", required=True)

    rep = sub.add_parser("report", help="per-iteration bubble/overlap table")
    rep.add_argument("trace")
    rep.add_argument("--json", dest="json_out", default=None,
                     help="also write the full report as JSON")

    cmp_ = sub.add_parser(
        "compare", help="assert bubble(async) < bubble(sync)")
    cmp_.add_argument("sync_trace")
    cmp_.add_argument("async_trace")

    args = ap.parse_args(argv)

    if args.cmd == "report":
        report = analyze_file(args.trace)
        print(render(report))
        if args.json_out:
            with open(args.json_out, "w") as f:
                json.dump(report, f, indent=1)
        return 0

    sync_rep = analyze_file(args.sync_trace)
    async_rep = analyze_file(args.async_trace)
    try:
        bs = sync_rep["summary"]["bubble_fraction"]
        ba = async_rep["summary"]["bubble_fraction"]
    except KeyError:
        print("compare: traces missing iteration events", file=sys.stderr)
        return 1
    print(f"bubble sync={bs:.3f} async={ba:.3f}")
    if not ba < bs:
        print("FAIL: async bubble fraction is not below sync",
              file=sys.stderr)
        return 1
    print("OK: async bubble fraction strictly below sync")
    return 0


if __name__ == "__main__":
    sys.exit(main())
