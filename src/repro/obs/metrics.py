"""Metrics registry (DESIGN.md §Observability).

A process-wide registry of named counters, gauges, and histograms fed by
the engines and the weight-plane — the scalar complement to the trace
timeline: spec acceptance, prefix hit/miss/evict, pages live/reclaimed,
drain blocks, wire bytes per bucket.

Hot-tier discipline: call sites cache the metric object once (engine
``__init__``) and update it at *block* granularity (per drain block, per
bucket), never per token — each update is one small-lock add, always on,
cheap enough to leave enabled (the <2% disabled-overhead budget is
measured by table10).

Memory discipline: every metric is O(1) in the number of observations.
Histograms bucket into a FIXED boundary ladder (log-spaced 1-2.5-5 per
decade, spanning microseconds to gigabytes) and keep only per-bucket
counts plus exact count/sum/min/max — a multi-hour run observing one
TTFT per request holds the same few hundred bytes as a ten-second one.
``summary()`` percentiles are therefore *estimates*, linearly
interpolated inside the containing bucket; the error is bounded by one
bucket width (≤ 2.5x), which tests pin against the exact computation on
small samples. The bucket ladder doubles as the Prometheus histogram
exposition (``/metrics``; obs/server.py).
"""
from __future__ import annotations

import bisect
import threading
from typing import Dict, List, Sequence, Tuple, Union


class Counter:
    """Monotonic accumulator."""
    __slots__ = ("_lock", "_value")

    def __init__(self):
        self._lock = threading.Lock()
        self._value = 0.0

    def add(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Gauge:
    """Last-write-wins instantaneous value."""
    __slots__ = ("_lock", "_value")

    def __init__(self):
        self._lock = threading.Lock()
        self._value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self._value = v

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


def _default_bounds() -> Tuple[float, ...]:
    """1-2.5-5 ladder per decade, 1e-6 .. 1e9: wide enough for seconds
    (TTFT ~1e-3..1e2) and bytes (buckets ~1e6) on one fixed grid."""
    out: List[float] = []
    for e in range(-6, 10):
        for m in (1.0, 2.5, 5.0):
            out.append(m * 10.0 ** e)
    return tuple(out)


class Histogram:
    """Fixed-bucket histogram: O(buckets) memory regardless of how many
    values are observed (an unbounded per-observation list would retain
    every TTFT of a multi-hour serving run). Tracks exact
    count/sum/min/max; ``summary()`` percentiles interpolate within the
    containing bucket (error ≤ one bucket width)."""
    __slots__ = ("_lock", "_bounds", "_counts", "_count", "_sum",
                 "_min", "_max")

    def __init__(self, bounds: Sequence[float] = ()):
        self._lock = threading.Lock()
        self._bounds = tuple(bounds) or _default_bounds()
        assert list(self._bounds) == sorted(self._bounds), \
            "histogram bucket bounds must be sorted"
        # counts[i] = observations with value <= bounds[i] (non-cumulative
        # per-bucket here; cumulated on read); counts[-1] = overflow (+Inf)
        self._counts = [0] * (len(self._bounds) + 1)
        self._count = 0
        self._sum = 0.0
        self._min = None
        self._max = None

    def observe(self, v: float) -> None:
        i = bisect.bisect_left(self._bounds, v)
        with self._lock:
            self._counts[i] += 1
            self._count += 1
            self._sum += v
            if self._min is None or v < self._min:
                self._min = v
            if self._max is None or v > self._max:
                self._max = v

    def _quantile_locked(self, q: float) -> float:
        """Value at quantile ``q`` estimated from the bucket CDF: linear
        interpolation between the containing bucket's edges, clamped to
        the exact observed min/max (so degenerate single-bucket samples
        report sane numbers)."""
        rank = q * (self._count - 1) if self._count > 1 else 0.0
        seen = 0.0
        for i, c in enumerate(self._counts):
            if c == 0:
                continue
            if seen + c > rank:
                lo = self._bounds[i - 1] if i > 0 else self._min
                hi = self._bounds[i] if i < len(self._bounds) else self._max
                frac = (rank - seen) / c
                est = lo + (hi - lo) * frac
                return float(min(max(est, self._min), self._max))
            seen += c
        return float(self._max)

    def summary(self) -> Dict[str, float]:
        with self._lock:
            if not self._count:
                return {"count": 0, "sum": 0.0}
            return {"count": self._count, "sum": self._sum,
                    "min": self._min, "max": self._max,
                    "p50": self._quantile_locked(0.50),
                    "p99": self._quantile_locked(0.99)}

    def buckets(self) -> Tuple[Tuple[float, ...], List[int], int, float]:
        """(bounds, CUMULATIVE counts per bound + +Inf, count, sum) in one
        lock hold — the Prometheus histogram exposition (obs/server.py):
        ``le`` labels are the bounds, the final cumulative count equals
        ``count`` by construction, so a scrape can never tear."""
        with self._lock:
            cum: List[int] = []
            run = 0
            for c in self._counts:
                run += c
                cum.append(run)
            return self._bounds, cum, self._count, self._sum


Metric = Union[Counter, Gauge, Histogram]


class MetricsRegistry:
    """Named get-or-create metric store. Creation takes the registry
    lock; updates only take the metric's own lock."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[str, Metric] = {}

    def _get(self, name: str, cls):
        m = self._metrics.get(name)
        if m is None:
            with self._lock:
                m = self._metrics.setdefault(name, cls())
        assert isinstance(m, cls), \
            f"metric {name!r} already registered as {type(m).__name__}"
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def collect(self) -> List[Tuple[str, Metric]]:
        """Stable-ordered (name, metric) pairs — the scrape path; values
        are read per metric by the renderer, each under its own lock."""
        with self._lock:
            return sorted(self._metrics.items())

    def snapshot(self) -> Dict[str, object]:
        out: Dict[str, object] = {}
        for name, m in self.collect():
            out[name] = m.summary() if isinstance(m, Histogram) else m.value
        return out

    def reset(self) -> None:
        with self._lock:
            self._metrics.clear()


_default = MetricsRegistry()


def metrics() -> MetricsRegistry:
    """The process-wide default registry."""
    return _default
