"""Metrics registry (DESIGN.md §Observability).

A process-wide registry of named counters, gauges, and histograms fed by
the engines and the weight-plane — the scalar complement to the trace
timeline: spec acceptance, prefix hit/miss/evict, pages live/reclaimed,
drain blocks, wire bytes per bucket.

Hot-tier discipline: call sites cache the metric object once (engine
``__init__``) and update it at *block* granularity (per drain block, per
bucket), never per token — each update is one small-lock add, always on,
cheap enough to leave enabled (the <2% disabled-overhead budget is
measured by table10).
"""
from __future__ import annotations

import threading
from typing import Dict, List, Union


class Counter:
    """Monotonic accumulator."""
    __slots__ = ("_lock", "_value")

    def __init__(self):
        self._lock = threading.Lock()
        self._value = 0.0

    def add(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Gauge:
    """Last-write-wins instantaneous value."""
    __slots__ = ("_lock", "_value")

    def __init__(self):
        self._lock = threading.Lock()
        self._value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self._value = v

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Histogram:
    """Stores observations; snapshot() summarises count/sum/min/max and
    p50/p99 (exact — sample volume here is per-bucket / per-block, not
    per-token, so keeping the values is fine)."""
    __slots__ = ("_lock", "_values")

    def __init__(self):
        self._lock = threading.Lock()
        self._values: List[float] = []

    def observe(self, v: float) -> None:
        with self._lock:
            self._values.append(v)

    def summary(self) -> Dict[str, float]:
        with self._lock:
            vals = sorted(self._values)
        if not vals:
            return {"count": 0, "sum": 0.0}
        n = len(vals)
        return {"count": n, "sum": sum(vals), "min": vals[0],
                "max": vals[-1], "p50": vals[n // 2],
                "p99": vals[min(n - 1, int(n * 0.99))]}


Metric = Union[Counter, Gauge, Histogram]


class MetricsRegistry:
    """Named get-or-create metric store. Creation takes the registry
    lock; updates only take the metric's own lock."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[str, Metric] = {}

    def _get(self, name: str, cls):
        m = self._metrics.get(name)
        if m is None:
            with self._lock:
                m = self._metrics.setdefault(name, cls())
        assert isinstance(m, cls), \
            f"metric {name!r} already registered as {type(m).__name__}"
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def snapshot(self) -> Dict[str, object]:
        with self._lock:
            items = list(self._metrics.items())
        out: Dict[str, object] = {}
        for name, m in items:
            out[name] = m.summary() if isinstance(m, Histogram) else m.value
        return out

    def reset(self) -> None:
        with self._lock:
            self._metrics.clear()


_default = MetricsRegistry()


def metrics() -> MetricsRegistry:
    """The process-wide default registry."""
    return _default
