"""Live ops plane: socket serving front-end + scrape endpoints
(DESIGN.md §Observability).

Everything before this module is post-mortem — traces export at exit,
metrics are readable only in-process. `OpsServer` turns the pipeline
into something an operator can watch *while it runs*, stdlib-only
(``http.server`` threads, no new deps):

* ``POST /v1/generate`` — accepts a generation request from a real
  socket and streams committed tokens back as Server-Sent Events, riding
  the paged engine's ``submit(on_token=...)`` hook. The handler thread
  submits; a single driver thread steps the engine (``submit``/``step``
  are engine-mutex-safe, the same convoy contract the inference pool
  uses). Per-request keys are ``fold_in(key, rid)`` — the identical
  scheduling-order-invariant derivation as the in-process
  ``RequestDriver``, so a socket-served request is bitwise-identical to
  the driver path (asserted server-side against ``host_rows`` on every
  request, and cross-checked in tests/benchmarks).
* ``GET /metrics`` — the `MetricsRegistry` in Prometheus text format
  0.0.4. Every sample is read under its own metric lock and histograms
  snapshot cumulatively in one hold (`Histogram.buckets`), so a mid-run
  scrape can never tear: counters are monotone across scrapes and
  ``_bucket{le="+Inf"} == _count`` within one.
* ``GET /healthz`` / ``GET /status`` — liveness + a JSON introspection
  snapshot: server counters, engine pool occupancy
  (`PagedGroupEngine.status_snapshot`, one mutex hold), pipeline state
  via an injected ``status_fn`` (`PeriodicAsyncScheduler.status`), and
  an *online* bubble fraction computed incrementally from recent spans
  by `OnlineBubble` (a tracer listener over a bounded window) instead of
  a post-hoc full-trace walk.

Thread shape (lock-discipline checked; this module is in
THREADED_MODULES): `ThreadingHTTPServer` gives one thread per
connection; `OpsServer` owns ``_lock`` guarding its request counters and
lifecycle flag; the driver thread polls them briefly and never holds the
lock across an engine step. The HTTP handler class keeps no shared
state of its own — everything cross-thread goes through `OpsServer`
public methods.

``python -m repro.obs.server --smoke`` boots a tiny engine + server,
scrapes itself, runs one SSE request end-to-end, and exits nonzero on
any failure — the CI gate.
"""
from __future__ import annotations

import json
import queue
import re
import threading
import time
from collections import deque
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.obs import trace as otrace
from repro.obs.analyze import _clip, _intersect, _merge, _total
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               metrics)

# =========================================================================
# Prometheus text exposition (format 0.0.4)
# =========================================================================

_NAME_SANITIZE = re.compile(r"[^a-zA-Z0-9_]")


def _prom_name(name: str) -> str:
    """Registry name -> Prometheus metric name: ``paged.pages_live`` ->
    ``repro_paged_pages_live`` (namespaced, dots to underscores)."""
    return "repro_" + _NAME_SANITIZE.sub("_", name)


def _fmt(v: float) -> str:
    if isinstance(v, float) and v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return format(v, ".10g")


def render_prometheus(reg: Optional[MetricsRegistry] = None) -> str:
    """The registry as Prometheus text. Counters get the ``_total``
    suffix; histograms expose the cumulative bucket ladder (sparse:
    only bounds where the CDF moves, plus ``+Inf``), ``_sum`` and
    ``_count`` — all from one `Histogram.buckets` lock hold, so the
    family is internally consistent even mid-``observe``."""
    reg = reg if reg is not None else metrics()
    lines: List[str] = []
    for name, m in reg.collect():
        base = _prom_name(name)
        if isinstance(m, Counter):
            lines.append(f"# TYPE {base}_total counter")
            lines.append(f"{base}_total {_fmt(m.value)}")
        elif isinstance(m, Gauge):
            lines.append(f"# TYPE {base} gauge")
            lines.append(f"{base} {_fmt(m.value)}")
        elif isinstance(m, Histogram):
            bounds, cum, count, total = m.buckets()
            lines.append(f"# TYPE {base} histogram")
            prev = 0
            for b, c in zip(bounds, cum):
                if c != prev:
                    lines.append(f'{base}_bucket{{le="{_fmt(b)}"}} {c}')
                    prev = c
            lines.append(f'{base}_bucket{{le="+Inf"}} {count}')
            lines.append(f"{base}_sum {_fmt(total)}")
            lines.append(f"{base}_count {count}")
    return "\n".join(lines) + "\n"


_SAMPLE_RE = re.compile(
    r'^([a-zA-Z_:][a-zA-Z0-9_:]*)(\{[^{}]*\})?'
    r'\s+(-?(?:[0-9]*\.?[0-9]+(?:[eE][+-]?[0-9]+)?|Inf)|NaN|\+Inf)$')
_LE_RE = re.compile(r'le="([^"]+)"')


def parse_prometheus_text(text: str) -> Dict[str, float]:
    """Validating parser for the exposition above — the well-formedness
    gate tests and CI scrape through. Checks: every sample line matches
    the grammar, every sample's family has a preceding ``# TYPE``,
    histogram buckets are cumulative (non-decreasing in ``le`` order)
    and ``+Inf`` equals ``_count``. Returns ``{name+labels: value}``;
    raises ``ValueError`` on any violation."""
    types: Dict[str, str] = {}
    samples: Dict[str, float] = {}
    hist_buckets: Dict[str, List[Tuple[float, float]]] = {}
    for lineno, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        if line.startswith("# TYPE "):
            parts = line.split()
            if len(parts) != 4 or parts[3] not in (
                    "counter", "gauge", "histogram"):
                raise ValueError(f"line {lineno}: malformed TYPE: {line!r}")
            types[parts[2]] = parts[3]
            continue
        if line.startswith("#"):
            continue
        m = _SAMPLE_RE.match(line)
        if m is None:
            raise ValueError(f"line {lineno}: malformed sample: {line!r}")
        name, labels, val = m.group(1), m.group(2) or "", float(m.group(3))
        family = name
        for suffix in ("_bucket", "_sum", "_count"):
            if name.endswith(suffix) and name[:-len(suffix)] in types:
                family = name[:-len(suffix)]
                break
        if family not in types:
            raise ValueError(
                f"line {lineno}: sample {name!r} has no # TYPE declaration")
        if types[family] == "histogram" and name == family + "_bucket":
            le = _LE_RE.search(labels)
            if le is None:
                raise ValueError(f"line {lineno}: bucket without le label")
            bound = float("inf") if le.group(1) == "+Inf" \
                else float(le.group(1))
            hist_buckets.setdefault(family, []).append((bound, val))
        key = name + labels
        if key in samples:
            raise ValueError(f"line {lineno}: duplicate sample {key!r}")
        samples[key] = val
    for family, buckets in hist_buckets.items():
        in_order = sorted(buckets)
        if [v for _, v in in_order] != sorted(v for _, v in buckets):
            raise ValueError(f"{family}: bucket counts not cumulative")
        if not in_order or in_order[-1][0] != float("inf"):
            raise ValueError(f"{family}: missing le=+Inf bucket")
        count = samples.get(family + "_count")
        if count is None or in_order[-1][1] != count:
            raise ValueError(
                f"{family}: +Inf bucket {in_order[-1][1]} != _count {count}")
        if family + "_sum" not in samples:
            raise ValueError(f"{family}: missing _sum")
    return samples


# =========================================================================
# Online bubble: incremental stage-occupancy over a sliding window
# =========================================================================

class OnlineBubble:
    """Tracer listener that maintains the bubble/overlap estimate of
    `obs.analyze` *incrementally*: producer/consumer spans land in
    bounded deques at emit time; `value()` merges only the spans inside
    the trailing ``window_s`` — O(window), no full-trace walk, callable
    at any point mid-run from the ``/status`` handler."""

    _PRODUCER = ("producer.busy",)
    _CONSUMER = ("train.group", "train.update")

    def __init__(self, window_s: float = 30.0, max_spans: int = 4096):
        self.window_s = window_s
        self._lock = threading.Lock()
        self._p: deque = deque(maxlen=max_spans)
        self._c: deque = deque(maxlen=max_spans)
        self._tmax: Optional[float] = None

    def on_event(self, ev: tuple) -> None:
        """Raw-event-tuple hook (`Tracer.add_listener`); called from the
        emitting thread, so only the deque append happens here."""
        ph, name, ts_us, x = ev[0], ev[1], ev[2], ev[3]
        if ph != "X":
            return
        if name in self._PRODUCER:
            kind = "p"
        elif name in self._CONSUMER:
            kind = "c"
        else:
            return
        lo, hi = ts_us / 1e6, (ts_us + x) / 1e6
        with self._lock:
            (self._p if kind == "p" else self._c).append((lo, hi))
            if self._tmax is None or hi > self._tmax:
                self._tmax = hi

    def value(self) -> Optional[dict]:
        with self._lock:
            if self._tmax is None:
                return None
            p, c, tmax = list(self._p), list(self._c), self._tmax
        starts = [lo for lo, _ in p] + [lo for lo, _ in c]
        lo = max(tmax - self.window_s, min(starts))
        wall = tmax - lo
        if wall <= 0:
            return None
        p_u = _merge(_clip(p, lo, tmax))
        c_u = _merge(_clip(c, lo, tmax))
        p_occ, c_occ = _total(p_u), _total(c_u)
        overlap = _total(_intersect(p_u, c_u))
        denom = min(p_occ, c_occ)
        return {"window_s": wall,
                "producer_busy_s": p_occ,
                "consumer_busy_s": c_occ,
                "bubble_fraction": 1.0 - (p_occ + c_occ) / (2.0 * wall),
                "overlap_efficiency": overlap / denom if denom > 0 else 0.0}


# =========================================================================
# HTTP front-end
# =========================================================================

class _Handler(BaseHTTPRequestHandler):
    """One instance per connection (ThreadingHTTPServer). Keeps no
    cross-request state — all shared mutation goes through `OpsServer`
    public methods, which own the lock."""

    server_version = "repro-ops/1.0"
    protocol_version = "HTTP/1.0"   # connection-close delimits the stream

    def log_message(self, fmt, *args):  # quiet: the server is scrapeable
        pass

    @property
    def ops(self) -> "OpsServer":
        return self.server.ops  # type: ignore[attr-defined]

    def _send_text(self, code: int, body: str,
                   ctype: str = "text/plain; charset=utf-8") -> None:
        data = body.encode()
        self.send_response(code)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(data)))
        self.end_headers()
        self.wfile.write(data)

    def do_GET(self):
        if self.path == "/healthz":
            self._send_text(200, "ok\n")
        elif self.path == "/metrics":
            self._send_text(200, render_prometheus(self.ops.registry),
                            "text/plain; version=0.0.4; charset=utf-8")
        elif self.path == "/status":
            self._send_text(200, json.dumps(self.ops.status(), indent=1,
                                            default=str) + "\n",
                            "application/json")
        else:
            self._send_text(404, "not found\n")

    def do_POST(self):
        if self.path != "/v1/generate":
            self._send_text(404, "not found\n")
            return
        ops = self.ops
        if ops.eng is None:
            self._send_text(503, "no engine attached\n")
            return
        try:
            n = int(self.headers.get("Content-Length") or 0)
            req = json.loads(self.rfile.read(n) or b"{}")
        except (ValueError, json.JSONDecodeError):
            self._send_text(400, "bad json\n")
            return
        prompt = req.get("prompt")
        if not isinstance(prompt, list) or not prompt or \
                not all(isinstance(t, int) for t in prompt):
            self._send_text(400, "prompt must be a non-empty int list\n")
            return
        rid = int(req["rid"]) if "rid" in req else ops.alloc_rid()
        max_new = int(req["max_new"]) if "max_new" in req else None

        import jax
        import numpy as np
        q: "queue.Queue[int]" = queue.Queue()
        t_submit = time.time()
        # arrival == submit: a socket request has no open-loop queue model
        otrace.begin("request", uid=rid, rid=rid,
                     arrival=t_submit, submit=t_submit)
        try:
            handle = ops.eng.submit(
                np.asarray(prompt, np.int32),
                jax.random.fold_in(ops.key, rid), max_new=max_new,
                on_token=lambda row, tok: q.put(int(tok)))
        except Exception as e:  # inadmissible prompt etc.
            otrace.end("request", uid=rid, rid=rid, error=str(e))
            self._send_text(400, f"submit rejected: {e}\n")
            return
        ops.request_started()
        try:
            self.send_response(200)
            self.send_header("Content-Type", "text/event-stream")
            self.send_header("Cache-Control", "no-cache")
            self.send_header("Connection", "close")
            self.end_headers()
            streamed: List[int] = []
            first_t: Optional[float] = None
            deadline = time.time() + ops.request_timeout_s
            timed_out = False
            while True:
                try:
                    tok = q.get(timeout=0.05)
                except queue.Empty:
                    if handle.done() and q.empty():
                        break
                    if time.time() > deadline:
                        timed_out = True
                        break
                    continue
                if first_t is None:
                    first_t = time.time()
                streamed.append(tok)
                otrace.instant("request.token", rid=rid)
                self.wfile.write(
                    f"data: {json.dumps({'token': tok})}\n\n".encode())
                self.wfile.flush()
            if timed_out:
                otrace.end("request", uid=rid, rid=rid, error="timeout")
                self.wfile.write(
                    b'event: error\ndata: {"error": "timeout"}\n\n')
                return
            # bitwise-identity proof, per request: the streamed token ids
            # must equal the engine's committed host rows exactly — the
            # same assertion RequestDriver makes on the in-process path
            final = handle.host_rows()[0].tolist()
            verified = streamed == final
            otrace.end("request", uid=rid, rid=rid, num_tokens=len(streamed))
            if first_t is not None:
                ops.ttft_hist.observe(first_t - t_submit)
            ops.tokens_counter.add(len(streamed))
            done = {"num_tokens": len(streamed), "verified": verified}
            self.wfile.write(
                f"event: done\ndata: {json.dumps(done)}\n\n".encode())
        except BrokenPipeError:
            pass  # client went away mid-stream; the engine finishes alone
        finally:
            ops.request_finished()


class OpsServer:
    """The live ops front-end. ``engine`` (optional) must be a paged
    engine with ``group_size == 1`` (the serving shape); without one the
    server still exposes ``/metrics``/``/healthz``/``/status`` — the
    metrics-only mode ``launch/train.py --metrics-port`` uses.

    ``status_fn`` is merged into ``/status`` under ``"pipeline"``; each
    contributor (engine, scheduler) snapshots its fields atomically
    under its own lock, so no multi-field view can tear."""

    def __init__(self, *, engine=None, key=None,
                 status_fn: Optional[Callable[[], dict]] = None,
                 registry: Optional[MetricsRegistry] = None,
                 host: str = "127.0.0.1", port: int = 0,
                 window_s: float = 30.0,
                 request_timeout_s: float = 120.0):
        if engine is not None:
            assert engine.G == 1, \
                "OpsServer serves 1-row groups (build_paged_engine shape)"
            assert key is not None, "an engine needs a base sampling key"
        self.eng = engine
        self.key = key
        self.status_fn = status_fn
        self.registry = registry if registry is not None else metrics()
        self.request_timeout_s = request_timeout_s
        self.bubble = OnlineBubble(window_s=window_s)
        self.ttft_hist = self.registry.histogram("serve.ttft_s")
        self.tokens_counter = self.registry.counter("serve.streamed_tokens")
        self.t0 = time.time()
        self._lock = threading.Lock()
        self._stopped = False
        self._started = False
        self._active = 0
        self._next_rid = 0
        self.requests_served = 0
        self._threads: List[threading.Thread] = []
        self._httpd = ThreadingHTTPServer((host, port), _Handler)
        self._httpd.daemon_threads = True
        self._httpd.ops = self  # type: ignore[attr-defined]

    @property
    def port(self) -> int:
        return self._httpd.server_address[1]

    @property
    def url(self) -> str:
        return f"http://{self._httpd.server_address[0]}:{self.port}"

    def start(self) -> "OpsServer":
        tracer = otrace.get()
        if tracer is not None:
            tracer.add_listener(self.bubble.on_event)
        serve_t = threading.Thread(
            target=self._httpd.serve_forever, kwargs={"poll_interval": 0.05},
            name="ops-http", daemon=True)
        threads = [serve_t]
        if self.eng is not None:
            threads.append(threading.Thread(
                target=self._drive, name="ops-drive", daemon=True))
        with self._lock:
            self._started = True
            self._threads.extend(threads)
        for t in threads:
            t.start()
        return self

    def _drive(self) -> None:
        """Engine-stepping thread: steps only while server-submitted
        requests are in flight, sleeps otherwise. Never holds the ops
        lock across a step — ``PagedGroupEngine.step`` has its own
        mutex and may block on a drain."""
        while True:
            with self._lock:
                if self._stopped:
                    return
                active = self._active
            if active:
                if not self.eng.step():
                    # submitted but not yet admitted, or done and the
                    # handler hasn't decremented yet — don't hot-spin
                    time.sleep(0.002)
            else:
                time.sleep(0.01)

    def alloc_rid(self) -> int:
        with self._lock:
            rid = self._next_rid
            self._next_rid += 1
        return rid

    def request_started(self) -> None:
        with self._lock:
            self._active += 1

    def request_finished(self) -> None:
        with self._lock:
            self._active -= 1
            self.requests_served += 1

    def status(self) -> dict:
        with self._lock:
            served = self.requests_served
            active = self._active
        out: Dict[str, Any] = {
            "uptime_s": time.time() - self.t0,
            "requests_served": served,
            "active_requests": active,
        }
        online = self.bubble.value()
        if online is not None:
            out["online"] = online
        if self.eng is not None:
            out["engine"] = self.eng.status_snapshot()
        if self.status_fn is not None:
            out["pipeline"] = self.status_fn()
        return out

    def stop(self) -> None:
        with self._lock:
            already = self._stopped or not self._started
            self._stopped = True
            threads = list(self._threads)
        if already:
            return
        tracer = otrace.get()
        if tracer is not None:
            tracer.remove_listener(self.bubble.on_event)
        self._httpd.shutdown()
        self._httpd.server_close()
        for t in threads:
            t.join(timeout=5)

    def __enter__(self) -> "OpsServer":
        return self.start()

    def __exit__(self, *exc) -> bool:
        self.stop()
        return False


# =========================================================================
# CLI / CI smoke
# =========================================================================

def _sse_request(base: str, payload: dict, timeout: float = 120.0
                 ) -> Tuple[List[int], Optional[dict]]:
    """Minimal SSE client (the README walkthrough shape): POST the
    request, read ``data:`` lines until the ``done`` event."""
    import urllib.request
    req = urllib.request.Request(
        base + "/v1/generate", data=json.dumps(payload).encode(),
        headers={"Content-Type": "application/json"}, method="POST")
    tokens: List[int] = []
    done: Optional[dict] = None
    with urllib.request.urlopen(req, timeout=timeout) as resp:
        assert resp.status == 200, resp.status
        event = None
        for raw in resp:
            line = raw.decode().strip()
            if line.startswith("event: "):
                event = line[len("event: "):]
            elif line.startswith("data: "):
                doc = json.loads(line[len("data: "):])
                if event == "done":
                    done = doc
                elif event == "error":
                    raise RuntimeError(f"server error: {doc}")
                else:
                    tokens.append(doc["token"])
    return tokens, done


def _smoke() -> int:
    """Boot a tiny engine + server, scrape ourselves, stream one request
    — the CI benchmark-smoke gate (response codes + Prometheus
    well-formedness + one verified SSE round trip)."""
    import urllib.request

    import jax

    from repro.configs import get_config, reduced_config
    from repro.launch.serve import build_paged_engine
    from repro.models import init

    cfg = reduced_config(get_config("llama3.2-3b"))
    params = init(jax.random.PRNGKey(0), cfg)
    eng = build_paged_engine(cfg, max_prompt_len=16, max_new=8,
                             num_slots=2, page_size=8, seed=0)
    eng.set_params(params)
    srv = OpsServer(engine=eng, key=jax.random.PRNGKey(1))
    srv.start()
    try:
        def get(path: str) -> Tuple[int, str]:
            with urllib.request.urlopen(srv.url + path, timeout=30) as r:
                return r.status, r.read().decode()

        code, body = get("/healthz")
        assert code == 200 and body == "ok\n", (code, body)
        code, text = get("/metrics")
        assert code == 200, code
        before = parse_prometheus_text(text)

        tokens, done = _sse_request(
            srv.url, {"prompt": list(range(1, 9)), "rid": 0, "max_new": 8})
        assert tokens, "no tokens streamed"
        assert done is not None and done["verified"], done
        assert done["num_tokens"] == len(tokens), done

        code, text = get("/metrics")
        after = parse_prometheus_text(text)
        for k, v in before.items():
            if k.endswith("_total") or "_bucket" in k or k.endswith("_count"):
                assert after.get(k, v) >= v, f"counter {k} went backwards"
        assert after["repro_serve_streamed_tokens_total"] >= len(tokens)

        code, body = get("/status")
        st = json.loads(body)
        assert code == 200 and st["requests_served"] >= 1, st
        assert "engine" in st and "pages_live" in st["engine"], st
        print(f"ops-server smoke OK: {len(tokens)} tokens streamed, "
              f"{len(after)} samples scraped, status keys "
              f"{sorted(st)}")
        return 0
    finally:
        srv.stop()


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser(prog="python -m repro.obs.server")
    ap.add_argument("--smoke", action="store_true",
                    help="boot a tiny engine+server, self-scrape, one SSE "
                         "request; exit nonzero on failure (the CI gate)")
    args = ap.parse_args(argv)
    if args.smoke:
        return _smoke()
    ap.error("serving mode is launched via repro.launch.serve --serve-port; "
             "this entry point only runs --smoke")
    return 2


if __name__ == "__main__":
    import sys
    sys.exit(main())
