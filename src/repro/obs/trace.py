"""Thread-aware tracing plane (DESIGN.md §Observability).

The pipeline's whole claim is a *timeline* claim — periodic asynchrony
overlaps the producer (rollout pool) and consumer (trainer) stages — so
this module records spans on every stage and exports them as Chrome
trace-event JSON, viewable directly in Perfetto (ui.perfetto.dev).

Design constraints, in priority order:

* **Near-zero overhead when disabled.** Instrumentation sites call the
  module-level ``span()``/``complete()``/``instant()`` facade; with no
  tracer installed these are one global load + a ``None`` check (and
  ``span()`` returns a shared no-op context manager). Nothing allocates.
* **No clock of its own on the hot tier.** Span timestamps reuse the
  clock reads the pipeline already takes (the deferred busy-settle
  clock, the boundary stopwatches) via ``complete(name, t0, t1)``; the
  tracer never calls ``jax.block_until_ready`` and adds zero host syncs
  to the dispatch stream (gated by ``repro-check --forbid-hot`` and the
  obs-discipline checker).
* **Lock-cheap under threads.** Every thread appends to its own buffer
  (``threading.local``); the tracer lock is taken once per thread at
  first use and once at export, never per event.

Event model (Chrome trace-event phases):

* ``span("name", **attrs)`` — a ``with``-scoped complete ("X") event on
  the calling thread's track.
* ``complete(name, t0, t1, **attrs)`` — a retro-recorded "X" event with
  explicit ``time.perf_counter()`` endpoints (the deferred-clock path);
  ``track=`` pins it to a stable virtual track (e.g. one per producer
  instance) instead of the emitting thread.
* ``begin(name, uid)`` / ``end(name, uid)`` — async ("b"/"e") events for
  spans that start and finish on different threads (serving request
  lifecycle).
* ``instant(name)`` / ``counter(name, value)`` — "i" point events and
  "C" counter tracks (pages live, queue depth).
"""
from __future__ import annotations

import json
import threading
import time
from typing import Any, Dict, List, Optional, Tuple


class _NullSpan:
    """Shared no-op context manager returned by ``span()`` when tracing
    is disabled — the entire disabled-path cost of a ``with`` site."""
    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **attrs) -> "_NullSpan":
        return self


_NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("_tracer", "name", "attrs", "_t0")

    def __init__(self, tracer: "Tracer", name: str, attrs: Dict[str, Any]):
        self._tracer = tracer
        self.name = name
        self.attrs = attrs

    def __enter__(self) -> "_Span":
        self._t0 = time.perf_counter()
        return self

    def set(self, **attrs) -> "_Span":
        self.attrs.update(attrs)
        return self

    def __exit__(self, *exc) -> bool:
        tr = self._tracer
        t1 = time.perf_counter()
        tr._emit(("X", self.name, tr._ts(self._t0),
                  (t1 - self._t0) * 1e6, None, self.attrs))
        return False


class Tracer:
    """Collects trace events into per-thread buffers; ``export`` writes
    the merged Chrome trace-event JSON."""

    def __init__(self, process_name: str = "repro"):
        self.process_name = process_name
        self._epoch = time.perf_counter()
        self._lock = threading.Lock()
        # (thread_ident, thread_name, event list) per writer thread
        self._buffers: List[Tuple[int, str, list]] = []
        self._local = threading.local()
        # virtual tracks: stable synthetic tids for events whose natural
        # home is a logical lane (producer instance) rather than the
        # emitting thread (settle threads are one-shot)
        self._tracks: Dict[str, int] = {}
        self._next_track = 1 << 20

    # -- clock ----------------------------------------------------------
    def _ts(self, t: float) -> float:
        return (t - self._epoch) * 1e6  # perf_counter -> trace microseconds

    # -- per-thread buffers ---------------------------------------------
    def _buf(self) -> list:
        buf = getattr(self._local, "buf", None)
        if buf is None:
            buf = []
            th = threading.current_thread()
            with self._lock:
                self._buffers.append((th.ident or 0, th.name, buf))
            self._local.buf = buf
        return buf

    def _emit(self, ev: tuple) -> None:
        self._buf().append(ev)

    def track_tid(self, track: str) -> int:
        tid = self._tracks.get(track)
        if tid is None:
            with self._lock:
                tid = self._tracks.setdefault(
                    track, self._next_track + len(self._tracks))
        return tid

    # -- recording API --------------------------------------------------
    def span(self, name: str, **attrs) -> _Span:
        return _Span(self, name, attrs)

    def complete(self, name: str, t0: float, t1: float,
                 track: Optional[str] = None, **attrs) -> None:
        """Retro-record a finished span from two existing perf_counter
        reads — the deferred-clock path: no new timestamps are invented
        and nothing blocks."""
        tid = None if track is None else self.track_tid(track)
        self._emit(("X", name, self._ts(t0), (t1 - t0) * 1e6, tid, attrs))

    def begin(self, name: str, uid: Any = None, **attrs) -> None:
        t = time.perf_counter()
        self._emit(("b", name, self._ts(t), uid if uid is not None else name,
                    None, attrs))

    def end(self, name: str, uid: Any = None, **attrs) -> None:
        t = time.perf_counter()
        self._emit(("e", name, self._ts(t), uid if uid is not None else name,
                    None, attrs))

    def instant(self, name: str, **attrs) -> None:
        t = time.perf_counter()
        self._emit(("i", name, self._ts(t), None, None, attrs))

    def counter(self, name: str, value: float) -> None:
        t = time.perf_counter()
        self._emit(("C", name, self._ts(t), None, None, {"value": value}))

    # -- export ---------------------------------------------------------
    def events(self) -> List[dict]:
        """Merged Chrome trace-event dicts (also the analyzer's input)."""
        pid = 0
        out: List[dict] = [{
            "ph": "M", "name": "process_name", "pid": pid, "tid": 0,
            "args": {"name": self.process_name}}]
        with self._lock:
            buffers = [(tid, name, list(buf))
                       for tid, name, buf in self._buffers]
            tracks = dict(self._tracks)
        for tid, name, _ in buffers:
            out.append({"ph": "M", "name": "thread_name", "pid": pid,
                        "tid": tid, "args": {"name": name}})
        for track, tid in tracks.items():
            out.append({"ph": "M", "name": "thread_name", "pid": pid,
                        "tid": tid, "args": {"name": track}})
        for tid, _, buf in buffers:
            for ph, name, ts, x, etid, attrs in buf:
                ev: Dict[str, Any] = {"ph": ph, "name": name, "pid": pid,
                                      "tid": etid if etid is not None else tid,
                                      "ts": ts}
                if ph == "X":
                    ev["dur"] = x
                elif ph in ("b", "e"):
                    ev["cat"] = "async"
                    ev["id"] = str(x)
                elif ph == "i":
                    ev["s"] = "t"
                if attrs:
                    ev["args"] = dict(attrs)
                out.append(ev)
        out.sort(key=lambda e: e.get("ts", 0.0))
        return out

    def export(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump({"traceEvents": self.events(),
                       "displayTimeUnit": "ms"}, f)
        return path


# -- module-level facade (what instrumentation sites import) ------------
_active: Optional[Tracer] = None


def install(process_name: str = "repro") -> Tracer:
    """Install a fresh process-wide tracer and return it."""
    global _active
    _active = Tracer(process_name)
    return _active


def uninstall() -> None:
    global _active
    _active = None


def get() -> Optional[Tracer]:
    return _active


def active() -> bool:
    return _active is not None


def span(name: str, **attrs):
    t = _active
    if t is None:
        return _NULL_SPAN
    return t.span(name, **attrs)


def complete(name: str, t0: float, t1: float,
             track: Optional[str] = None, **attrs) -> None:
    t = _active
    if t is not None:
        t.complete(name, t0, t1, track=track, **attrs)


def begin(name: str, uid: Any = None, **attrs) -> None:
    t = _active
    if t is not None:
        t.begin(name, uid=uid, **attrs)


def end(name: str, uid: Any = None, **attrs) -> None:
    t = _active
    if t is not None:
        t.end(name, uid=uid, **attrs)


def instant(name: str, **attrs) -> None:
    t = _active
    if t is not None:
        t.instant(name, **attrs)


def counter(name: str, value: float) -> None:
    t = _active
    if t is not None:
        t.counter(name, value)


def export(path: str) -> Optional[str]:
    t = _active
    return None if t is None else t.export(path)
