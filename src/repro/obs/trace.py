"""Thread-aware tracing plane (DESIGN.md §Observability).

The pipeline's whole claim is a *timeline* claim — periodic asynchrony
overlaps the producer (rollout pool) and consumer (trainer) stages — so
this module records spans on every stage and exports them as Chrome
trace-event JSON, viewable directly in Perfetto (ui.perfetto.dev).

Design constraints, in priority order:

* **Near-zero overhead when disabled.** Instrumentation sites call the
  module-level ``span()``/``complete()``/``instant()`` facade; with no
  tracer installed these are one global load + a ``None`` check (and
  ``span()`` returns a shared no-op context manager). Nothing allocates.
* **No clock of its own on the hot tier.** Span timestamps reuse the
  clock reads the pipeline already takes (the deferred busy-settle
  clock, the boundary stopwatches) via ``complete(name, t0, t1)``; the
  tracer never calls ``jax.block_until_ready`` and adds zero host syncs
  to the dispatch stream (gated by ``repro-check --forbid-hot`` and the
  obs-discipline checker).
* **Lock-cheap under threads.** Every thread appends to its own buffer
  (``threading.local``); the tracer lock is taken once per thread at
  first use and once at export, never per event.
* **Bounded peak memory when streaming.** With ``stream_dir`` set, each
  thread's buffer is flushed to rotating JSONL segments
  (``trace-000N.jsonl``) once it reaches ``flush_events`` entries, so
  resident events never exceed ``threads x flush_events`` no matter how
  long the run — a multi-hour trace costs disk, not RAM
  (``peak_buffer_events`` records the observed bound for tests).
  Monolithic mode (no ``stream_dir``) keeps the original
  buffer-until-``export`` behaviour for short runs.

Event model (Chrome trace-event phases):

* ``span("name", **attrs)`` — a ``with``-scoped complete ("X") event on
  the calling thread's track.
* ``complete(name, t0, t1, **attrs)`` — a retro-recorded "X" event with
  explicit ``time.perf_counter()`` endpoints (the deferred-clock path);
  ``track=`` pins it to a stable virtual track (e.g. one per producer
  instance) instead of the emitting thread.
* ``begin(name, uid)`` / ``end(name, uid)`` — async ("b"/"e") events for
  spans that start and finish on different threads (serving request
  lifecycle).
* ``instant(name)`` / ``counter(name, value)`` — "i" point events and
  "C" counter tracks (pages live, queue depth).

Listeners: ``add_listener(fn)`` registers a callback invoked with each
raw event tuple at emit time — the hook the ops server's online bubble
estimator rides (obs/server.py). With no listeners registered the emit
path pays one truthiness check.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple


class _NullSpan:
    """Shared no-op context manager returned by ``span()`` when tracing
    is disabled — the entire disabled-path cost of a ``with`` site."""
    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **attrs) -> "_NullSpan":
        return self


_NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("_tracer", "name", "attrs", "_t0")

    def __init__(self, tracer: "Tracer", name: str, attrs: Dict[str, Any]):
        self._tracer = tracer
        self.name = name
        self.attrs = attrs

    def __enter__(self) -> "_Span":
        self._t0 = time.perf_counter()
        return self

    def set(self, **attrs) -> "_Span":
        self.attrs.update(attrs)
        return self

    def __exit__(self, *exc) -> bool:
        tr = self._tracer
        t1 = time.perf_counter()
        tr._emit(("X", self.name, tr._ts(self._t0),
                  (t1 - self._t0) * 1e6, None, self.attrs))
        return False


class Tracer:
    """Collects trace events into per-thread buffers; ``export`` writes
    the merged Chrome trace-event JSON (monolithic mode) or flushes the
    final JSONL segment (streaming mode)."""

    def __init__(self, process_name: str = "repro",
                 stream_dir: Optional[str] = None,
                 flush_events: int = 256,
                 segment_events: int = 8192):
        self.process_name = process_name
        self._epoch = time.perf_counter()
        self._lock = threading.Lock()
        # (thread_ident, thread_name, event list) per writer thread
        self._buffers: List[Tuple[int, str, list]] = []
        self._local = threading.local()
        # virtual tracks: stable synthetic tids for events whose natural
        # home is a logical lane (producer instance) rather than the
        # emitting thread (settle threads are one-shot)
        self._tracks: Dict[str, int] = {}
        self._next_track = 1 << 20
        self._listeners: List[Callable[[tuple], None]] = []
        # -- streaming state (all mutated under _io_lock) ---------------
        self.stream_dir = stream_dir
        self.flush_events = max(1, flush_events)
        self.segment_events = max(self.flush_events, segment_events)
        self.peak_buffer_events = 0  # monotone max; tests assert the bound
        self._io_lock = threading.Lock()
        self._seg_file = None
        self._seg_index = -1
        self._seg_count = 0
        self._closed = False
        if stream_dir is not None:
            os.makedirs(stream_dir, exist_ok=True)
            with self._io_lock:
                self._rotate_io_locked()
                self._write_io_locked([{
                    "ph": "M", "name": "process_name", "pid": 0, "tid": 0,
                    "args": {"name": process_name}}])

    @property
    def streaming(self) -> bool:
        return self.stream_dir is not None

    # -- clock ----------------------------------------------------------
    def _ts(self, t: float) -> float:
        return (t - self._epoch) * 1e6  # perf_counter -> trace microseconds

    # -- per-thread buffers ---------------------------------------------
    def _buf(self) -> list:
        buf = getattr(self._local, "buf", None)
        if buf is None:
            buf = []
            th = threading.current_thread()
            entry = (th.ident or 0, th.name, buf)
            with self._lock:
                self._buffers.append(entry)
            if self.streaming:
                with self._io_lock:
                    self._write_io_locked([{
                        "ph": "M", "name": "thread_name", "pid": 0,
                        "tid": entry[0], "args": {"name": th.name}}])
            self._local.buf = buf
        return buf

    def _emit(self, ev: tuple) -> None:
        buf = self._buf()
        buf.append(ev)
        if self._listeners:
            for fn in tuple(self._listeners):
                fn(ev)
        if self.streaming:
            n = len(buf)
            if n > self.peak_buffer_events:
                # benign racy max: monotone, and any lost update is
                # re-observed by the next append on the same thread
                self.peak_buffer_events = n
            if n >= self.flush_events:
                self._flush_one(buf)

    def add_listener(self, fn: Callable[[tuple], None]) -> None:
        with self._lock:
            self._listeners = self._listeners + [fn]

    def remove_listener(self, fn: Callable[[tuple], None]) -> None:
        # == not `is`: a bound method is a fresh object per attribute
        # access, so identity would never match the one registered
        with self._lock:
            self._listeners = [f for f in self._listeners if f != fn]

    def track_tid(self, track: str) -> int:
        tid = self._tracks.get(track)
        if tid is None:
            with self._lock:
                tid = self._tracks.setdefault(
                    track, self._next_track + len(self._tracks))
            if self.streaming:
                with self._io_lock:
                    self._write_io_locked([{
                        "ph": "M", "name": "thread_name", "pid": 0,
                        "tid": tid, "args": {"name": track}}])
        return tid

    # -- recording API --------------------------------------------------
    def span(self, name: str, **attrs) -> _Span:
        return _Span(self, name, attrs)

    def complete(self, name: str, t0: float, t1: float,
                 track: Optional[str] = None, **attrs) -> None:
        """Retro-record a finished span from two existing perf_counter
        reads — the deferred-clock path: no new timestamps are invented
        and nothing blocks."""
        tid = None if track is None else self.track_tid(track)
        self._emit(("X", name, self._ts(t0), (t1 - t0) * 1e6, tid, attrs))

    def begin(self, name: str, uid: Any = None, **attrs) -> None:
        t = time.perf_counter()
        self._emit(("b", name, self._ts(t), uid if uid is not None else name,
                    None, attrs))

    def end(self, name: str, uid: Any = None, **attrs) -> None:
        t = time.perf_counter()
        self._emit(("e", name, self._ts(t), uid if uid is not None else name,
                    None, attrs))

    def instant(self, name: str, **attrs) -> None:
        t = time.perf_counter()
        self._emit(("i", name, self._ts(t), None, None, attrs))

    def counter(self, name: str, value: float) -> None:
        t = time.perf_counter()
        self._emit(("C", name, self._ts(t), None, None, {"value": value}))

    # -- event-dict conversion ------------------------------------------
    @staticmethod
    def _to_dict(ev: tuple, default_tid: int) -> dict:
        ph, name, ts, x, etid, attrs = ev
        out: Dict[str, Any] = {"ph": ph, "name": name, "pid": 0,
                               "tid": etid if etid is not None else default_tid,
                               "ts": ts}
        if ph == "X":
            out["dur"] = x
        elif ph in ("b", "e"):
            out["cat"] = "async"
            out["id"] = str(x)
        elif ph == "i":
            out["s"] = "t"
        if attrs:
            out["args"] = dict(attrs)
        return out

    # -- streaming IO (segment rotation) --------------------------------
    def _rotate_io_locked(self) -> None:
        if self._seg_file is not None:
            self._seg_file.close()
        self._seg_index += 1
        self._seg_count = 0
        path = os.path.join(self.stream_dir,
                            f"trace-{self._seg_index:04d}.jsonl")
        self._seg_file = open(path, "w")

    def _write_io_locked(self, dicts: List[dict]) -> None:
        if self._closed or self._seg_file is None:
            return
        for d in dicts:
            self._seg_file.write(json.dumps(d) + "\n")
        self._seg_count += len(dicts)
        self._seg_file.flush()
        # rotate at batch boundaries: a segment may overshoot the cap by
        # at most one flush batch, never split an event across files
        if self._seg_count >= self.segment_events:
            self._rotate_io_locked()

    def _flush_one(self, buf: list, tid: Optional[int] = None) -> None:
        """Drain one thread's buffer to the current segment. Safe from
        both the owning thread (threshold hit) and a foreign flusher
        (export/close): the length is re-read under the IO lock and only
        the first ``n`` entries are written+removed, so a concurrent
        owner append (GIL-atomic, lands past ``n``) is never lost or
        double-written."""
        if tid is None:
            tid = threading.get_ident()
        with self._io_lock:
            n = len(buf)
            if n:
                self._write_io_locked([self._to_dict(ev, tid)
                                       for ev in buf[:n]])
                del buf[:n]

    def flush(self) -> None:
        """Flush every thread's buffer (streaming mode); no-op otherwise.
        Called on export/close and by the flush-on-crash wrappers in
        launch/."""
        if not self.streaming:
            return
        with self._lock:
            buffers = list(self._buffers)
        for tid, _, buf in buffers:
            self._flush_one(buf, tid=tid)

    def close(self) -> Optional[str]:
        """Flush all buffers and close the active segment; returns the
        stream dir (None in monolithic mode). Idempotent."""
        if not self.streaming:
            return None
        self.flush()
        with self._io_lock:
            if self._seg_file is not None:
                self._seg_file.close()
                self._seg_file = None
            self._closed = True
        return self.stream_dir

    # -- export ---------------------------------------------------------
    def events(self) -> List[dict]:
        """Merged Chrome trace-event dicts (also the analyzer's input).
        Monolithic mode only — a streaming tracer's events live on disk
        (read them back with ``obs.analyze.load_trace(stream_dir)``)."""
        if self.streaming:
            raise RuntimeError(
                "events() unavailable on a streaming tracer; "
                "load the segment dir with obs.analyze.load_trace()")
        pid = 0
        out: List[dict] = [{
            "ph": "M", "name": "process_name", "pid": pid, "tid": 0,
            "args": {"name": self.process_name}}]
        with self._lock:
            buffers = [(tid, name, list(buf))
                       for tid, name, buf in self._buffers]
            tracks = dict(self._tracks)
        for tid, name, _ in buffers:
            out.append({"ph": "M", "name": "thread_name", "pid": pid,
                        "tid": tid, "args": {"name": name}})
        for track, tid in tracks.items():
            out.append({"ph": "M", "name": "thread_name", "pid": pid,
                        "tid": tid, "args": {"name": track}})
        for tid, _, buf in buffers:
            for ev in buf:
                out.append(self._to_dict(ev, tid))
        out.sort(key=lambda e: e.get("ts", 0.0))
        return out

    def export(self, path: str = "") -> str:
        """Monolithic: write one Chrome-JSON file at ``path``. Streaming:
        flush+close the segments and return the stream dir (``path`` is
        ignored — the segments are already on disk)."""
        if self.streaming:
            return self.close() or self.stream_dir
        with open(path, "w") as f:
            json.dump({"traceEvents": self.events(),
                       "displayTimeUnit": "ms"}, f)
        return path


# -- module-level facade (what instrumentation sites import) ------------
_active: Optional[Tracer] = None


def install(process_name: str = "repro",
            stream_dir: Optional[str] = None,
            flush_events: int = 256,
            segment_events: int = 8192) -> Tracer:
    """Install a fresh process-wide tracer and return it. ``stream_dir``
    selects streaming JSONL-segment mode (bounded memory)."""
    global _active
    _active = Tracer(process_name, stream_dir=stream_dir,
                     flush_events=flush_events,
                     segment_events=segment_events)
    return _active


def uninstall() -> None:
    global _active
    t = _active
    _active = None
    if t is not None and t.streaming:
        t.close()


def get() -> Optional[Tracer]:
    return _active


def active() -> bool:
    return _active is not None


def span(name: str, **attrs):
    t = _active
    if t is None:
        return _NULL_SPAN
    return t.span(name, **attrs)


def complete(name: str, t0: float, t1: float,
             track: Optional[str] = None, **attrs) -> None:
    t = _active
    if t is not None:
        t.complete(name, t0, t1, track=track, **attrs)


def begin(name: str, uid: Any = None, **attrs) -> None:
    t = _active
    if t is not None:
        t.begin(name, uid=uid, **attrs)


def end(name: str, uid: Any = None, **attrs) -> None:
    t = _active
    if t is not None:
        t.end(name, uid=uid, **attrs)


def instant(name: str, **attrs) -> None:
    t = _active
    if t is not None:
        t.instant(name, **attrs)


def counter(name: str, value: float) -> None:
    t = _active
    if t is not None:
        t.counter(name, value)


def export(path: str = "") -> Optional[str]:
    t = _active
    return None if t is None else t.export(path)
