from repro.optim.adam import AdamState, adam_init, adam_update
from repro.optim.accumulate import GradAccumulator

__all__ = ["AdamState", "adam_init", "adam_update", "GradAccumulator"]
