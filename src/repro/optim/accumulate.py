"""Micro-batch gradient accumulation (paper §3, Eq. 1).

J_batch = (1/M) sum_i (1/m) sum_j (...) — the consumer accumulates
micro-batch gradients as rollouts arrive from the queue and applies one
parameter update per iteration. Commutativity of the finite sum is what
makes completion-order consumption gradient-equivalent (Remark 1);
``tests/test_onpolicy.py`` asserts this numerically.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


class GradAccumulator:
    """Host-side accumulator: O <- O + grad(micro_batch), then mean."""

    def __init__(self):
        self._sum = None
        self._weight = 0.0
        self._count = 0

    def add(self, grads, weight: float = 1.0) -> None:
        """weight = number of samples in the micro-batch, so unequal
        micro-batches still average to the exact full-batch mean."""
        if self._sum is None:
            self._sum = jax.tree.map(
                lambda g: g.astype(jnp.float32) * weight, grads)
        else:
            self._sum = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32) * weight,
                self._sum, grads)
        self._weight += float(weight)
        self._count += 1

    @property
    def count(self) -> int:
        return self._count

    def mean(self):
        if self._sum is None:
            raise ValueError("no gradients accumulated")
        w = self._weight
        return jax.tree.map(lambda a: a / w, self._sum)

    def reset(self) -> None:
        self._sum = None
        self._weight = 0.0
        self._count = 0
