"""AdamW with fp32 optimizer state and global-norm gradient clipping —
the paper's shared optimization settings (Table 7):
Adam(b1=0.9, b2=0.95), lr 1e-6, weight decay 0.01, clip 1.0,
bf16 params / fp32 grads / fp32 optimizer state.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jax.Array     # scalar int32
    mu: dict            # fp32 first moment (params pytree)
    nu: dict            # fp32 second moment


def adam_init(params) -> AdamState:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamState(step=jnp.zeros((), jnp.int32),
                     mu=jax.tree.map(f32, params),
                     nu=jax.tree.map(f32, params))


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adam_update(params, grads, state: AdamState, *, lr: float,
                b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                weight_decay: float = 0.01, grad_clip: float = 1.0):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-12))
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamState(step, new_m, new_v), {"grad_norm": gnorm}
