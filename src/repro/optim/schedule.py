"""LR schedules. The paper uses constant lr 1e-6 with 0 warmup (Table 7);
warmup-cosine provided for general use."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def warmup_cosine(lr: float, warmup: int, total: int, floor: float = 0.1):
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = lr * step / jnp.maximum(warmup, 1)
        prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        cos = lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)
    return fn
