from repro.rl.grpo import grpo_loss, group_advantages, make_grad_step, make_train_step
from repro.rl.reward import RuleBasedReward
from repro.rl.rollout import Sampler

__all__ = ["grpo_loss", "group_advantages", "make_grad_step",
           "make_train_step", "RuleBasedReward", "Sampler"]
