"""GRPO with the unified tri-model forward (paper §4.2.1, Figure 2).

A micro-step computes THREE per-token log-probs — policy (with grad),
old-policy and reference — inside one jitted program. The no-grad pair is
evaluated by a *stacked vmap* over the two parameter pytrees: the JAX
analogue of the paper's shared-parallel-layout tri-model, fusing both
forwards into a single XLA computation with identical sharding.

Loss (PPO-clip + k3 KL penalty, paper Eq. 1 / Table 8):
    J = E_t[ min(r_t A, clip(r_t, 1-eps_l, 1+eps_h) A) - beta * KL_t ]
    KL_t = exp(ref - pol) - (ref - pol) - 1        (k3 estimator, >= 0)
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RLConfig
from repro.models import forward_hidden, token_logprobs
from repro.optim.adam import adam_update


class MicroBatch(NamedTuple):
    """One micro-batch of packed samples (SPA-packed or plain).

    ``loss_mask`` carries per-token loss WEIGHTS (1/len(sample) on that
    sample's label positions, 0 elsewhere) so the loss is the exact
    per-sample token-mean regardless of row packing; the micro-batch loss is
    sum(per_token * weight) / n_samples."""
    tokens: jax.Array        # (m, S) int32
    labels: jax.Array        # (m, S) int32 — next-token ids
    positions: jax.Array     # (m, S) int32
    segments: jax.Array      # (m, S) int32 — 0 = prompt/shared, k = response k
    loss_mask: jax.Array     # (m, S) f32 — per-token loss weights (see above)
    advantages: jax.Array    # (m, S) f32 — group-normalised, broadcast per token
    n_samples: jax.Array = 1.0  # scalar f32 — number of packed samples
    extras: dict = {}        # modality-frontend stubs: vision_embeds / enc_embeds
    # (m, S) f32 rollout-captured behavior logprobs scattered onto label
    # positions (0 elsewhere), or None when the rollouts carried no capture
    # — see DESIGN.md §Tri-model-capture. Under Proposition 1 these ARE the
    # old-policy logprobs, so the grad step can skip the old recompute.
    logp_behavior: Optional[jax.Array] = None


def jaxify(mb: MicroBatch) -> MicroBatch:
    """Host-packed (numpy) micro-batch -> device arrays; ``None`` fields
    (absent captured logprobs) and empty extras pass through untouched."""
    return jax.tree.map(jnp.asarray, mb)


def group_advantages(rewards: jax.Array, eps: float = 1e-4) -> jax.Array:
    """GRPO advantages: per-group standardised rewards. rewards: (G,)."""
    mu = rewards.mean()
    sd = rewards.std()
    return (rewards - mu) / (sd + eps)


def _model_logprobs(params, cfg: ModelConfig, mb: MicroBatch) -> jax.Array:
    h, _, aux, _ = forward_hidden(params, cfg, mb.tokens,
                                  positions=mb.positions,
                                  segments=mb.segments,
                                  **(mb.extras or {}))
    if cfg.vision_prefix_len:       # hidden rows of the image prefix carry no loss
        h = h[:, cfg.vision_prefix_len:]
    return token_logprobs(params, cfg, h, mb.labels), aux


def trimodel_ref_old_logprobs(old_params, ref_params, cfg: ModelConfig,
                              mb: MicroBatch):
    """Fused old+ref forward: stack the two pytrees and vmap once."""
    stacked = jax.tree.map(lambda a, b: jnp.stack([a, b]), old_params, ref_params)
    lp, _ = jax.vmap(lambda p: _model_logprobs(p, cfg, mb))(stacked)
    return lp[0], lp[1]      # old, ref


def grpo_loss(policy_params, cfg: ModelConfig, rl: RLConfig, mb: MicroBatch,
              logp_old: jax.Array, logp_ref: jax.Array):
    logp, aux = _model_logprobs(policy_params, cfg, mb)
    ratio = jnp.exp(logp - logp_old)
    clipped = jnp.clip(ratio, 1.0 - rl.clip_eps_low, 1.0 + rl.clip_eps_high)
    adv = mb.advantages
    surr = jnp.minimum(ratio * adv, clipped * adv)
    d = logp_ref - logp
    kl = jnp.exp(d) - d - 1.0
    per_tok = surr - rl.kl_coef * kl
    n = jnp.asarray(mb.n_samples, jnp.float32)
    j = (per_tok * mb.loss_mask).sum() / jnp.maximum(n, 1.0)
    loss = -j + aux
    hard_mask = (mb.loss_mask > 0).astype(jnp.float32)
    denom = jnp.maximum(hard_mask.sum(), 1.0)
    metrics = {
        "loss": loss,
        "kl": (kl * hard_mask).sum() / denom,
        "ratio_mean": (ratio * hard_mask).sum() / denom,
        "aux": aux,
        "n_tokens": hard_mask.sum(),
    }
    return loss, metrics


def make_grad_step(cfg: ModelConfig, rl: RLConfig):
    """grad_step(policy, old, ref, mb) -> (grads, metrics). The consumer
    accumulates these over the B rollouts of an iteration (Algorithm 1,
    lines 7-9)."""

    @jax.jit
    def grad_step(policy_params, old_params, ref_params, mb: MicroBatch):
        logp_old, logp_ref = trimodel_ref_old_logprobs(
            old_params, ref_params, cfg, mb)
        logp_old = jax.lax.stop_gradient(logp_old)
        logp_ref = jax.lax.stop_gradient(logp_ref)
        (loss, metrics), grads = jax.value_and_grad(
            grpo_loss, has_aux=True)(policy_params, cfg, rl, mb,
                                     logp_old, logp_ref)
        return grads, metrics

    return grad_step


def make_grad_step_captured(cfg: ModelConfig, rl: RLConfig):
    """Capture-path grad step (DESIGN.md §Tri-model-capture): the ratio's
    denominator is ``mb.logp_behavior`` — the logprobs the inference engine
    evaluated while sampling — so the no-grad pass shrinks from the stacked
    old+ref vmap to a SINGLE reference forward (~1/3 of the tri-model's
    training forward FLOPs deleted). Same signature as ``make_grad_step``
    so the scheduler can dispatch per micro-batch; ``old_params`` is
    accepted and unused. In strict on-policy modes the captured values
    equal the old-policy recompute up to fp reduction order; in
    ``async_offpolicy`` they are evaluated under the BEHAVIOR weights
    (the weights that actually sampled the rollout) rather than the
    current old weights, removing the old~behavior weights approximation.
    Both paths use raw-distribution logprobs — rollout temperature/top-p
    filtering sits outside the ratio convention either way."""

    @jax.jit
    def grad_step(policy_params, old_params, ref_params, mb: MicroBatch):
        del old_params                   # behavior logprobs ride the batch
        logp_ref, _ = _model_logprobs(ref_params, cfg, mb)
        logp_ref = jax.lax.stop_gradient(logp_ref)
        (loss, metrics), grads = jax.value_and_grad(
            grpo_loss, has_aux=True)(policy_params, cfg, rl, mb,
                                     mb.logp_behavior, logp_ref)
        return grads, metrics

    return grad_step


def make_apply_update(cfg: ModelConfig, rl: RLConfig):
    @jax.jit
    def apply_update(policy_params, opt_state, grads):
        return adam_update(policy_params, grads, opt_state,
                           lr=rl.learning_rate, b1=rl.adam_b1, b2=rl.adam_b2,
                           weight_decay=rl.weight_decay,
                           grad_clip=rl.grad_clip)
    return apply_update


def make_train_step(cfg: ModelConfig, rl: RLConfig,
                    num_microbatches: int = 1):
    """Fused step (tri-model logits -> loss -> grad -> Adam) — the step
    lowered by the multi-pod dry-run for the train_4k shape.

    ``num_microbatches > 1`` applies the paper's Eq. 1 micro-batching INSIDE
    the compiled step: a lax.scan over M row-slices accumulates fp32
    gradients (Table 7: gradient dtype fp32) and applies one Adam update —
    mathematically identical to the monolithic step, with activation memory
    bounded by one micro-batch. Needed for the largest configs, whose
    tri-model + fp32-Adam resident state alone fills most of HBM."""

    def grad_micro(policy_params, old_params, ref_params, mb: MicroBatch):
        logp_old, logp_ref = trimodel_ref_old_logprobs(
            old_params, ref_params, cfg, mb)
        logp_old = jax.lax.stop_gradient(logp_old)
        logp_ref = jax.lax.stop_gradient(logp_ref)
        return jax.value_and_grad(grpo_loss, has_aux=True)(
            policy_params, cfg, rl, mb, logp_old, logp_ref)

    def train_step(policy_params, old_params, ref_params, opt_state,
                   mb: MicroBatch):
        M = num_microbatches
        if M == 1:
            (_, metrics), grads = grad_micro(
                policy_params, old_params, ref_params, mb)
        else:
            def split(a):
                return a.reshape((M, a.shape[0] // M) + a.shape[1:])

            n_micro = jnp.asarray(mb.n_samples, jnp.float32) / M
            xs = (split(mb.tokens), split(mb.labels), split(mb.positions),
                  split(mb.segments), split(mb.loss_mask),
                  split(mb.advantages), jax.tree.map(split, mb.extras or {}))

            def body(acc, xs_i):
                t, y, p, s, w, a, ex = xs_i
                mb_i = MicroBatch(t, y, p, s, w, a,
                                  n_samples=n_micro, extras=ex)
                (_, metrics), grads = grad_micro(
                    policy_params, old_params, ref_params, mb_i)
                acc = jax.tree.map(
                    lambda c, g: c + g.astype(jnp.float32), acc, grads)
                return acc, metrics

            acc0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), policy_params)
            acc, metrics_stack = jax.lax.scan(body, acc0, xs)
            grads = jax.tree.map(lambda a: a / M, acc)
            metrics = jax.tree.map(lambda m: m.mean(), metrics_stack)

        new_params, new_opt, opt_metrics = adam_update(
            policy_params, grads, opt_state,
            lr=rl.learning_rate, b1=rl.adam_b1, b2=rl.adam_b2,
            weight_decay=rl.weight_decay, grad_clip=rl.grad_clip)
        metrics.update(opt_metrics)
        return new_params, new_opt, metrics

    return train_step
