"""PPO variant — the framework is algorithm-agnostic (paper §2: compatible
with any standard on-policy algorithm without staleness-aware variants).
PPO here = GRPO machinery with externally supplied per-token advantages
(e.g. from a value model / GAE) instead of group-standardised rewards."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RLConfig
from repro.rl.grpo import MicroBatch, grpo_loss, trimodel_ref_old_logprobs


def gae_advantages(rewards: jax.Array, values: jax.Array, gamma: float = 1.0,
                   lam: float = 0.95) -> jax.Array:
    """Generalised advantage estimation over a (T,) reward/value sequence."""
    T = rewards.shape[0]

    def body(carry, xs):
        adv_next, v_next = carry
        r, v = xs
        delta = r + gamma * v_next - v
        adv = delta + gamma * lam * adv_next
        return (adv, v), adv

    (_, _), advs = jax.lax.scan(
        body, (jnp.zeros(()), jnp.zeros(())),
        (rewards[::-1], values[::-1]))
    return advs[::-1]


def make_ppo_grad_step(cfg: ModelConfig, rl: RLConfig):
    @jax.jit
    def grad_step(policy_params, old_params, ref_params, mb: MicroBatch):
        logp_old, logp_ref = trimodel_ref_old_logprobs(
            old_params, ref_params, cfg, mb)
        logp_old = jax.lax.stop_gradient(logp_old)
        logp_ref = jax.lax.stop_gradient(logp_ref)
        (loss, metrics), grads = jax.value_and_grad(
            grpo_loss, has_aux=True)(policy_params, cfg, rl, mb,
                                     logp_old, logp_ref)
        return grads, metrics
    return grad_step
