"""Rule-based reward (paper §6.1): the predicted answer is correct iff it can
be accurately extracted and matches the ground truth; otherwise 0.

Reward evaluation runs inside the producer's worker threads — each rollout is
scored independently and enqueued with its reward (Figure 1), decoupling
reward computation from both inference and training."""
from __future__ import annotations

from repro.data.tasks import extract_answer
from repro.data.tokenizer import Tokenizer


class RuleBasedReward:
    def __init__(self, tokenizer: Tokenizer):
        self.tok = tokenizer

    def __call__(self, response_ids, answer: int) -> float:
        text = self.tok.decode(response_ids)
        pred = extract_answer(text)
        return 1.0 if pred is not None and pred == int(answer) else 0.0
