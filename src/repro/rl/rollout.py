"""Batched rollout sampler — the inference-engine compute core (the vLLM
stand-in). One jitted program performs prefill + a lax.scan decode loop with
temperature / top-p sampling and EOS masking; prompts are left-padded so all
rows share the cache write index while keeping true per-row positions.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.tokenizer import Tokenizer
from repro.models import forward_hidden, init_caches
from repro.models.layers import lm_head_weight


class RolloutBatch(NamedTuple):
    response_ids: jax.Array   # (B, max_new) int32, PAD after EOS
    response_len: jax.Array   # (B,) int32 (includes the EOS token)
    # (B, max_new) f32 log p(sampled id | context) under the UNFILTERED
    # model distribution (no temperature / top-p), captured from the logits
    # already in hand at each decode step; 0 past response_len. None when
    # capture is disabled (DESIGN.md §Tri-model-capture).
    response_logprobs: Optional[jax.Array] = None


def _filter_logits(logits, temperature: float, top_p: float):
    """Temperature + nucleus filtering (row-independent, f32 in/out)."""
    logits = logits / temperature
    if top_p < 1.0:
        sorted_logits = jnp.sort(logits, axis=-1)[:, ::-1]
        probs = jax.nn.softmax(sorted_logits, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        cutoff_idx = jnp.sum(cum < top_p, axis=-1)           # first idx where cum >= p
        cutoff = jnp.take_along_axis(sorted_logits, cutoff_idx[:, None], axis=-1)
        logits = jnp.where(logits < cutoff, -jnp.inf, logits)
    return logits


def _sample_token(key, logits, temperature: float, top_p: float):
    logits = logits.astype(jnp.float32)
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = _filter_logits(logits, temperature, top_p)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


def sampled_token_logprob(logits, tok):
    """log p(tok) under the RAW next-token distribution (no temperature /
    top-p filtering) — exactly the per-token quantity the trainer's
    old-policy forward recomputes via ``models.token_logprobs``, captured
    here for free while the step's logits are in hand
    (DESIGN.md §Tri-model-capture). logits: (B, V); tok: (B,) int32."""
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return jnp.take_along_axis(lp, tok[:, None], axis=-1)[:, 0]


def _sample_token_rows(keys, logits, rows, group_size: int,
                       temperature: float, top_p: float):
    """Row-exact replica of ``_sample_token`` for token-level engines.

    Slot b holds row ``rows[b]`` of some (group_size, V) group batch whose
    step key is ``keys[b]``; it must draw the very token the batched
    ``_sample_token(keys[b], group_logits)`` would give that row.
    ``categorical(key, lg)`` is ``argmax(gumbel(key, lg.shape) + lg)``, and
    the nucleus filter is row-independent, so drawing the full group's
    gumbel field and picking this row reproduces the draw bit-for-bit.

    keys: (B, 2) raw uint32 step keys; logits: (B, V); rows: (B,) int32.
    """
    logits = logits.astype(jnp.float32)
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = _filter_logits(logits, temperature, top_p)
    V = logits.shape[-1]

    def one(k, lg, r):
        noise = jax.random.gumbel(k, (group_size, V), jnp.float32)[r]
        return jnp.argmax(noise + lg, axis=-1)

    return jax.vmap(one)(keys, logits, rows).astype(jnp.int32)


@partial(jax.jit, static_argnums=(1,))
def stepwise_keys(key, num_steps: int):
    """The per-step sampling keys ``Sampler._generate``'s scan threads:
    step t uses the second half of the t-th split of the carried key.
    Returns (num_steps, 2) so a token-level engine can consume the same
    key schedule out of lock-step (rows admitted at different engine
    steps still index by their OWN decode step t)."""

    def body(k, _):
        k, ks = jax.random.split(k)
        return k, ks

    _, ks = jax.lax.scan(body, key, None, length=num_steps)
    return ks


class Sampler:
    """generate(): (B, Lp) left-padded prompts -> (B, max_new) responses."""

    def __init__(self, cfg: ModelConfig, max_prompt_len: int,
                 max_new_tokens: int, temperature: float = 1.0,
                 top_p: float = 1.0, eos_id: int = Tokenizer.EOS,
                 pad_id: int = Tokenizer.PAD, capture_logprobs: bool = True):
        self.cfg = cfg
        self.max_prompt_len = max_prompt_len
        self.max_new_tokens = max_new_tokens
        self.temperature = temperature
        self.top_p = top_p
        self.eos_id = eos_id
        self.pad_id = pad_id
        self.capture_logprobs = capture_logprobs
        self._gen = jax.jit(self._generate)

    # -- host-side helpers ---------------------------------------------------
    def pad_prompts(self, prompts: list) -> tuple:
        """list of 1-D int arrays -> (B, Lp) left-padded + (B,) lengths."""
        Lp = self.max_prompt_len
        B = len(prompts)
        out = np.full((B, Lp), self.pad_id, np.int32)
        lens = np.zeros((B,), np.int32)
        for i, p in enumerate(prompts):
            p = np.asarray(p, np.int32)[-Lp:]
            out[i, Lp - len(p):] = p
            lens[i] = len(p)
        return jnp.asarray(out), jnp.asarray(lens)

    def generate(self, params, prompts: list, key) -> RolloutBatch:
        toks, lens = self.pad_prompts(prompts)
        return self._gen(params, toks, lens, key)

    # -- jitted core ---------------------------------------------------------
    def _generate(self, params, prompt_ids, prompt_lens, key) -> RolloutBatch:
        cfg = self.cfg
        B, Lp = prompt_ids.shape
        T = self.max_new_tokens
        W = lm_head_weight(params["embed"], cfg)

        pad = Lp - prompt_lens[:, None]                           # (B,1)
        ar = jnp.arange(Lp, dtype=jnp.int32)[None, :]
        is_real = ar >= pad
        positions = jnp.where(is_real, ar - pad, 0).astype(jnp.int32)
        segments = jnp.where(is_real, 0, -1).astype(jnp.int32)

        caches = init_caches(params, cfg, B, Lp + T)
        h, caches, _, _ = forward_hidden(
            params, cfg, prompt_ids, positions=positions, segments=segments,
            caches=caches, cache_offset=0)
        logits0 = jnp.einsum("bd,dv->bv", h[:, -1].astype(jnp.float32),
                             W.astype(jnp.float32))

        def step(carry, xs):
            caches, logits, done, pos, key = carry
            t = xs
            key, k_s = jax.random.split(key)
            tok = _sample_token(k_s, logits, self.temperature, self.top_p)
            tok = jnp.where(done, self.pad_id, tok)
            if self.capture_logprobs:
                lp = jnp.where(done, 0.0, sampled_token_logprob(logits, tok))
                emit = (tok, lp)
            else:
                emit = tok
            done_next = done | (tok == self.eos_id)
            h, caches, _, _ = forward_hidden(
                params, cfg, tok[:, None],
                positions=pos[:, None], segments=jnp.zeros((B, 1), jnp.int32),
                caches=caches, cache_offset=Lp + t)
            logits_next = jnp.einsum("bd,dv->bv", h[:, 0].astype(jnp.float32),
                                     W.astype(jnp.float32))
            return (caches, logits_next, done_next, pos + 1, key), emit

        init = (caches, logits0, jnp.zeros((B,), bool), prompt_lens, key)
        _, emitted = jax.lax.scan(step, init, jnp.arange(T, dtype=jnp.int32))
        if self.capture_logprobs:
            toks, lps = emitted
            lps = jnp.moveaxis(lps, 0, 1)                         # (B, T)
        else:
            toks, lps = emitted, None
        toks = jnp.moveaxis(toks, 0, 1)                           # (B, T)
        # response length = index of first EOS + 1, else T
        is_eos = toks == self.eos_id
        has_eos = is_eos.any(axis=1)
        first_eos = jnp.argmax(is_eos, axis=1)
        lens = jnp.where(has_eos, first_eos + 1, T).astype(jnp.int32)
        return RolloutBatch(response_ids=toks, response_len=lens,
                            response_logprobs=lps)
