from repro.sharding.specs import (constrain, current_mesh, param_specs,
                                  set_mesh, use_mesh)

__all__ = ["constrain", "current_mesh", "param_specs", "set_mesh", "use_mesh"]
