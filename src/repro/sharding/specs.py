"""Sharding rules: logical-axis -> mesh-axis resolution.

Logical axes used throughout the model code:
  "batch"  -> (pod, data)   activations' leading batch dim
  "model"  -> model         head / ffn-hidden / vocab dims of weights
  "expert" -> model         MoE expert dim (expert parallelism)

``constrain(x, *logical)`` applies a with_sharding_constraint when (a) a mesh
has been installed via :func:`set_mesh`/:func:`use_mesh` and (b) the dim is
divisible by the mesh-axis size — otherwise it is a transparent no-op, so
model code runs unmodified in single-device tests.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()

LOGICAL_TO_MESH = {
    "batch": ("pod", "data"),
    # FSDP storage axis for weights: crosses the pod boundary on the
    # multi-pod mesh (DCN all-gathers) — without it the 235B tri-model +
    # fp32 Adam state cannot fit 16 GB/chip (see EXPERIMENTS.md).
    "data": ("pod", "data"),
    "model": ("model",),
    # expert parallelism lives on the data axis (all-to-all from the
    # batch-sharded token buffer); per-expert ffn dim is on "model".
    "expert": ("data",),
    "expert_data": ("data",),
    # Megatron-style sequence parallelism: activations' seq dim lives on the
    # model axis between tensor-parallel regions, so per-layer residuals
    # saved by the remat scan are 1/TP the size.
    "seq": ("model",),
}

# --------------------------------------------------------------------------
# sharding profiles (§Perf hillclimb) — switch the logical-axis mapping.
#
#   baseline  — paper-faithful Megatron-flavoured mapping: activations
#               batch x seq sharded (sequence parallelism on the model
#               axis), weights 2D FSDP. The KV-chunk scan then pays
#               per-chunk activation collectives (measured: the dominant
#               roofline term for dense train_4k).
#   dp2       — beyond-paper: activations sharded on batch over BOTH mesh
#               axes (("pod","data","model")), seq unsharded. All attention
#               and FFN compute is device-local; the only collectives left
#               are the FSDP weight gathers + gradient reductions.
# --------------------------------------------------------------------------

_PROFILES = {
    "baseline": dict(LOGICAL_TO_MESH),
    "dp2": {
        "batch": ("pod", "data", "model"),
        "data": ("pod", "data"),
        "model": ("model",),
        "expert": ("data",),
        "expert_data": ("data",),
        "seq": (),        # unresolvable -> no constraint
    },
    # dp2 + Megatron-style weight storage: weights/opt state sharded ONLY on
    # the model axis (replicated across data) -> zero FSDP gathers; grads
    # all-reduce across data once per step. Fits models whose bf16 tri-model
    # + fp32 Adam state / TP-degree stays under HBM (~<= 20B at TP16).
    # baseline + head-sharded attention (Megatron SP<->TP transition): the
    # seq-sharded activations are resharded to head-sharded q (+ replicated
    # k/v) ONCE per layer instead of paying per-KV-chunk collectives inside
    # the attention scan. Applies only when num_heads divides the model
    # axis (64-head archs); the constraint is a no-op otherwise.
    "sp_heads": {
        "batch": ("pod", "data"),
        "data": ("pod", "data"),
        "model": ("model",),
        "expert": ("data",),
        "expert_data": ("data",),
        "seq": ("model",),
        "heads": ("model",),
        "ffn": ("model",),
    },
    "dp2_zero1": {
        "batch": ("pod", "data", "model"),
        "data": (),
        "model": ("model",),
        "expert": ("data",),
        "expert_data": ("data",),
        "seq": (),
    },
    # inference-pool profile (the weight-plane's destination layout —
    # DESIGN.md §Weight-plane): weights TP-sharded on the model axis and
    # REPLICATED across data (data -> ()), so decode pays zero FSDP
    # gathers; activations batch-sharded. The trainer keeps its FSDP
    # profile — repro.transfer reshards leaf-by-leaf in flight.
    "infer_tp": {
        "batch": ("pod", "data"),
        "data": (),
        "model": ("model",),
        "expert": ("data",),
        "expert_data": ("data",),
        "seq": (),
    },
}


def profile_has(axis: str) -> bool:
    """True if the active profile maps this logical axis to mesh axes —
    used to gate Megatron-SP constraint groups (see models/attention.py,
    models/layers.py)."""
    return bool(LOGICAL_TO_MESH.get(axis))


def set_profile(name: str) -> None:
    """Install a sharding profile (mutates the live mapping)."""
    LOGICAL_TO_MESH.clear()
    LOGICAL_TO_MESH.update(_PROFILES[name])


def current_profile_map() -> dict:
    return dict(LOGICAL_TO_MESH)


@contextlib.contextmanager
def use_profile(name: str):
    """Temporarily install a sharding profile (restores the previous live
    mapping on exit) — used to resolve param specs under a profile other
    than the active one, e.g. the weight-plane computing its destination
    (inference) layout while the trainer profile stays installed."""
    prev = dict(LOGICAL_TO_MESH)
    set_profile(name)
    try:
        yield
    finally:
        LOGICAL_TO_MESH.clear()
        LOGICAL_TO_MESH.update(prev)


def param_specs_for_profile(params, mesh: Mesh, profile: str):
    """NamedSharding pytree for ``params`` as profile ``profile`` would
    place it — the src/dst spec trees a reshard plan is built from."""
    with use_profile(profile):
        return param_specs(params, mesh)


def set_mesh(mesh: Optional[Mesh]) -> None:
    _state.mesh = mesh


def current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    prev = current_mesh()
    set_mesh(mesh)
    try:
        with mesh:
            yield mesh
    finally:
        set_mesh(prev)


def _resolve(mesh: Mesh, dim: int, logical) -> Optional[tuple]:
    if logical is None:
        return None
    axes = tuple(a for a in LOGICAL_TO_MESH.get(logical, (logical,))
                 if a in mesh.axis_names)
    if not axes:
        return None
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    if size == 0 or dim % size:
        return None
    return axes if len(axes) > 1 else axes[0]


def spec_for(mesh: Mesh, shape: tuple, logical: tuple) -> P:
    return P(*(_resolve(mesh, d, l) for d, l in zip(shape, logical)))


def shard_map(f, mesh: Mesh, in_specs, out_specs):
    """Version-portable fully-manual shard_map with replication checking
    off (the callers' out_specs deliberately leave collectively-reduced /
    replicated axes unmentioned): newer JAX exposes ``jax.shard_map``
    with ``check_vma``, older releases only
    ``jax.experimental.shard_map.shard_map`` with ``check_rep``."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


def constrain(x: jax.Array, *logical):
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = spec_for(mesh, x.shape, logical)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# --------------------------------------------------------------------------
# parameter partition rules (matched by trailing path names)
# --------------------------------------------------------------------------

_PARAM_RULES = [
    # (name match, logical axes for the trailing dims).
    # 2D sharding: the tensor-parallel dim goes to "model", the other big dim
    # to "data" (FSDP/ZeRO-3 style) so 34B-235B params + fp32 Adam state fit
    # 16 GB HBM. Weights sharded on "data" are all-gathered at use and grads
    # reduce-scattered — the standard GSPMD FSDP pattern.
    ("embedding", ("model", "data")),
    ("lm_head", ("data", "model")),
    ("router", (None, None)),
    ("w_gate", ("data", "model")),   # (d, ff)
    ("w_up", ("data", "model")),
    ("w_down", ("model", "data")),   # (ff, d)
    ("wq", ("data", "model")),
    ("wk", ("data", "model")),
    ("wv", ("data", "model")),
    ("wo", ("model", "data")),
    ("w_dkv", ("data", "model")),
    ("w_kr", ("data", None)),
    ("w_uk", ("data", "model")),
    ("w_uv", ("data", "model")),
    ("in_proj", ("data", "model")),
    ("out_proj", ("model", "data")),
    ("conv_w", (None, "model")),
    ("conv_b", ("model",)),
    ("gate_norm", ("model",)),
]

# MoE expert weights: experts over "data" (expert parallelism, all-to-all at
# dispatch); a weight dim over "model" for ZeRO-3-style storage, all-gathered
# just-in-time inside the expert-parallel shard_map (see models/moe.py).
_EXPERT_RULES = {
    "w_gate": ("expert_data", "model", None),   # (E, d, ff)
    "w_up": ("expert_data", "model", None),
    "w_down": ("expert_data", None, "model"),   # (E, ff, d)
}


def _rule_for(path_str: str, name: str, ndim: int) -> tuple:
    is_expert = "moe" in path_str and name in _EXPERT_RULES and ndim >= 3
    if is_expert:
        base = _EXPERT_RULES[name]
        return (None,) * (ndim - 3) + base
    for key, axes in _PARAM_RULES:
        if name == key:
            axes_full = (None,) * (ndim - len(axes)) + axes
            return axes_full if len(axes_full) == ndim else (None,) * ndim
    return (None,) * ndim


# decode-cache partition rules: batch over ("pod","data"), cache length over
# "model" (sequence-sharded KV — heads are usually < 16 so the length dim is
# the shardable one); SSM state / conv tails shard on batch only.
_CACHE_RULES = {
    "k": ("batch", "seq", None, None),
    "v": ("batch", "seq", None, None),
    "pos": ("batch", "seq"),
    "seg": ("batch", "seq"),
    "ckv": ("batch", "seq", None),
    "kr": ("batch", "seq", None),
    "state": ("batch", None, None, None),
    "conv": ("batch", None, None),
}


def cache_specs(caches, mesh: Mesh):
    """NamedSharding pytree for a decode-cache pytree (leading stacked-layer
    dims padded with None)."""
    def one(path, leaf):
        keys = [getattr(e, "key", getattr(e, "name", None)) for e in path]
        keys = [k for k in keys if isinstance(k, str)]
        name = keys[-1] if keys else ""
        axes = _CACHE_RULES.get(name, ())
        logical = (None,) * (leaf.ndim - len(axes)) + axes
        logical = logical[-leaf.ndim:] if leaf.ndim else ()
        return NamedSharding(mesh, spec_for(mesh, leaf.shape, logical))

    return jax.tree_util.tree_map_with_path(one, caches)


def param_specs(params, mesh: Mesh):
    """Build a pytree of NamedSharding for a params pytree.

    With scan-over-layers, stacked layer params carry a leading layer dim
    which is handled by the (None,)*(ndim-len) padding in the rules."""
    def one(path, leaf):
        keys = [getattr(e, "key", getattr(e, "name", None)) for e in path]
        keys = [k for k in keys if isinstance(k, str)]
        name = keys[-1] if keys else ""
        logical = _rule_for("/".join(keys), name, leaf.ndim)
        return NamedSharding(mesh, spec_for(mesh, leaf.shape, logical))

    return jax.tree_util.tree_map_with_path(one, params)
