"""Speculative-decode plane (DESIGN.md §Spec-decode): draft/verify decode
for the rollout pool and the serving path, distribution-exact by rejection
sampling — the paper's Proposition 1 on-policy equality survives untouched,
unlike staleness-based speedups.

* ``verify.py``  — the exactness core: accept/reject drafted tokens against
  the k+1 target distributions one multi-token forward produces, resample
  rejections from the leftover distribution, bonus-sample after a clean
  sweep. Greedy verification is token-identical to non-spec decode.
* ``draft.py``   — pluggable draft providers: prompt-lookup n-gram reuse
  (no extra model) and a small resident draft model.
* ``sampler.py`` — ``SpecSampler``, the group-at-a-time spec engine (the
  ``Sampler`` drop-in); the dense-slot and paged engines integrate spec
  in ``core/cbatch.py`` / ``core/paged.py``.
"""
from repro.spec.draft import (ModelDraft, PromptLookupDraft, draft_config,
                              make_draft_provider)
from repro.spec.sampler import SpecSampler
from repro.spec.verify import assemble_commit, verify_block

__all__ = ["verify_block", "assemble_commit", "PromptLookupDraft",
           "ModelDraft", "draft_config", "make_draft_provider",
           "SpecSampler"]
