"""Draft providers for the spec-decode plane (DESIGN.md §Spec-decode).

Both providers are DETERMINISTIC (point-mass proposals), which is what
makes `spec/verify.py`'s accept-with-prob-p rule exact. The provider API is
slot-oriented so all three decode engines share it:

    start(slot, prompt_ids)   row admitted into a decode slot
    commit(slot, tokens)      tokens the verify step committed for the slot
    stop(slot)                row finished / evicted
    propose(slots, k)         (num_slots, k) int32 drafts for active slots

Correctness never depends on draft quality — a garbage draft is simply
rejected and costs nothing beyond the (bandwidth-cheap) k+1-token verify —
so providers are free to be heuristic.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.tokenizer import Tokenizer


class PromptLookupDraft:
    """Prompt-lookup n-gram drafting (PLD): propose the k tokens that
    followed the most recent earlier occurrence of the current context
    suffix (n-gram, longest first). No extra model, no extra memory
    traffic — RL math/code responses copy prompt content heavily, and
    greedy decode of a fixed policy falls into verbatim repetition loops,
    both of which this provider turns into multi-token accepts. With
    shared-prompt pages the prompt is already resident, so the lookup is
    pure host-side index arithmetic."""

    def __init__(self, num_slots: int, *, ngram_max: int = 3,
                 ngram_min: int = 1):
        self.B = num_slots
        self.ngram_max = ngram_max
        self.ngram_min = max(1, ngram_min)
        self._ctx: List[Optional[list]] = [None] * num_slots

    def start(self, slot: int, prompt_ids) -> None:
        self._ctx[slot] = [int(t) for t in np.asarray(prompt_ids)]

    def commit(self, slot: int, tokens) -> None:
        self._ctx[slot].extend(int(t) for t in tokens)

    def stop(self, slot: int) -> None:
        self._ctx[slot] = None

    def _lookup(self, ctx: list, k: int) -> np.ndarray:
        arr = np.asarray(ctx, np.int32)
        L = len(arr)
        for n in range(min(self.ngram_max, L - 1), self.ngram_min - 1, -1):
            pat = arr[-n:]
            # windows over arr[:-1]: start positions 0..L-1-n — the suffix
            # itself (start L-n) is excluded, overlapping starts are not
            # (self-overlap is exactly the repetition-loop case)
            win = np.lib.stride_tricks.sliding_window_view(arr[:-1], n)
            hits = np.nonzero((win == pat).all(axis=1))[0]
            if len(hits):
                i = int(hits[-1])                  # most recent occurrence
                cand = arr[i + n: i + n + k]
                if len(cand):
                    out = np.empty((k,), np.int32)
                    out[: len(cand)] = cand
                    out[len(cand):] = cand[-1]     # pad with the tail token
                    return out
        return np.full((k,), arr[-1], np.int32)    # no match: repeat last

    def propose(self, slots, k: int) -> np.ndarray:
        out = np.zeros((self.B, k), np.int32)
        for s in slots:
            out[s] = self._lookup(self._ctx[s], k)
        return out


def draft_config(cfg: ModelConfig) -> ModelConfig:
    """Default resident-draft-model shape for ``cfg``: same family and
    vocab (proposals must live in the target's token space), half the
    depth. In a real deployment the draft is a distilled checkpoint; here
    its params are independently initialised and held by the engine —
    reusing the tri-model convention of several resident param trees per
    process (core/trimodel.py)."""
    return dataclasses.replace(cfg, name=cfg.name + "-draft",
                               num_layers=max(1, cfg.num_layers // 2))


class ModelDraft:
    """Small resident draft model, greedy-decoding k proposals per step.

    The draft model free-runs: its dense cache (one row per slot,
    ``ring=False`` so every position is addressable) is advanced with the
    COMMITTED tokens each step, while `propose` speculatively decodes k
    greedy tokens from the committed frontier. Speculative entries written
    past the frontier are never visible — slot index equals position, so a
    stale entry always carries a position greater than any live query until
    the commit feed overwrites it (same argument as the verify block's
    rollback, DESIGN.md §Spec-decode)."""

    def __init__(self, cfg: ModelConfig, params, num_slots: int, *,
                 max_prompt_len: int, max_ctx: int,
                 pad_id: int = Tokenizer.PAD):
        from repro.models import forward_hidden, init_caches
        from repro.models.layers import lm_head_weight
        self.cfg = cfg
        self.params = params
        self.B = num_slots
        self.Lp = max_prompt_len
        self.L = max_ctx
        self.pad_id = pad_id
        self._fh = forward_hidden
        self._head = lm_head_weight
        self.caches = init_caches(params, cfg, num_slots, max_ctx,
                                  ring=False)
        self.logits = jnp.zeros((num_slots, cfg.vocab_size), jnp.float32)
        self.off = np.zeros((num_slots,), np.int32)   # committed frontier
        self._pending: List[list] = [[] for _ in range(num_slots)]
        self._prefill_j = jax.jit(self._prefill, donate_argnums=(0,))
        self._feed_j = jax.jit(self._feed, donate_argnums=(0,))
        self._kstep_j = jax.jit(self._kstep, donate_argnums=(0,),
                                static_argnames=("k",))

    # -- jitted cores -------------------------------------------------------

    def _prefill(self, caches, tokens, length, slot):
        """tokens: (1, Lp) right-padded; splice the row cache into
        ``slot`` and return the last-real-token logits."""
        cfg = self.cfg
        from repro.models import init_caches
        ar = jnp.arange(tokens.shape[1], dtype=jnp.int32)[None, :]
        real = ar < length
        positions = jnp.where(real, ar, 0).astype(jnp.int32)
        segments = jnp.where(real, 0, -1).astype(jnp.int32)
        row = init_caches(self.params, cfg, 1, self.L, ring=False)
        h, row, _, _ = self._fh(self.params, cfg, tokens,
                                positions=positions, segments=segments,
                                caches=row, cache_offset=0)
        W = self._head(self.params["embed"], cfg)
        h_last = jnp.take_along_axis(
            h, (length - 1)[None, :, None], axis=1)[:, 0]
        logits = jnp.einsum("bd,dv->bv", h_last.astype(jnp.float32),
                            W.astype(jnp.float32))
        splice = lambda pool, r: jax.lax.dynamic_update_slice_in_dim(
            pool, r, slot, axis=1)
        return jax.tree.map(splice, caches, row), logits[0]

    def _feed(self, caches, logits, tokens, counts, offsets, active):
        """Advance the committed frontier: tokens (B, C) right-padded
        commit blocks, counts (B,) real lengths. Per-row multi-token
        decode write; rows with count 0 keep their logits."""
        cfg = self.cfg
        B, C = tokens.shape
        ar = jnp.arange(C, dtype=jnp.int32)[None, :]
        real = active[:, None] & (ar < counts[:, None])
        positions = jnp.where(real, offsets[:, None] + ar, 2**30)
        segments = jnp.where(real, 0, -1).astype(jnp.int32)
        h, caches, _, _ = self._fh(self.params, cfg, tokens,
                                   positions=positions.astype(jnp.int32),
                                   segments=segments, caches=caches,
                                   cache_offset=offsets)
        W = self._head(self.params["embed"], cfg)
        h_last = jnp.take_along_axis(
            h, jnp.maximum(counts - 1, 0)[:, None, None], axis=1)[:, 0]
        new_logits = jnp.einsum("bd,dv->bv", h_last.astype(jnp.float32),
                                W.astype(jnp.float32))
        logits = jnp.where((active & (counts > 0))[:, None], new_logits,
                           logits)
        return caches, logits

    def _kstep(self, caches, logits, offsets, active, *, k: int):
        """k fused greedy draft steps (one ``lax.scan`` — the draft-plane
        piece of the device-resident decode loop, DESIGN.md
        §Device-resident-decode): every step argmax-decodes one token per
        active slot and writes it past the committed frontier
        (speculative — masked until committed or overwritten). Returns
        (toks (k, B), caches); the carried logits/offsets are local to the
        proposal and deliberately discarded."""
        cfg = self.cfg

        def body(carry, _):
            caches, logits, off = carry
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            tok = jnp.where(active, tok, self.pad_id)
            positions = jnp.where(active, off, 2**30).astype(
                jnp.int32)[:, None]
            segments = jnp.where(active, 0, -1).astype(jnp.int32)[:, None]
            h, caches, _, _ = self._fh(self.params, cfg, tok[:, None],
                                       positions=positions,
                                       segments=segments, caches=caches,
                                       cache_offset=jnp.where(
                                           active, off, 0).astype(jnp.int32))
            W = self._head(self.params["embed"], cfg)
            logits = jnp.einsum("bd,dv->bv", h[:, 0].astype(jnp.float32),
                                W.astype(jnp.float32))
            off = off + active.astype(jnp.int32)
            return (caches, logits, off), tok

        (caches, _, _), toks = jax.lax.scan(
            body, (caches, logits, offsets), None, length=k)
        return toks, caches

    # -- provider API -------------------------------------------------------

    def start(self, slot: int, prompt_ids) -> None:
        p = np.asarray(prompt_ids, np.int32)[-self.Lp:]
        row = np.full((1, self.Lp), self.pad_id, np.int32)
        row[0, : len(p)] = p
        self.caches, lg = self._prefill_j(
            self.caches, jnp.asarray(row),
            jnp.asarray([len(p)], jnp.int32), slot)
        self.logits = self.logits.at[slot].set(lg)
        self.off[slot] = len(p)
        self._pending[slot] = []

    def commit(self, slot: int, tokens) -> None:
        self._pending[slot].extend(int(t) for t in tokens)

    def stop(self, slot: int) -> None:
        self._pending[slot] = []

    def propose(self, slots, k: int) -> np.ndarray:
        B = self.B
        # flush buffered commits in one fixed-width multi-token feed
        C = max((len(p) for p in self._pending), default=0)
        if C:
            toks = np.full((B, C), self.pad_id, np.int32)
            counts = np.zeros((B,), np.int32)
            active = np.zeros((B,), bool)
            for s in range(B):
                n = len(self._pending[s])
                if n:
                    toks[s, :n] = self._pending[s]
                    counts[s] = n
                    active[s] = True
                    self._pending[s] = []
            self.caches, self.logits = self._feed_j(
                self.caches, self.logits, jnp.asarray(toks),
                jnp.asarray(counts), jnp.asarray(self.off),
                jnp.asarray(active))
            self.off += counts
        # k speculative greedy steps from the committed frontier, fused
        # into ONE jitted scan (one trace per distinct k)
        active = np.zeros((B,), bool)
        active[list(slots)] = True
        toks, self.caches = self._kstep_j(
            self.caches, self.logits, jnp.asarray(self.off),
            jnp.asarray(active), k=k)
        # repro: allow(host-sync): one readback per k-step draft scan
        # feeding the host-side proposal buffer, not per draft token —
        # DESIGN.md §Device-resident-decode
        return np.asarray(toks).T.copy()       # (B, k)


def make_draft_provider(kind: str, cfg: ModelConfig, num_slots: int, *,
                        spec_k: int, ngram: int = 3,
                        max_prompt_len: int, max_new_tokens: int,
                        pad_id: int = Tokenizer.PAD, draft_params=None,
                        draft_cfg: Optional[ModelConfig] = None, seed: int = 0):
    """Build a draft provider for an engine with ``num_slots`` slots.

    ``kind``: "prompt_lookup" (default, no extra model) or "model" (small
    resident draft model; params independently initialised from ``seed``
    unless supplied)."""
    if kind == "prompt_lookup":
        return PromptLookupDraft(num_slots, ngram_max=ngram)
    if kind == "model":
        dcfg = draft_cfg or draft_config(cfg)
        if draft_params is None:
            from repro.models import init
            draft_params = init(jax.random.PRNGKey(seed ^ 0x5bec), dcfg)
        max_ctx = max_prompt_len + max_new_tokens + spec_k + 2
        return ModelDraft(dcfg, draft_params, num_slots,
                          max_prompt_len=max_prompt_len, max_ctx=max_ctx,
                          pad_id=pad_id)
    raise KeyError(f"unknown draft provider {kind!r}; "
                   f"known: prompt_lookup, model")
