"""SpecSampler — the group-at-a-time speculative rollout engine
(DESIGN.md §Spec-decode): the ``rl/rollout.py Sampler`` drop-in that
decodes k+1 tokens per target forward instead of 1.

Per step, each live row proposes k draft tokens (host-side provider), then
ONE jitted k+1-token verify forward produces the k+1 conditional target
distributions; `spec/verify.py` accepts a leading run of drafts and samples
one tail token, so a row commits between 1 and k+1 tokens per forward.
Greedy decode is bitwise token-identical to the Sampler (the argmax chain
is the same chain); sampled decode draws exactly from the target policy.

State invariant between steps (shared with the cbatch / paged spec paths):
the cache holds every committed token EXCEPT the last one, which rides
into the next verify block as its first fed token. A freshly prefilled row
instead holds its last-prompt logits in hand (``fresh``), and its first
block carries k drafts plus one masked pad slot — the same (k+1) shape, so
one compiled program serves both phases. Rejected speculative cache
entries need no explicit rollback: slot index equals position, every stale
entry carries a position past the committed frontier (masked by causality)
until the next block's writes cover it.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, require_engine_support
from repro.data.tokenizer import Tokenizer
from repro.models import forward_hidden, init_caches
from repro.models.attention import INVALID_POS
from repro.models.layers import lm_head_weight
from repro.rl.rollout import RolloutBatch, Sampler
from repro.spec.draft import make_draft_provider
from repro.spec.verify import commit_block, verify_block


def pack_row_block(tokens_row, pos_row, seg_row, fresh: bool, draft_row,
                   last_tok: int, pos_base: int, k: int) -> int:
    """Fill ONE row of the (k+1) verify-block arrays in place and return
    the row's cache-slot delta from its frontier: a fresh row packs
    [d_1..d_k, masked pad] starting AT the frontier (delta 0); a steady
    row packs [unfed last token, d_1..d_k] starting one before it
    (delta -1). ``pos_base`` is the frontier's sequence position (prompt
    len + committed count). Shared by every spec engine so the block
    layout cannot drift between them."""
    if fresh:
        tokens_row[:k] = draft_row
        pos_row[:k] = pos_base + np.arange(k)
        seg_row[:k] = 0
        return 0
    tokens_row[0] = last_tok
    tokens_row[1:] = draft_row
    pos_row[:] = pos_base - 1 + np.arange(k + 1)
    seg_row[:] = 0
    return -1


def truncate_commit(ct, cl, remaining: int, eos_id: int):
    """Cap one row's committed tokens at its remaining budget and its
    first EOS (inclusive, matching the Sampler's length rule). Returns
    (tokens, logprobs, finished)."""
    ct, cl = ct[:remaining], cl[:remaining]
    if eos_id in ct:
        n = ct.index(eos_id) + 1
        ct, cl = ct[:n], cl[:n]
    done = (bool(ct) and ct[-1] == eos_id) or len(ct) >= remaining
    return ct, cl, done


def dense_verify_step(cfg, temperature, top_p, capture, params, caches,
                      tokens, positions, segs, offsets, prev_logits, fresh,
                      draft, keys, folds):
    """One k+1-token verify forward against a dense/ring cache — the step
    both dense spec engines (this module's SpecSampler and
    ``core/cbatch.py``'s spec path) jit with (cfg, temperature, top_p,
    capture) bound. ``fresh`` rows use their prefill logits as p_0 (their
    block's last slot is a masked pad); steady rows' p_0..p_k are all
    outputs of this forward. The accept/commit walk runs ON DEVICE
    (``commit_block``, DESIGN.md §Device-resident-decode), so the step
    returns right-padded (B, k+1) commit buffers + per-row counts:
    (toks, lps, count, caches)."""
    h, caches, _, _ = forward_hidden(
        params, cfg, tokens, positions=positions, segments=segs,
        caches=caches, cache_offset=offsets)
    W = lm_head_weight(params["embed"], cfg)
    out = jnp.einsum("bsd,dv->bsv", h.astype(jnp.float32),
                     W.astype(jnp.float32))                # (B, k+1, V)
    p = jnp.where(fresh[:, None, None],
                  jnp.concatenate([prev_logits[:, None], out[:, :-1]],
                                  axis=1),
                  out)
    accept, alt, lp_d, lp_a = verify_block(
        p, draft, keys, folds, temperature=temperature, top_p=top_p,
        capture=capture)
    toks, lps, count = commit_block(accept, alt, draft, lp_d, lp_a)
    return toks, lps, count, caches


class SpecSampler:
    """generate(): (B, Lp) left-padded prompts -> (B, max_new) responses,
    k+1 tokens per target forward. Same construction surface as Sampler
    plus the spec knobs (RLConfig.spec_*)."""

    def __init__(self, cfg: ModelConfig, max_prompt_len: int,
                 max_new_tokens: int, *, spec_k: int = 4,
                 draft: str = "prompt_lookup", ngram: int = 3,
                 draft_params=None, draft_cfg: Optional[ModelConfig] = None,
                 temperature: float = 1.0, top_p: float = 1.0,
                 eos_id: int = Tokenizer.EOS, pad_id: int = Tokenizer.PAD,
                 capture_logprobs: bool = True, seed: int = 0):
        require_engine_support(cfg, "spec")
        if spec_k < 1:
            raise ValueError(f"spec_k must be >= 1, got {spec_k}")
        self.cfg = cfg
        self.Lp = self.max_prompt_len = max_prompt_len
        self.T = self.max_new_tokens = max_new_tokens
        self.k = spec_k
        self.temperature = temperature
        self.top_p = top_p
        self.eos_id = eos_id
        self.pad_id = pad_id
        self.capture_logprobs = capture_logprobs
        self._draft_kw = dict(kind=draft, cfg=cfg, spec_k=spec_k,
                              ngram=ngram, max_prompt_len=max_prompt_len,
                              max_new_tokens=max_new_tokens, pad_id=pad_id,
                              draft_params=draft_params,
                              draft_cfg=draft_cfg, seed=seed)
        self._providers = {}           # batch size -> provider (jit reuse)
        self._prefill = jax.jit(self._prefill_fn)
        from functools import partial
        self._vstep = jax.jit(
            partial(dense_verify_step, cfg, temperature, top_p,
                    capture_logprobs),
            donate_argnums=(1,))
        self.pad_prompts = Sampler.pad_prompts.__get__(self)
        self.reset_stats()

    # -- stats --------------------------------------------------------------

    def reset_stats(self) -> None:
        self.spec_steps = 0            # verify forwards (row-steps)
        self.drafted_tokens = 0
        self.accepted_tokens = 0       # drafts that survived verification
        self.committed_tokens = 0      # tokens actually emitted

    @property
    def acceptance_rate(self) -> float:
        return (self.accepted_tokens / self.drafted_tokens
                if self.drafted_tokens else 0.0)

    # -- jitted cores -------------------------------------------------------

    def _prefill_fn(self, params, prompt_ids, prompt_lens):
        """The Sampler's prefill, verbatim: left-padded prompts, cache
        sized Lp + T + k + 1 (speculative slack; ring_slack widens windowed
        rings the same way)."""
        cfg = self.cfg
        B, Lp = prompt_ids.shape
        W = lm_head_weight(params["embed"], cfg)
        pad = Lp - prompt_lens[:, None]
        ar = jnp.arange(Lp, dtype=jnp.int32)[None, :]
        is_real = ar >= pad
        positions = jnp.where(is_real, ar - pad, 0).astype(jnp.int32)
        segments = jnp.where(is_real, 0, -1).astype(jnp.int32)
        caches = init_caches(params, cfg, B, Lp + self.T + self.k + 1,
                             ring_slack=self.k + 1)
        h, caches, _, _ = forward_hidden(
            params, cfg, prompt_ids, positions=positions, segments=segments,
            caches=caches, cache_offset=0)
        logits0 = jnp.einsum("bd,dv->bv", h[:, -1].astype(jnp.float32),
                             W.astype(jnp.float32))
        return caches, logits0

    # -- host loop ----------------------------------------------------------

    def _drain_verify(self, ctoks, clps, count):
        """Drain one fused verify block's commit buffers — the accept/
        commit walk already ran on device (``commit_block``), so this is
        the loop's only device->host touch, once per k+1-token block."""
        for buf in (ctoks, clps, count):
            if hasattr(buf, "copy_to_host_async"):
                buf.copy_to_host_async()
        # repro: allow(host-sync): one buffered readback per verify block
        # (device-side commit walk) — DESIGN.md §Device-resident-decode
        return jax.device_get((ctoks, clps, count))

    def _commit_rows(self, active, ctoks, clps, count, resp, lps, done,
                     fresh, provider) -> None:
        """Drain one verify block and commit its rows — the host half of
        the loop body, one frame below the hot entry point so the hot
        tier itself stays sync-free (DESIGN.md §Device-resident-decode).
        After the buffered drain the walk touches only host numpy."""
        k, T = self.k, self.T
        ctoks, clps, count = self._drain_verify(ctoks, clps, count)
        for b in active:
            n = int(count[b])
            ct = [int(t) for t in ctoks[b, :n]]
            cl = [float(x) for x in clps[b, :n]]
            self.spec_steps += 1
            self.drafted_tokens += k
            self.accepted_tokens += n - 1
            ct, cl, row_done = truncate_commit(
                ct, cl, T - len(resp[b]), self.eos_id)
            resp[b].extend(ct)
            lps[b].extend(cl)
            provider.commit(b, ct)
            self.committed_tokens += len(ct)
            fresh[b] = False
            if row_done:
                done[b] = True
                provider.stop(b)

    def generate(self, params, prompts: list, key) -> RolloutBatch:
        toks, lens = self.pad_prompts(prompts)
        B = len(prompts)
        k, T, Lp = self.k, self.T, self.Lp
        caches, logits0 = self._prefill(params, toks, lens)
        if B not in self._providers:
            kw = dict(self._draft_kw)
            self._providers[B] = make_draft_provider(
                kw.pop("kind"), kw.pop("cfg"), B, **kw)
        provider = self._providers[B]
        plens = np.asarray(lens)
        for b, p in enumerate(prompts):
            provider.start(b, np.asarray(p, np.int32)[-Lp:])
        # per-row keys stay device-resident — the verify step is their
        # only consumer (§Device-resident-decode)
        row_keys = jax.random.split(key, B)
        resp = [[] for _ in range(B)]
        lps = [[] for _ in range(B)]
        done = np.zeros((B,), bool)
        fresh = np.ones((B,), bool)
        step = 0
        while not done.all():
            active = [b for b in range(B) if not done[b]]
            draft = provider.propose(active, k)               # (B, k)
            tokens = np.full((B, k + 1), self.pad_id, np.int32)
            positions = np.full((B, k + 1), int(INVALID_POS), np.int32)
            segs = np.full((B, k + 1), -1, np.int32)
            offs = np.full((B,), Lp, np.int32)
            for b in active:
                t = len(resp[b])
                delta = pack_row_block(tokens[b], positions[b], segs[b],
                                       fresh[b], draft[b],
                                       resp[b][-1] if resp[b] else 0,
                                       int(plens[b]) + t, k)
                offs[b] = Lp + t + delta
            folds = np.full((B,), step, np.int32)
            ctoks, clps, count, caches = self._vstep(
                params, caches, jnp.asarray(tokens), jnp.asarray(positions),
                jnp.asarray(segs), jnp.asarray(offs), logits0,
                jnp.asarray(fresh), jnp.asarray(draft),
                row_keys, jnp.asarray(folds))
            self._commit_rows(active, ctoks, clps, count, resp, lps,
                              done, fresh, provider)
            step += 1
        out = np.full((B, T), self.pad_id, np.int32)
        out_lp = np.zeros((B, T), np.float32)
        out_len = np.zeros((B,), np.int32)
        for b in range(B):
            n = len(resp[b])
            out[b, :n] = resp[b]
            out_lp[b, :n] = lps[b]
            out_len[b] = n
        return RolloutBatch(
            response_ids=jnp.asarray(out),
            response_len=jnp.asarray(out_len),
            response_logprobs=(jnp.asarray(out_lp)
                               if self.capture_logprobs else None))
