"""Rejection-sampling verification — the exactness core of the spec-decode
plane (DESIGN.md §Spec-decode).

One k+1-token target forward yields the k+1 conditional distributions
p_0..p_k (p_j = p(. | context, d_1..d_j)). Every draft provider here is
DETERMINISTIC (a point-mass proposal q = delta_d), so the standard
speculative rejection rule specialises to:

  * accept d_{j+1} with probability p_j(d_{j+1})  (min(1, p/q) with q = 1);
  * on the first rejection at j, resample from the leftover distribution
    norm(max(p_j - q, 0)) = p_j with d_{j+1} masked out, renormalised;
  * after a clean sweep of all k drafts, draw a free "bonus" token from
    p_k.

The marginal of each committed token is exactly p_j — the target policy's
own distribution (tests/test_spec_property.py proves it empirically under
hypothesis) — so GRPO rollouts remain draws from the current policy and
Proposition 1 is untouched. Greedy decode (temperature <= 0) degenerates to
"accept iff the draft IS the argmax", which makes spec decode bitwise
token-identical to non-spec greedy decode (tests/test_spec.py).

Acceptance tests use the FILTERED distribution (temperature / top-p — the
distribution the engines actually sample from), while the returned logprobs
are RAW-distribution values: exactly what `capture_logprobs` ships to the
trainer, now obtained from the verify pass for free (§Tri-model-capture).
"""
from __future__ import annotations

from functools import partial
from typing import List, Tuple

import jax
import jax.numpy as jnp

from repro.rl.rollout import _filter_logits


@partial(jax.jit, static_argnames=("temperature", "top_p", "capture"))
def verify_block(logits, draft, keys, folds, *, temperature: float,
                 top_p: float, capture: bool = True):
    """Verify one k+1-token block for every row.

    logits: (B, k+1, V) RAW target logits — logits[:, j] is p_j, the
    distribution of the j-th candidate position; draft: (B, k) int32
    deterministic proposals; keys: (B, 2) raw uint32 per-row step keys;
    folds: (B,) int32 decorrelation values folded into each row's key (the
    paged engine folds the GRPO row index — rows of a group share step
    keys; the group engines fold the step counter).

    Returns (accept, alt, lp_draft, lp_alt):
      accept: (B, k) bool — draft j accepted under p_j;
      alt:    (B, k+1) int32 — the leftover resample at j < k, the bonus
              draw at j = k (valid wherever the commit walk lands on it);
      lp_draft: (B, k) f32 raw log p_j(draft_j) (capture payload);
      lp_alt:   (B, k+1) f32 raw log p_j(alt_j).

    ``capture=False`` (serving: no trainer consumes behavior logprobs)
    skips the full-vocab raw log-softmax and returns zero lp arrays —
    the same deliberate saving the non-spec decode step makes
    (§Tri-model-capture cost note).

    ``assemble_commit`` below walks these on the host into the committed
    token list (variable length per row — exactly what the token-level
    SlotScheduler supports).
    """
    B, K1, V = logits.shape
    k = K1 - 1
    lg = logits.astype(jnp.float32)

    if temperature <= 0.0:
        # greedy: the target "distribution" is a point mass at the argmax —
        # accept iff the draft is it, and every alternative IS the argmax.
        alt = jnp.argmax(lg, axis=-1).astype(jnp.int32)        # (B, k+1)
        accept = draft == alt[:, :k]
    else:
        filt = _filter_logits(lg.reshape(B * K1, V), temperature,
                              top_p).reshape(B, K1, V)
        logp_f = jax.nn.log_softmax(filt, axis=-1)

        def row_keys(key, fold):
            kr = jax.random.fold_in(key, fold)
            return jax.vmap(
                lambda j: jax.random.split(jax.random.fold_in(kr, j))
            )(jnp.arange(K1))                                  # (K1, 2, 2)

        ks = jax.vmap(row_keys)(keys, folds)
        ku, kc = ks[:, :, 0], ks[:, :, 1]
        u = jax.vmap(jax.vmap(jax.random.uniform))(ku)         # (B, K1)
        p_draft = jnp.exp(jnp.take_along_axis(
            logp_f[:, :k], draft[..., None], axis=-1))[..., 0]
        accept = u[:, :k] < p_draft
        # leftover distribution: p_j masked at the draft, renormalised by
        # the categorical itself; position k (bonus) is unmasked (draft -1
        # matches no vocab id). A fully-degenerate leftover (p_draft == 1)
        # is never sampled — acceptance is certain.
        draft_pad = jnp.concatenate(
            [draft, jnp.full((B, 1), -1, jnp.int32)], axis=1)
        iota = jnp.arange(V, dtype=jnp.int32)
        masked = jnp.where(iota[None, None, :] == draft_pad[..., None],
                           -jnp.inf, filt)
        alt = jax.vmap(jax.vmap(jax.random.categorical))(
            kc, masked).astype(jnp.int32)                      # (B, k+1)

    if not capture:
        return (accept, alt, jnp.zeros((B, k), jnp.float32),
                jnp.zeros((B, K1), jnp.float32))
    raw_lp = jax.nn.log_softmax(lg, axis=-1)
    lp_draft = jnp.take_along_axis(raw_lp[:, :k], draft[..., None],
                                   axis=-1)[..., 0]
    lp_alt = jnp.take_along_axis(raw_lp, alt[..., None], axis=-1)[..., 0]
    return accept, alt, lp_draft, lp_alt


def commit_block(accept, alt, draft, lp_draft, lp_alt):
    """Device-side ``assemble_commit`` for every row at once (the fused
    verify step of the device-resident decode loop, DESIGN.md
    §Device-resident-decode): the commit is the leading run of accepted
    drafts plus one tail token, assembled with vector ops so the engines
    read back ONE right-padded (B, k+1) buffer per verify block instead
    of walking accept/alt on the host.

    Returns (toks, lps, count):
      toks:  (B, k+1) int32 — committed tokens, right-padded with 0 past
             ``count`` (callers slice before use);
      lps:   (B, k+1) f32 raw logprobs, same layout;
      count: (B,) int32 in 1..k+1 — committed tokens per row.

    Bitwise identical to ``assemble_commit`` row by row: the leading-run
    length is ``n = sum(cumprod(accept))`` and the tail is ``alt[n]``.
    """
    B, k = draft.shape
    n = jnp.cumprod(accept.astype(jnp.int32), axis=1).sum(axis=1)  # (B,)
    j = jnp.arange(k + 1, dtype=jnp.int32)[None, :]
    pad_i = jnp.zeros((B, 1), jnp.int32)
    pad_f = jnp.zeros((B, 1), jnp.float32)
    tail_t = jnp.take_along_axis(alt, n[:, None], axis=1)          # (B, 1)
    tail_l = jnp.take_along_axis(lp_alt, n[:, None], axis=1)
    toks = jnp.where(j < n[:, None],
                     jnp.concatenate([draft, pad_i], axis=1),
                     jnp.where(j == n[:, None], tail_t, 0))
    lps = jnp.where(j < n[:, None],
                    jnp.concatenate([lp_draft, pad_f], axis=1),
                    jnp.where(j == n[:, None], tail_l, 0.0))
    return toks.astype(jnp.int32), lps.astype(jnp.float32), n + 1


def assemble_commit(accept, alt, draft, lp_draft,
                    lp_alt) -> Tuple[List[int], List[float]]:
    """Walk ONE row's verify outputs into its committed tokens (host side).

    The commit is the leading run of accepted drafts plus one sampled tail
    token (the leftover resample at the first rejection, or the bonus draw
    after a clean sweep) — between 1 and k+1 tokens.

    Returns (tokens, raw_logprobs) of equal length; the caller truncates at
    EOS / the per-row cap and rolls back speculative cache state past the
    committed frontier.
    """
    k = len(draft)
    n = 0
    while n < k and bool(accept[n]):
        n += 1
    toks = [int(t) for t in draft[:n]] + [int(alt[n])]
    lps = [float(l) for l in lp_draft[:n]] + [float(lp_alt[n])]
    return toks, lps
