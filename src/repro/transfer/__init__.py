"""Weight-plane: versioned, resharding, overlap-capable trainer->pool
parameter transfer (DESIGN.md §Weight-plane).

    build_plan  -> per-leaf reshard plan coalesced into wire buckets
    VersionedParamStore -> per-instance double buffer, atomic (params,
                           version) flips
    WeightTransferService -> publish / publish_async / ensure (the
                             iteration-boundary barrier + sync-gap meter)
"""
from repro.transfer.plan import (Bucket, LeafPlan, TransferPlan, build_plan,
                                 flatten_with_keys, pack_bucket,
                                 unpack_bucket)
from repro.transfer.service import VersionedParamStore, WeightTransferService

__all__ = [
    "Bucket", "LeafPlan", "TransferPlan", "build_plan", "flatten_with_keys",
    "pack_bucket", "unpack_bucket",
    "VersionedParamStore", "WeightTransferService",
]
