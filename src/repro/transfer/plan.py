"""Reshard plans: per-leaf trainer->pool transfer descriptions, coalesced
into fixed-size buckets (the weight-plane's unit of streaming).

The trainer and the inference pool hold the SAME parameter pytree under
DIFFERENT sharding layouts (e.g. trainer FSDP/DP profile vs inference
TP/replicated profile — see ``sharding/specs.py`` profiles). A
:class:`TransferPlan` records, per leaf, the source and destination
placements plus the wire dtype, and groups leaves into buckets of at most
``bucket_bytes`` wire bytes so the iteration-boundary weight push is a
stream of bounded chunks rather than one whole-tree op:

  * a chunk can be in flight while the previous one is still landing
    (the service overlaps buckets with the trainer's iteration tail);
  * a destination flips to the new version only once EVERY bucket of that
    version has landed — partial trees are never observable.

Leaves larger than ``bucket_bytes`` get a bucket of their own (they are
never split: a leaf is the atomic unit of the device_put reshard).

Packing is value-preserving by default (``wire_dtype=None`` streams the
storage dtype — pushed params are bitwise-identical to the source tree).
An explicit ``wire_dtype`` (e.g. bf16 payload while fp32 master weights
stay trainer-side) casts on pack and re-casts on unpack; the plan records
both dtypes so the destination always materialises the storage dtype.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "::"


def flatten_with_keys(tree) -> Tuple[List[str], list, "jax.tree_util.PyTreeDef"]:
    """(path keys, leaves, treedef) with checkpoint-compatible path keys."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys, leaves = [], []
    for path, leaf in flat:
        keys.append(_SEP.join(
            str(getattr(e, "key", getattr(e, "idx", getattr(e, "name", e))))
            for e in path))
        leaves.append(leaf)
    return keys, leaves, treedef


def _dtype_bytes(dtype) -> int:
    return jnp.dtype(dtype).itemsize


@dataclasses.dataclass(frozen=True)
class LeafPlan:
    key: str                      # pytree path (checkpoint-style)
    index: int                    # position in tree_flatten order
    shape: tuple
    dtype: str                    # storage dtype (destination materialises this)
    wire_dtype: str               # dtype on the wire (== dtype unless casting)
    wire_bytes: int
    src_spec: Optional[object]    # NamedSharding / None (trainer placement)
    dst_spec: Optional[object]    # NamedSharding / None (pool placement)

    @property
    def resharded(self) -> bool:
        """True when source and destination placements differ — the leaf
        changes layout in flight (FSDP shard -> TP/replicated, etc.)."""
        s = getattr(self.src_spec, "spec", self.src_spec)
        d = getattr(self.dst_spec, "spec", self.dst_spec)
        return s != d


@dataclasses.dataclass(frozen=True)
class Bucket:
    bid: int
    indices: Tuple[int, ...]      # leaf indices (tree_flatten order)
    wire_bytes: int


@dataclasses.dataclass(frozen=True)
class TransferPlan:
    leaves: Tuple[LeafPlan, ...]
    buckets: Tuple[Bucket, ...]
    treedef: object
    total_wire_bytes: int

    @property
    def num_resharded(self) -> int:
        return sum(1 for l in self.leaves if l.resharded)

    def describe(self) -> dict:
        sizes = [b.wire_bytes for b in self.buckets]
        return {"leaves": len(self.leaves), "buckets": len(self.buckets),
                "total_wire_bytes": self.total_wire_bytes,
                "max_bucket_bytes": max(sizes) if sizes else 0,
                "resharded_leaves": self.num_resharded}


def build_plan(params, *, bucket_bytes: int,
               src_specs=None, dst_specs=None,
               wire_dtype: Optional[str] = None) -> TransferPlan:
    """Compute the per-leaf plan and coalesce into buckets.

    ``src_specs`` / ``dst_specs`` are pytrees of placements matching
    ``params`` (e.g. from ``sharding.specs.param_specs`` under the trainer
    and inference profiles); either may be None (single-device / unplaced).
    Bucketing is greedy first-fit in tree-flatten order, so the bucket list
    is a pure function of (tree structure, shapes, dtypes, bucket_bytes) —
    source and destination always agree on it.
    """
    assert bucket_bytes > 0, "bucket_bytes must be positive"
    keys, leaves, treedef = flatten_with_keys(params)
    src_flat = (flatten_with_keys(src_specs)[1] if src_specs is not None
                else [None] * len(leaves))
    dst_flat = (flatten_with_keys(dst_specs)[1] if dst_specs is not None
                else [None] * len(leaves))
    assert len(src_flat) == len(leaves) and len(dst_flat) == len(leaves), \
        "spec trees must match the param tree structure"

    plans: List[LeafPlan] = []
    for i, (k, leaf) in enumerate(zip(keys, leaves)):
        storage = str(jnp.asarray(leaf).dtype)
        wire = wire_dtype or storage
        nbytes = int(np.prod(leaf.shape, dtype=np.int64)) * _dtype_bytes(wire) \
            if leaf.shape else _dtype_bytes(wire)
        plans.append(LeafPlan(key=k, index=i, shape=tuple(leaf.shape),
                              dtype=storage, wire_dtype=wire,
                              wire_bytes=nbytes, src_spec=src_flat[i],
                              dst_spec=dst_flat[i]))

    buckets: List[Bucket] = []
    cur: List[int] = []
    cur_bytes = 0
    for lp in plans:
        if cur and cur_bytes + lp.wire_bytes > bucket_bytes:
            buckets.append(Bucket(len(buckets), tuple(cur), cur_bytes))
            cur, cur_bytes = [], 0
        cur.append(lp.index)
        cur_bytes += lp.wire_bytes
    if cur:
        buckets.append(Bucket(len(buckets), tuple(cur), cur_bytes))

    return TransferPlan(leaves=tuple(plans), buckets=tuple(buckets),
                        treedef=treedef,
                        total_wire_bytes=sum(l.wire_bytes for l in plans))


# --------------------------------------------------------------------------
# pack / unpack — the per-bucket wire operations
# --------------------------------------------------------------------------

def pack_bucket(plan: TransferPlan, leaves: Sequence, bucket: Bucket,
                *, cast_fn: Optional[Callable] = None) -> list:
    """Source side: the bucket's leaves as wire arrays (cast to the wire
    dtype when the plan says so; identity otherwise — bitwise pass-through).
    ``cast_fn(x, dtype)`` defaults to ``x.astype``; the Pallas fused
    cast+copy kernel (``kernels/transfer_cast.py``) slots in here."""
    out = []
    for i in bucket.indices:
        lp = plan.leaves[i]
        x = leaves[i]
        if lp.wire_dtype != lp.dtype:
            x = (cast_fn(x, lp.wire_dtype) if cast_fn is not None
                 else jnp.asarray(x).astype(lp.wire_dtype))
        out.append(x)
    return out


def unpack_bucket(plan: TransferPlan, bucket: Bucket, arrays: Sequence
                  ) -> List[Tuple[int, jax.Array]]:
    """Destination side: restore storage dtype and apply the destination
    placement. Returns [(leaf index, placed array)] — the store splices
    these into its staging buffer. The device_put against ``dst_spec`` IS
    the reshard: XLA moves only the shards each destination device needs.
    """
    out = []
    for i, x in zip(bucket.indices, arrays):
        lp = plan.leaves[i]
        x = jnp.asarray(x)
        if lp.wire_dtype != lp.dtype:
            x = x.astype(lp.dtype)
        x = jax.device_put(x, lp.dst_spec) if lp.dst_spec is not None \
            else jax.device_put(x)
        out.append((i, x))
    return out
