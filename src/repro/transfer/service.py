"""The weight-plane: a versioned, double-buffered trainer->pool parameter
transfer service (the LlamaRL-DDMA / AsyncFlow-streaming analogue, kept
strictly on-policy).

Pieces
------
:class:`VersionedParamStore` — per-instance double buffer. Readers take an
ATOMIC ``(params, version)`` snapshot (fixing the torn-read race the old
``InferenceInstance.sync_weights`` had: version *i* read, version *i+1*
params sampled). Writers stage bucket deliveries for a new version into the
back buffer and flip front<->back only once EVERY bucket of that version
has landed — a partially-transferred tree is never observable.

:class:`WeightTransferService` — drives a pool of stores from a
:class:`~repro.transfer.plan.TransferPlan`. The trainer ``publish``\\ es at
the iteration boundary; with overlap enabled the bucket stream runs on a
background thread starting the moment the optimizer update materialises new
params, so the wire time hides under the trainer's iteration tail (stats
bookkeeping, straggler producers, the off-policy baseline's early grad
steps) instead of extending the boundary. ``ensure`` is the boundary
barrier: it blocks until every instance has flipped to the published
version and reports the residual block time — the pool's sync-gap.

Why overlap cannot break Proposition 1: rollouts are version-GATED, not
time-gated. A generation request for iteration *i* carries ``min_version=i``
and blocks until the store's active buffer holds version *i*; the flip is
atomic; and in strict modes the scheduler's boundary ``ensure`` runs after
the queue drain, so no request is in flight while a flip lands (the paged
engine additionally asserts quiescence in its ``set_params``). Every
sampled token therefore provably comes from the iteration-*i* policy —
``OnPolicyMonitor`` re-asserts the equality at consumption.
"""
from __future__ import annotations

import threading
import time
from typing import List, Optional

import jax
import numpy as np

from repro.obs import trace as otrace
from repro.obs.metrics import metrics
from repro.transfer.plan import (TransferPlan, build_plan, pack_bucket,
                                 unpack_bucket)


class VersionedParamStore:
    """Double-buffered (params, version) pair with staged bucket delivery."""

    def __init__(self, name: str = "store", on_flip=None,
                 defer_flip: bool = False):
        self.name = name
        # hook run under the flip lock with the NEW params (e.g. the paged
        # engine's set_params, which asserts decode quiescence)
        self.on_flip = on_flip
        # True when background flips are unsafe (paged engines need
        # quiescence) — the service then leaves the buffer staged and the
        # boundary ``ensure`` performs the flip after the queue drain
        self.defer_flip = defer_flip
        self._cv = threading.Condition()
        self._params = None
        self._version = -1
        self._staging: Optional[dict] = None
        self._failed: Optional[BaseException] = None
        self.flips = 0

    # -- reader side --------------------------------------------------------
    @property
    def version(self) -> int:
        with self._cv:
            return self._version

    def snapshot(self) -> tuple:
        """Atomic (params, version) — the pair always belongs together."""
        with self._cv:
            return self._params, self._version

    def wait_version(self, min_version: Optional[int],
                     timeout: Optional[float] = None) -> tuple:
        """Atomic snapshot gated on ``version >= min_version`` — the
        rollout-side half of the version gate. A failed bucket stream
        poisons the gate (``fail``): gated requests raise instead of
        wedging forever with the instance lock held."""
        with self._cv:
            if min_version is not None:
                ok = self._cv.wait_for(
                    lambda: (self._version >= min_version
                             or self._failed is not None), timeout=timeout)
                if not ok:
                    raise TimeoutError(
                        f"{self.name}: version {min_version} not published "
                        f"within {timeout}s (at {self._version})")
                if self._version < min_version:
                    raise RuntimeError(
                        f"{self.name}: weight stream failed before version "
                        f"{min_version} landed") from self._failed
            return self._params, self._version

    def fail(self, exc: BaseException) -> None:
        """Poison the gate after a stream failure: wake every gated reader
        with the error. Cleared by the next successful publish/flip."""
        with self._cv:
            self._failed = exc
            self._cv.notify_all()

    # -- writer side --------------------------------------------------------
    def install(self, params, version: int) -> None:
        """Eager whole-tree path (legacy ``sync_weights`` semantics): place
        the full tree and flip in one atomic step."""
        placed = jax.tree.map(jax.device_put, params)
        with self._cv:
            self._publish_locked(placed, version)

    def begin(self, version: int, plan: TransferPlan) -> None:
        """Open the back buffer for ``version``'s bucket stream."""
        with self._cv:
            assert version > self._version, \
                f"{self.name}: stale publish {version} (at {self._version})"
            self._staging = {
                "version": version, "plan": plan,
                "slots": [None] * len(plan.leaves),
                "remaining": {b.bid for b in plan.buckets},
            }

    def deliver(self, bucket, placed) -> bool:
        """Land one bucket ([(leaf index, placed array)]) in the back
        buffer. Returns True when the version's LAST bucket landed (the
        buffer is flippable)."""
        with self._cv:
            st = self._staging
            assert st is not None, f"{self.name}: deliver without begin"
            assert bucket.bid in st["remaining"], \
                f"{self.name}: bucket {bucket.bid} delivered twice"
            for i, arr in placed:
                st["slots"][i] = arr
            st["remaining"].discard(bucket.bid)
            return not st["remaining"]

    @property
    def staged_version(self) -> Optional[int]:
        """Version whose buckets have ALL landed but not yet flipped."""
        with self._cv:
            st = self._staging
            return (st["version"]
                    if st is not None and not st["remaining"] else None)

    def flip(self) -> int:
        """front <- back: atomically publish the fully-landed version."""
        with self._cv:
            st = self._staging
            assert st is not None and not st["remaining"], \
                f"{self.name}: flip before all buckets landed"
            params = jax.tree_util.tree_unflatten(st["plan"].treedef,
                                                  st["slots"])
            self._staging = None
            return self._publish_locked(params, st["version"])

    def _publish_locked(self, params, version: int) -> int:
        if self.on_flip is not None:
            self.on_flip(params)
        self._params = params
        self._version = version
        self._failed = None
        self.flips += 1
        self._cv.notify_all()
        return version


class WeightTransferService:  # repro: allow(lock-discipline): single in-flight publisher thread; _join_pending's Thread.join is the happens-before edge for every shared field
    """Streams versioned parameter buckets from the trainer to every
    instance store, with optional overlap (background streaming) and a
    boundary barrier that measures the pool's residual sync-gap."""

    def __init__(self, instances, *, bucket_bytes: int = 1 << 22,
                 wire_dtype: Optional[str] = None,
                 use_pallas_cast: bool = False,
                 wire_latency: float = 0.0,
                 overlap: bool = True,
                 src_specs=None, dst_specs=None):
        self.instances: List = getattr(instances, "instances", instances)
        self.bucket_bytes = bucket_bytes
        self.wire_dtype = wire_dtype or None
        self.use_pallas_cast = use_pallas_cast
        # simulated per-bucket interconnect latency (seconds) — the
        # trainer->pool hop is free on this single host; benchmarks set it
        # to model the DCN/RDMA wire the paper's deployment pays
        self.wire_latency = wire_latency
        self.overlap = overlap
        self.src_specs = src_specs
        self.dst_specs = dst_specs
        self.plan: Optional[TransferPlan] = None
        self._pending_version: Optional[int] = None
        self._pending_thread: Optional[threading.Thread] = None
        self._pending_error: Optional[BaseException] = None
        # telemetry the boundary benchmark reads
        self.bytes_streamed = 0
        self.buckets_streamed = 0
        self.publishes: List[dict] = []
        self.gaps: List[dict] = []
        # registry metrics, cached once (DESIGN.md §Observability)
        self._m_wire_bytes = metrics().counter("transfer.wire_bytes")
        self._m_bucket_bytes = metrics().histogram("transfer.bucket_bytes")

    # ------------------------------------------------------------------
    def _ensure_plan(self, params) -> TransferPlan:
        if self.plan is None:
            self.plan = build_plan(params, bucket_bytes=self.bucket_bytes,
                                   src_specs=self.src_specs,
                                   dst_specs=self.dst_specs,
                                   wire_dtype=self.wire_dtype)
        return self.plan

    def _cast_fn(self):
        if not self.use_pallas_cast:
            return None
        from repro.kernels.ops import transfer_cast
        return transfer_cast

    def _stream(self, params, version: int) -> None:
        """Pack and deliver every bucket to every store, flipping each
        store as its last bucket lands — except deferred (paged) stores,
        which stay staged until the boundary ``ensure`` (flips there need
        decode quiescence). A failure poisons every store's version gate:
        requests already dispatched against this version (the boundary
        submits before the barrier) error out instead of wedging with the
        instance lock held."""
        stores = [inst.store for inst in self.instances]
        try:
            with otrace.span("transfer.stream", version=version) as sp:
                plan = self._ensure_plan(params)
                leaves = jax.tree_util.tree_flatten(params)[0]  # plan order
                cast = self._cast_fn()
                for store in stores:
                    store.begin(version, plan)
                t0 = time.perf_counter()
                for bucket in plan.buckets:
                    with otrace.span("transfer.bucket", bid=bucket.bid,
                                     wire_bytes=bucket.wire_bytes):
                        wire = pack_bucket(plan, leaves, bucket, cast_fn=cast)
                        if wire:
                            # repro: allow(host-sync): wire barrier — a
                            # version must not publish before its buckets
                            # land
                            jax.block_until_ready(wire[-1])
                        if self.wire_latency:
                            time.sleep(self.wire_latency)  # one per bucket
                        placed = unpack_bucket(plan, bucket, wire)
                        for store in stores:
                            if (store.deliver(bucket, placed)
                                    and not store.defer_flip):
                                store.flip()
                    self.bytes_streamed += bucket.wire_bytes
                    self.buckets_streamed += 1
                    self._m_wire_bytes.add(bucket.wire_bytes)
                    self._m_bucket_bytes.observe(bucket.wire_bytes)
                sp.set(buckets=len(plan.buckets),
                       wire_bytes=plan.total_wire_bytes)
        except BaseException as exc:
            for store in stores:
                store.fail(exc)
            raise
        self.publishes.append({
            "version": version, "buckets": len(plan.buckets),
            "wire_bytes": plan.total_wire_bytes,
            "stream_wall": time.perf_counter() - t0})

    # ------------------------------------------------------------------
    def publish(self, params, version: int) -> None:
        """Blocking eager publish: stream every bucket and flip every
        store before returning (the overlap-off / first-iteration path).
        Caller guarantees paged engines are quiescent (queue drained)."""
        self._join_pending()
        self._stream(params, version)
        for inst in self.instances:
            if inst.store.version < version:
                inst.store.flip()

    def publish_async(self, params, version: int) -> None:
        """Overlap path: start the bucket stream on a background thread and
        return immediately — called right after the optimizer update so the
        wire time hides under the trainer's iteration tail. Deferred
        (paged) stores are left staged for the boundary ``ensure``."""
        if not self.overlap:
            return      # boundary ensure() will publish eagerly
        self._join_pending()
        self._pending_version = version
        self._pending_error = None

        def run():
            try:
                self._stream(params, version)
            except BaseException as exc:        # surfaced by ensure()
                self._pending_error = exc

        otrace.instant("transfer.publish_async", version=version)
        self._pending_thread = threading.Thread(
            target=run, name=f"weight-plane-v{version}", daemon=True)
        self._pending_thread.start()

    def _join_pending(self) -> None:
        if self._pending_thread is not None:
            self._pending_thread.join()
            self._pending_thread = None
            if self._pending_error is not None:
                err, self._pending_error = self._pending_error, None
                self._pending_version = None
                raise RuntimeError(
                    "weight-plane background stream failed") from err

    # ------------------------------------------------------------------
    def ensure(self, params, version: int) -> int:
        """Boundary barrier: make every store hold exactly ``version`` and
        record the time this call blocked — the pool's sync-gap. Three
        cases: the version is already everywhere (no-op); a background
        publish for it is pending (wait for the stream tail, flip deferred
        stores); nothing pending (eager publish, the overlap-off cost).

        Returns the version the stores are OBSERVED to hold (not the
        argument), so the caller's boundary invariant check — the
        scheduler's ``refresh_old(expected_rollout_version=...)`` —
        compares the pool's actual state against the policy's."""
        t0 = time.perf_counter()
        versions = [inst.store.version for inst in self.instances]
        if all(v == version for v in versions):
            self.gaps.append({"version": version, "gap": 0.0, "mode": "noop"})
            otrace.complete("transfer.ensure", t0, time.perf_counter(),
                            version=version, gap=0.0, mode="noop")
            return versions[0]
        if self._pending_version == version:
            self._join_pending()
            self._pending_version = None
            mode = "overlap"
        else:
            self.publish(params, version)
            mode = "eager"
        for inst in self.instances:
            if inst.store.staged_version == version:
                inst.store.flip()
        # the Proposition-1 gate: the pool now serves the iteration's
        # policy, exactly — a mismatch here would mean gated rollouts
        # sample a different version than the trainer consumes
        versions = [inst.store.version for inst in self.instances]
        assert all(v == version for v in versions), \
            f"weight-plane flip incomplete: stores at {versions}, " \
            f"boundary requires {version}"
        t1 = time.perf_counter()
        self.gaps.append({"version": version, "gap": t1 - t0, "mode": mode})
        # barrier span from the gap stopwatch's own endpoints, so the
        # analyzer's sync-gap attribution equals metrics["sync_gap"]
        otrace.complete("transfer.ensure", t0, t1, version=version,
                        gap=t1 - t0, mode=mode)
        return versions[0]

    def drain(self) -> None:
        """Join any in-flight background bucket stream (flips stay with
        ``ensure``). Call before process/benchmark teardown — a daemon
        stream thread mid-device_put at interpreter shutdown aborts the
        runtime. Surfaces a failed stream's error."""
        self._join_pending()

    # ------------------------------------------------------------------
    @property
    def last_gap(self) -> float:
        return self.gaps[-1]["gap"] if self.gaps else 0.0

    def gap_stats(self, skip: int = 1) -> dict:
        """Mean/max boundary sync-gap, skipping the first ``skip`` warmup
        boundaries (iteration 0 is always an eager first publish)."""
        gaps = [g["gap"] for g in self.gaps[skip:]]
        return {"boundaries": len(gaps),
                "mean_gap": float(np.mean(gaps)) if gaps else 0.0,
                "max_gap": float(np.max(gaps)) if gaps else 0.0}
