import os
import sys

# NOTE: no XLA_FLAGS here — smoke tests and benches must see 1 device.
# Multi-device tests spawn subprocesses that set the flag themselves.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

jax.config.update("jax_enable_x64", False)
