import os
import sys

# NOTE: no XLA_FLAGS here — smoke tests and benches must see 1 device.
# Multi-device tests spawn subprocesses that set the flag themselves.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

jax.config.update("jax_enable_x64", False)


# --------------------------------------------------------------------------
# hypothesis (optional dependency — the container has no wheel baked in)
# --------------------------------------------------------------------------

def require_hypothesis():
    """Skip the calling module unless hypothesis is installed, then return
    its ``(given, settings, strategies)`` triple. For modules that are
    hypothesis-only (tests/test_property.py, tests/test_spec_property.py):

        given, settings, st = require_hypothesis()
    """
    import pytest
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies
    return given, settings, strategies


def optional_hypothesis():
    """``(given, settings, strategies)`` or None — for modules whose
    hypothesis tests ride alongside env-independent ones
    (tests/test_radix_property.py): the module keeps collecting, only the
    decorated tests disappear when the wheel is absent."""
    try:
        from hypothesis import given, settings, strategies
    except ImportError:
        return None
    return given, settings, strategies
