"""Tests for the repro-check static-analysis pass (src/repro/analysis).

Each checker gets at least one bug-injection fixture (a small module
written to trip the rule) and one clean fixture (the idiomatic repo
pattern that must NOT trip it). Fixture paths reuse the repo-config
suffixes ("core/paged.py" etc.) so the module-scoped rules engage.
"""
import json

import pytest

from repro.analysis.cli import main as cli_main
from repro.analysis.framework import Module, discover, run_checkers
from repro.analysis.host_sync import HostSyncChecker
from repro.analysis.lock_discipline import LockDisciplineChecker
from repro.analysis.refcount import RefcountChecker
from repro.analysis.registry import ALL_CHECKERS, CHECKER_NAMES
from repro.analysis.support_matrix import SupportMatrixChecker
from repro.analysis.trace_purity import TracePurityChecker


def run_one(checker, *mods):
    return checker.run([Module.from_source(p, src) for p, src in mods])


def run_full(checker, *mods):
    return run_checkers([Module.from_source(p, src) for p, src in mods],
                        [checker], known_names=CHECKER_NAMES)


# ---------------------------------------------------------------------------
# host-sync
# ---------------------------------------------------------------------------

HS_BUG = """\
import jax


class PagedGroupEngine:
    def __init__(self):
        self._decode = jax.jit(self._decode_fn)

    def step(self):
        tok = self._decode(1)
        self.helper()
        return float(tok)

    def helper(self):
        return jax.device_get(self.table)
"""


def test_host_sync_hot_implicit_cast():
    fs = run_one(HostSyncChecker(), ("core/paged.py", HS_BUG))
    cast = [f for f in fs if "float" in f.message]
    assert len(cast) == 1
    assert cast[0].severity == "error" and "[hot" in cast[0].message
    assert cast[0].line == 11


def test_host_sync_depth_tiering():
    # helper is one call away from the step entry point -> warm/warning
    fs = run_one(HostSyncChecker(), ("core/paged.py", HS_BUG))
    dg = [f for f in fs if "device_get" in f.message]
    assert len(dg) == 1
    assert dg[0].severity == "warning" and "[warm" in dg[0].message


def test_host_sync_cold_off_path():
    src = """\
import jax


def teardown(x):
    jax.block_until_ready(x)
"""
    fs = run_one(HostSyncChecker(), ("core/paged.py", src))
    assert len(fs) == 1
    assert fs[0].severity == "info" and "not on a decode path" in fs[0].message


def test_host_sync_untaint_after_asarray():
    src = """\
import jax
import numpy as np


class PagedGroupEngine:
    def __init__(self):
        self._decode = jax.jit(self._decode_fn)

    def step(self):
        tok = self._decode(1)
        tok = np.asarray(tok)
        a = float(tok)
        return a
"""
    fs = run_one(HostSyncChecker(), ("core/paged.py", src))
    # the asarray IS the transfer; float() afterwards is host-side
    assert len(fs) == 1 and "np.asarray" in fs[0].message
    assert fs[0].line == 11


def test_host_sync_clean():
    src = """\
import jax


class PagedGroupEngine:
    def __init__(self):
        self._decode = jax.jit(self._decode_fn)

    def step(self, limit):
        n = float(limit)
        return self._decode(n)
"""
    assert run_one(HostSyncChecker(), ("core/paged.py", src)) == []


# ---------------------------------------------------------------------------
# lock-discipline
# ---------------------------------------------------------------------------

LOCK_BUG = """\
import threading


class Engine:
    def __init__(self):
        self._lock = threading.Lock()
        self.count = 0

    def bump(self):
        self.count += 1

    def snapshot(self):
        with self._lock:
            return self.count
"""


def test_lock_discipline_unlocked_write():
    fs = run_one(LockDisciplineChecker(), ("core/engine.py", LOCK_BUG))
    assert len(fs) == 1
    f = fs[0]
    assert f.line == 10 and "Engine.count" in f.message
    assert "without holding with self._lock" in f.message


def test_lock_discipline_module_scoped():
    # same class outside THREADED_MODULES: not checked
    assert run_one(LockDisciplineChecker(), ("rl/grpo.py", LOCK_BUG)) == []


def test_lock_discipline_thread_root_lockless_class():
    src = """\
import threading


class Pump:
    def __init__(self):
        self.buf = []
        self.worker = threading.Thread(target=self._drain)

    def _drain(self):
        while self.buf:
            self.buf.pop()

    def feed(self, x):
        self.buf.append(x)
"""
    fs = run_one(LockDisciplineChecker(), ("core/queue.py", src))
    funcs = {f.message.split(" in ")[1].split(" ")[0] for f in fs}
    assert funcs == {"Pump._drain", "Pump.feed"}
    assert all("a lock (class owns none)" in f.message for f in fs)


def test_lock_discipline_clean():
    src = """\
import threading


class Engine:
    def __init__(self):
        self._lock = threading.Lock()
        self.count = 0

    def bump(self):
        with self._lock:
            self.count += 1

    def snapshot(self):
        with self._lock:
            return self.count
"""
    assert run_one(LockDisciplineChecker(), ("core/engine.py", src)) == []


def test_lock_discipline_locked_suffix_inference():
    src = """\
import threading


class Engine:
    def __init__(self):
        self._lock = threading.Lock()
        self.count = 0

    def bump(self):
        with self._lock:
            self._bump_locked()

    def _bump_locked(self):
        self.count += 1

    def snapshot(self):
        with self._lock:
            return self.count
"""
    assert run_one(LockDisciplineChecker(), ("core/engine.py", src)) == []


# ---------------------------------------------------------------------------
# refcount-pairing
# ---------------------------------------------------------------------------

RC_BUG = """\
class PagedPool:
    def admit(self, n):
        pages = self.allocator.alloc(n)
        return 0

    def shed(self):
        self.allocator.alloc(2)

    def evict_row(self, g):
        g.pages.pop()
"""


def test_refcount_bug_fixture():
    fs = run_one(RefcountChecker(), ("core/paged.py", RC_BUG))
    msgs = "\n".join(f.message for f in fs)
    assert "never handed off" in msgs          # admit
    assert "result discarded" in msgs          # shed
    assert "never calls release()/free()" in msgs  # evict_row
    assert len(fs) == 3


def test_refcount_early_exit_leak():
    src = """\
class PagedPool:
    def admit(self, n):
        pages = self.allocator.alloc(n)
        if n > 3:
            return None
        self.live.extend(pages)
"""
    fs = run_one(RefcountChecker(), ("core/paged.py", src))
    assert len(fs) == 1 and "early return" in fs[0].message
    assert fs[0].line == 5


def test_refcount_clean():
    src = """\
class PagedPool:
    def admit(self, n):
        pages = self.allocator.alloc(n)
        self.live.extend(pages)
        return pages

    def evict_row(self):
        pid = self.pages.pop()
        self.allocator.release([pid])
"""
    assert run_one(RefcountChecker(), ("core/paged.py", src)) == []


def test_refcount_module_scoped():
    assert run_one(RefcountChecker(), ("core/engine.py", RC_BUG)) == []


# ---------------------------------------------------------------------------
# trace-purity
# ---------------------------------------------------------------------------

TP_BUG = """\
import time
import jax


class Engine:
    def __init__(self):
        self._step = jax.jit(self._step_fn, static_argnames=("k",))
        self._cache = jax.jit(self._cache_fn)

    def _step_fn(self, x, k):
        t0 = time.time()
        if x > 0:
            return x
        if k > 0:
            return x + t0
        return -x

    def _cache_fn(self, x):
        self.last = x
        return x
"""


def test_trace_purity_bug_fixture():
    fs = run_one(TracePurityChecker(), ("core/engine.py", TP_BUG))
    msgs = "\n".join(f.message for f in fs)
    assert "impure call time.time()" in msgs
    assert "attribute store on 'self'" in msgs
    branch = [f for f in fs if "Python branch" in f.message]
    # x is dynamic -> flagged; k is static_argnames -> exempt
    assert len(branch) == 1 and "'x'" in branch[0].message
    assert branch[0].severity == "warning"


def test_trace_purity_pallas_ref_write_is_clean():
    src = """\
def kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2


def launch(x):
    return pl.pallas_call(kernel, out_shape=x)(x)
"""
    assert run_one(TracePurityChecker(), ("kernels/k.py", src)) == []


def test_trace_purity_local_rebuild_is_clean():
    src = """\
import jax


@jax.jit
def update(state):
    new = {}
    new["a"] = state["a"] + 1
    return new
"""
    assert run_one(TracePurityChecker(), ("models/m.py", src)) == []


def test_trace_purity_transitive_callee():
    src = """\
import time
import jax


@jax.jit
def outer(x):
    return helper(x)


def helper(x):
    time.sleep(0)
    return time.perf_counter() + x
"""
    fs = run_one(TracePurityChecker(), ("models/m.py", src))
    assert any("time.perf_counter" in f.message
               and "transitively traced" in f.message for f in fs)


# ---------------------------------------------------------------------------
# support-matrix
# ---------------------------------------------------------------------------

SM_BASE = """\
ROLLOUT_ENGINES = ("group", "paged")
SPEC_PLANE = "spec"


def engine_support(cfg, engine):
    if engine == "group":
        return (True, "")
    if engine == "spec":
        return _spec_support(cfg)
    if cfg.hybrid:
        return (False, "no hybrid decode")
    return (True, "")


def _spec_support(cfg):
    if cfg.is_encoder_decoder:
        return (False, "enc-dec")
    return (True, "")
"""

SM_CLIENT_BUG = """\
def make_paged(cfg):
    require_engine_support(cfg, "paged")


def make_typo(cfg):
    require_engine_support(cfg, "pagedd")


def make_dyn(cfg, engine):
    require_engine_support(cfg, engine)


def guard(cfg):
    assert cfg.family == "ssm", "nope"
"""


def test_support_matrix_bug_fixture():
    fs = run_one(SupportMatrixChecker(), ("configs/base.py", SM_BASE),
                 ("core/make.py", SM_CLIENT_BUG))
    msgs = "\n".join(f.message for f in fs)
    assert "engine not declared" in msgs                   # S2 typo
    assert "non-literal engine argument" in msgs           # S2 dynamic
    assert "hand-rolled capability guard" in msgs          # S3
    # S1: spec is restricted (its helper has a False path) and nothing
    # outside configs/ enforces it; paged IS enforced, group is open.
    s1 = [f for f in fs if "no call site outside configs/" in f.message]
    assert len(s1) == 1 and "'spec'" in s1[0].message
    assert s1[0].path == "configs/base.py"


def test_support_matrix_clean():
    client = """\
def make_paged(cfg):
    require_engine_support(cfg, "paged")


def make_spec(cfg):
    require_engine_support(cfg, "spec")
"""
    fs = run_one(SupportMatrixChecker(), ("configs/base.py", SM_BASE),
                 ("core/make.py", client))
    assert fs == []


def test_support_matrix_guard_inside_configs_ok():
    # capability asserts are allowed to live in configs/ (that IS the
    # matrix); the same guard outside is the S3 finding
    guard = """\
def check(cfg):
    assert not cfg.is_encoder_decoder
"""
    assert run_one(SupportMatrixChecker(),
                   ("configs/validate.py", guard)) == []
    fs = run_one(SupportMatrixChecker(), ("core/x.py", guard))
    assert len(fs) == 1 and "hand-rolled capability guard" in fs[0].message


# ---------------------------------------------------------------------------
# pragma grammar / suppression
# ---------------------------------------------------------------------------

def test_pragma_suppresses_with_justification():
    src = """\
import jax


def flush(x):
    # repro: allow(host-sync): teardown barrier
    jax.block_until_ready(x)
"""
    fs = run_full(HostSyncChecker(), ("util/flush.py", src))
    assert len(fs) == 1
    assert fs[0].suppressed and fs[0].justification == "teardown barrier"
    assert "[suppressed: teardown barrier]" in fs[0].render()


def test_bare_allow_is_itself_a_finding():
    src = """\
import jax


def flush(x):
    # repro: allow(host-sync)
    jax.block_until_ready(x)
"""
    fs = run_full(HostSyncChecker(), ("util/flush.py", src))
    open_f = [f for f in fs if not f.suppressed]
    assert len(open_f) == 2          # original stays open + pragma finding
    assert any(f.checker == "pragma" and "bare allow" in f.message
               for f in open_f)


def test_unknown_checker_pragma():
    src = "# repro: allow(frobnicate): because\n"
    fs = run_full(HostSyncChecker(), ("util/x.py", src))
    assert len(fs) == 1
    assert fs[0].checker == "pragma" and "unknown checker" in fs[0].message


def test_unused_pragma_is_flagged():
    src = "# repro: allow(host-sync): nothing here\nX = 1\n"
    fs = run_full(HostSyncChecker(), ("util/x.py", src))
    assert len(fs) == 1
    assert fs[0].checker == "pragma" and "unused" in fs[0].message
    assert fs[0].severity == "warning"


def test_def_line_pragma_covers_whole_body():
    src = """\
import jax


def flush(x):  # repro: allow(host-sync): whole-function barrier helper
    jax.block_until_ready(x)
    y = jax.device_get(x)
    return y
"""
    fs = run_full(HostSyncChecker(), ("util/flush.py", src))
    assert len(fs) == 2 and all(f.suppressed for f in fs)


def test_pragma_over_comment_block_reaches_code_line():
    src = """\
import jax


def flush(x):
    # repro: allow(host-sync): two-line justification that keeps
    # going on a second comment line before the code
    jax.block_until_ready(x)
"""
    fs = run_full(HostSyncChecker(), ("util/flush.py", src))
    assert len(fs) == 1 and fs[0].suppressed


def test_pragma_cannot_silence_pragma_findings():
    # a justified allow(pragma) never matches anything (meta-findings are
    # unsuppressible) -> reported as unused, not unknown
    src = "# repro: allow(pragma): try to silence the meta layer\nX = 1\n"
    fs = run_full(HostSyncChecker(), ("util/x.py", src))
    assert len(fs) == 1 and "unused" in fs[0].message


# ---------------------------------------------------------------------------
# framework / CLI
# ---------------------------------------------------------------------------

def test_syntax_error_becomes_parse_finding(tmp_path):
    bad = tmp_path / "broken.py"
    bad.write_text("def f(:\n")
    mods = discover([bad], tmp_path)
    fs = run_checkers(mods, ALL_CHECKERS, known_names=CHECKER_NAMES)
    assert any(f.checker == "parse" for f in fs)


def test_cli_exit_codes_and_json(tmp_path, capsys):
    core = tmp_path / "core"
    core.mkdir()
    (core / "paged.py").write_text(RC_BUG)
    report = tmp_path / "report.json"
    rc = cli_main([str(core), "--root", str(tmp_path),
                   "--json", str(report)])
    assert rc == 1
    out = capsys.readouterr().out
    assert "open" in out and "refcount-pairing" in out
    data = json.loads(report.read_text())
    assert data["tool"] == "repro-check" and data["open"] == 3
    assert all(f["path"] == "core/paged.py" for f in data["findings"])

    (core / "paged.py").write_text("X = 1\n")
    assert cli_main([str(core), "--root", str(tmp_path)]) == 0


def test_cli_checker_filter(tmp_path):
    core = tmp_path / "core"
    core.mkdir()
    (core / "paged.py").write_text(RC_BUG)
    # refcount findings exist, but we only run lock-discipline
    rc = cli_main([str(core), "--root", str(tmp_path),
                   "--checker", "lock-discipline"])
    assert rc == 0


def test_registry_names_match_issue():
    assert set(CHECKER_NAMES) >= {"host-sync", "lock-discipline",
                                  "refcount-pairing", "trace-purity",
                                  "support-matrix"}


def test_repo_is_clean():
    """The dogfood gate, as a test: repro-check over src/ has zero
    unsuppressed findings (CI runs the CLI too; this keeps the property
    inside the tier-1 suite)."""
    import pathlib
    root = pathlib.Path(__file__).resolve().parents[1]
    src = root / "src"
    if not src.is_dir():                      # installed-package run
        pytest.skip("repo src/ tree not present")
    mods = discover([src], root)
    fs = run_checkers(mods, ALL_CHECKERS, known_names=CHECKER_NAMES)
    open_f = [f for f in fs if not f.suppressed]
    assert open_f == [], "\n".join(f.render() for f in open_f)
