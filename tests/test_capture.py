"""Rollout-time logprob capture (DESIGN.md §Tri-model-capture) and the
async-bookkeeping bugfixes that ride with it.

* Captured-logprob equivalence: for BOTH rollout engines, the per-token
  logprobs the engine evaluates while sampling must be fp-close to the
  trainer's packed-forward recompute (the KV-cache decode path reduces in
  a different order — tolerance documented in DESIGN.md).
* Grad-step equivalence: training with captured vs. recomputed
  old-logprobs produces matching parameter updates in sync/async modes.
* Scheduler bookkeeping regressions: run()-twice in async_offpolicy must
  not double-submit; async train_time must exclude producer wait.
* Error-path accounting: a producer that put_errors mid-batch leaves the
  queue consistent; the paged engine still asserts quiescence at weight
  sync with a capture-enabled group in flight.
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.configs.base import RLConfig
from repro.core.engine import InferenceInstance, InferencePool
from repro.core.generator import TemporaryDataGenerator
from repro.core.paged import PagedGroupEngine
from repro.core.queue import RolloutGroup, RolloutQueue
from repro.core.spa import pack_plain, pack_spa
from repro.data.tasks import ArithmeticTask
from repro.data.tokenizer import Tokenizer
from repro.launch.train import build_pipeline
from repro.models import init
from repro.rl.grpo import (_model_logprobs, jaxify, make_grad_step,
                           make_grad_step_captured)
from repro.rl.rollout import RolloutBatch, Sampler

G, T, LP = 4, 8, 16

# fp32 reduced configs: rollout decode (KV-cached, token-at-a-time) and the
# packed training forward differ only by reduction order — observed ~1e-6;
# asserted with margin. See DESIGN.md §Tri-model-capture for the bf16 story.
CAPTURE_ATOL = 5e-5


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config(get_config("llama3.2-3b"))
    params = init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _rollout_group(prompt, out) -> RolloutGroup:
    return RolloutGroup(
        uid=0, prompt_ids=np.asarray(prompt, np.int32),
        response_ids=np.asarray(out.response_ids),
        response_len=np.asarray(out.response_len),
        rewards=np.zeros(np.asarray(out.response_ids).shape[0], np.float32),
        weight_version=0,
        response_logprobs=np.asarray(out.response_logprobs))


def _assert_capture_matches_recompute(cfg, params, group):
    """Captured logprobs, scattered by BOTH packers, must match a
    training-side old-policy recompute at every label position."""
    adv = np.zeros(group.response_ids.shape[0])
    for pack in (lambda: pack_plain([group], [adv], LP, T),
                 lambda: pack_spa(group, adv, LP, T, responses_per_row=G)):
        mb = pack()
        lp, _ = _model_logprobs(params, cfg, jaxify(mb))
        mask = np.asarray(mb.loss_mask) > 0
        assert mask.any()
        np.testing.assert_allclose(np.asarray(mb.logp_behavior)[mask],
                                   np.asarray(lp)[mask],
                                   atol=CAPTURE_ATOL, rtol=0)


# =========================================================================
# captured == recomputed, for BOTH rollout engines
# =========================================================================

@pytest.mark.parametrize("temperature", [0.0, 1.0])
def test_sampler_capture_matches_training_recompute(setup, temperature):
    cfg, params = setup
    prompt = np.asarray([1, 9, 4, 7, 3], np.int32)
    s = Sampler(cfg, LP, T, temperature=temperature)
    out = s.generate(params, [prompt] * G, jax.random.PRNGKey(5))
    assert out.response_logprobs is not None
    _assert_capture_matches_recompute(cfg, params,
                                      _rollout_group(prompt, out))


def test_paged_capture_matches_training_recompute(setup):
    """Token-level engine: slots < group size forces staggered admission —
    captured values must still land on the right steps."""
    cfg, params = setup
    prompt = np.asarray([1, 9, 4, 7, 3], np.int32)
    eng = PagedGroupEngine(cfg, num_slots=3, page_size=4, num_pages=0,
                           max_prompt_len=LP, max_new_tokens=T,
                           group_size=G, temperature=1.0)
    eng.set_params(params)
    h = eng.submit(prompt, jax.random.PRNGKey(5))
    while eng.step():
        pass
    out = h.result(1)
    assert out.response_logprobs is not None
    _assert_capture_matches_recompute(cfg, params,
                                      _rollout_group(prompt, out))


def test_cross_engine_capture_close(setup):
    """Both engines sample identical tokens under one key (proven in
    test_paged_pool); their captured logprobs must agree to fp tolerance."""
    cfg, params = setup
    prompt = np.asarray([1, 9, 4, 7, 3], np.int32)
    key = jax.random.PRNGKey(5)
    ref = Sampler(cfg, LP, T, temperature=1.0).generate(
        params, [prompt] * G, key)
    eng = PagedGroupEngine(cfg, num_slots=3, page_size=4, num_pages=0,
                           max_prompt_len=LP, max_new_tokens=T,
                           group_size=G, temperature=1.0)
    eng.set_params(params)
    h = eng.submit(prompt, key)
    while eng.step():
        pass
    out = h.result(1)
    np.testing.assert_array_equal(np.asarray(out.response_ids),
                                  np.asarray(ref.response_ids))
    np.testing.assert_allclose(np.asarray(out.response_logprobs),
                               np.asarray(ref.response_logprobs),
                               atol=CAPTURE_ATOL, rtol=0)


def test_packers_scatter_onto_label_positions():
    """Unit check with synthetic values: logprob j of response k must land
    exactly where that response's j-th label sits (weight > 0), zeros
    elsewhere; groups without capture yield logp_behavior None."""
    rng = np.random.RandomState(0)
    lens = np.asarray([3, 5, 2, 4], np.int32)
    resp = rng.randint(3, 200, size=(G, T)).astype(np.int32)
    lps = np.zeros((G, T), np.float32)
    for j in range(G):
        lps[j, : lens[j]] = -(j + 1) - np.arange(lens[j]) / 10.0
    g = RolloutGroup(uid=0, prompt_ids=np.asarray([1, 9, 4], np.int32),
                     response_ids=resp, response_len=lens,
                     rewards=np.zeros(G, np.float32), weight_version=0,
                     response_logprobs=lps)
    for mb in (pack_plain([g], [np.zeros(G)], LP, T),
               pack_spa(g, np.zeros(G), LP, T, responses_per_row=2),
               pack_spa(g, np.zeros(G), LP, T, responses_per_row=G,
                        align=16)):
        got = sorted(np.asarray(mb.logp_behavior)[
            np.asarray(mb.loss_mask) > 0].tolist())
        want = sorted(v for j in range(G) for v in lps[j, : lens[j]])
        np.testing.assert_allclose(got, want)
        # nothing leaks outside label positions
        assert (np.asarray(mb.logp_behavior)[
            np.asarray(mb.loss_mask) == 0] == 0).all()
    g_nolp = dataclasses.replace(g, response_logprobs=None)
    assert pack_plain([g_nolp], [np.zeros(G)], LP, T).logp_behavior is None
    assert pack_spa(g_nolp, np.zeros(G), LP, T,
                    responses_per_row=G).logp_behavior is None


# =========================================================================
# grad-step equivalence: captured vs recomputed old-logprobs
# =========================================================================

def test_grad_step_captured_matches_recompute_direct(setup):
    """Micro-step level: the captured-path step (single ref forward) must
    produce the same gradients as the stacked old+ref recompute when
    old == rollout weights (Proposition 1)."""
    cfg, params = setup
    rl = RLConfig(max_prompt_len=LP, max_response_len=T, group_size=G)
    prompt = np.asarray([1, 9, 4, 7, 3], np.int32)
    out = Sampler(cfg, LP, T, temperature=1.0).generate(
        params, [prompt] * G, jax.random.PRNGKey(5))
    grp = _rollout_group(prompt, out)
    adv = np.linspace(-1, 1, G)
    mb = jaxify(pack_plain([grp], [adv], LP, T))
    g_cap, m_cap = make_grad_step_captured(cfg, rl)(
        params, params, params, mb)
    g_rec, m_rec = make_grad_step(cfg, rl)(
        params, params, params, mb._replace(logp_behavior=None))
    for a, b in zip(jax.tree.leaves(g_cap), jax.tree.leaves(g_rec)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(float(m_cap["ratio_mean"]),
                               float(m_rec["ratio_mean"]), atol=1e-4)


@pytest.mark.parametrize("mode,iters", [("sync", 1), ("async", 2)])
def test_update_equivalence_capture_on_off(mode, iters):
    """End-to-end: the parameter trajectory with capture on (behavior
    logprobs ride the batch, single-ref no-grad pass) matches capture off
    (stacked old+ref recompute) within fp tolerance."""
    cfg = reduced_config(get_config("llama3.2-3b"))

    def run(capture):
        rl = RLConfig(mode=mode, batch_prompts=2, group_size=G,
                      micro_batch=2, num_inference_instances=2,
                      max_prompt_len=24, max_response_len=T,
                      learning_rate=1e-3, seed=0,
                      capture_logprobs=capture)
        sched, parts = build_pipeline(cfg, rl, seed=0)
        sched.run(iters)
        return sched, parts["tri"].policy

    s_on, p_on = run(True)
    s_off, p_off = run(False)
    assert s_on.captured_micro_steps > 0 and s_on.recomputed_micro_steps == 0
    assert s_off.captured_micro_steps == 0 and s_off.recomputed_micro_steps > 0
    for a, b in zip(jax.tree.leaves(p_on), jax.tree.leaves(p_off)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=5e-6, rtol=1e-5)


def test_offpolicy_ratio_uses_behavior_logprobs():
    """async_offpolicy + capture: every micro-step's importance ratio is
    built from the TRUE behavior logprobs (captured at rollout time), not
    the old~behavior approximation — no recompute steps taken."""
    cfg = reduced_config(get_config("llama3.2-3b"))
    rl = RLConfig(mode="async_offpolicy", batch_prompts=2, group_size=3,
                  micro_batch=3, num_inference_instances=1,
                  max_prompt_len=24, max_response_len=6,
                  learning_rate=1e-3, staleness_eta=1, seed=0)
    sched, _ = build_pipeline(cfg, rl, seed=0)
    hist = sched.run(2)
    assert sched.captured_micro_steps > 0
    assert sched.recomputed_micro_steps == 0
    assert max(s.max_staleness for s in hist) >= 1   # genuinely off-policy


def test_scripted_rollouts_fall_back_to_recompute():
    """Simulated/scripted instances carry no captured logprobs; with
    capture enabled the scheduler must fall back per micro-batch instead
    of crashing."""
    cfg = reduced_config(get_config("llama3.2-3b"))

    def scripted(prompts, key):
        Gn, Tn = len(prompts), 6
        resp = np.random.RandomState(1).randint(
            3, 200, size=(Gn, Tn)).astype(np.int32)
        return RolloutBatch(response_ids=jnp.asarray(resp),
                            response_len=jnp.full((Gn,), Tn, jnp.int32))

    rl = RLConfig(mode="async", batch_prompts=2, group_size=3,
                  micro_batch=3, num_inference_instances=1,
                  max_prompt_len=24, max_response_len=6,
                  learning_rate=1e-3, seed=0, capture_logprobs=True)
    sched, _ = build_pipeline(cfg, rl, seed=0, scripted_fn=scripted)
    sched.run(1)
    assert sched.captured_micro_steps == 0
    assert sched.recomputed_micro_steps > 0


# =========================================================================
# scheduler bookkeeping regressions
# =========================================================================

def _scripted_echo(prompts, key):
    Gn, Tn = len(prompts), 6
    rng = np.random.RandomState(int(np.asarray(prompts[0]).sum()) % 997)
    resp = rng.randint(3, 200, size=(Gn, Tn)).astype(np.int32)
    return RolloutBatch(response_ids=jnp.asarray(resp),
                        response_len=jnp.full((Gn,), Tn, jnp.int32))


def test_run_twice_offpolicy_no_double_submit():
    """Calling run() twice in async_offpolicy mode must carry the
    eta-lookahead tail across the boundary: no re-fetch/re-submit of
    batches whose groups already sit in the queue, and the backlog stays
    bounded at eta batches instead of growing per call."""
    cfg = reduced_config(get_config("llama3.2-3b"))
    rl = RLConfig(mode="async_offpolicy", batch_prompts=3, group_size=2,
                  micro_batch=2, num_inference_instances=2,
                  max_prompt_len=24, max_response_len=6,
                  learning_rate=1e-3, staleness_eta=1, seed=0)
    sched, parts = build_pipeline(cfg, rl, seed=0,
                                  scripted_fn=_scripted_echo)
    q = parts["queue"]
    sched.run(2)
    backlog1 = q.outstanding
    sched.run(2)
    backlog2 = q.outstanding
    # steady-state backlog: exactly the eta-lookahead groups, both times
    assert backlog1 == rl.staleness_eta * rl.batch_prompts == backlog2
    # every consumed group was checked exactly once (4 iterations total)
    assert sched.monitor.checked == 4 * rl.batch_prompts
    assert max(s.max_staleness for s in sched.history) <= rl.staleness_eta


def test_run_error_poisons_retry_and_keeps_bookkeeping():
    """An error unwinding run() mid-iteration (producer put_error surfaced
    by queue.get) leaves the pipeline unresumable — partially consumed
    batches, half-accumulated gradients. run() must (a) keep the
    submitted-batch bookkeeping for diagnosis instead of silently dropping
    it, and (b) REFUSE a retry with a clear error rather than deadlocking
    on wait_empty or training shifted batch boundaries."""
    cfg = reduced_config(get_config("llama3.2-3b"))
    rl = RLConfig(mode="async_offpolicy", batch_prompts=3, group_size=2,
                  micro_batch=2, num_inference_instances=1,
                  max_prompt_len=24, max_response_len=6,
                  learning_rate=1e-3, staleness_eta=1, seed=0)
    sched, parts = build_pipeline(cfg, rl, seed=0,
                                  scripted_fn=_scripted_echo)
    calls = []

    def poisoned_reward(resp, answer):
        if not calls:                    # first group of the first batch
            calls.append(1)
            raise RuntimeError("reward model died")
        return 0.0

    parts["generator"].reward_fn = poisoned_reward
    with pytest.raises(RuntimeError, match="reward model died"):
        sched.run(2)
    # the submitted batches stay tracked (>= the eta lookahead; none were
    # fully consumed when the error surfaced)
    assert len(sched._inflight) == 2
    # re-entry refuses loudly instead of deadlocking / double-submitting
    with pytest.raises(RuntimeError, match="Rebuild the pipeline"):
        sched.run(1)


def test_async_train_time_excludes_producer_wait():
    """train_time must measure consumer BUSY time, not wall-since-first-
    get. Machine-speed independent: a known producer-wait is INJECTED by
    wrapping queue.get with a sleep, so however slow the grad steps are,
    an accounting that starts the clock before the get loop (the old bug)
    would absorb the full injected wait while busy-time cannot."""
    cfg = reduced_config(get_config("llama3.2-3b"))
    rl = RLConfig(mode="async", batch_prompts=4, group_size=3,
                  micro_batch=3, num_inference_instances=1,
                  max_prompt_len=24, max_response_len=6,
                  learning_rate=1e-3, seed=0)
    sched, parts = build_pipeline(cfg, rl, seed=0,
                                  scripted_fn=_scripted_echo)
    sched.run(1)                        # jit warmup, unpatched
    q = parts["queue"]
    wait = 0.3
    orig_get = q.get

    def slow_get(timeout=None):
        time.sleep(wait)                # deterministic "producer wait"
        return orig_get(timeout)

    q.get = slow_get
    try:
        hist = sched.run(1)
    finally:
        q.get = orig_get
    injected = wait * rl.batch_prompts              # 4 gets x 0.3 s
    assert hist[0].wall_time >= injected
    # busy time excludes every injected second (modulo one grad step's
    # jitter); the pre-fix accounting would report >= `injected` here
    assert hist[0].train_time <= hist[0].wall_time - 0.8 * injected, \
        (hist[0].train_time, hist[0].wall_time, injected)


# =========================================================================
# error-path accounting + generator drain semantics
# =========================================================================

def test_generator_join_reports_drained():
    """join(timeout) must distinguish 'drained' from 'timed out with
    producers still alive' instead of silently returning None."""
    cfg = reduced_config(get_config("llama3.2-3b"))

    def slow_scripted(prompts, key):
        time.sleep(0.3)
        return _scripted_echo(prompts, key)

    inst = InferenceInstance(0, cfg, None, scripted_fn=slow_scripted)
    inst.sync_weights(None, version=0)
    queue = RolloutQueue()
    gen = TemporaryDataGenerator(InferencePool([inst]), queue,
                                 lambda r, a: 0.0, group_size=2)
    task = ArithmeticTask(seed=0)
    tok = Tokenizer(cfg.vocab_size)
    batch = [(p, np.asarray(tok.encode(p.prompt)[:LP], np.int32))
             for p in task.batch(2)]
    gen.submit_batch(batch, jax.random.PRNGKey(0), 0)
    assert gen.join(timeout=0.02) is False     # still producing
    for _ in range(len(batch)):
        queue.get(timeout=5.0)
    assert gen.join(timeout=5.0) is True       # drained
    assert gen.join() is True                  # idempotent


def test_put_error_mid_batch_keeps_outstanding_consistent():
    """One poisoned problem out of three: the consumer sees the error,
    the other two groups still arrive, and the queue's outstanding count
    drains to zero — the NEXT iteration's wait_empty must not deadlock."""
    cfg = reduced_config(get_config("llama3.2-3b"))
    inst = InferenceInstance(0, cfg, None, scripted_fn=_scripted_echo)
    inst.sync_weights(None, version=0)
    queue = RolloutQueue()

    def reward(resp, answer):
        if answer == "BOOM":
            raise RuntimeError("reward model died")
        return 0.0

    gen = TemporaryDataGenerator(InferencePool([inst]), queue, reward,
                                 group_size=2)
    task = ArithmeticTask(seed=0)
    tok = Tokenizer(cfg.vocab_size)
    problems = task.batch(3)
    problems[1].answer = "BOOM"
    batch = [(p, np.asarray(tok.encode(p.prompt)[:LP], np.int32))
             for p in problems]
    gen.submit_batch(batch, jax.random.PRNGKey(0), 0)
    got, errs = [], 0
    for _ in range(len(batch)):
        try:
            got.append(queue.get(timeout=10.0))
        except RuntimeError:
            errs += 1
    assert errs == 1 and len(got) == 2
    assert queue.outstanding == 0
    assert queue.wait_empty(timeout=1.0)       # no deadlock next iteration
    # the batch thread must drain cleanly despite the mid-batch failure
    assert gen.join(timeout=5.0) is True


def test_paged_set_params_asserts_quiescence_with_capture_inflight(setup):
    """Weight sync while a capture-enabled group is mid-decode must still
    trip the Proposition 1 quiescence assert, then succeed once drained."""
    cfg, params = setup
    eng = PagedGroupEngine(cfg, num_slots=2, page_size=4, num_pages=0,
                           max_prompt_len=LP, max_new_tokens=T,
                           group_size=G, temperature=1.0,
                           capture_logprobs=True)
    eng.set_params(params)
    h = eng.submit(np.asarray([1, 9, 4], np.int32), jax.random.PRNGKey(2))
    eng.step()                                  # group mid-flight
    with pytest.raises(AssertionError, match="in flight"):
        eng.set_params(params)
    while eng.step():
        pass
    assert h.result(1).response_logprobs is not None
    eng.set_params(params)                      # quiescent again -> fine
