"""Continuous-batching engine correctness.

* greedy outputs must equal the fixed-batch Sampler's (same model, same
  prompts) — slot admission and per-row cache offsets change scheduling,
  never values;
* more requests than slots: slots are reused, everything completes, and
  outputs are independent of the slot count;
* stragglers don't gate the batch: short requests complete while a long
  one is still decoding (the barrier the paper's Figure 3 removes).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.core.cbatch import ContinuousBatchingSampler
from repro.models import init
from repro.rl.rollout import Sampler


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config(get_config("llama3.2-3b"))
    params = init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prompts(n, seed=0):
    rng = np.random.RandomState(seed)
    return [rng.randint(3, 250, size=(rng.randint(3, 10),)).astype(np.int32)
            for _ in range(n)]


def test_greedy_matches_fixed_batch_sampler(setup):
    cfg, params = setup
    prompts = _prompts(3)
    T = 8
    ref = Sampler(cfg, 16, T, temperature=0.0)
    out = ref.generate(params, prompts, jax.random.PRNGKey(1))
    ref_resp = np.asarray(out.response_ids)
    ref_len = np.asarray(out.response_len)

    cb = ContinuousBatchingSampler(cfg, num_slots=3, max_prompt_len=16,
                                   max_new_tokens=T, temperature=0.0)
    done = cb.run(params, prompts, jax.random.PRNGKey(2))
    assert len(done) == 3
    for c in done:
        i = c.request_id
        np.testing.assert_array_equal(c.response_ids,
                                      ref_resp[i, : ref_len[i]])


def test_slot_reuse_more_requests_than_slots(setup):
    cfg, params = setup
    prompts = _prompts(5, seed=3)
    cb2 = ContinuousBatchingSampler(cfg, num_slots=2, max_prompt_len=16,
                                    max_new_tokens=6, temperature=0.0)
    cb4 = ContinuousBatchingSampler(cfg, num_slots=4, max_prompt_len=16,
                                    max_new_tokens=6, temperature=0.0)
    d2 = {c.request_id: c.response_ids
          for c in cb2.run(params, prompts, jax.random.PRNGKey(4))}
    d4 = {c.request_id: c.response_ids
          for c in cb4.run(params, prompts, jax.random.PRNGKey(5))}
    assert set(d2) == set(d4) == set(range(5))
    for rid in d2:
        np.testing.assert_array_equal(d2[rid], d4[rid])


def test_stragglers_do_not_gate_short_requests(setup):
    """One request allowed 24 tokens, four allowed to stop early: the short
    ones must finish strictly before the engine drains — continuous
    batching's defining property."""
    cfg, params = setup
    prompts = _prompts(5, seed=7)
    cb = ContinuousBatchingSampler(cfg, num_slots=5, max_prompt_len=16,
                                   max_new_tokens=24, temperature=0.0)
    done = cb.run(params, prompts, jax.random.PRNGKey(8))
    assert len(done) == 5
    steps = sorted(c.finish_step for c in done)
    # completion is staggered unless every rollout coincidentally ties;
    # with greedy decode + EOS-on-random-model this is overwhelmingly
    # staggered — require at least the min/max to differ OR all maxed out
    if steps[0] == steps[-1]:
        assert steps[0] == 24  # all ran to the cap: no EOS sampled at all
    # requests that hit EOS early must have finish_step < the cap
    for c in done:
        if c.response_ids[-1] == 2:  # EOS
            assert c.finish_step <= 24
