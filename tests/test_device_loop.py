"""Device-resident decode loop (DESIGN.md §Device-resident-decode).

The fused D-step decode block must be bitwise TOKEN-IDENTICAL to the
legacy one-drain-per-token cadence, which is itself token-identical to
the group Sampler — so every ``drain_interval`` is proven against the
same oracle, across the cache families (GQA / MLA latent / sliding
window), under greedy and sampled decode, with spec and the radix
prefix cache riding along. Drain edge cases get targeted tests: a row
hitting EOS in the middle of an in-flight block (the optimistic extra
steps run device-masked and must write nothing), EOS landing exactly on
a block's last buffered token, blocks that don't divide the response
budget, and slot re-assignment while a stale block drains.

The satellite contracts live here too: the deferred busy clock
(``InferenceInstance._defer_busy`` charges off the dispatch path,
``flush_busy`` joins at the boundary), the ``commit_block`` device walk
vs the host ``assemble_commit`` oracle, the ``repro-check --forbid-hot``
severity gate, and the shard_map'd dense-GQA decode step (subprocess,
like test_moe_ep.py, so forced fake devices never leak into the suite).
"""
import dataclasses
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.core.cbatch import ContinuousBatchingSampler
from repro.core.engine import InferenceInstance, InferencePool
from repro.core.paged import PagedGroupEngine
from repro.models import init
from repro.rl.rollout import Sampler
from repro.spec import SpecSampler, assemble_commit
from repro.spec.verify import commit_block

G, T, LP = 4, 8, 16


def _gqa():
    return reduced_config(get_config("llama3.2-3b"))


def _mla_nomoe():
    c = reduced_config(get_config("deepseek-v2-lite-16b"))
    return dataclasses.replace(c, num_experts=0, num_experts_per_tok=0,
                               num_shared_experts=0, moe_d_ff=0,
                               first_k_dense=0, dense_d_ff=0)


def _swa():
    return dataclasses.replace(_gqa(), sliding_window=8)


VARIANTS = {"gqa": _gqa, "mla": _mla_nomoe, "swa": _swa}


@pytest.fixture(scope="module")
def setups():
    out = {}
    for name, mk in VARIANTS.items():
        cfg = mk()
        out[name] = (cfg, init(jax.random.PRNGKey(0), cfg))
    return out


PROMPT = np.asarray([1, 9, 4, 7, 3], np.int32)


def _assert_group_identical(out, ref):
    pr, pl = np.asarray(out.response_ids), np.asarray(out.response_len)
    rr, rl = np.asarray(ref.response_ids), np.asarray(ref.response_len)
    np.testing.assert_array_equal(pl, rl)
    for i in range(rr.shape[0]):
        np.testing.assert_array_equal(pr[i, : pl[i]], rr[i, : rl[i]])


def _engine(cfg, **kw):
    base = dict(num_slots=3, page_size=4, num_pages=0, max_prompt_len=LP,
                max_new_tokens=T, group_size=G)
    base.update(kw)
    return PagedGroupEngine(cfg, **base)


def _run_group(eng, params, prompt, key):
    eng.set_params(params)
    h = eng.submit(prompt, key)
    while eng.step():
        pass
    return h.result(1)


# =========================================================================
# fused == legacy == Sampler, across families / drains / temperatures
# =========================================================================

@pytest.mark.parametrize("drain,temperature", [(2, 0.0), (3, 1.0),
                                               (8, 0.0), (8, 1.0)])
def test_paged_fused_drain_token_identical_gqa(setups, drain, temperature):
    """Every drain interval reproduces the Sampler's tokens exactly under
    the same key: D=3 doesn't divide T=8 (the last block is short), D=8
    fuses the whole budget into one block, and slots < group size force
    rows of one group into different block phases. Paged sampling draws
    per-token keys, so this holds sampled, not just greedy."""
    cfg, params = setups["gqa"]
    key = jax.random.PRNGKey(5)
    ref = Sampler(cfg, LP, T, temperature=temperature)
    eng = _engine(cfg, temperature=temperature, drain_interval=drain)
    _assert_group_identical(_run_group(eng, params, PROMPT, key),
                            ref.generate(params, [PROMPT] * G, key))


@pytest.mark.parametrize("variant", ["mla", "swa"])
@pytest.mark.parametrize("drain", [3, 8])
def test_paged_fused_drain_token_identical_backends(setups, variant, drain):
    """The cache backends the fused block must not disturb: MLA latent
    pages (absorbed-decode gather) and sliding-window reclamation, which
    the fused dispatcher performs once per block at the block's first
    query position."""
    cfg, params = setups[variant]
    key = jax.random.PRNGKey(13)
    ref = Sampler(cfg, LP, T, temperature=1.0)
    eng = _engine(cfg, temperature=1.0, drain_interval=drain)
    free0 = eng.alloc.num_free
    _assert_group_identical(_run_group(eng, params, PROMPT, key),
                            ref.generate(params, [PROMPT] * G, key))
    assert eng.alloc.num_free == free0 and eng.idle


def test_paged_spec_fused_drain_greedy_identical(setups):
    """Spec verify blocks drain per k+1-token block on their own cadence;
    a drain_interval > 1 must ride along without disturbing the spec
    path's exactness (guards against future coupling of the two knobs)."""
    cfg, params = setups["gqa"]
    key = jax.random.PRNGKey(7)
    ref = Sampler(cfg, LP, T, temperature=0.0)
    eng = _engine(cfg, temperature=0.0, spec_k=2, drain_interval=8)
    _assert_group_identical(_run_group(eng, params, PROMPT, key),
                            ref.generate(params, [PROMPT] * G, key))


def test_paged_prefix_cache_fused_identical(setups):
    """Radix prefix cache + fused blocks: warm fused serving must be
    token-identical to cold legacy serving (a cached page is bitwise the
    page a cold prefill would write; the fused block never reads one)."""
    cfg, params = setups["gqa"]
    rng = np.random.RandomState(3)
    sys_p = list(rng.randint(3, 200, size=12))
    prompts = [np.asarray(sys_p + list(rng.randint(3, 200, size=3)),
                          np.int32) for _ in range(4)]
    key = jax.random.PRNGKey(11)

    def serve(**kw):
        eng = _engine(cfg, group_size=1, num_slots=2, temperature=0.0, **kw)
        done = eng.serve(params, prompts, key)
        return {c.request_id: list(c.response_ids) for c in done}, eng

    cold, _ = serve(drain_interval=1)
    warm_eng = _engine(cfg, group_size=1, num_slots=2, temperature=0.0,
                       prefix_cache=True, drain_interval=8)
    warm_eng.serve(params, prompts, key)          # populates the tree
    done = warm_eng.serve(params, prompts, key)   # served warm
    warm = {c.request_id: list(c.response_ids) for c in done}
    assert warm == cold
    assert warm_eng.prefix_hit_pages > 0


@pytest.mark.parametrize("drain", [2, 3, 8])
def test_cbatch_fused_drain_greedy_identical(setups, drain):
    """Slot engine: the fused loop under greedy decode is token-identical
    for every D (a sampled chain legitimately realigns at D>1 — the
    per-slot key stream is consumed at different steps). Per-request caps
    force rows to stop mid-block."""
    cfg, params = setups["gqa"]
    prompts = [np.asarray([1, 9, 4, 7, 3][: 2 + i % 4], np.int32)
               for i in range(6)]
    targets = [3, 8, 5, 1, 7, 4]       # rows stop inside fused blocks
    key = jax.random.PRNGKey(2)

    def run(d):
        eng = ContinuousBatchingSampler(cfg, num_slots=2, max_prompt_len=LP,
                                        max_new_tokens=T, temperature=0.0,
                                        drain_interval=d)
        done = eng.run(params, prompts, key, max_new_per_request=targets)
        return {c.request_id: list(c.response_ids) for c in done}

    legacy = run(1)
    assert all(len(v) <= t for v, t in
               zip((legacy[i] for i in range(6)), targets))
    assert run(drain) == legacy


# =========================================================================
# drain edge cases: EOS inside / at the edge of an in-flight block
# =========================================================================

def test_paged_eos_mid_block_and_block_boundary(setups):
    """Pin EOS to exact steps by re-running with eos_id set to a token the
    no-EOS greedy stream emits: mid-block (the optimistic trailing steps
    of the in-flight block run device-masked and must contribute
    nothing), the last buffered token of a block (drain must not read
    past it), and the final budgeted step."""
    cfg, params = setups["gqa"]
    key = jax.random.PRNGKey(4)
    D = 3

    def serve_one(eos_id, drain):
        eng = _engine(cfg, group_size=1, num_slots=1, temperature=0.0,
                      eos_id=eos_id, drain_interval=drain)
        done = eng.serve(params, [PROMPT], key)
        return list(done[0].response_ids)

    stream = serve_one(-1, 1)          # eos never fires: full budget
    assert len(stream) == T
    for t_star in (D + 1, 2 * D - 1, T - 1):   # mid-block, block-last, end
        tok = stream[t_star]
        want = stream.index(tok) + 1   # first occurrence stops the row
        legacy = serve_one(tok, 1)
        fused = serve_one(tok, D)
        assert fused == legacy
        assert len(fused) == want and fused[-1] == tok


def test_paged_slot_reassignment_during_stale_drain(setups):
    """More requests than slots with a large D: a row finishing inside an
    earlier block frees its slot while a later optimistic block for that
    slot is still in flight; the drain must skip the stale plan entries
    (slot re-assigned) and the admitted successor must decode exactly as
    under the legacy cadence."""
    cfg, params = setups["gqa"]
    rng = np.random.RandomState(9)
    prompts = [rng.randint(3, 200, size=(2 + i,)).astype(np.int32)
               for i in range(6)]
    key = jax.random.PRNGKey(6)

    def serve(d):
        eng = _engine(cfg, group_size=1, num_slots=2, temperature=1.0,
                      drain_interval=d)
        done = eng.serve(params, prompts, key)
        assert eng.idle
        return {c.request_id: list(c.response_ids) for c in done}

    assert serve(5) == serve(1)


# =========================================================================
# commit_block (device walk) == assemble_commit (host oracle)
# =========================================================================

def test_commit_block_matches_assemble_commit():
    rng = np.random.RandomState(0)
    B, k = 16, 4
    for trial in range(25):
        accept = rng.randint(0, 2, size=(B, k)).astype(bool)
        alt = rng.randint(0, 50, size=(B, k + 1)).astype(np.int32)
        draft = rng.randint(0, 50, size=(B, k)).astype(np.int32)
        lp_d = rng.randn(B, k).astype(np.float32)
        lp_a = rng.randn(B, k + 1).astype(np.float32)
        toks, lps, count = jax.jit(commit_block)(
            jnp.asarray(accept), jnp.asarray(alt), jnp.asarray(draft),
            jnp.asarray(lp_d), jnp.asarray(lp_a))
        toks, lps, count = jax.device_get((toks, lps, count))
        for b in range(B):
            ref_t, ref_l = assemble_commit(accept[b], alt[b], draft[b],
                                           lp_d[b], lp_a[b])
            n = int(count[b])
            assert n == len(ref_t)
            assert [int(t) for t in toks[b, :n]] == ref_t
            np.testing.assert_array_equal(lps[b, :n],
                                          np.asarray(ref_l, np.float32))
            assert not toks[b, n:].any() and not lps[b, n:].any()


# =========================================================================
# deferred busy clock
# =========================================================================

def test_busy_clock_defers_and_flushes(setups):
    """_defer_busy must not charge on the dispatch path; flush_busy (and
    the pool's boundary reads) join the settle threads and land the exact
    dispatch->ready interval."""
    cfg, _ = setups["gqa"]
    inst = InferenceInstance(0, cfg, sampler=None)
    t0 = time.perf_counter() - 0.25          # pretend dispatch was 250ms ago
    inst._defer_busy(t0, jnp.zeros((4,)))
    inst.flush_busy()
    assert not inst._settles
    assert inst.busy_time >= 0.25

    pool = InferencePool([inst])
    inst._defer_busy(time.perf_counter() - 0.5, jnp.zeros((4,)))
    # the boundary read flushes pending settles itself
    assert pool.busy_time >= 0.75
    inst._defer_busy(time.perf_counter() - 0.1, jnp.zeros((4,)))
    pool.reset_stats()                       # flush-then-zero: no leak
    assert inst.busy_time == 0.0 and not inst._settles


# =========================================================================
# repro-check --forbid-hot severity gate
# =========================================================================

HOT_SUPPRESSED = """\
import jax


class PagedGroupEngine:
    def __init__(self):
        self._decode = jax.jit(self._decode_fn)

    def step(self):
        tok = self._decode(1)
        # repro: allow(host-sync): justified, but hot tier
        return float(tok)
"""

WARM_SUPPRESSED = """\
import jax


class PagedGroupEngine:
    def __init__(self):
        self._decode = jax.jit(self._decode_fn)

    def step(self):
        tok = self._decode(1)
        return self._drain(tok)

    def _drain(self, tok):
        # repro: allow(host-sync): one buffered readback per block
        return jax.device_get(tok)
"""


def test_cli_forbid_hot_gate(tmp_path, capsys):
    """A justified pragma exempts a warm sync but NOT a hot-tier one:
    --forbid-hot fails (exit 2) on any error-severity host-sync finding,
    suppressed or not — the device-resident-decode CI gate."""
    from repro.analysis.cli import main as cli_main
    core = tmp_path / "core"
    core.mkdir()
    (core / "paged.py").write_text(HOT_SUPPRESSED)
    base = [str(core), "--root", str(tmp_path), "--checker", "host-sync"]
    assert cli_main(base) == 0                    # suppression holds...
    rc = cli_main(base + ["--forbid-hot"])        # ...but not on hot tier
    assert rc == 2
    assert "hot-tier host-sync" in capsys.readouterr().out

    (core / "paged.py").write_text(WARM_SUPPRESSED)
    assert cli_main(base + ["--forbid-hot"]) == 0


# =========================================================================
# shard_map'd dense-GQA decode (subprocess: forced fake devices)
# =========================================================================

def _usable_cpus() -> int:
    try:
        return len(os.sched_getaffinity(0))
    except AttributeError:
        return os.cpu_count() or 1


SHMAP_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import jax, jax.numpy as jnp
from repro.models.attention import (DenseCacheBackend, gqa_attention,
                                    init_gqa, _shmap_decode_fit)
from repro.configs.base import ModelConfig
from repro.sharding.specs import use_mesh, current_mesh

cfg = ModelConfig(name="t", d_model=32, num_heads=4, num_kv_heads=2,
                  head_dim=8, num_layers=1, d_ff=64, vocab_size=64)
params = init_gqa(jax.random.PRNGKey(0), cfg, jnp.float32)
B, L = 2, 16
st = DenseCacheBackend(cfg, L).init(B, jnp.float32)
x_pre = jax.random.normal(jax.random.PRNGKey(1), (B, 4, 32))
pos = jnp.broadcast_to(jnp.arange(4), (B, 4)).astype(jnp.int32)
seg = jnp.zeros((B, 4), jnp.int32)
_, st = gqa_attention(params, cfg, x_pre, pos, seg, cache=st,
                      cache_offset=0)

xd = jax.random.normal(jax.random.PRNGKey(2), (B, 1, 32))
posd = jnp.full((B, 1), 4, jnp.int32)
segd = jnp.zeros((B, 1), jnp.int32)

# single-program reference, jitted WITHOUT a mesh -> plain GSPMD branch
ref_out, ref_st = jax.jit(lambda c: gqa_attention(
    params, cfg, xd, posd, segd, cache=c, cache_offset=4))(st)

mesh = jax.make_mesh((1, 2), ("data", "model"))
with use_mesh(mesh):
    assert _shmap_decode_fit(cfg, st, current_mesh(), 1), \
        "seq-sharded dense GQA decode must take the shard_map branch"
    for off in (4, jnp.full((B,), 4, jnp.int32)):   # both offset forms
        out, new = jax.jit(lambda c, o: gqa_attention(
            params, cfg, xd, posd, segd, cache=c, cache_offset=o))(st, off)
        err = float(jnp.abs(ref_out - out).max())
        print("out err", err)
        assert err < 1e-5, err
        for kk in ("k", "v", "pos", "seg"):
            d = float(jnp.abs(jnp.asarray(ref_st[kk], jnp.float32)
                              - jnp.asarray(new[kk], jnp.float32)).max())
            assert d == 0.0, (kk, d)    # cache write: bitwise
print("OK")
"""


@pytest.mark.skipif(
    _usable_cpus() < 2 and not os.environ.get("FORCE_SHMAP_DECODE"),
    reason="host has <2 usable cores for the forced-2-device shard_map "
           "decode check (FORCE_SHMAP_DECODE=1 overrides)")
def test_shmap_decode_matches_gspmd_reference():
    """The shard_map'd decode step (seq-sharded cache, masked local write,
    flash partial-stat combine over the mesh) must reproduce the plain
    GSPMD branch: output to fp tolerance, cache writes bitwise."""
    r = subprocess.run([sys.executable, "-c", SHMAP_SCRIPT],
                       capture_output=True, text=True, timeout=420,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert r.returncode == 0, \
        f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    assert "OK" in r.stdout
