"""Model-level Pallas kernel integration: cfg.use_pallas_attention swaps the
pure-JAX chunked path for the fused kernel (interpret mode on CPU) — the
full forward must agree, including SPA-packed inputs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.core.queue import RolloutGroup
from repro.core.spa import pack_spa
from repro.models import forward_hidden, init
from repro.rl.grpo import group_advantages


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config(get_config("llama3.2-3b"))
    params = init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_pallas_path_matches_chunked_forward(setup):
    cfg, params = setup
    cfg_k = dataclasses.replace(cfg, use_pallas_attention=True)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 3,
                              cfg.vocab_size)
    h_ref, _, _, _ = forward_hidden(params, cfg, toks)
    h_ker, _, _, _ = forward_hidden(params, cfg_k, toks)
    np.testing.assert_allclose(np.asarray(h_ker), np.asarray(h_ref),
                               atol=2e-4, rtol=2e-4)


def test_pallas_path_matches_on_spa_packed_rows(setup):
    """The kernel's raison d'etre: SPA-packed segment masks."""
    cfg, params = setup
    cfg_k = dataclasses.replace(cfg, use_pallas_attention=True)
    rng = np.random.RandomState(0)
    g = RolloutGroup(
        uid=0, prompt_ids=rng.randint(3, 250, size=(12,)).astype(np.int32),
        response_ids=rng.randint(3, 250, size=(3, 6)).astype(np.int32),
        response_len=np.full((3,), 6, np.int32),
        rewards=np.asarray([1.0, 0.0, 1.0], np.float32), weight_version=0)
    adv = np.asarray(group_advantages(jnp.asarray(g.rewards)))
    mb = pack_spa(g, adv, 12, 6, responses_per_row=3)
    kw = dict(positions=jnp.asarray(mb.positions),
              segments=jnp.asarray(mb.segments))
    toks = jnp.asarray(mb.tokens)
    h_ref, _, _, _ = forward_hidden(params, cfg, toks, **kw)
    h_ker, _, _, _ = forward_hidden(params, cfg_k, toks, **kw)
    np.testing.assert_allclose(np.asarray(h_ker), np.asarray(h_ref),
                               atol=2e-4, rtol=2e-4)
