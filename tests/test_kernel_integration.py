"""Model-level Pallas kernel integration: cfg.use_pallas_attention swaps the
pure-JAX chunked path for the fused kernel (interpret mode on CPU) — the
full forward must agree, including SPA-packed inputs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.core.queue import RolloutGroup
from repro.core.spa import pack_spa
from repro.models import forward_hidden, init
from repro.rl.grpo import group_advantages


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config(get_config("llama3.2-3b"))
    params = init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_pallas_path_matches_chunked_forward(setup):
    cfg, params = setup
    cfg_k = dataclasses.replace(cfg, use_pallas_attention=True)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 3,
                              cfg.vocab_size)
    h_ref, _, _, _ = forward_hidden(params, cfg, toks)
    h_ker, _, _, _ = forward_hidden(params, cfg_k, toks)
    np.testing.assert_allclose(np.asarray(h_ker), np.asarray(h_ref),
                               atol=2e-4, rtol=2e-4)


def test_pallas_path_matches_on_spa_packed_rows(setup):
    """The kernel's raison d'etre: SPA-packed segment masks."""
    cfg, params = setup
    cfg_k = dataclasses.replace(cfg, use_pallas_attention=True)
    rng = np.random.RandomState(0)
    g = RolloutGroup(
        uid=0, prompt_ids=rng.randint(3, 250, size=(12,)).astype(np.int32),
        response_ids=rng.randint(3, 250, size=(3, 6)).astype(np.int32),
        response_len=np.full((3,), 6, np.int32),
        rewards=np.asarray([1.0, 0.0, 1.0], np.float32), weight_version=0)
    adv = np.asarray(group_advantages(jnp.asarray(g.rewards)))
    mb = pack_spa(g, adv, 12, 6, responses_per_row=3)
    kw = dict(positions=jnp.asarray(mb.positions),
              segments=jnp.asarray(mb.segments))
    toks = jnp.asarray(mb.tokens)
    h_ref, _, _, _ = forward_hidden(params, cfg, toks, **kw)
    h_ker, _, _, _ = forward_hidden(params, cfg_k, toks, **kw)
    np.testing.assert_allclose(np.asarray(h_ker), np.asarray(h_ref),
                               atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("arch", ["llama3.2-3b", "deepseek-v2-lite-16b"])
def test_pallas_paged_decode_matches_pure(arch):
    """One paged decode step through the attention block with
    cfg.use_pallas_attention on vs off (GQA K/V pages and MLA latent
    pages): the flash-decode kernel wrapper and the pure-JAX gather path
    must agree on the same page pool."""
    from repro.models.attention import (PagedCacheBackend, attention,
                                        init_attention)
    cfg = reduced_config(get_config(arch))
    cfg_k = dataclasses.replace(cfg, use_pallas_attention=True)
    rng = np.random.RandomState(7)
    params = init_attention(jax.random.PRNGKey(11), cfg, jnp.float32)
    P, page, n_max, B = 6, 4, 3, 2
    be = PagedCacheBackend(cfg, page)
    cache = be.init(P, jnp.float32)
    # fill pages 2..5 with a fake history at positions 0..7 per row
    cache = {k: (jnp.asarray(rng.randn(*v.shape), jnp.float32)
                 if v.dtype != jnp.int32 else v) for k, v in cache.items()}
    pos = np.full((P, page), 2 ** 30, np.int64)
    for j, p0 in ((2, 0), (3, 4), (4, 0), (5, 4)):
        pos[j] = np.arange(p0, p0 + page)
    cache["pos_pages"] = jnp.asarray(pos, jnp.int32)
    table = jnp.asarray([[2, 3, 0], [4, 5, 0]], jnp.int32)
    x = jnp.asarray(rng.randn(B, 1, cfg.d_model), jnp.float32)
    positions = jnp.full((B, 1), 8, jnp.int32)
    segments = jnp.zeros((B, 1), jnp.int32)
    wslot = jnp.asarray([3 * page + 0, 5 * page + 0], jnp.int32)
    o_ref, c_ref = attention(params, cfg, x, positions, segments,
                             cache=cache, cache_offset=wslot,
                             page_table=table)
    o_ker, c_ker = attention(params, cfg_k, x, positions, segments,
                             cache=cache, cache_offset=wslot,
                             page_table=table)
    np.testing.assert_allclose(np.asarray(o_ker), np.asarray(o_ref),
                               atol=2e-4, rtol=2e-4)
    for k in c_ref:
        np.testing.assert_allclose(np.asarray(c_ker[k]),
                                   np.asarray(c_ref[k]), atol=1e-6)
