"""Pallas kernel sweeps: shapes x dtypes x mask variants, interpret mode on
CPU, assert_allclose against the pure-jnp oracles in repro.kernels.ref.

Also checks the structural property that makes spa_attention the paper's
K-fold win: the block map really drops the response_i x response_j tiles.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ref import decode_attention_ref, spa_attention_ref
from repro.kernels.spa_attention import block_map, spa_attention
from repro.kernels.decode_attention import decode_attention

INTERP = dict(interpret=True)


def spa_layout(key, B, Lp, Lr, K, H, Hkv, D, dtype, pad_tail=0):
    """Build a shared-prompt packed row: [prompt, r_1..r_K] + optional pad."""
    S = Lp + K * Lr + pad_tail
    pos = np.zeros((B, S), np.int32)
    seg = np.full((B, S), -1, np.int32)
    pos[:, :Lp] = np.arange(Lp)
    seg[:, :Lp] = 0
    off = Lp
    for k in range(K):
        pos[:, off:off + Lr] = np.arange(Lp, Lp + Lr)
        seg[:, off:off + Lr] = k + 1
        off += Lr
    if pad_tail:
        pos[:, off:] = 2 ** 30 - 1   # invalid-pad: masked by causal rule
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, S, H, D), dtype)
    k = jax.random.normal(kk, (B, S, Hkv, D), dtype)
    v = jax.random.normal(kv, (B, S, Hkv, D), dtype)
    return q, k, v, jnp.asarray(pos), jnp.asarray(seg)


TOL = {jnp.float32: dict(atol=2e-5, rtol=2e-5),
       jnp.bfloat16: dict(atol=3e-2, rtol=3e-2)}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,Lp,Lr,K,H,Hkv,D,bq,bk",
    [
        (1, 32, 16, 2, 2, 2, 64, 16, 16),     # MHA, tiny tiles
        (2, 40, 24, 3, 4, 2, 64, 32, 32),     # GQA 2:1, non-divisible -> pad
        (1, 64, 32, 4, 8, 2, 128, 64, 64),    # GQA 4:1, wide head
        (1, 17, 9, 2, 2, 1, 32, 16, 16),      # ragged lengths -> padding path
    ])
def test_spa_kernel_matches_ref(dtype, B, Lp, Lr, K, H, Hkv, D, bq, bk):
    q, k, v, pos, seg = spa_layout(jax.random.PRNGKey(0), B, Lp, Lr, K,
                                   H, Hkv, D, dtype)
    got = spa_attention(q, k, v, pos, pos, seg, seg,
                        block_q=bq, block_k=bk, **INTERP)
    want = spa_attention_ref(q, k, v, pos, pos, seg, seg)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


@pytest.mark.parametrize("window", [8, 32, None])
def test_spa_kernel_window(window):
    q, k, v, pos, seg = spa_layout(jax.random.PRNGKey(1), 2, 32, 16, 2,
                                   4, 2, 64, jnp.float32)
    got = spa_attention(q, k, v, pos, pos, seg, seg, window=window,
                        block_q=16, block_k=16, **INTERP)
    want = spa_attention_ref(q, k, v, pos, pos, seg, seg, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_spa_kernel_with_padding_tail():
    """Rows padded past the packed content (seg=-1, huge pos) must not leak
    into real outputs."""
    q, k, v, pos, seg = spa_layout(jax.random.PRNGKey(2), 2, 24, 8, 2,
                                   2, 2, 32, jnp.float32, pad_tail=24)
    got = spa_attention(q, k, v, pos, pos, seg, seg,
                        block_q=16, block_k=16, **INTERP)
    want = spa_attention_ref(q, k, v, pos, pos, seg, seg)
    real = 24 + 2 * 8
    np.testing.assert_allclose(np.asarray(got)[:, :real],
                               np.asarray(want)[:, :real],
                               atol=2e-5, rtol=2e-5)


def test_spa_equals_per_sample_attention():
    """The packed SPA output at response k's rows equals standard causal
    attention over [prompt; response_k] alone — the paper's exactness claim
    at the kernel level."""
    B, Lp, Lr, K, H, D = 1, 32, 16, 3, 2, 64
    key = jax.random.PRNGKey(3)
    q, k, v, pos, seg = spa_layout(key, B, Lp, Lr, K, H, H, D, jnp.float32)
    packed = spa_attention(q, k, v, pos, pos, seg, seg,
                           block_q=16, block_k=16, **INTERP)
    for j in range(K):
        sl = np.r_[0:Lp, Lp + j * Lr: Lp + (j + 1) * Lr]
        qj, kj, vj = q[:, sl], k[:, sl], v[:, sl]
        pj = pos[:, sl]
        zj = jnp.zeros_like(pj)
        want = spa_attention_ref(qj, kj, vj, pj, pj, zj, zj)  # plain causal
        np.testing.assert_allclose(
            np.asarray(packed[:, Lp + j * Lr: Lp + (j + 1) * Lr]),
            np.asarray(want[:, Lp:]), atol=2e-5, rtol=2e-5)


def test_block_map_sparsity_structure():
    """Tiles fully inside response_i x response_j (i != j) must be dead, and
    the live fraction must approach Eq. 5's rho for Lp >> Lr."""
    B, Lp, Lr, K = 1, 256, 64, 4
    S = Lp + K * Lr
    pos = np.zeros((B, S), np.int32)
    seg = np.zeros((B, S), np.int32)
    pos[:, :Lp] = np.arange(Lp)
    off = Lp
    for k in range(K):
        pos[:, off:off + Lr] = np.arange(Lp, Lp + Lr)
        seg[:, off:off + Lr] = k + 1
        off += Lr
    bq = bk = 64
    bm = np.asarray(block_map(jnp.asarray(pos), jnp.asarray(pos),
                              jnp.asarray(seg), jnp.asarray(seg), bq, bk))
    nq = S // bq
    # response_i x response_j dead tiles: query tile in resp i, kv tile in
    # resp j != i (both fully inside one response since Lr == tile size)
    for i in range(K):
        for j in range(K):
            qt = (Lp + i * Lr) // bq
            kt = (Lp + j * Lr) // bk
            if i == j:
                assert bm[0, qt, kt] == 1
            else:
                assert bm[0, qt, kt] == 0, (i, j)
    # kv tiles in the shared prompt are live for all later query tiles
    assert bm[0, nq - 1, 0] == 1
    live_frac = bm.mean()
    # dense causal would be ~0.56; SPA structure must prune well below it
    dense_causal = np.tril(np.ones((nq, nq))).mean()
    assert live_frac < dense_causal


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,L,H,Hkv,D,bL",
    [
        (2, 64, 4, 4, 64, 32),     # MHA
        (2, 100, 8, 2, 64, 32),    # GQA 4:1, ragged L -> pad
        (1, 256, 8, 1, 128, 64),   # MQA
        (4, 33, 2, 2, 32, 16),     # tiny ragged
    ])
def test_decode_kernel_matches_ref(dtype, B, L, H, Hkv, D, bL):
    key = jax.random.PRNGKey(0)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, H, D), dtype)
    k = jax.random.normal(kk, (B, L, Hkv, D), dtype)
    v = jax.random.normal(kv, (B, L, Hkv, D), dtype)
    kv_pos = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32), (B, L))
    q_pos = jnp.full((B,), L, jnp.int32)
    got = decode_attention(q, k, v, kv_pos, q_pos, block_l=bL, **INTERP)
    want = decode_attention_ref(q, k, v, kv_pos, q_pos)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


@pytest.mark.parametrize("window", [16, 64])
def test_decode_kernel_window_and_invalid_slots(window):
    """Ring-buffer semantics: some slots carry INVALID pos (2**30) and the
    window must exclude old positions."""
    B, L, H, Hkv, D = 2, 96, 4, 2, 64
    key = jax.random.PRNGKey(7)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, H, D), jnp.float32)
    k = jax.random.normal(kk, (B, L, Hkv, D), jnp.float32)
    v = jax.random.normal(kv, (B, L, Hkv, D), jnp.float32)
    kv_pos = np.broadcast_to(np.arange(L, dtype=np.int32), (B, L)).copy()
    kv_pos[:, 70:] = 2 ** 30    # unwritten ring slots
    kv_pos = jnp.asarray(kv_pos)
    q_pos = jnp.full((B,), 70, jnp.int32)
    got = decode_attention(q, k, v, kv_pos, q_pos, window=window,
                           block_l=32, **INTERP)
    want = decode_attention_ref(q, k, v, kv_pos, q_pos, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_decode_kernel_matches_spa_kernel_single_token():
    """Cross-kernel consistency: decoding one token with decode_attention
    equals running spa_attention with Sq=1."""
    B, L, H, Hkv, D = 2, 64, 4, 2, 64
    key = jax.random.PRNGKey(9)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, 1, H, D), jnp.float32)
    k = jax.random.normal(kk, (B, L, Hkv, D), jnp.float32)
    v = jax.random.normal(kv, (B, L, Hkv, D), jnp.float32)
    kv_pos = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32), (B, L))
    q_pos1 = jnp.full((B, 1), L, jnp.int32)
    zq = jnp.zeros((B, 1), jnp.int32)
    zk = jnp.zeros((B, L), jnp.int32)
    a = spa_attention(q, k, v, q_pos1, kv_pos, zq, zk,
                      block_q=16, block_k=16, **INTERP)
    b = decode_attention(q[:, 0], k, v, kv_pos, q_pos1[:, 0],
                         block_l=32, **INTERP)
    np.testing.assert_allclose(np.asarray(a[:, 0]), np.asarray(b),
                               atol=2e-5, rtol=2e-5)
