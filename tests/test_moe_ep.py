"""Expert-parallel MoE correctness on a multi-device mesh.

The shard_map EP path (dispatch all-to-all + ZeRO-3 weight gather, plus the
decode-regime psum variant from §Perf) must match the single-device local
oracle. Runs in a SUBPROCESS so the 8 fake host devices never leak into the
rest of the suite (conftest requirement: tests see 1 device).

The subprocess forces 8 XLA host devices; compiling the (4, 2)-mesh EP
program is CPU-bound per fake device, so hosts with fewer physical cores
than mesh devices blow the subprocess timeout (triaged in DESIGN.md
§Known-issues). Skipped there — NOT an allowed-failure: on capable hosts
a real regression still fails the suite.
"""
import os
import subprocess
import sys

import pytest

MESH_DEVICES = 8      # --xla_force_host_platform_device_count below


def _usable_cpus() -> int:
    """CPUs this process can actually run on — affinity/cgroup-aware where
    the platform exposes it (os.cpu_count() reports the host's logical
    cores even under docker --cpus / taskset)."""
    try:
        return len(os.sched_getaffinity(0))
    except AttributeError:            # macOS / platforms without affinity
        return os.cpu_count() or 1

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import jax.numpy as jnp
import numpy as np
from repro.configs import get_config, reduced_config
from repro.models.moe import _moe_ffn_ep, _moe_ffn_local
from repro.models import init
from repro.sharding.specs import use_mesh

cfg = reduced_config(get_config("qwen3-moe-235b-a22b"))
assert cfg.num_experts == 4
params = init(jax.random.PRNGKey(0), cfg)
# unstack layer 0's moe params
moe_p = jax.tree.map(lambda a: a[0], params["layers"])["moe"]

mesh = jax.make_mesh((4, 2), ("data", "model"))

def check(B, S, label):
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model),
                          jnp.float32)
    y_ref, aux_ref = _moe_ffn_local(moe_p, cfg, x)
    with use_mesh(mesh):
        y_ep, aux_ep = jax.jit(
            lambda p, x: _moe_ffn_ep(p, cfg, x, mesh))(moe_p, x)
    err = float(jnp.abs(y_ref - y_ep).max())
    aerr = abs(float(aux_ref) - float(aux_ep))
    print(label, "err", err, "aux_err", aerr)
    assert err < 2e-4, (label, err)
    assert aerr < 1e-4, (label, aerr)

# train regime (S > 1): ZeRO-3 gather path. capacity must not drop tokens
# differently between paths -> use few tokens per expert.
check(B=8, S=2, label="train_gather_path")
# decode regime (S == 1, few tokens): psum path (use_psum True)
check(B=8, S=1, label="decode_psum_path")
print("OK")
"""


@pytest.mark.skipif(
    _usable_cpus() < MESH_DEVICES and not os.environ.get("FORCE_MOE_EP"),
    reason=f"host has {_usable_cpus()} usable cores < {MESH_DEVICES} mesh "
           "devices: the forced-8-device EP compile exceeds the subprocess "
           "timeout (DESIGN.md §Known-issues; FORCE_MOE_EP=1 overrides)")
def test_moe_ep_matches_local_oracle():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=420,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    assert "OK" in r.stdout
