"""Expert-parallel MoE correctness on a multi-device mesh.

The shard_map EP path (dispatch all-to-all + ZeRO-3 weight gather, plus the
decode-regime psum variant from §Perf) must match the single-device local
oracle. Runs in a SUBPROCESS so the 8 fake host devices never leak into the
rest of the suite (conftest requirement: tests see 1 device).
"""
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import jax.numpy as jnp
import numpy as np
from repro.configs import get_config, reduced_config
from repro.models.moe import _moe_ffn_ep, _moe_ffn_local
from repro.models import init
from repro.sharding.specs import use_mesh

cfg = reduced_config(get_config("qwen3-moe-235b-a22b"))
assert cfg.num_experts == 4
params = init(jax.random.PRNGKey(0), cfg)
# unstack layer 0's moe params
moe_p = jax.tree.map(lambda a: a[0], params["layers"])["moe"]

mesh = jax.make_mesh((4, 2), ("data", "model"))

def check(B, S, label):
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model),
                          jnp.float32)
    y_ref, aux_ref = _moe_ffn_local(moe_p, cfg, x)
    with use_mesh(mesh):
        y_ep, aux_ep = jax.jit(
            lambda p, x: _moe_ffn_ep(p, cfg, x, mesh))(moe_p, x)
    err = float(jnp.abs(y_ref - y_ep).max())
    aerr = abs(float(aux_ref) - float(aux_ep))
    print(label, "err", err, "aux_err", aerr)
    assert err < 2e-4, (label, err)
    assert aerr < 1e-4, (label, aerr)

# train regime (S > 1): ZeRO-3 gather path. capacity must not drop tokens
# differently between paths -> use few tokens per expert.
check(B=8, S=2, label="train_gather_path")
# decode regime (S == 1, few tokens): psum path (use_psum True)
check(B=8, S=1, label="decode_psum_path")
print("OK")
"""


def test_moe_ep_matches_local_oracle():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=420,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    assert "OK" in r.stdout
