"""Tests for the tracing/metrics plane (src/repro/obs) and its checker.

Covers: the disabled fast path (shared null span, no allocation), the
Chrome trace-event export format (per-thread buffers, virtual tracks,
async b/e pairing), the metrics registry, the busy-clock O(1) boundary
regression (settles deregister; repeated reads join nothing), and the
obs-discipline checker (begin/end balance + hot-tier span-over-sync),
with bug-injection and clean fixtures like the rest of test_analysis.
"""
import json
import threading

import jax
import numpy as np
import pytest

from repro.analysis.framework import Module
from repro.analysis.obs_discipline import ObsDisciplineChecker
from repro.obs import MetricsRegistry, Tracer
from repro.obs import trace as otrace


@pytest.fixture(autouse=True)
def _no_leaked_tracer():
    yield
    otrace.uninstall()


# ---------------------------------------------------------------------------
# disabled fast path
# ---------------------------------------------------------------------------

def test_disabled_span_is_shared_noop():
    otrace.uninstall()
    a = otrace.span("x", k=1)
    b = otrace.span("y")
    assert a is b            # ONE shared null object: nothing allocates
    with a as s:
        assert s.set(more=2) is s
    # and every other facade call is a no-op, not an error
    otrace.complete("n", 0.0, 1.0)
    otrace.begin("n", uid=1)
    otrace.end("n", uid=1)
    otrace.instant("n")
    otrace.counter("n", 3)
    assert otrace.export("/nonexistent/dir/never-written.json") is None
    assert not otrace.active()


def test_install_uninstall_swaps_facade():
    t = otrace.install("p")
    assert otrace.get() is t and otrace.active()
    otrace.uninstall()
    assert otrace.get() is None


# ---------------------------------------------------------------------------
# export format
# ---------------------------------------------------------------------------

def test_span_and_complete_export(tmp_path):
    tr = Tracer("proc")
    with tr.span("work", stage="a") as sp:
        sp.set(extra=1)
    tr.complete("retro", tr._epoch + 1.0, tr._epoch + 3.0, foo="bar")
    path = tr.export(str(tmp_path / "t.json"))
    doc = json.load(open(path))
    evs = doc["traceEvents"]
    assert doc["displayTimeUnit"] == "ms"
    proc = [e for e in evs if e["ph"] == "M" and e["name"] == "process_name"]
    assert proc[0]["args"]["name"] == "proc"
    work = next(e for e in evs if e["name"] == "work")
    assert work["ph"] == "X" and work["dur"] >= 0
    assert work["args"] == {"stage": "a", "extra": 1}
    retro = next(e for e in evs if e["name"] == "retro")
    assert retro["ts"] == pytest.approx(1e6, rel=1e-6)
    assert retro["dur"] == pytest.approx(2e6, rel=1e-6)


def test_per_thread_buffers_and_thread_names():
    tr = Tracer()

    def worker():
        tr.complete("w", tr._epoch, tr._epoch + 0.1)

    th = threading.Thread(target=worker, name="worker-thread")
    th.start()
    th.join()
    tr.complete("m", tr._epoch, tr._epoch + 0.1)
    evs = tr.events()
    w = next(e for e in evs if e["name"] == "w")
    m = next(e for e in evs if e["name"] == "m")
    assert w["tid"] != m["tid"]     # each writer thread has its own track
    names = {e["tid"]: e["args"]["name"] for e in evs
             if e["ph"] == "M" and e["name"] == "thread_name"}
    assert names[w["tid"]] == "worker-thread"


def test_virtual_track_pins_tid():
    tr = Tracer()
    tr.complete("a", tr._epoch, tr._epoch + 0.1, track="producer/inst0")
    tr.complete("b", tr._epoch, tr._epoch + 0.1, track="producer/inst0")
    tr.complete("c", tr._epoch, tr._epoch + 0.1, track="producer/inst1")
    evs = tr.events()
    tid = {e["name"]: e["tid"] for e in evs if e["ph"] == "X"}
    assert tid["a"] == tid["b"] != tid["c"]
    names = {e["args"]["name"] for e in evs
             if e["ph"] == "M" and e["name"] == "thread_name"}
    assert {"producer/inst0", "producer/inst1"} <= names


def test_async_begin_end_and_instant():
    tr = Tracer()
    tr.begin("request", uid=7, rid=7)
    tr.instant("request.token", rid=7)
    tr.end("request", uid=7)
    evs = [e for e in tr.events() if e["ph"] in "bei"]
    b, i, e = evs
    assert (b["ph"], i["ph"], e["ph"]) == ("b", "i", "e")
    assert b["cat"] == e["cat"] == "async"
    assert b["id"] == e["id"] == "7"   # Perfetto joins b/e by (cat, id)
    assert i["s"] == "t"
    assert b["ts"] <= i["ts"] <= e["ts"]


def test_events_sorted_by_ts():
    tr = Tracer()
    tr.complete("late", tr._epoch + 5.0, tr._epoch + 6.0)
    tr.complete("early", tr._epoch + 1.0, tr._epoch + 2.0)
    xs = [e["name"] for e in tr.events() if e["ph"] == "X"]
    assert xs == ["early", "late"]


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_metrics_counter_gauge_histogram():
    reg = MetricsRegistry()
    c = reg.counter("prefix.hit_pages")
    c.add(3)
    c.add(2)
    assert reg.counter("prefix.hit_pages") is c   # get-or-create
    reg.gauge("paged.pages_live").set(17)
    h = reg.histogram("transfer.bucket_bytes")
    for v in (10, 20, 30, 40):
        h.observe(v)
    snap = reg.snapshot()
    assert snap["prefix.hit_pages"] == 5
    assert snap["paged.pages_live"] == 17
    assert snap["transfer.bucket_bytes"]["count"] == 4
    assert snap["transfer.bucket_bytes"]["min"] == 10
    assert snap["transfer.bucket_bytes"]["max"] == 40
    reg.reset()
    assert reg.counter("prefix.hit_pages").value == 0


def test_metrics_type_conflict_raises():
    reg = MetricsRegistry()
    reg.counter("x")
    with pytest.raises(AssertionError):
        reg.gauge("x")


def test_metrics_threaded_counter():
    reg = MetricsRegistry()
    c = reg.counter("n")
    threads = [threading.Thread(target=lambda: [c.add(1) for _ in range(500)])
               for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value == 2000


# ---------------------------------------------------------------------------
# busy-clock boundary regression: settles deregister themselves; repeated
# busy_time reads between boundaries join nothing and agree exactly
# ---------------------------------------------------------------------------

def test_busy_time_repeated_reads_are_o1_and_identical():
    from repro.configs import get_config, reduced_config
    from repro.core.engine import InferenceInstance, InferencePool
    from repro.models import init
    from repro.rl.rollout import Sampler

    cfg = reduced_config(get_config("llama3.2-3b"))
    params = init(jax.random.PRNGKey(0), cfg)
    sampler = Sampler(cfg, 16, 4)
    inst = InferenceInstance(0, cfg, sampler)
    inst.sync_weights(params, version=1)
    pool = InferencePool([inst])
    prompts = [np.asarray([1, 5, 9], np.int32)] * 2
    for _ in range(3):   # three deferred settle threads charged the clock
        inst.generate_group(prompts, jax.random.PRNGKey(0))

    first = pool.busy_time           # boundary read: flushes the settles
    joins_after_first = inst.settle_joins
    reads = [pool.busy_time for _ in range(50)]
    assert all(r == first for r in reads)        # identical, not just close
    # O(1): none of the 50 reads re-joined a settle thread — completed
    # settles deregistered themselves at the first boundary
    assert inst.settle_joins == joins_after_first
    assert inst._settles == []
    assert first > 0.0


def test_reset_stats_clears_busy_clock():
    from repro.configs import get_config, reduced_config
    from repro.core.engine import InferenceInstance, InferencePool
    from repro.models import init
    from repro.rl.rollout import Sampler

    cfg = reduced_config(get_config("llama3.2-3b"))
    params = init(jax.random.PRNGKey(0), cfg)
    inst = InferenceInstance(0, cfg, Sampler(cfg, 16, 4))
    inst.sync_weights(params, version=1)
    pool = InferencePool([inst])
    inst.generate_group([np.asarray([1, 2, 3], np.int32)] * 2,
                        jax.random.PRNGKey(0))
    assert pool.busy_time > 0
    pool.reset_stats()
    assert pool.busy_time == 0.0


# ---------------------------------------------------------------------------
# obs-discipline checker
# ---------------------------------------------------------------------------

def run_one(*mods):
    return ObsDisciplineChecker().run(
        [Module.from_source(p, src) for p, src in mods])


UNBALANCED = """\
from repro.obs import trace as otrace


def submit(r):
    otrace.begin("request", uid=r)
"""

BALANCED_CROSS_MODULE_A = """\
from repro.obs import trace as otrace


def submit(r):
    otrace.begin("request", uid=r)
"""

BALANCED_CROSS_MODULE_B = """\
from repro.obs import trace as otrace


def finish(r):
    otrace.end("request", uid=r)
"""


def test_unbalanced_begin_flagged():
    fs = run_one(("launch/serve.py", UNBALANCED))
    assert len(fs) == 1
    assert "no matching otrace.end" in fs[0].message
    assert fs[0].line == 5


def test_end_without_begin_flagged():
    fs = run_one(("launch/serve.py", BALANCED_CROSS_MODULE_B))
    assert len(fs) == 1
    assert "no matching otrace.begin" in fs[0].message


def test_cross_module_balance_is_clean():
    # begin and end legitimately live in different functions/modules —
    # the pairing is by span NAME repo-wide, not lexical
    fs = run_one(("launch/serve.py", BALANCED_CROSS_MODULE_A),
                 ("core/engine.py", BALANCED_CROSS_MODULE_B))
    assert fs == []


def test_unrelated_begin_method_not_matched():
    src = """\
class VersionedParamStore:
    def begin(self, version):
        return version


def publish(store, v):
    store.begin(v)
    self.store.begin(v)
"""
    assert run_one(("transfer/service.py", src)) == []


def test_dynamic_span_name_warns():
    src = """\
from repro.obs import trace as otrace


def submit(name, r):
    otrace.begin(name, uid=r)
    otrace.end(name, uid=r)
"""
    fs = run_one(("launch/serve.py", src))
    assert len(fs) == 2
    assert all(f.severity == "warning" for f in fs)
    assert "dynamic span name" in fs[0].message


HOT_SPAN_BUG = """\
import jax
from repro.obs import trace as otrace


class PagedGroupEngine:
    def __init__(self):
        self._decode = jax.jit(self._decode_fn)

    def step(self):
        with otrace.span("paged.step"):
            tok = self._decode(1)
            jax.device_get(tok)
"""

WARM_SPAN_OK = """\
import jax
from repro.obs import trace as otrace


class PagedGroupEngine:
    def __init__(self):
        self._decode = jax.jit(self._decode_fn)

    def step(self):
        self._drain_block()

    def _drain_block(self):
        with otrace.span("paged.drain"):
            jax.device_get(self.buf)
"""

HOT_SPAN_NO_SYNC = """\
from repro.obs import trace as otrace


class PagedGroupEngine:
    def step(self):
        with otrace.span("paged.admit"):
            self.queue.append(1)
"""


def test_hot_tier_span_over_sync_flagged():
    fs = run_one(("core/paged.py", HOT_SPAN_BUG))
    assert len(fs) == 1
    assert "wraps a host sync" in fs[0].message
    assert "otrace.complete()" in fs[0].message
    assert fs[0].line == 10     # the span line, where the fix goes


def test_drain_tier_span_over_sync_is_legal():
    # depth >= 1 is exactly where retro-recorded drain spans belong
    assert run_one(("core/paged.py", WARM_SPAN_OK)) == []


def test_hot_tier_span_without_sync_is_legal():
    assert run_one(("core/paged.py", HOT_SPAN_NO_SYNC)) == []


def test_repo_is_obs_clean():
    """Dogfood: the checker reports nothing across src/ (same gate CI
    runs via repro-check)."""
    import pathlib

    from repro.analysis.framework import discover, run_checkers
    from repro.analysis.registry import CHECKER_NAMES
    root = pathlib.Path(__file__).resolve().parents[1]
    mods = discover([root / "src"], root=root)
    fs = [f for f in run_checkers(mods, [ObsDisciplineChecker()],
                                  known_names=CHECKER_NAMES)
          if f.checker == "obs-discipline" and not f.suppressed]
    assert fs == [], [f.render() for f in fs]
