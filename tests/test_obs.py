"""Tests for the tracing/metrics plane (src/repro/obs) and its checker.

Covers: the disabled fast path (shared null span, no allocation), the
Chrome trace-event export format (per-thread buffers, virtual tracks,
async b/e pairing), the metrics registry, the busy-clock O(1) boundary
regression (settles deregister; repeated reads join nothing), and the
obs-discipline checker (begin/end balance + hot-tier span-over-sync),
with bug-injection and clean fixtures like the rest of test_analysis.
"""
import json
import threading

import jax
import numpy as np
import pytest

from repro.analysis.framework import Module
from repro.analysis.obs_discipline import ObsDisciplineChecker
from repro.obs import MetricsRegistry, Tracer
from repro.obs import trace as otrace


@pytest.fixture(autouse=True)
def _no_leaked_tracer():
    yield
    otrace.uninstall()


# ---------------------------------------------------------------------------
# disabled fast path
# ---------------------------------------------------------------------------

def test_disabled_span_is_shared_noop():
    otrace.uninstall()
    a = otrace.span("x", k=1)
    b = otrace.span("y")
    assert a is b            # ONE shared null object: nothing allocates
    with a as s:
        assert s.set(more=2) is s
    # and every other facade call is a no-op, not an error
    otrace.complete("n", 0.0, 1.0)
    otrace.begin("n", uid=1)
    otrace.end("n", uid=1)
    otrace.instant("n")
    otrace.counter("n", 3)
    assert otrace.export("/nonexistent/dir/never-written.json") is None
    assert not otrace.active()


def test_install_uninstall_swaps_facade():
    t = otrace.install("p")
    assert otrace.get() is t and otrace.active()
    otrace.uninstall()
    assert otrace.get() is None


# ---------------------------------------------------------------------------
# export format
# ---------------------------------------------------------------------------

def test_span_and_complete_export(tmp_path):
    tr = Tracer("proc")
    with tr.span("work", stage="a") as sp:
        sp.set(extra=1)
    tr.complete("retro", tr._epoch + 1.0, tr._epoch + 3.0, foo="bar")
    path = tr.export(str(tmp_path / "t.json"))
    doc = json.load(open(path))
    evs = doc["traceEvents"]
    assert doc["displayTimeUnit"] == "ms"
    proc = [e for e in evs if e["ph"] == "M" and e["name"] == "process_name"]
    assert proc[0]["args"]["name"] == "proc"
    work = next(e for e in evs if e["name"] == "work")
    assert work["ph"] == "X" and work["dur"] >= 0
    assert work["args"] == {"stage": "a", "extra": 1}
    retro = next(e for e in evs if e["name"] == "retro")
    assert retro["ts"] == pytest.approx(1e6, rel=1e-6)
    assert retro["dur"] == pytest.approx(2e6, rel=1e-6)


def test_per_thread_buffers_and_thread_names():
    tr = Tracer()

    def worker():
        tr.complete("w", tr._epoch, tr._epoch + 0.1)

    th = threading.Thread(target=worker, name="worker-thread")
    th.start()
    th.join()
    tr.complete("m", tr._epoch, tr._epoch + 0.1)
    evs = tr.events()
    w = next(e for e in evs if e["name"] == "w")
    m = next(e for e in evs if e["name"] == "m")
    assert w["tid"] != m["tid"]     # each writer thread has its own track
    names = {e["tid"]: e["args"]["name"] for e in evs
             if e["ph"] == "M" and e["name"] == "thread_name"}
    assert names[w["tid"]] == "worker-thread"


def test_virtual_track_pins_tid():
    tr = Tracer()
    tr.complete("a", tr._epoch, tr._epoch + 0.1, track="producer/inst0")
    tr.complete("b", tr._epoch, tr._epoch + 0.1, track="producer/inst0")
    tr.complete("c", tr._epoch, tr._epoch + 0.1, track="producer/inst1")
    evs = tr.events()
    tid = {e["name"]: e["tid"] for e in evs if e["ph"] == "X"}
    assert tid["a"] == tid["b"] != tid["c"]
    names = {e["args"]["name"] for e in evs
             if e["ph"] == "M" and e["name"] == "thread_name"}
    assert {"producer/inst0", "producer/inst1"} <= names


def test_async_begin_end_and_instant():
    tr = Tracer()
    tr.begin("request", uid=7, rid=7)
    tr.instant("request.token", rid=7)
    tr.end("request", uid=7)
    evs = [e for e in tr.events() if e["ph"] in "bei"]
    b, i, e = evs
    assert (b["ph"], i["ph"], e["ph"]) == ("b", "i", "e")
    assert b["cat"] == e["cat"] == "async"
    assert b["id"] == e["id"] == "7"   # Perfetto joins b/e by (cat, id)
    assert i["s"] == "t"
    assert b["ts"] <= i["ts"] <= e["ts"]


def test_events_sorted_by_ts():
    tr = Tracer()
    tr.complete("late", tr._epoch + 5.0, tr._epoch + 6.0)
    tr.complete("early", tr._epoch + 1.0, tr._epoch + 2.0)
    xs = [e["name"] for e in tr.events() if e["ph"] == "X"]
    assert xs == ["early", "late"]


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_metrics_counter_gauge_histogram():
    reg = MetricsRegistry()
    c = reg.counter("prefix.hit_pages")
    c.add(3)
    c.add(2)
    assert reg.counter("prefix.hit_pages") is c   # get-or-create
    reg.gauge("paged.pages_live").set(17)
    h = reg.histogram("transfer.bucket_bytes")
    for v in (10, 20, 30, 40):
        h.observe(v)
    snap = reg.snapshot()
    assert snap["prefix.hit_pages"] == 5
    assert snap["paged.pages_live"] == 17
    assert snap["transfer.bucket_bytes"]["count"] == 4
    assert snap["transfer.bucket_bytes"]["min"] == 10
    assert snap["transfer.bucket_bytes"]["max"] == 40
    reg.reset()
    assert reg.counter("prefix.hit_pages").value == 0


def test_metrics_type_conflict_raises():
    reg = MetricsRegistry()
    reg.counter("x")
    with pytest.raises(AssertionError):
        reg.gauge("x")


def test_metrics_threaded_counter():
    reg = MetricsRegistry()
    c = reg.counter("n")
    threads = [threading.Thread(target=lambda: [c.add(1) for _ in range(500)])
               for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value == 2000


# ---------------------------------------------------------------------------
# busy-clock boundary regression: settles deregister themselves; repeated
# busy_time reads between boundaries join nothing and agree exactly
# ---------------------------------------------------------------------------

def test_busy_time_repeated_reads_are_o1_and_identical():
    from repro.configs import get_config, reduced_config
    from repro.core.engine import InferenceInstance, InferencePool
    from repro.models import init
    from repro.rl.rollout import Sampler

    cfg = reduced_config(get_config("llama3.2-3b"))
    params = init(jax.random.PRNGKey(0), cfg)
    sampler = Sampler(cfg, 16, 4)
    inst = InferenceInstance(0, cfg, sampler)
    inst.sync_weights(params, version=1)
    pool = InferencePool([inst])
    prompts = [np.asarray([1, 5, 9], np.int32)] * 2
    for _ in range(3):   # three deferred settle threads charged the clock
        inst.generate_group(prompts, jax.random.PRNGKey(0))

    first = pool.busy_time           # boundary read: flushes the settles
    joins_after_first = inst.settle_joins
    reads = [pool.busy_time for _ in range(50)]
    assert all(r == first for r in reads)        # identical, not just close
    # O(1): none of the 50 reads re-joined a settle thread — completed
    # settles deregistered themselves at the first boundary
    assert inst.settle_joins == joins_after_first
    assert inst._settles == []
    assert first > 0.0


def test_reset_stats_clears_busy_clock():
    from repro.configs import get_config, reduced_config
    from repro.core.engine import InferenceInstance, InferencePool
    from repro.models import init
    from repro.rl.rollout import Sampler

    cfg = reduced_config(get_config("llama3.2-3b"))
    params = init(jax.random.PRNGKey(0), cfg)
    inst = InferenceInstance(0, cfg, Sampler(cfg, 16, 4))
    inst.sync_weights(params, version=1)
    pool = InferencePool([inst])
    inst.generate_group([np.asarray([1, 2, 3], np.int32)] * 2,
                        jax.random.PRNGKey(0))
    assert pool.busy_time > 0
    pool.reset_stats()
    assert pool.busy_time == 0.0


# ---------------------------------------------------------------------------
# bounded histogram: O(1) memory, bucket-CDF percentiles vs exact
# ---------------------------------------------------------------------------

def test_histogram_bounded_memory_exact_aggregates():
    from repro.obs.metrics import Histogram
    h = Histogram()
    n_buckets = len(h._counts)
    vals = [(i % 997) / 100.0 + 0.001 for i in range(10_000)]
    for v in vals:
        h.observe(v)
    assert len(h._counts) == n_buckets    # no per-observation retention
    s = h.summary()
    assert s["count"] == 10_000
    assert s["sum"] == pytest.approx(sum(vals))
    assert s["min"] == min(vals) and s["max"] == max(vals)


def test_histogram_percentiles_vs_exact_small_n():
    from repro.obs.metrics import Histogram
    rng = np.random.RandomState(0)
    vals = rng.lognormal(mean=-2.0, sigma=1.5, size=200)
    h = Histogram()
    for v in vals:
        h.observe(float(v))
    s = h.summary()
    for q, key in ((50, "p50"), (99, "p99")):
        exact = float(np.percentile(vals, q))
        est = s[key]
        # bucket-CDF estimate: error bounded by one bucket width of the
        # 1-2.5-5 ladder (max edge ratio 2.5)
        assert exact / 2.5 <= est <= exact * 2.5, (key, est, exact)


def test_histogram_degenerate_and_empty():
    from repro.obs.metrics import Histogram
    h = Histogram()
    assert h.summary() == {"count": 0, "sum": 0.0}
    for _ in range(5):
        h.observe(0.3)
    s = h.summary()
    # single-bucket sample: clamped to exact observed min/max
    assert s["p50"] == s["p99"] == pytest.approx(0.3)


def test_histogram_buckets_cumulative_and_consistent():
    from repro.obs.metrics import Histogram
    h = Histogram()
    for v in (0.001, 0.5, 0.5, 123.0, 1e12):   # incl. +Inf overflow
        h.observe(v)
    bounds, cum, count, total = h.buckets()
    assert len(cum) == len(bounds) + 1
    assert cum == sorted(cum)                  # cumulative by construction
    assert cum[-1] == count == 5
    assert total == pytest.approx(sum((0.001, 0.5, 0.5, 123.0, 1e12)))


# ---------------------------------------------------------------------------
# streaming trace export: segments == monolithic, bounded peak memory
# ---------------------------------------------------------------------------

def _script_pipeline_events(tr):
    """Deterministic span set (offsets from the tracer's own epoch) —
    identical input to a monolithic and a streaming tracer."""
    e = tr._epoch

    def one_iter(i):
        lo = e + i * 1.0
        tr.complete("iteration", lo, lo + 1.0, iteration=i, mode="async")
        tr.complete("producer.busy", lo + 0.05, lo + 0.60, busy=0.5)
        tr.complete("train.group", lo + 0.40, lo + 0.80)
        tr.complete("train.update", lo + 0.80, lo + 0.95)
        tr.complete("transfer.ensure", lo + 0.95, lo + 0.97, gap=0.02)

    threads = [threading.Thread(target=one_iter, args=(i,))
               for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()


def test_streaming_segments_report_equals_monolithic(tmp_path):
    from repro.obs.analyze import analyze, load_trace
    mono = Tracer("p")
    _script_pipeline_events(mono)
    want = analyze(mono.events())
    assert len(want["iterations"]) == 4      # non-trivial report

    stream = Tracer("p", stream_dir=str(tmp_path / "seg"),
                    flush_events=4, segment_events=8)
    _script_pipeline_events(stream)
    out_dir = stream.export()
    got = analyze(load_trace(out_dir))
    assert got == want                        # exactly, not approximately


def test_streaming_peak_buffer_bounded(tmp_path):
    tr = Tracer("p", stream_dir=str(tmp_path / "seg"), flush_events=16)

    def emit(k):
        for i in range(500):
            tr.complete(f"x{k}", tr._epoch + i, tr._epoch + i + 0.5)

    threads = [threading.Thread(target=emit, args=(k,)) for k in range(3)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    tr.close()
    # the documented bound: resident events never exceed the flush batch
    # per thread, no matter how many events the run emits
    assert 0 < tr.peak_buffer_events <= 16


def test_streaming_rotation_and_readback(tmp_path):
    from repro.obs.analyze import load_trace
    d = tmp_path / "seg"
    tr = Tracer("p", stream_dir=str(d), flush_events=4, segment_events=8)
    for i in range(100):
        tr.complete("ev", tr._epoch + i, tr._epoch + i + 0.5, n=i)
    assert tr.export() == str(d)
    segs = sorted(d.glob("trace-*.jsonl"))
    assert len(segs) > 3                      # actually rotated
    for seg in segs:
        n_lines = sum(1 for _ in open(seg))
        # cap + at most one flush batch of overshoot (+ meta lines)
        assert n_lines <= 8 + 4 + 2
    evs = load_trace(str(d))
    xs = [e for e in evs if e.get("ph") == "X"]
    assert [e["args"]["n"] for e in xs] == list(range(100))  # all, in order
    assert tr.peak_buffer_events <= 4


def test_streaming_tracer_rejects_events_and_tolerates_truncation(tmp_path):
    from repro.obs.analyze import load_trace
    d = tmp_path / "seg"
    tr = Tracer("p", stream_dir=str(d), flush_events=2, segment_events=1000)
    for i in range(10):
        tr.complete("ev", tr._epoch + i, tr._epoch + i + 0.5)
    with pytest.raises(RuntimeError):
        tr.events()                           # streaming: events live on disk
    tr.close()
    segs = sorted(d.glob("trace-*.jsonl"))
    # a hard kill can truncate the LAST line of the LAST segment mid-write;
    # the loader drops exactly that and nothing else
    with open(segs[-1], "a") as f:
        f.write('{"ph": "X", "name": "torn')
    evs = load_trace(str(d))
    assert sum(1 for e in evs if e.get("ph") == "X") == 10
    # the same garbage in a non-final position is corruption, not a crash
    with open(segs[-1], "a") as f:
        f.write('\n{"ph": "M", "name": "process_name", "ts": 0}\n')
    with pytest.raises(json.JSONDecodeError):
        load_trace(str(d))


def test_streaming_close_idempotent_and_uninstall_closes(tmp_path):
    d = str(tmp_path / "seg")
    tr = otrace.install("p", stream_dir=d, flush_events=4)
    tr.complete("ev", tr._epoch, tr._epoch + 1.0)
    otrace.uninstall()                        # closes the streaming tracer
    assert tr._closed
    assert tr.close() == d                    # idempotent


# ---------------------------------------------------------------------------
# flush-on-crash: a SIGKILLed training run leaves readable segments
# ---------------------------------------------------------------------------

def test_killed_run_leaves_readable_segments(tmp_path):
    import os
    import pathlib
    import subprocess
    import sys
    import time

    from repro.obs.analyze import analyze, load_trace
    d = tmp_path / "seg"
    root = pathlib.Path(__file__).resolve().parents[1]
    env = dict(os.environ)
    env["PYTHONPATH"] = str(root / "src")
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.train", "--iterations", "99",
         "--batch-prompts", "2", "--group-size", "2", "--micro-batch", "1",
         "--instances", "1", "--max-prompt-len", "16",
         "--max-response-len", "8", "--trace-dir", str(d),
         "--trace-flush-events", "4", "--trace-segment-events", "16"],
        cwd=root, env=env, stdout=subprocess.DEVNULL,
        stderr=subprocess.DEVNULL)
    try:
        # wait until real span events (not just meta lines) are on disk —
        # i.e. the run is mid-iteration — then kill it dead, no cleanup
        deadline = time.time() + 180
        while time.time() < deadline:
            if any('"X"' in open(f).read()
                   for f in sorted(d.glob("trace-*.jsonl"))):
                break
            time.sleep(0.2)
            if proc.poll() is not None:
                raise AssertionError("training run exited prematurely")
        else:
            raise AssertionError("no flushed span events before deadline")
    finally:
        proc.kill()
        proc.wait(timeout=30)
    evs = load_trace(str(d))                  # readable despite the kill
    assert any(e.get("ph") == "X" for e in evs)
    analyze(evs)                              # and analyzable, not just JSON


# ---------------------------------------------------------------------------
# obs-discipline checker
# ---------------------------------------------------------------------------

def run_one(*mods):
    return ObsDisciplineChecker().run(
        [Module.from_source(p, src) for p, src in mods])


UNBALANCED = """\
from repro.obs import trace as otrace


def submit(r):
    otrace.begin("request", uid=r)
"""

BALANCED_CROSS_MODULE_A = """\
from repro.obs import trace as otrace


def submit(r):
    otrace.begin("request", uid=r)
"""

BALANCED_CROSS_MODULE_B = """\
from repro.obs import trace as otrace


def finish(r):
    otrace.end("request", uid=r)
"""


def test_unbalanced_begin_flagged():
    fs = run_one(("launch/serve.py", UNBALANCED))
    assert len(fs) == 1
    assert "no matching otrace.end" in fs[0].message
    assert fs[0].line == 5


def test_end_without_begin_flagged():
    fs = run_one(("launch/serve.py", BALANCED_CROSS_MODULE_B))
    assert len(fs) == 1
    assert "no matching otrace.begin" in fs[0].message


def test_cross_module_balance_is_clean():
    # begin and end legitimately live in different functions/modules —
    # the pairing is by span NAME repo-wide, not lexical
    fs = run_one(("launch/serve.py", BALANCED_CROSS_MODULE_A),
                 ("core/engine.py", BALANCED_CROSS_MODULE_B))
    assert fs == []


def test_unrelated_begin_method_not_matched():
    src = """\
class VersionedParamStore:
    def begin(self, version):
        return version


def publish(store, v):
    store.begin(v)
    self.store.begin(v)
"""
    assert run_one(("transfer/service.py", src)) == []


def test_dynamic_span_name_warns():
    src = """\
from repro.obs import trace as otrace


def submit(name, r):
    otrace.begin(name, uid=r)
    otrace.end(name, uid=r)
"""
    fs = run_one(("launch/serve.py", src))
    assert len(fs) == 2
    assert all(f.severity == "warning" for f in fs)
    assert "dynamic span name" in fs[0].message


HOT_SPAN_BUG = """\
import jax
from repro.obs import trace as otrace


class PagedGroupEngine:
    def __init__(self):
        self._decode = jax.jit(self._decode_fn)

    def step(self):
        with otrace.span("paged.step"):
            tok = self._decode(1)
            jax.device_get(tok)
"""

WARM_SPAN_OK = """\
import jax
from repro.obs import trace as otrace


class PagedGroupEngine:
    def __init__(self):
        self._decode = jax.jit(self._decode_fn)

    def step(self):
        self._drain_block()

    def _drain_block(self):
        with otrace.span("paged.drain"):
            jax.device_get(self.buf)
"""

HOT_SPAN_NO_SYNC = """\
from repro.obs import trace as otrace


class PagedGroupEngine:
    def step(self):
        with otrace.span("paged.admit"):
            self.queue.append(1)
"""


def test_hot_tier_span_over_sync_flagged():
    fs = run_one(("core/paged.py", HOT_SPAN_BUG))
    assert len(fs) == 1
    assert "wraps a host sync" in fs[0].message
    assert "otrace.complete()" in fs[0].message
    assert fs[0].line == 10     # the span line, where the fix goes


def test_drain_tier_span_over_sync_is_legal():
    # depth >= 1 is exactly where retro-recorded drain spans belong
    assert run_one(("core/paged.py", WARM_SPAN_OK)) == []


def test_hot_tier_span_without_sync_is_legal():
    assert run_one(("core/paged.py", HOT_SPAN_NO_SYNC)) == []


def test_repo_is_obs_clean():
    """Dogfood: the checker reports nothing across src/ (same gate CI
    runs via repro-check)."""
    import pathlib

    from repro.analysis.framework import discover, run_checkers
    from repro.analysis.registry import CHECKER_NAMES
    root = pathlib.Path(__file__).resolve().parents[1]
    mods = discover([root / "src"], root=root)
    fs = [f for f in run_checkers(mods, [ObsDisciplineChecker()],
                                  known_names=CHECKER_NAMES)
          if f.checker == "obs-discipline" and not f.suppressed]
    assert fs == [], [f.render() for f in fs]
