"""On-policy correctness (paper §4.2.3).

* Remark 1 — gradient permutation invariance: consuming the same rollout
  groups in any order accumulates to the same mean gradient.
* Proposition 1 — periodic weight consistency: every group consumed in
  iteration t was generated under theta_t; sync and async schedulers produce
  (numerically) the same parameter trajectory; the off-policy baseline
  provably does NOT (staleness > 0 observed).
* OnPolicyMonitor turns the proof obligation into a runtime assertion.
"""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.configs.base import RLConfig
from repro.core.onpolicy import OnPolicyMonitor, OnPolicyViolation
from repro.core.queue import RolloutGroup, RolloutQueue
from repro.launch.train import build_pipeline
from repro.optim.accumulate import GradAccumulator
from repro.rl.grpo import group_advantages


def scripted_echo(prompts, key):
    """Deterministic scripted inference: responds with tokens derived from
    the prompt (same policy-version-independent output for every call), so
    sync and async runs see byte-identical rollouts."""
    from repro.rl.rollout import RolloutBatch
    G = len(prompts)
    T = 8
    resp = np.zeros((G, T), np.int32)
    lens = np.zeros((G,), np.int32)
    seed = int(np.asarray(prompts[0]).sum()) % 1000
    rng = np.random.RandomState(seed)
    for g in range(G):
        n = rng.randint(3, T)
        resp[g, :n] = rng.randint(3, 200, size=(n,))
        resp[g, n - 1] = 2  # EOS
        lens[g] = n
    return RolloutBatch(response_ids=jnp.asarray(resp),
                        response_len=jnp.asarray(lens))


def _mini_rl(mode: str, **kw) -> RLConfig:
    return RLConfig(mode=mode, batch_prompts=3, group_size=4, micro_batch=2,
                    num_inference_instances=2, max_prompt_len=24,
                    max_response_len=8, learning_rate=1e-3, seed=0, **kw)


@pytest.fixture(scope="module")
def cfg():
    return reduced_config(get_config("llama3.2-3b"))


def _run(cfg, mode: str, iterations: int = 3, **kw):
    rl = _mini_rl(mode, **kw)
    sched, parts = build_pipeline(cfg, rl, seed=0, scripted_fn=scripted_echo)
    hist = sched.run(iterations)
    return sched, parts, hist


# =========================================================================
# Remark 1: permutation invariance of the accumulated gradient
# =========================================================================

def test_grad_accumulator_permutation_invariance():
    key = jax.random.PRNGKey(0)
    grads = [jax.tree.map(lambda _: jax.random.normal(
        jax.random.fold_in(key, i), (16, 16)), {"w": 0, "b": 0})
        for i in range(6)]
    weights = [1.0, 2.0, 1.0, 3.0, 1.0, 2.0]

    def accumulate(order):
        acc = GradAccumulator()
        for i in order:
            acc.add(grads[i], weights[i])
        return acc.mean()

    a = accumulate(range(6))
    b = accumulate([5, 3, 1, 0, 4, 2])
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-6, atol=1e-7)


# =========================================================================
# Proposition 1 end-to-end: sync == async parameter trajectory
# =========================================================================

def test_sync_async_same_params(cfg):
    """The paper's central claim: periodic asynchrony changes only the
    *consumption order*, so the parameter trajectory matches the synchronous
    baseline (up to fp32 summation reordering)."""
    s_sync, p_sync, _ = _run(cfg, "sync")
    s_async, p_async, _ = _run(cfg, "async")
    leaves_a = jax.tree.leaves(p_sync["tri"].policy)
    leaves_b = jax.tree.leaves(p_async["tri"].policy)
    for a, b in zip(leaves_a, leaves_b):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=5e-5, rtol=5e-4)


def test_async_strictly_onpolicy(cfg):
    """Every consumed group carries the current weight version (staleness 0)."""
    sched, _, hist = _run(cfg, "async")
    assert all(s.max_staleness == 0 for s in hist)
    assert sched.monitor.checked == 3 * 3  # iterations x batch_prompts


def test_offpolicy_baseline_is_stale(cfg):
    """The AReaL-like baseline must observe staleness > 0 — demonstrating
    what periodic asynchrony avoids."""
    sched, _, hist = _run(cfg, "async_offpolicy", staleness_eta=1)
    assert max(s.max_staleness for s in hist) >= 1


def test_old_policy_is_previous_iteration(cfg):
    """Algorithm 1 lines 10-11 ordering: after iteration t the old-policy
    weights equal the policy weights that generated iteration t's rollouts
    (i.e. pre-update), not the post-update ones."""
    rl = _mini_rl("async")
    sched, parts, _ = (lambda s: (s[0], s[1], s[0].run(1)))(
        build_pipeline(cfg, rl, seed=0, scripted_fn=scripted_echo))
    tri = parts["tri"]
    # after 1 iteration: old == theta_0 (the generator of batch 0),
    # policy == theta_1 != old
    assert tri.version == 1
    diff = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()),
        tri.policy, tri.old)))
    assert diff > 0


# =========================================================================
# OnPolicyMonitor unit behaviour
# =========================================================================

def _fake_group(version: int) -> RolloutGroup:
    return RolloutGroup(uid=1, prompt_ids=np.zeros(4, np.int32),
                        response_ids=np.zeros((2, 4), np.int32),
                        response_len=np.ones(2, np.int32),
                        rewards=np.zeros(2, np.float32),
                        weight_version=version)


def test_monitor_strict_raises_on_stale():
    m = OnPolicyMonitor(strict=True)
    m.check(_fake_group(3), 3)
    with pytest.raises(OnPolicyViolation):
        m.check(_fake_group(2), 3)


def test_monitor_lenient_measures():
    m = OnPolicyMonitor(strict=False)
    m.check(_fake_group(1), 3)
    assert m.max_staleness_seen == 2


# =========================================================================
# Queue semantics that Proposition 1's proof relies on
# =========================================================================

def test_queue_wait_empty_blocks_until_consumed():
    q = RolloutQueue()
    q.register_pending(2)
    assert not q.wait_empty(timeout=0.05)
    q.put(_fake_group(0))
    q.put(_fake_group(0))
    assert not q.wait_empty(timeout=0.05)   # enqueued but not consumed
    q.get(); q.get()
    assert q.wait_empty(timeout=0.05)


def test_queue_completion_order_not_submission_order():
    """The queue hands out groups in completion-time order — the async
    scheduler's defining behaviour (Figure 3b)."""
    q = RolloutQueue()
    q.register_pending(3)
    done = []

    def produce(uid, delay):
        import time
        time.sleep(delay)
        g = _fake_group(0)
        g.uid = uid
        q.put(g)

    ts = [threading.Thread(target=produce, args=(i, d))
          for i, d in enumerate([0.15, 0.01, 0.08])]
    for t in ts:
        t.start()
    for _ in range(3):
        done.append(q.get(timeout=2.0).uid)
    for t in ts:
        t.join()
    assert done == [1, 2, 0]     # completion order, not submission order


def test_queue_producer_error_propagates():
    q = RolloutQueue()
    q.register_pending(1)
    q.put_error(RuntimeError("rollout worker died"))
    with pytest.raises(RuntimeError, match="worker died"):
        q.get(timeout=1.0)


# =========================================================================
# Group advantages (GRPO) sanity
# =========================================================================

def test_group_advantages_standardised():
    r = jnp.asarray([1.0, 0.0, 0.0, 1.0])
    a = np.asarray(group_advantages(r))
    np.testing.assert_allclose(a.mean(), 0.0, atol=1e-6)
    assert a[0] > 0 > a[1]


def test_group_advantages_constant_rewards_are_zero():
    a = np.asarray(group_advantages(jnp.ones(4)))
    np.testing.assert_allclose(a, 0.0, atol=1e-3)
