"""Tests for the live ops plane (src/repro/obs/server.py).

Covers: Prometheus rendering + the validating parser (round-trip and
rejection of torn/malformed text), the scrape endpoints in metrics-only
mode, SSE socket serving proven bitwise-identical to the in-process
``RequestDriver`` under *sampled* (non-greedy) decode — the key-derivation
contract, not just greedy determinism — scrape-under-load while the paged
engine drains, and the `OnlineBubble` incremental estimator against
hand-computed occupancies.
"""
import json
import threading
import time
import urllib.error
import urllib.request

import jax
import numpy as np
import pytest

from repro.obs import trace as otrace
from repro.obs.metrics import MetricsRegistry
from repro.obs.server import (OnlineBubble, OpsServer, _sse_request,
                              parse_prometheus_text, render_prometheus)


@pytest.fixture(autouse=True)
def _no_leaked_tracer():
    yield
    otrace.uninstall()


def _get(url: str, path: str):
    with urllib.request.urlopen(url + path, timeout=30) as r:
        return r.status, r.read().decode()


# ---------------------------------------------------------------------------
# Prometheus text format: render -> parse round trip
# ---------------------------------------------------------------------------

def test_render_parse_roundtrip():
    reg = MetricsRegistry()
    reg.counter("prefix.hit_pages").add(7)
    reg.gauge("paged.pages_live").set(13)
    h = reg.histogram("serve.ttft_s")
    for v in (0.01, 0.02, 0.02, 5.0):
        h.observe(v)
    samples = parse_prometheus_text(render_prometheus(reg))
    assert samples["repro_prefix_hit_pages_total"] == 7
    assert samples["repro_paged_pages_live"] == 13
    assert samples["repro_serve_ttft_s_count"] == 4
    assert samples["repro_serve_ttft_s_sum"] == pytest.approx(5.05)
    # sparse cumulative ladder: the +Inf bucket equals _count
    assert samples['repro_serve_ttft_s_bucket{le="+Inf"}'] == 4


def test_render_empty_registry_parses():
    assert parse_prometheus_text(render_prometheus(MetricsRegistry())) == {}


@pytest.mark.parametrize("text,why", [
    ("foo 1\n", "no TYPE"),
    ("# TYPE x counter\nx_total 1\nx_tot", "torn mid-line"),
    ("# TYPE x counter\nx_total 1\nx_total 2\n", "duplicate sample"),
    ("# TYPE x counter\nx_total abc\n", "non-numeric value"),
    ('# TYPE h histogram\nh_bucket{le="1"} 5\nh_bucket{le="+Inf"} 3\n'
     'h_sum 1\nh_count 3\n', "non-cumulative buckets"),
    ('# TYPE h histogram\nh_bucket{le="1"} 2\nh_sum 1\nh_count 2\n',
     "missing +Inf"),
    ('# TYPE h histogram\nh_bucket{le="+Inf"} 3\nh_sum 1\nh_count 2\n',
     "+Inf != _count"),
    ('# TYPE h histogram\nh_bucket{le="+Inf"} 2\nh_count 2\n',
     "missing _sum"),
])
def test_parser_rejects_malformed(text, why):
    with pytest.raises(ValueError):
        parse_prometheus_text(text)


# ---------------------------------------------------------------------------
# endpoints, metrics-only mode (no engine): the --metrics-port shape
# ---------------------------------------------------------------------------

def test_endpoints_metrics_only_mode():
    reg = MetricsRegistry()
    reg.counter("scheduler.trained_tokens").add(42)
    with OpsServer(registry=reg,
                   status_fn=lambda: {"iteration": 3}) as srv:
        code, body = _get(srv.url, "/healthz")
        assert (code, body) == (200, "ok\n")
        code, text = _get(srv.url, "/metrics")
        assert code == 200
        assert parse_prometheus_text(text)[
            "repro_scheduler_trained_tokens_total"] == 42
        code, body = _get(srv.url, "/status")
        st = json.loads(body)
        assert code == 200 and st["requests_served"] == 0
        assert st["pipeline"]["iteration"] == 3   # status_fn merged
        with pytest.raises(urllib.error.HTTPError) as ei:
            _get(srv.url, "/nope")
        assert ei.value.code == 404
        # generation needs an engine: 503, not a crash
        req = urllib.request.Request(
            srv.url + "/v1/generate", data=b'{"prompt": [1]}',
            method="POST")
        with pytest.raises(urllib.error.HTTPError) as ei:
            urllib.request.urlopen(req, timeout=10)
        assert ei.value.code == 503


def test_generate_rejects_bad_payloads():
    cfg, params, eng = _engine()
    with OpsServer(engine=eng, key=jax.random.PRNGKey(1)) as srv:
        for payload in (b"not json", b"{}", b'{"prompt": "text"}',
                        b'{"prompt": []}'):
            req = urllib.request.Request(
                srv.url + "/v1/generate", data=payload, method="POST")
            with pytest.raises(urllib.error.HTTPError) as ei:
                urllib.request.urlopen(req, timeout=10)
            assert ei.value.code == 400, payload


# ---------------------------------------------------------------------------
# socket serving == in-process driver, bitwise (sampled decode)
# ---------------------------------------------------------------------------

_ENGINE_CACHE = {}


def _engine():
    """One serving-shaped engine per module run (jit compile is the
    expensive part); temperature 0.7 so identity below exercises the
    per-request key derivation, not greedy argmax determinism."""
    if "eng" not in _ENGINE_CACHE:
        from repro.configs import get_config, reduced_config
        from repro.launch.serve import build_paged_engine
        from repro.models import init
        cfg = reduced_config(get_config("llama3.2-3b"))
        params = init(jax.random.PRNGKey(0), cfg)
        eng = build_paged_engine(cfg, max_prompt_len=16, max_new=8,
                                 num_slots=2, page_size=8, seed=0)
        eng.set_params(params)
        _ENGINE_CACHE["eng"] = (cfg, params, eng)
    return _ENGINE_CACHE["eng"]


def test_sse_stream_bitwise_identical_to_driver():
    from repro.launch.serve import serve_requests
    cfg, params, eng = _engine()
    prompts = [np.asarray([3, 1, 4, 1, 5, 9, 2, 6], np.int32),
               np.asarray([2, 7, 1, 8, 2, 8, 1, 8], np.int32)]
    eng.reset_stats()
    reqs, _, _ = serve_requests(
        cfg, prompts, max_prompt_len=16, max_new=8,
        arrivals=np.zeros(len(prompts)), params=params, engine=eng, seed=0)
    driver_tokens = {r.rid: r.tokens for r in reqs}
    # seed+1: the same base key serve_requests hands its RequestDriver
    with OpsServer(engine=eng, key=jax.random.PRNGKey(1)) as srv:
        for rid, prompt in enumerate(prompts):
            toks, done = _sse_request(
                srv.url, {"prompt": [int(t) for t in prompt], "rid": rid,
                          "max_new": 8})
            assert done is not None and done["verified"], done
            assert toks == driver_tokens[rid], \
                f"rid {rid}: socket stream diverged from driver"


def test_sse_auto_rid_and_status_counters():
    _, _, eng = _engine()
    with OpsServer(engine=eng, key=jax.random.PRNGKey(1)) as srv:
        toks, done = _sse_request(
            srv.url, {"prompt": [5, 4, 3, 2, 1], "max_new": 4})
        assert toks and done["verified"]
        st = json.loads(_get(srv.url, "/status")[1])
        assert st["requests_served"] == 1
        assert st["active_requests"] == 0
        eng_st = st["engine"]
        assert eng_st["pages_live"] + eng_st["pages_free"] == \
            eng_st["pages_total"]
        assert eng_st["slots_active"] == 0


# ---------------------------------------------------------------------------
# scrape under load: /metrics and /status hammered while the engine drains
# ---------------------------------------------------------------------------

def test_scrape_under_load_never_tears():
    _, _, eng = _engine()
    with OpsServer(engine=eng, key=jax.random.PRNGKey(1)) as srv:
        stop = threading.Event()
        # per-thread series: only within one thread is scrape order the
        # wall order (cross-thread list appends interleave arbitrarily)
        series, statuses, errors = [[], []], [], []

        def hammer(out):
            try:
                while not stop.is_set():
                    out.append(
                        parse_prometheus_text(_get(srv.url, "/metrics")[1]))
                    statuses.append(json.loads(_get(srv.url, "/status")[1]))
            except Exception as e:  # surfaced below, not swallowed
                errors.append(e)

        hammers = [threading.Thread(target=hammer, args=(out,))
                   for out in series]
        for t in hammers:
            t.start()
        # several generation requests drain through the engine meanwhile
        results = []

        def generate(rid):
            results.append(_sse_request(
                srv.url, {"prompt": [rid + 1] * 6, "rid": rid,
                          "max_new": 8}))

        gens = [threading.Thread(target=generate, args=(rid,))
                for rid in range(4)]
        for t in gens:
            t.start()
        for t in gens:
            t.join(timeout=120)
        stop.set()
        for t in hammers:
            t.join(timeout=30)
        assert not errors, errors     # every scrape parsed as well-formed
        assert len(results) == 4 and all(d["verified"] for _, d in results)
        assert sum(len(s) for s in series) >= 2
        for scraped in series:        # counters monotone per scrape thread
            for prev, cur in zip(scraped, scraped[1:]):
                for name, v in prev.items():
                    if name.endswith("_total") and name in cur:
                        assert cur[name] >= v, f"{name} went backwards"
        for st in statuses:           # no torn multi-field engine view
            e = st["engine"]
            assert e["pages_live"] + e["pages_free"] == e["pages_total"]
            assert 0 <= e["slots_active"] <= e["slots_total"]


# ---------------------------------------------------------------------------
# OnlineBubble: incremental estimator vs hand-computed occupancy
# ---------------------------------------------------------------------------

def _x(name, lo_s, hi_s):
    return ("X", name, lo_s * 1e6, (hi_s - lo_s) * 1e6, None, {})


def test_online_bubble_matches_hand_computation():
    ob = OnlineBubble(window_s=30.0)
    assert ob.value() is None                       # nothing seen yet
    ob.on_event(_x("producer.busy", 0.0, 1.0))
    ob.on_event(_x("train.group", 0.5, 1.5))
    ob.on_event(_x("paged.drain", 0.0, 9.0))        # neither stage: ignored
    ob.on_event(("i", "request.token", 5e6, None, None, {}))  # non-X
    v = ob.value()
    # wall [0, 1.5]: p busy 1.0, c busy 1.0, overlap [0.5, 1.0] = 0.5
    assert v["window_s"] == pytest.approx(1.5)
    assert v["producer_busy_s"] == pytest.approx(1.0)
    assert v["consumer_busy_s"] == pytest.approx(1.0)
    assert v["bubble_fraction"] == pytest.approx(1 - 2.0 / 3.0)
    assert v["overlap_efficiency"] == pytest.approx(0.5)


def test_online_bubble_window_clips_old_spans():
    ob = OnlineBubble(window_s=1.0)
    ob.on_event(_x("producer.busy", 0.0, 1.0))
    ob.on_event(_x("train.update", 0.5, 1.5))
    v = ob.value()
    # window [0.5, 1.5]: p clipped to 0.5s, c full 1.0s, overlap 0.5s
    assert v["window_s"] == pytest.approx(1.0)
    assert v["producer_busy_s"] == pytest.approx(0.5)
    assert v["consumer_busy_s"] == pytest.approx(1.0)
    assert v["bubble_fraction"] == pytest.approx(1 - 1.5 / 2.0)
    assert v["overlap_efficiency"] == pytest.approx(1.0)


def test_online_bubble_rides_tracer_listener():
    otrace.install("p")
    ob = OnlineBubble()
    otrace.get().add_listener(ob.on_event)
    t = otrace.get()
    t.complete("producer.busy", t._epoch + 0.0, t._epoch + 1.0)
    t.complete("train.group", t._epoch + 0.5, t._epoch + 1.5)
    v = ob.value()
    assert v is not None and v["producer_busy_s"] == pytest.approx(1.0)
    otrace.get().remove_listener(ob.on_event)
    t.complete("producer.busy", t._epoch + 2.0, t._epoch + 9.0)
    assert ob.value()["producer_busy_s"] == pytest.approx(1.0)  # detached


# ---------------------------------------------------------------------------
# /status exposes the online bubble when a tracer is live
# ---------------------------------------------------------------------------

def test_status_includes_online_bubble_with_tracer():
    otrace.install("p")
    with OpsServer() as srv:
        t = otrace.get()
        t.complete("producer.busy", t._epoch, t._epoch + 0.2)
        t.complete("train.group", t._epoch + 0.1, t._epoch + 0.3)
        st = json.loads(_get(srv.url, "/status")[1])
        assert "online" in st
        assert 0.0 <= st["online"]["bubble_fraction"] <= 1.0


def test_stop_is_idempotent_and_port_is_real():
    srv = OpsServer()
    srv.start()
    port = srv.port
    assert port > 0
    srv.stop()
    srv.stop()                         # second stop: no-op, no raise
    time.sleep(0.05)
    with pytest.raises(Exception):     # socket actually closed
        urllib.request.urlopen(srv.url + "/healthz", timeout=2)
