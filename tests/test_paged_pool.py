"""Token-level paged continuous batching: pool-level decode must be
token-identical to the group-at-a-time path under a fixed PRNG key
(greedy AND sampled), with mid-batch admission/eviction, shared prompt
pages refcounted back to the freelist, and the periodic-asynchrony
contract (zero staleness in async mode) intact.

The CacheBackend layer (DESIGN.md §Cache-backends) extends the same
contract to MLA (latent pages) and sliding-window configs (out-of-window
page reclamation) — proven token-identical below, with a long-decode test
asserting reclaimed pages actually return to the freelist.
"""
import dataclasses
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.configs.base import RLConfig
from repro.core.cbatch import SlotScheduler
from repro.core.engine import InferenceInstance, InferencePool
from repro.core.generator import TemporaryDataGenerator
from repro.core.paged import FIRST_PAGE, PagedGroupEngine
from repro.core.queue import RolloutQueue
from repro.data.tasks import ArithmeticTask
from repro.data.tokenizer import Tokenizer
from repro.launch.train import build_pipeline
from repro.models import init
from repro.rl.rollout import Sampler

G, T, LP = 4, 8, 16


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config(get_config("llama3.2-3b"))
    params = init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _engine(cfg, **kw):
    base = dict(num_slots=3, page_size=4, num_pages=0, max_prompt_len=LP,
                max_new_tokens=T, group_size=G)
    base.update(kw)
    return PagedGroupEngine(cfg, **base)


def _assert_group_identical(paged_out, ref_out):
    pr, pl = np.asarray(paged_out.response_ids), np.asarray(paged_out.response_len)
    rr, rl = np.asarray(ref_out.response_ids), np.asarray(ref_out.response_len)
    np.testing.assert_array_equal(pl, rl)
    for i in range(rr.shape[0]):
        np.testing.assert_array_equal(pr[i, : pl[i]], rr[i, : rl[i]])


# =========================================================================
# the tentpole contract: token-identical to the group-at-a-time Sampler
# =========================================================================

@pytest.mark.parametrize("temperature,top_p", [(0.0, 1.0), (1.0, 1.0),
                                               (1.0, 0.9)])
def test_token_identical_to_group_path(setup, temperature, top_p):
    """Greedy, sampled, and nucleus-sampled decode must reproduce the
    Sampler's tokens exactly under the same key — slots < group size, so
    rows of one group are admitted at different engine steps and still
    consume their own step keys."""
    cfg, params = setup
    prompt = np.asarray([1, 9, 4, 7, 3], np.int32)
    key = jax.random.PRNGKey(5)
    ref = Sampler(cfg, LP, T, temperature=temperature, top_p=top_p)
    eng = _engine(cfg, temperature=temperature, top_p=top_p)
    eng.set_params(params)
    h = eng.submit(prompt, key)
    while eng.step():
        pass
    _assert_group_identical(h.result(1), ref.generate(params, [prompt] * G, key))


def test_mixed_length_mid_batch_admission_eviction(setup):
    """Three groups with different prompt lengths on 3 slots (12 rows total)
    force slots to be evicted and re-admitted mid-batch; every group must
    still be token-identical to its own Sampler call, and every page must
    return to the freelist."""
    cfg, params = setup
    prompts = [np.asarray([1, 9, 4], np.int32),
               np.asarray([1, 5, 6, 7, 8, 9, 10, 11, 12, 13], np.int32),
               np.asarray([1, 2, 3, 4, 5, 6], np.int32)]
    keys = jax.random.split(jax.random.PRNGKey(11), 3)
    eng = _engine(cfg, temperature=1.0)
    eng.set_params(params)
    free0 = eng.alloc.num_free
    handles = [eng.submit(p, k) for p, k in zip(prompts, keys)]
    while eng.step():
        pass
    ref = Sampler(cfg, LP, T, temperature=1.0)
    for p, k, h in zip(prompts, keys, handles):
        _assert_group_identical(h.result(1), ref.generate(params, [p] * G, k))
    # slots were reused across groups: 12 rows never fit 3 slots at once
    assert eng.decode_steps > T
    assert eng.alloc.num_free == free0 and eng.idle


def test_short_rows_free_slots_before_stragglers(setup):
    """A greedy group where some rows hit EOS early must release those
    slots while the longest row keeps decoding — generated tokens then
    track true lengths, not group_size x max_new."""
    cfg, params = setup
    rng = np.random.RandomState(3)
    eng = _engine(cfg, num_slots=G, temperature=1.0)
    eng.set_params(params)
    h = eng.submit(rng.randint(3, 250, size=(7,)).astype(np.int32),
                   jax.random.PRNGKey(4))
    while eng.step():
        pass
    lens = np.asarray(h.result(1).response_len)
    assert eng.generated_tokens == int(lens.sum())
    if lens.min() < lens.max():        # rows staggered (the common case)
        assert eng.generated_tokens < G * T


# =========================================================================
# pool level: concurrent groups batch together; pipeline stays on-policy
# =========================================================================

def test_concurrent_groups_share_decode_steps(setup):
    """Two groups submitted from two threads through one instance must
    decode together: total engine steps stay well below the sum of the
    groups' serial step counts."""
    cfg, params = setup
    eng = _engine(cfg, num_slots=2 * G, temperature=0.0)
    sampler = Sampler(cfg, LP, T, temperature=0.0)
    inst = InferenceInstance(0, cfg, sampler, paged_engine=eng)
    inst.sync_weights(params, version=3)
    prompts = [np.asarray([1, 9, 4, 7], np.int32),
               np.asarray([1, 2, 8, 5, 6], np.int32)]
    keys = jax.random.split(jax.random.PRNGKey(7), 2)
    results = [None, None]

    def worker(i):
        results[i] = inst.generate_group([prompts[i]] * G, keys[i])

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    for i in range(2):
        out, version = results[i]
        assert version == 3
        _assert_group_identical(out, sampler.generate(params,
                                                      [prompts[i]] * G,
                                                      keys[i]))
    assert eng.idle


def test_generator_paged_pool_matches_group_pool(setup):
    """End-to-end producer equivalence: the TemporaryDataGenerator feeding a
    paged pool must enqueue the same rollouts (per uid) as the group pool
    under the same base key — completion order may differ, content may not."""
    cfg, params = setup
    tok = Tokenizer(cfg.vocab_size)
    task = ArithmeticTask(seed=0)
    problems = task.batch(3)
    batch = [(p, np.asarray(tok.encode(p.prompt)[:LP], np.int32))
             for p in problems]
    reward = lambda resp, ans: 0.0
    base_key = jax.random.PRNGKey(9)

    def produce(paged: bool):
        sampler = Sampler(cfg, LP, T, temperature=1.0)
        eng = _engine(cfg, num_slots=4, temperature=1.0) if paged else None
        inst = InferenceInstance(0, cfg, sampler, paged_engine=eng)
        inst.sync_weights(params, version=0)
        queue = RolloutQueue()
        gen = TemporaryDataGenerator(InferencePool([inst]), queue, reward, G)
        gen.submit_batch(batch, base_key, 0)
        gen.join()
        groups = [queue.get() for _ in range(len(batch))]
        return {g.uid: g for g in groups}

    by_uid_group = produce(paged=False)
    by_uid_paged = produce(paged=True)
    assert set(by_uid_group) == set(by_uid_paged)
    for uid in by_uid_group:
        a, b = by_uid_group[uid], by_uid_paged[uid]
        np.testing.assert_array_equal(np.asarray(a.response_len),
                                      np.asarray(b.response_len))
        np.testing.assert_array_equal(np.asarray(a.response_ids),
                                      np.asarray(b.response_ids))


def test_pipeline_async_paged_zero_staleness(setup):
    """Periodic-asynchrony contract with the token-level engine: weight
    sync only at iteration boundaries, OnPolicyMonitor sees staleness 0."""
    cfg, _ = setup
    rl = RLConfig(mode="async", batch_prompts=2, group_size=3, micro_batch=3,
                  num_inference_instances=1, max_prompt_len=24,
                  max_response_len=6, learning_rate=1e-3,
                  rollout_engine="paged", cbatch_slots=4, kv_page_size=8)
    sched, parts = build_pipeline(cfg, rl)
    hist = sched.run(2)
    assert len(hist) == 2
    for s in hist:
        assert s.trained_tokens > 0
        assert s.max_staleness == 0
        assert s.infer_time > 0
    assert parts["queue"].outstanding == 0
    for inst in parts["pool"].instances:
        assert inst.paged_engine.idle


def test_paged_rejects_offpolicy_mode(setup):
    cfg, _ = setup
    rl = RLConfig(mode="async_offpolicy", rollout_engine="paged",
                  batch_prompts=2, group_size=2)
    with pytest.raises(ValueError, match="quiescent"):
        build_pipeline(cfg, rl)


# =========================================================================
# CacheBackend families: MLA latent pages + sliding-window reclamation
# =========================================================================

@pytest.fixture(scope="module")
def setup_mla():
    cfg = reduced_config(get_config("deepseek-v2-lite-16b"))
    params = init(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.mark.parametrize("temperature", [0.0, 1.0])
def test_paged_mla_token_identical(setup_mla, temperature):
    """DeepSeek-V2 MLA through the paged pool: pages hold (ckv, kr) latent
    rows and absorbed decode gathers them; output must be token-identical
    to the group Sampler under the same key (greedy and sampled), with
    slots < group size forcing out-of-lock-step admission."""
    cfg, params = setup_mla
    prompt = np.asarray([1, 9, 4, 7, 3, 8, 2], np.int32)
    key = jax.random.PRNGKey(13)
    ref = Sampler(cfg, LP, T, temperature=temperature)
    eng = _engine(cfg, temperature=temperature)
    eng.set_params(params)
    free0 = eng.alloc.num_free
    h = eng.submit(prompt, key)
    while eng.step():
        pass
    _assert_group_identical(h.result(1),
                            ref.generate(params, [prompt] * G, key))
    assert eng.alloc.num_free == free0 and eng.idle


@pytest.mark.parametrize("arch", ["llama3.2-3b", "deepseek-v2-lite-16b"])
def test_paged_sliding_window_token_identical(setup, setup_mla, arch):
    """Sliding-window configs through the paged pool: the window slides
    past prompt AND response pages mid-decode (Lp + T > window), pages are
    reclaimed, and the output still matches the group Sampler's ring-cache
    decode token for token."""
    cfg, params = (setup if arch == "llama3.2-3b" else setup_mla)
    cfg = dataclasses.replace(cfg, sliding_window=8)
    params = init(jax.random.PRNGKey(0), cfg)
    prompt = np.asarray([1, 5, 6, 7, 8, 9, 10, 11, 12, 13, 3, 4], np.int32)
    key = jax.random.PRNGKey(17)
    ref = Sampler(cfg, LP, T, temperature=1.0)
    eng = _engine(cfg, temperature=1.0)
    eng.set_params(params)
    free0 = eng.alloc.num_free
    h = eng.submit(prompt, key)
    while eng.step():
        pass
    _assert_group_identical(h.result(1),
                            ref.generate(params, [prompt] * G, key))
    assert eng.reclaimed_pages > 0, "window slid past pages; none reclaimed"
    assert eng.alloc.num_free == free0 and eng.idle


def test_windowed_long_decode_reclaims_pages_to_freelist(setup):
    """The O(window) residency claim: a pool too small to hold the full
    decode's pages (prompt + G rows x all response pages) must still
    complete a long windowed decode because out-of-window pages return to
    the freelist mid-flight; peak occupancy stays within the admission
    budget rather than growing with context."""
    cfg, params = setup
    cfg = dataclasses.replace(cfg, sliding_window=8)
    params = init(jax.random.PRNGKey(0), cfg)
    T_long, page = 32, 4
    n_resp = T_long // page                                   # 8 pages/row
    budget = 8 // page + 3                                    # 5 < 8
    # full-history demand: 2 live prompt pages + 4 rows x 8 = 34 pages;
    # give only enough for the windowed budget (2 + 4 x 5 = 22)
    num_pages = FIRST_PAGE + 2 + G * budget
    eng = PagedGroupEngine(cfg, num_slots=G, page_size=page,
                           num_pages=num_pages, max_prompt_len=LP,
                           max_new_tokens=T_long, group_size=G,
                           temperature=1.0)
    eng.set_params(params)
    free0 = eng.alloc.num_free
    h = eng.submit(np.asarray([1, 9, 4, 7, 3, 8, 2], np.int32),
                   jax.random.PRNGKey(23))
    while eng.step():
        pass
    lens = np.asarray(h.result(1).response_len)
    assert lens.max() > 8, "decode too short to slide the window"
    assert eng.reclaimed_pages >= G * (n_resp - budget), \
        "long decode must recycle out-of-window pages"
    assert eng.peak_pages_used <= 2 + G * budget, \
        "resident pages must be O(window), not O(context)"
    assert eng.alloc.num_free == free0 and eng.idle


def test_submit_rejects_impossible_prompt(setup):
    """A group whose prompt + per-row page budget exceed what the pool can
    EVER free must raise at submit (with the required vs available count)
    instead of sitting in the admission queue forever."""
    cfg, params = setup
    # pool passes the construction check (max prompt + 1 response page =
    # 4 + 1 + 2 reserved <= 7) but can never admit a full-length prompt
    # alongside the T=8 response budget (4 + 2 = 6 > 5 free-able)
    eng = PagedGroupEngine(cfg, num_slots=2, page_size=4,
                           num_pages=FIRST_PAGE + 5, max_prompt_len=LP,
                           max_new_tokens=T, group_size=2)
    eng.set_params(params)
    with pytest.raises(ValueError, match="never be admitted"):
        eng.submit(np.arange(1, LP + 1, dtype=np.int32),
                   jax.random.PRNGKey(0))
    # a short prompt still fits the same pool
    h = eng.submit(np.asarray([1, 2, 3], np.int32), jax.random.PRNGKey(1))
    while eng.step():
        pass
    assert h.done() and eng.idle


def test_engine_support_matrix():
    """The validation matrix (configs/base.py) every engine construction
    consults: remaining paged exclusions are architectural — recurrent
    state, bounded enc-dec decode, dense vision prefix."""
    from repro.configs.base import engine_support
    paged_ok = {"llama3.2-3b": True, "deepseek-v2-lite-16b": True,
                "internlm2-20b": True, "qwen3-moe-235b-a22b": True,
                "mamba2-2.7b": False, "hymba-1.5b": False,
                "whisper-tiny": False, "internvl2-76b": False}
    for arch, ok in paged_ok.items():
        got, reason = engine_support(get_config(arch), "paged")
        assert got == ok, f"{arch}: expected paged={ok}, got {got} ({reason})"
        assert reason
    # windowed variants of pageable families stay pageable (reclamation)
    win = dataclasses.replace(get_config("llama3.2-3b"), sliding_window=8192)
    ok, reason = engine_support(win, "paged")
    assert ok and "reclaim" in reason
    # group path serves everything; cbatch rejects enc-dec/VLM only
    for arch in paged_ok:
        assert engine_support(get_config(arch), "group")[0]
    assert not engine_support(get_config("whisper-tiny"), "cbatch")[0]
    assert engine_support(get_config("mamba2-2.7b"), "cbatch")[0]


def test_paged_mla_decode_attention_kernel_matches_gather():
    """The latent-page flash-decode wrapper must agree with the plain
    kernel on the pre-gathered, concatenated latent streams (absorbed MLA
    decode == MQA with Dk = r + rd, Dv = r)."""
    from repro.kernels.decode_attention import (decode_attention,
                                                paged_mla_decode_attention)
    rng = np.random.RandomState(0)
    B, H, r, rd, P, page, n_max = 2, 4, 16, 8, 6, 4, 3
    q = jnp.asarray(rng.randn(B, H, r + rd), jnp.float32)
    ckv_pages = jnp.asarray(rng.randn(P, page, r), jnp.float32)
    kr_pages = jnp.asarray(rng.randn(P, page, rd), jnp.float32)
    pos_pages = jnp.asarray(rng.randint(0, 10, size=(P, page)), jnp.int32)
    pos_pages = pos_pages.at[0].set(2 ** 30)          # null page masked
    table = jnp.asarray([[1, 2, 0], [3, 4, 5]], jnp.int32)
    q_pos = jnp.asarray([7, 9], jnp.int32)
    out = paged_mla_decode_attention(q, ckv_pages, kr_pages, pos_pages,
                                     table, q_pos, block_l=4, interpret=True)
    L = n_max * page
    k = jnp.concatenate([ckv_pages[table].reshape(B, L, r),
                         kr_pages[table].reshape(B, L, rd)],
                        axis=-1)[:, :, None, :]
    v = ckv_pages[table].reshape(B, L, r)[:, :, None, :]
    ref = decode_attention(q, k, v, pos_pages[table].reshape(B, L), q_pos,
                           block_l=4, interpret=True)
    assert out.shape == (B, H, r)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6)


# =========================================================================
# scheduler + allocator units
# =========================================================================

def test_slot_scheduler_fifo_and_gate():
    sched = SlotScheduler(2)
    for r in "abcd":
        sched.submit(r)
    assert [(s, r) for s, r in sched.admit()] == [(0, "a"), (1, "b")]
    assert sched.admit() == []                     # no free slots
    assert sched.evict(0) == "a"
    # gate refuses the FIFO front -> nothing admitted (no overtaking)
    assert sched.admit(gate=lambda r: False) == []
    assert sched.admit() == [(0, "c")]
    sched.evict(0), sched.evict(1)
    assert sched.admit() == [(0, "d")]
    sched.evict(0)
    assert sched.idle


def test_engine_rejects_undersized_page_pool(setup):
    cfg, _ = setup
    with pytest.raises(ValueError, match="page pool too small"):
        PagedGroupEngine(cfg, num_slots=2, page_size=4,
                         num_pages=FIRST_PAGE + 1, max_prompt_len=LP,
                         max_new_tokens=T, group_size=2)


def test_page_gate_backpressure_tight_pool(setup):
    """Many slots, page pool sized for barely more than one group: the
    admission gate must apply backpressure (rows wait for pages, the engine
    keeps stepping) instead of over-admitting against a stale freelist.
    Output must still be token-identical per group."""
    cfg, params = setup
    # one group needs 2 prompt pages + 4 rows x 2 resp pages = 10;
    # give 13 usable pages so a second group's prompt can load but not all
    # of its rows — rows trickle in as pages free
    eng = PagedGroupEngine(cfg, num_slots=8, page_size=4,
                           num_pages=FIRST_PAGE + 13, max_prompt_len=LP,
                           max_new_tokens=T, group_size=G, temperature=1.0)
    eng.set_params(params)
    prompts = [np.asarray([1, 9, 4, 7, 2], np.int32),
               np.asarray([1, 5, 6, 7, 8, 9], np.int32),
               np.asarray([1, 2, 3], np.int32)]
    keys = jax.random.split(jax.random.PRNGKey(21), 3)
    handles = [eng.submit(p, k) for p, k in zip(prompts, keys)]
    while eng.step():
        pass
    ref = Sampler(cfg, LP, T, temperature=1.0)
    for p, k, h in zip(prompts, keys, handles):
        _assert_group_identical(h.result(1), ref.generate(params, [p] * G, k))
    assert eng.alloc.num_free == 13 and eng.idle


def test_paged_engine_rejects_heterogeneous_group(setup):
    cfg, params = setup
    eng = _engine(cfg)
    sampler = Sampler(cfg, LP, T)
    inst = InferenceInstance(0, cfg, sampler, paged_engine=eng)
    inst.sync_weights(params, version=0)
    prompts = [np.asarray([1, 2, 3], np.int32)] * (G - 1) + \
              [np.asarray([1, 2, 4], np.int32)]
    with pytest.raises(AssertionError, match="identical"):
        inst.generate_group(prompts, jax.random.PRNGKey(0))


def test_paged_decode_attention_kernel_matches_gather(setup):
    """The paged flash-decode wrapper (page-table gather inside the kernel
    module) must agree with the plain kernel on pre-gathered pages."""
    from repro.kernels.decode_attention import (decode_attention,
                                               paged_decode_attention)
    rng = np.random.RandomState(0)
    B, H, Hkv, D, P, page, n_max = 2, 4, 2, 8, 6, 4, 3
    q = jnp.asarray(rng.randn(B, H, D), jnp.float32)
    k_pages = jnp.asarray(rng.randn(P, page, Hkv, D), jnp.float32)
    v_pages = jnp.asarray(rng.randn(P, page, Hkv, D), jnp.float32)
    pos_pages = jnp.asarray(
        rng.randint(0, 10, size=(P, page)), jnp.int32)
    pos_pages = pos_pages.at[0].set(2 ** 30)          # null page masked
    table = jnp.asarray([[1, 2, 0], [3, 4, 5]], jnp.int32)
    q_pos = jnp.asarray([7, 9], jnp.int32)
    out = paged_decode_attention(q, k_pages, v_pages, pos_pages, table,
                                 q_pos, block_l=4, interpret=True)
    L = n_max * page
    ref = decode_attention(
        q, k_pages[table].reshape(B, L, Hkv, D),
        v_pages[table].reshape(B, L, Hkv, D),
        pos_pages[table].reshape(B, L), q_pos, block_l=4, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6)
